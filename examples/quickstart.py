"""Quickstart: DiveBatch end to end in ~1 minute on CPU.

Trains the paper's synthetic logistic-regression task with the adaptive
batch controller, shows the batch-size/diversity trajectory, checkpoints,
kills the trainer, and resumes — the five core APIs in one file.

  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax

from repro.ckpt import CheckpointManager
from repro.core import AdaptiveBatchController, make_policy, step_decay
from repro.data import sigmoid_synthetic
from repro.models import small
from repro.optim import sgd
from repro.train.loop import ModelFns, Trainer


def main():
    # 1. data + model (the paper's eq. 3 synthetic task)
    train, val, _ = sigmoid_synthetic(n=8000, d=128, seed=0)
    params = small.logreg_init(jax.random.key(0), 128)
    fns = ModelFns(
        batch_loss=small.logreg_batch_loss,
        example_loss=small.logreg_loss,  # per-sample: enables the exact tier
        metrics=lambda p, b: {"acc": small.logreg_accuracy(p, b)},
    )

    # 2. DiveBatch controller: m <- min(m_max, delta * n * Delta_hat)
    controller = AdaptiveBatchController(
        make_policy("divebatch", m0=64, m_max=2048, delta=1.0,
                    dataset_size=len(train), granule=16),
        base_lr=2.0,
        lr_rule="none",                       # paper's main setting
        lr_schedule=step_decay(0.75, 20),     # paper's background decay
    )

    # 3. train with checkpointing
    ckpt_dir = tempfile.mkdtemp(prefix="divebatch_quickstart_")
    trainer = Trainer(fns, params, sgd(momentum=0.9), controller, train, val,
                      estimator="exact", ckpt=CheckpointManager(ckpt_dir),
                      ckpt_every=2)
    print("== training 6 epochs ==")
    trainer.run(6)

    # 4. simulate a crash: rebuild everything, resume from the checkpoint
    print("== 'crash' -> resume ==")
    controller2 = AdaptiveBatchController(
        make_policy("divebatch", m0=64, m_max=2048, delta=1.0,
                    dataset_size=len(train), granule=16),
        base_lr=2.0, lr_schedule=step_decay(0.75, 20),
    )
    trainer2 = Trainer(fns, small.logreg_init(jax.random.key(0), 128),
                       sgd(momentum=0.9), controller2, train, val,
                       estimator="exact", ckpt=CheckpointManager(ckpt_dir))
    trainer2.resume()
    trainer2.run(2)

    print("\nbatch-size trajectory:",
          [h.batch_size for h in trainer2.history])
    print("diversity trajectory:  ",
          [f"{h.diversity:.3f}" if h.diversity is not None else "-"
           for h in trainer2.history])
    print("final val acc:", trainer2.history[-1].val_metrics["acc"])
    stats = trainer2.engine.stats  # the bucketed compile cache at work
    print(f"engine: {stats.compiles} step compiles for buckets {stats.buckets}, "
          f"{stats.bucket_hits} cache hits, donated={stats.donate}")


if __name__ == "__main__":
    main()
