"""Quickstart: DiveBatch end to end in ~1 minute on CPU.

Trains the paper's synthetic logistic-regression task with a ``repro.adapt``
program (the composable, signal-driven adaptation API), shows the
batch-size/diversity trajectory, checkpoints, kills the trainer, and
resumes — the five core APIs in one file — with ``repro.obs`` telemetry on
the whole way: one span trace (Perfetto-loadable ``trace.json``) and one
typed JSONL run log span both trainers, and ``launch/monitor.py`` prints
the reconstructed schedule at the end.

The adaptation program replaces the old ``AdaptiveBatchController``, which
survives only as a deprecated shim over exactly this object: policies
observe ``Signals`` at ``Clock`` boundaries (epoch ends, every-k-steps
ticks, injected events), and a typed ``LrCoupling`` replaces the string
``lr_rule``/``lr_schedule`` pair.

  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax

from repro.adapt import AdaptationProgram, DiveBatchPolicy, LrCoupling
from repro.ckpt import CheckpointManager
from repro.core import step_decay
from repro.data import sigmoid_synthetic
from repro.launch import monitor
from repro.models import small
from repro.obs import RunLog, Tracer
from repro.optim import sgd
from repro.train.loop import ModelFns, Trainer


def make_program():
    """DiveBatch: m <- min(m_max, delta * n * Delta_hat), epoch cadence,
    with the paper's background step decay on the learning rate."""
    return AdaptationProgram(
        DiveBatchPolicy(m0=64, m_max=2048, delta=1.0, dataset_size=8000,
                        granule=16),
        base_lr=2.0,
        coupling=LrCoupling(rule="none",              # paper's main setting
                            decay=step_decay(0.75, 20)),  # background decay
        estimator="exact",
    )


def main():
    # 1. data + model (the paper's eq. 3 synthetic task)
    train, val, _ = sigmoid_synthetic(n=8000, d=128, seed=0)
    params = small.logreg_init(jax.random.key(0), 128)
    fns = ModelFns(
        batch_loss=small.logreg_batch_loss,
        example_loss=small.logreg_loss,  # per-sample: enables the exact tier
        metrics=lambda p, b: {"acc": small.logreg_accuracy(p, b)},
    )

    # 2. the adaptation program (see make_program above)
    program = make_program()

    # 3. telemetry: one tracer + one run log span the whole session
    #    (equivalently: launch/train.py --trace DIR --runlog)
    run_dir = tempfile.mkdtemp(prefix="divebatch_quickstart_run_")
    tracer = Tracer()
    runlog = RunLog(run_dir, meta={"cmd": "quickstart"})

    # 4. train with checkpointing
    ckpt_dir = tempfile.mkdtemp(prefix="divebatch_quickstart_")
    trainer = Trainer(fns, params, sgd(momentum=0.9), program, train, val,
                      estimator="exact", ckpt=CheckpointManager(ckpt_dir),
                      ckpt_every=2, tracer=tracer, runlog=runlog)
    print("== training 6 epochs ==")
    trainer.run(6)

    # 5. simulate a crash: rebuild everything, resume from the checkpoint
    #    (checkpoints carry the program state — schema v2; pre-redesign v1
    #    controller checkpoints restore through the same path).  The same
    #    obs sinks carry over, so one trace/log covers both trainers.
    print("== 'crash' -> resume ==")
    trainer2 = Trainer(fns, small.logreg_init(jax.random.key(0), 128),
                       sgd(momentum=0.9), make_program(), train, val,
                       estimator="exact", ckpt=CheckpointManager(ckpt_dir),
                       tracer=tracer, runlog=runlog)
    trainer2.resume()
    trainer2.run(2)

    print("\nbatch-size trajectory:",
          [h.batch_size for h in trainer2.history])
    print("diversity trajectory:  ",
          [f"{h.diversity:.3f}" if h.diversity is not None else "-"
           for h in trainer2.history])
    print("final val acc:", trainer2.history[-1].val_metrics["acc"])
    stats = trainer2.engine.stats  # the bucketed compile cache at work
    print(f"engine: {stats.compiles} step compiles for buckets {stats.buckets}, "
          f"{stats.bucket_hits} cache hits, donated={stats.donate}")

    # 6. what the run log + trace captured (launch/monitor.py is the reader:
    #    python -m repro.launch.monitor <run_dir> [--follow] [--trace OUT])
    print("\n== telemetry (repro.obs) ==")
    print("trace:", tracer.save(run_dir), f"({len(tracer.events)} events —"
          " load it in Perfetto / chrome://tracing)")
    runlog.close()
    print(monitor.summary(monitor.load(run_dir)))


if __name__ == "__main__":
    main()
