"""Paper §5.1 reproduction: synthetic convex + nonconvex experiments.

Runs SGD / DiveBatch / Oracle on the eq. 3 dataset with the paper's protocol
(grid-selected small-batch baseline LR, delta search values, step decay) and
writes a JSON + printed table mirroring Figures 1-2.

  PYTHONPATH=src python examples/synthetic_convex.py [--full]
"""

import argparse
import dataclasses
import json

import jax
import numpy as np

from repro.core import AdaptiveBatchController, make_policy, step_decay
from repro.data import sigmoid_synthetic
from repro.models import small
from repro.optim import sgd
from repro.train.loop import ModelFns, Trainer


def run_method(task, method, estimator, *, n, d, epochs, delta, m0, m_max, lr, seed):
    train, val, _ = sigmoid_synthetic(n=n, d=d, seed=seed)
    if task == "convex":
        params = small.logreg_init(jax.random.key(seed), d)
        fns = ModelFns(small.logreg_batch_loss, small.logreg_loss,
                       lambda p, b: {"acc": small.logreg_accuracy(p, b)})
    else:
        params = small.mlp_init(jax.random.key(seed), d)
        fns = ModelFns(small.mlp_batch_loss, small.mlp_loss,
                       lambda p, b: {"acc": small.mlp_accuracy(p, b)})
    ctrl = AdaptiveBatchController(
        make_policy(method if method != "oracle" else "divebatch",
                    m0=m0, m_max=m_max, delta=delta, dataset_size=len(train),
                    granule=16),
        base_lr=lr, lr_schedule=step_decay(0.75, 20),
    )
    t = Trainer(fns, params, sgd(momentum=0.9), ctrl, train, val,
                estimator=estimator, seed=seed)
    return t.run(epochs, verbose=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale n=20000, d=512, 100 epochs (slow on CPU)")
    ap.add_argument("--out", default="runs/synthetic_convex.json")
    args = ap.parse_args()

    scale = dict(n=20_000, d=512, epochs=100) if args.full else dict(n=4000, d=128, epochs=15)
    results = {}
    for task, delta, lr in [("convex", 1.0, 2.0), ("nonconvex", 0.1, 0.5)]:
        for method, est in [("sgd", "none"), ("divebatch", "exact"), ("oracle", "oracle")]:
            hist = run_method(task, method, est, delta=delta, m0=64,
                              m_max=1024 if not args.full else 4096,
                              lr=lr, seed=0, **scale)
            key = f"{task}/{method}"
            results[key] = [dataclasses.asdict(h) for h in hist]
            accs = [h.val_metrics["acc"] for h in hist]
            print(f"{key:24s} final_acc={accs[-1]:.4f} "
                  f"end_batch={hist[-1].batch_size:5d} "
                  f"acc_curve={[round(a, 3) for a in accs[:: max(len(accs)//6, 1)]]}")

    import os

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
