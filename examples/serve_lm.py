"""Serving example: batched prefill+decode through the DecodeEngine.

  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tf
from repro.serve import DecodeEngine, Request


def main():
    cfg = get_config("yi-6b", reduced=True).replace(num_layers=4, d_model=128,
                                                    num_heads=4, num_kv_heads=2)
    params = tf.init_params(cfg, jax.random.key(0))
    engine = DecodeEngine(cfg, params, max_batch=4, max_seq=256)

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(1, cfg.vocab_size, size=rng.integers(4, 24)).astype(np.int32),
                max_new_tokens=16)
        for _ in range(10)
    ]
    t0 = time.time()
    results = engine.generate(requests)
    dt = time.time() - t0
    total_tokens = sum(r.steps for r in results)
    for i, r in enumerate(results[:4]):
        print(f"req {i}: {r.steps} tokens -> {r.tokens.tolist()}")
    print(f"\n{len(requests)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s, batch={engine.max_batch})")


if __name__ == "__main__":
    main()
