"""Serving example: elastic continuous batching through the ServeEngine.

Requests stream in, the Scheduler admits them into pow2 slot buckets,
retires each one at its own EOS/max-token step, and (with more than one
device) a MeshLadder widens/narrows the mesh with the live batch.

``--policy`` swaps the admission policy (serve/policy.py): ``fifo`` is the
default engine behaviour, ``priority``/``fair`` read the tenant/priority
metadata this example stamps onto every other request.

  PYTHONPATH=src python examples/serve_lm.py
  PYTHONPATH=src python examples/serve_lm.py --policy fair
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.elastic import MeshLadder
from repro.models import transformer as tf
from repro.serve import POLICIES, Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="fifo", choices=list(POLICIES))
    args = ap.parse_args()

    cfg = get_config("yi-6b", reduced=True).replace(num_layers=4, d_model=128,
                                                    num_heads=4, num_kv_heads=2)
    params = tf.init_params(cfg, jax.random.key(0))
    ladder = MeshLadder(granule=1) if len(jax.devices()) > 1 else None
    engine = ServeEngine(cfg, params, max_slots=4, max_seq=256, elastic=ladder,
                         policy=args.policy)

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(1, cfg.vocab_size, size=rng.integers(4, 24)).astype(np.int32),
                max_new_tokens=16,
                tenant=f"t{i % 2}", priority=i % 2)
        for i in range(10)
    ]
    t0 = time.time()
    results = engine.generate(requests)
    dt = time.time() - t0
    total_tokens = sum(r.steps for r in results)
    for i, r in enumerate(results[:4]):
        print(f"req {i}: {r.steps} tokens -> {r.tokens.tolist()}")
    stats = engine.stats
    print(f"\n{len(requests)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s end-to-end, "
          f"{stats.tokens_per_sec:.1f} tok/s windowed)")
    print(f"slots: {stats.prefills} admissions over buckets {stats.buckets}, "
          f"{stats.slot_steps} decoded lanes for "
          f"{total_tokens - stats.prefills} decode tokens "
          f"(policy={args.policy})")
    if ladder is not None:
        print(f"elastic: dp={ladder.widths} reshards={stats.reshards}")


if __name__ == "__main__":
    main()
