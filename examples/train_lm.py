"""End-to-end LM training driver: the production train step (microbatch
accumulation + moment-estimator adaptation) on a transformer LM.

Adaptation runs through ``repro.adapt`` at STEP granularity — the streaming
regime the old epoch-only controller could not express: a tick-fired policy
(DiveBatch over the accumulation window, or ``--method gns`` for the
gradient-noise-scale family) observes the in-jit accumulators every
``--epoch-steps`` optimizer steps via ``read_signals`` (one stacked scalar
transfer) and resizes onto the ``num_micro`` bucket lattice.

Default is a CPU-friendly ~20M-param model for a quick demo; --model-100m
selects the ~100M configuration (same code path; a few hundred steps of it
is the intended single-host run, several minutes/step on CPU — on TPU this
is the config the dry-run lowers for 256 chips).

  PYTHONPATH=src python examples/train_lm.py --steps 30
  PYTHONPATH=src python examples/train_lm.py --method gns --steps 30
  PYTHONPATH=src python examples/train_lm.py --model-100m --steps 300
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.adapt import (
    AdaptationProgram,
    Clock,
    DiveBatchPolicy,
    GradNoisePolicy,
    read_signals,
)
from repro.configs.base import ModelConfig
from repro.data import TokenStream
from repro.models import transformer as tf
from repro.optim import sgd
from repro.train import StepEngine, init_state
from repro.ckpt import CheckpointManager


def model_config(big: bool) -> ModelConfig:
    if big:  # ~100M params
        return ModelConfig(
            name="lm-100m", family="dense", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32_000,
            param_dtype="float32", compute_dtype="float32", xent_chunk=128,
            remat=False,
        )
    return ModelConfig(  # ~20M params
        name="lm-20m", family="dense", num_layers=6, d_model=384,
        num_heads=6, num_kv_heads=2, d_ff=1024, vocab_size=8_000,
        param_dtype="float32", compute_dtype="float32", xent_chunk=128,
        remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--method", default="divebatch", choices=["divebatch", "gns"])
    ap.add_argument("--model-100m", action="store_true")
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--micro-batch", type=int, default=4)
    ap.add_argument("--m0", type=int, default=8, help="initial global batch (sequences)")
    ap.add_argument("--m-max", type=int, default=64)
    ap.add_argument("--delta", type=float, default=0.5,
                    help="DiveBatch scale: m = delta * n_epoch * Delta_hat")
    ap.add_argument("--epoch-steps", type=int, default=10,
                    help="steps per 'epoch' (diversity/batch-size update period)")
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_config(args.model_100m)
    params = tf.init_params(cfg, jax.random.key(0))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params")

    opt = sgd(momentum=0.9)
    state = init_state(params, opt)
    stream = TokenStream(cfg.vocab_size, seed=0)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    # one compiled, donated step per num_micro bucket — the same StepEngine
    # the Trainer and the multi-pod dry-run drive
    engine = StepEngine.for_lm(cfg, opt, micro_batch=args.micro_batch)

    # A tick-fired repro.adapt program over the step stream: DiveBatch
    # scaled by the accumulation window (dataset_size=None -> the samples
    # actually seen since the last reset), or the gradient-noise family.
    if args.method == "gns":
        policy = GradNoisePolicy(args.m0, args.m_max, granule=args.micro_batch,
                                 alpha=1.0, on_tick=True)
    else:
        policy = DiveBatchPolicy(args.m0, args.m_max, delta=args.delta,
                                 dataset_size=None, granule=args.micro_batch,
                                 on_tick=True)
    program = AdaptationProgram(policy, base_lr=args.lr, estimator="moment",
                                tick_every=args.epoch_steps)

    m = program.batch_size
    for step in range(args.steps):
        batch_np = stream.batch(step, m, args.seq_len)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        t0 = time.time()
        state, metrics = engine.step(state, batch, program.lr)
        dt = time.time() - t0
        if (step + 1) % program.tick_every == 0:
            # one stacked scalar transfer: diversity + GNS + window samples;
            # the reset starts the next accumulation window
            sig, state = read_signals(state, "moment", reset=True,
                                      batch_size=m,
                                      loss=float(metrics["loss"]))
            program.observe(sig, Clock(epoch=step // program.tick_every,
                                       step=step + 1, boundary="tick"))
            print(f"step {step+1:4d} loss={float(metrics['loss']):.4f} "
                  f"dt={dt:.2f}s  Delta={sig.diversity:.4f} gns={sig.gns:.1f} "
                  f"-> batch {m} -> {program.batch_size}")
            m = program.batch_size
            if mgr:
                mgr.save(step + 1, {"state": state},
                         extra={"program": program.state_dict()})
        elif step % 5 == 0:
            print(f"step {step+1:4d} loss={float(metrics['loss']):.4f} dt={dt:.2f}s batch={m}")

    stats = engine.stats
    print(f"done. compiled buckets: {sorted(stats.buckets)} (num_micro values), "
          f"{stats.compiles} compiles / {stats.steps} steps, donated={stats.donate}")


if __name__ == "__main__":
    main()
