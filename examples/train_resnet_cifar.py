"""Paper §5.2 reproduction driver: ResNet(GN) image classification with
SGD / AdaBatch / DiveBatch, CIFAR-shaped data.

By default uses the procedural CIFAR-shaped dataset (no offline CIFAR here);
pass --cifar-npz PATH to train on a real CIFAR-10 export with identical code
({"x": (N,32,32,3) float32, "y": (N,) int} arrays).

  PYTHONPATH=src python examples/train_resnet_cifar.py --epochs 8
"""

import argparse
import json

import jax
import numpy as np

from repro.core import AdaptiveBatchController, make_policy
from repro.data import ArrayDataset, imagelike_classification
from repro.models import resnet
from repro.optim import sgd
from repro.train.loop import ModelFns, Trainer


def load_data(args):
    if args.cifar_npz:
        z = np.load(args.cifar_npz)
        x, y = z["x"].astype(np.float32), z["y"].astype(np.int32)
        split = int(len(x) * 0.9)
        return (ArrayDataset({"x": x[:split], "y": y[:split]}),
                ArrayDataset({"x": x[split:], "y": y[split:]}), 10, 32)
    train, val = imagelike_classification(
        n=args.n, hw=args.hw, num_classes=args.classes, noise=0.8,
        template_rank=3, seed=0)
    return train, val, args.classes, args.hw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--methods", default="sgd,adabatch,divebatch")
    ap.add_argument("--depth", type=int, default=8, help="resnet depth (6n+2); paper uses 20")
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--hw", type=int, default=16)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--m0", type=int, default=64)
    ap.add_argument("--m-max", type=int, default=512)
    ap.add_argument("--delta", type=float, default=0.5)
    ap.add_argument("--cifar-npz", default=None)
    ap.add_argument("--out", default="runs/resnet_compare.json")
    args = ap.parse_args()

    train, val, classes, hw = load_data(args)
    out = {}
    for method in args.methods.split(","):
        params = resnet.resnet_init(jax.random.key(0), depth=args.depth,
                                    width=8, num_classes=classes)
        fns = ModelFns(resnet.resnet_batch_loss, resnet.resnet_loss,
                       lambda p, b: {"acc": resnet.resnet_accuracy(p, b)})
        m0 = args.m_max if method == "sgd_large" else args.m0
        ctrl = AdaptiveBatchController(
            make_policy(method if method != "sgd_large" else "sgd",
                        m0=m0, m_max=args.m_max, delta=args.delta,
                        dataset_size=len(train), granule=16, resize_freq=3),
            base_lr=0.1,
        )
        t = Trainer(fns, params, sgd(momentum=0.9, weight_decay=5e-4), ctrl,
                    train, val, estimator="exact" if method == "divebatch" else "none",
                    psn_microbatch=64)
        hist = t.run(args.epochs)
        out[method] = [
            {"epoch": h.epoch, "acc": h.val_metrics["acc"], "loss": h.val_loss,
             "batch": h.batch_size, "wall_s": h.wall_s} for h in hist
        ]
        print(f"== {method}: final acc {hist[-1].val_metrics['acc']:.4f}, "
              f"end batch {hist[-1].batch_size}")

    import os

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
