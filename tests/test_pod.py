"""repro.pod: virtual pod topology, the health registry, PodLadder's
cross-pod rungs (compressed gradients + error-feedback threading), the
diversity-bound signal/combinator, and degrade-don't-restart supervision."""

import contextlib
import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adapt import (
    AdaptationProgram,
    BoundedRung,
    Clock,
    Decision,
    FixedPolicy,
    PolicyBase,
    Signals,
    read_signals,
)
from repro.data import sigmoid_synthetic
from repro.dist.plan import ShardingPlan, use_plan
from repro.models import small
from repro.obs.runlog import RunLog, read_runlog
from repro.optim import sgd
from repro.pod import PodHealth, PodLadder, PodTopology
from repro.train import StepEngine, init_state
from repro.train.loop import ModelFns, Trainer
from _hypothesis_compat import given, settings, strategies as st

SEED, N, D = 3, 2048, 32


def _fns():
    return ModelFns(
        batch_loss=small.mlp_batch_loss,
        example_loss=small.mlp_loss,
        metrics=lambda p, b: {"acc": small.mlp_accuracy(p, b)},
    )


def _program(m0=128, m_max=1024):
    return AdaptationProgram(FixedPolicy(m0, m_max, granule=16), base_lr=0.5)


def _trainer(elastic=None, estimator="exact", **kw):
    train, val, _ = sigmoid_synthetic(n=N, d=D, seed=SEED)
    return Trainer(_fns(), small.mlp_init(jax.random.key(SEED), D),
                   sgd(momentum=0.9), _program(), train, val,
                   estimator=estimator, seed=SEED, elastic=elastic, **kw)


# ---------------------------------------------------------------------------
# PodTopology / PodHealth
# ---------------------------------------------------------------------------


class TestPodTopology:
    def test_partitions_contiguous_prefix_pods(self):
        devs = jax.devices()
        topo = PodTopology(2)
        assert len(topo) == topo.num_pods == 2
        assert topo.devices_per_pod == 4
        assert topo.pods[0] == devs[:4] and topo.pods[1] == devs[4:]
        assert topo.pod_of(devs[0]) == 0 and topo.pod_of(devs[5]) == 1

    def test_uneven_partition_raises(self):
        with pytest.raises(ValueError, match="partition"):
            PodTopology(3)  # 8 devices / 3 pods

    def test_bad_pod_count_raises(self):
        with pytest.raises(ValueError, match=">= 1"):
            PodTopology(0)
        with pytest.raises(ValueError, match="partition"):
            PodTopology(16)  # more pods than devices

    def test_foreign_device_raises(self):
        topo = PodTopology(2, jax.devices()[:4])
        with pytest.raises(ValueError, match="not in this topology"):
            topo.pod_of(jax.devices()[7])


class TestPodHealth:
    def test_prefix_semantics(self):
        h = PodHealth(4)
        assert h.healthy_prefix == 4 and h.lost == []
        assert all(h.prefix_healthy(k) for k in (1, 2, 3, 4))
        assert not h.prefix_healthy(0) and not h.prefix_healthy(5)
        h.mark_lost(2)
        assert h.prefix_healthy(2) and not h.prefix_healthy(3)
        assert h.healthy_prefix == 2 and h.lost == [2]
        h.mark_healthy(2)
        assert h.prefix_healthy(4)
        h.mark_lost(0)
        assert h.healthy_prefix == 0 and not h.prefix_healthy(1)
        assert repr(h) == "PodHealth(LHHH)"

    def test_out_of_range_raises(self):
        h = PodHealth(2)
        with pytest.raises(ValueError, match="out of range"):
            h.mark_lost(2)
        with pytest.raises(ValueError, match=">= 1"):
            PodHealth(0)


# ---------------------------------------------------------------------------
# PodLadder structure and health-filtered selection
# ---------------------------------------------------------------------------


class TestPodLadder:
    def test_rung_structure_two_pods(self):
        ladder = PodLadder(pods=2, granule=16)
        assert ladder.widths == [1, 2, 4, 8]
        assert [r.pods for r in ladder.rungs] == [1, 1, 1, 2]
        cross = ladder.rungs[3]
        assert cross.plan.dp == ("pod", "data")
        assert cross.plan.fsdp == ()  # params replicated on cross-pod rungs
        assert dict(cross.plan.mesh.shape) == {"pod": 2, "data": 4}

    def test_rungs_are_device_prefixes(self):
        ladder = PodLadder(pods=2, granule=1)
        ids = [[d.id for d in r.plan.mesh.devices.flat] for r in ladder.rungs]
        for narrow, wide in zip(ids, ids[1:]):
            assert wide[: len(narrow)] == narrow

    def test_four_pods_pow2_cross_rungs(self):
        ladder = PodLadder(pods=4, granule=1)
        # base ladder over pod 0's 2 devices, then 2-pod and 4-pod rungs
        assert ladder.widths == [1, 2, 4, 8]
        assert [r.pods for r in ladder.rungs] == [1, 1, 2, 4]

    def test_single_pod_raises(self):
        with pytest.raises(ValueError, match="pods >= 2"):
            PodLadder(pods=1)

    def test_rung_for_batch_is_health_filtered(self):
        ladder = PodLadder(pods=2, granule=16)
        assert ladder.rung_for_batch(128).index == 3
        assert ladder.rung_for_batch(64).index == 2
        ladder.health.mark_lost(1)
        assert ladder.rung_for_batch(128).index == 2  # cross rung filtered out
        ladder.health.mark_healthy(1)
        assert ladder.rung_for_batch(128).index == 3
        ladder.health.mark_lost(0)
        with pytest.raises(RuntimeError, match="pod 0"):
            ladder.rung_for_batch(128)

    def test_adapt_state_threads_error_feedback(self):
        ladder = PodLadder(pods=2, granule=16)
        state = init_state(small.logreg_init(jax.random.key(0), D), sgd())
        assert state.err_state is None
        cross, within = ladder.rungs[3], ladder.rungs[2]

        # cross-pod: freshly-zeroed stacked (pods, *shape) residuals
        s1 = ladder.adapt_state(state, None, cross)
        shapes = [x.shape for x in jax.tree.leaves(s1.err_state)]
        assert all(s[0] == 2 for s in shapes)
        assert [s[1:] for s in shapes] == [
            jnp.shape(p) for p in jax.tree.leaves(state.params)]
        # same pod layout: residuals survive untouched
        assert ladder.adapt_state(s1, cross, cross) is s1
        # within-pod: residuals dropped
        assert ladder.adapt_state(s1, cross, within).err_state is None
        # changed pod layout: re-zeroed, not carried
        dirty = s1._replace(err_state=jax.tree.map(
            lambda e: e + 1.0, s1.err_state))
        back = ladder.adapt_state(dirty, within, cross)
        assert all(float(jnp.abs(e).max()) == 0.0
                   for e in jax.tree.leaves(back.err_state))

    def test_uncompressed_ladder_carries_no_residuals(self):
        ladder = PodLadder(pods=2, granule=16, compress=False)
        state = init_state(small.logreg_init(jax.random.key(0), D), sgd())
        assert ladder.adapt_state(state, None, ladder.rungs[3]).err_state is None


# ---------------------------------------------------------------------------
# Signals.diversity_bound + BoundedRung
# ---------------------------------------------------------------------------


class _StubPolicy(PolicyBase):
    """Emits one fixed Decision at every boundary."""

    def __init__(self, decision):
        super().__init__()
        self.decision = decision
        self._m = decision.batch_size or 16

    def _decide(self, signals, clock):
        return self.decision

    @property
    def batch_size(self):
        return self._m

    def set_batch_size(self, m):
        self._m = int(m)


def test_diversity_bound_rides_the_stacked_read():
    """The bound is samples * diversity off the SAME transfer as gns —
    populated whenever the window is, zero after the boundary reset."""
    train, _, _ = sigmoid_synthetic(n=512, d=16, seed=0)
    fns = ModelFns(batch_loss=small.logreg_batch_loss,
                   example_loss=small.logreg_loss)
    eng = StepEngine.for_model_fns(fns, sgd(), estimator="moment",
                                   donate=False)
    state = init_state(small.logreg_init(jax.random.key(0), 16), sgd())
    for i in range(3):
        batch = {k: jnp.asarray(v) for k, v in
                 train.get(np.arange(i * 64, (i + 1) * 64)).items()}
        state, _ = eng.step(state, batch, 0.1)
    sig, state = read_signals(state, "moment", reset=False, batch_size=64)
    assert sig.samples == 192.0 and sig.diversity > 0
    assert sig.diversity_bound == pytest.approx(sig.samples * sig.diversity,
                                                rel=1e-6)
    # the epoch-boundary read resets the window; the next read is empty
    sig2, state = read_signals(state, "moment", reset=True, batch_size=64)
    assert sig2.diversity_bound == pytest.approx(sig.diversity_bound, rel=1e-6)
    sig3, _ = read_signals(state, "moment", reset=False, batch_size=64)
    assert sig3.samples == 0.0 and sig3.diversity_bound == 0.0


class TestBoundedRung:
    def _observe(self, decision, bound, **kw):
        pol = BoundedRung(_StubPolicy(decision), **kw)
        return pol.observe(Signals(diversity_bound=bound), Clock(0, 0))

    @settings(max_examples=60)
    @given(bound=st.floats(0.5, 5000.0), granule=st.integers(1, 32),
           m=st.integers(1, 4096))
    def test_never_emits_batch_above_bound(self, bound, granule, m):
        d = self._observe(Decision(batch_size=m, reason="stub"), bound,
                          granule=granule)
        if m <= bound:
            assert d.batch_size == m and d.reason == "stub"
        else:
            expect = granule
            while expect * 2 <= bound:
                expect *= 2
            assert d.batch_size == expect
            # on the lattice, under the cap unless floored at the granule
            assert d.batch_size <= max(granule, bound)
            assert d.reason == "stub+bound"

    @settings(max_examples=40)
    @given(bound=st.floats(0.5, 16.0), rung=st.integers(0, 3))
    def test_never_emits_rung_above_bound(self, bound, rung):
        ladder = PodLadder(pods=2, granule=16)
        d = self._observe(Decision(rung=rung, reason="stub"), bound,
                          ladder=ladder)
        dp = ladder.rungs[d.rung].dp
        assert dp <= bound or d.rung == 0  # narrowest rung is the floor

    def test_clamp_writes_back_into_inner(self):
        inner = _StubPolicy(Decision(batch_size=1024, reason="stub"))
        pol = BoundedRung(inner, granule=16)
        d = pol.observe(Signals(diversity_bound=100.0), Clock(0, 0))
        assert d.batch_size == 64  # largest 16 * 2^k <= 100
        assert inner.batch_size == 64  # inner state agrees with what runs

    def test_missing_or_degenerate_bound_passes_through(self):
        dec = Decision(batch_size=4096, rung=3, reason="stub")
        for bound in (None, 0.0, -1.0, float("inf"), float("nan")):
            d = self._observe(dec, bound, granule=16,
                              ladder=PodLadder(pods=2, granule=16))
            assert d is dec

    def test_margin_scales_the_cap(self):
        d = self._observe(Decision(batch_size=1024, reason="stub"), 100.0,
                          granule=16, margin=2.0)
        assert d.batch_size == 128  # largest 16 * 2^k <= 200

    def test_bad_args_raise(self):
        with pytest.raises(ValueError, match="granule"):
            BoundedRung(_StubPolicy(Decision()), granule=0)
        with pytest.raises(ValueError, match="margin"):
            BoundedRung(_StubPolicy(Decision()), margin=0.0)


# ---------------------------------------------------------------------------
# the cross-pod golden trajectory (compression round-trip)
# ---------------------------------------------------------------------------


def _run(mode, epochs=3):
    if mode == "full":
        elastic, ctx = None, use_plan(
            ShardingPlan(mesh=jax.make_mesh((8,), ("data",))))
    else:
        elastic = PodLadder(pods=2, granule=16,
                            compress=(mode == "compressed"))
        ctx = contextlib.nullcontext()
    with ctx:
        t = _trainer(elastic=elastic)
        hist = t.run(epochs, verbose=False)
    return t, hist


def test_golden_cross_pod_matches_full_mesh():
    """A FixedPolicy(128) run sits on the 2-pod rung the whole way; with
    compression off the (pod, data) pmean is arithmetically the full-mesh
    data-parallel mean, so the trajectory matches the fixed dp=8 run to
    reduction-order tolerance.  With int8+EF compression on, the same run
    stays within quantization tolerance — the round-trip loses no training
    signal — and the error-feedback residuals are live, not silently zero."""
    tf_, hf = _run("full")
    tn, hn = _run("uncompressed")
    tc, hc = _run("compressed")
    assert tn.rung.pods == 2 and tc.rung.pods == 2

    for a, b in zip(jax.tree.leaves(tn.state.params),
                    jax.tree.leaves(tf_.state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose([h.val_loss for h in hn],
                               [h.val_loss for h in hf], rtol=1e-4)

    assert tn.state.err_state is None  # uncompressed rungs carry none
    for a, b in zip(jax.tree.leaves(tc.state.params),
                    jax.tree.leaves(tf_.state.params)):
        # near-zero entries make per-element rtol meaningless: bound the
        # quantization drift relative to the tensor's own scale instead
        a, b = np.asarray(a), np.asarray(b)
        assert np.max(np.abs(a - b)) <= 2e-2 * max(np.max(np.abs(b)), 1.0)
    np.testing.assert_allclose([h.val_loss for h in hc],
                               [h.val_loss for h in hf], rtol=1e-2)

    # EF is live: residuals exist, are per-pod, and are nonzero after steps
    # (they also survived 3 epoch_end boundaries — not silently dropped)
    err = tc.state.err_state
    assert err is not None
    leaves = jax.tree.leaves(err)
    assert all(e.shape[0] == 2 for e in leaves)
    assert sum(float(jnp.abs(e).sum()) for e in leaves) > 0


def test_demote_drops_residuals_and_training_continues(tmp_path):
    """Degrade-don't-restart at the Trainer level: losing pod 1 demotes onto
    the widest all-healthy rung, the residuals (meaningless there) drop, and
    the run carries on producing finite losses — no checkpoint involved."""
    t = _trainer(elastic=PodLadder(pods=2, granule=16))
    assert t.rung.index == 3 and t.state.err_state is not None
    t.run(1, verbose=False)
    assert t.state.err_state is not None  # survived the epoch boundary
    t.elastic.health.mark_lost(1)
    src, dst = t.demote(note="pod 1 lost")
    assert (src, dst) == (3, 2)
    assert t.rung.pods == 1 and t.state.err_state is None
    before = t.history[-1].val_loss
    t.run(2, verbose=False)
    assert np.isfinite(t.history[-1].val_loss)
    assert t.history[-1].val_loss <= before  # still learning, post-demotion


# ---------------------------------------------------------------------------
# supervisor: a host loss degrades the ladder, never the checkpoint path
# ---------------------------------------------------------------------------


def test_supervised_pod_loss_demotes_without_restart(tmp_path):
    from repro.launch.supervisor import run_supervised

    run_dir = str(tmp_path / "run")
    log = RunLog(run_dir, meta={"cmd": "test-pod"})
    train, val, _ = sigmoid_synthetic(n=N, d=D, seed=SEED)

    def make_trainer(mgr):
        return Trainer(_fns(), small.mlp_init(jax.random.key(SEED), D),
                       sgd(momentum=0.9), _program(), train, val,
                       estimator="exact", seed=SEED, ckpt=mgr,
                       elastic=PodLadder(pods=2, granule=16))

    hist = run_supervised(make_trainer, 4, [], str(tmp_path / "ckpt"),
                          runlog=log, lose_pod=[(2, 1)])
    log.close()
    assert len(hist) == 4  # every epoch completed

    ev = read_runlog(run_dir)
    # zero checkpoint restores: one initial start, no restart events after
    restarts = [e for e in ev if e["kind"] == "restart"]
    assert [e["restarts"] for e in restarts] == [0]
    (lost,) = [e for e in ev if e["kind"] == "pod_lost"]
    assert lost["pod"] == 1 and lost["epoch"] == 2 and lost["rung"] == 3
    (dem,) = [e for e in ev if e["kind"] == "demote"]
    assert dem["src"] == 3 and dem["dst"] == 2 and dem["src"] > dem["dst"]
    assert dem["pods"] == 1 and dem["dp"] == 4
    # the run RESUMED on the shrunk rung: epochs after the loss exist and
    # the monitor's schedule reconstruction lands on the demoted rung
    assert sum(e["kind"] == "epoch" for e in ev) == 4
    from repro.launch import monitor

    sched = monitor.schedule(ev)
    assert sched[-1]["rung"] == 2
    assert "demote" in monitor.lifecycle(ev)


def test_supervised_lose_pod_without_pod_ladder_raises(tmp_path):
    from repro.launch.supervisor import run_supervised

    train, val, _ = sigmoid_synthetic(n=256, d=16, seed=0)
    fns = ModelFns(batch_loss=small.logreg_batch_loss)

    def make_trainer(mgr):
        return Trainer(fns, small.logreg_init(jax.random.key(0), 16), sgd(),
                       _program(16, 256), train, val, estimator="none",
                       ckpt=mgr)

    with pytest.raises(ValueError, match="PodLadder"):
        run_supervised(make_trainer, 2, [], str(tmp_path / "ckpt"),
                       lose_pod=[1])


@pytest.mark.slow
def test_supervisor_cli_multi_pod_demotion(tmp_path):
    """End to end in a fresh process: the CLI brings up a 2-pod ladder, a
    --lose-pod injection mid-run demotes (never restarts), and the run log
    written by the child proves it."""
    runlog = str(tmp_path / "runlog.jsonl")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.supervisor",
         "--epochs", "4", "--pods", "2", "--lose-pod", "2",
         "--ckpt-dir", str(tmp_path / "ckpt"), "--runlog", runlog],
        capture_output=True, text=True,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd="/root/repo", timeout=300,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "completed 4 epochs" in res.stdout
    ev = read_runlog(runlog)
    assert [e["restarts"] for e in ev if e["kind"] == "restart"] == [0]
    (dem,) = [e for e in ev if e["kind"] == "demote"]
    assert dem["src"] > dem["dst"]
    assert sum(e["kind"] == "epoch" for e in ev) == 4
