"""CI smoke for the engine benchmark: the `-m "not slow"`-safe variant runs
in seconds and must emit a well-formed BENCH_engine.json."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import bench_engine  # noqa: E402


def test_bench_engine_smoke(tmp_path):
    out = tmp_path / "BENCH_engine.json"
    rows = bench_engine.run(smoke=True, out_path=str(out))
    record = json.loads(out.read_text())
    assert record["workload"]["smoke"] is True
    for kind in ("fixed", "adaptive", "traced"):
        r = record[kind]
        assert r["steps_per_sec"] > 0
        assert r["compiles"] <= r["compile_bound"]
        assert r["donated"] is True
    # fixed batch compiles exactly one bucket
    assert record["fixed"]["compiles"] == 1
    # the obs A/B row rides along (tests/test_obs.py pins the disabled-path
    # cost deterministically; this is the enabled-tracer wall ratio)
    assert record["obs_overhead"] > 0
    names = [name for name, _, _ in rows]
    assert "engine_fixed_batch" in names and "engine_adaptive_batch" in names
    assert "engine_obs_overhead" in names
