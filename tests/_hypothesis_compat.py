"""Property-test shim: the real ``hypothesis`` when installed, else a minimal
deterministic stand-in (this container has no hypothesis and installing
dependencies is off-limits).

The stand-in covers exactly the API surface the test suite uses —
``@given(**strategies)``, ``@settings(max_examples=, deadline=)``, and the
``integers`` / ``floats`` / ``sampled_from`` strategies.  Each strategy
yields its boundary values first (min/max, every sampled element) and then
seeded-random draws, so every run explores the same examples.
"""

try:
    from hypothesis import given, settings, strategies  # noqa: F401
except ModuleNotFoundError:
    import functools
    import inspect
    import random

    class _Strategy:
        """boundary: deterministic first draws; draw: rng fallback."""

        def __init__(self, boundary, draw):
            self.boundary = list(boundary)
            self.draw = draw

        def example(self, i, rng):
            if i < len(self.boundary):
                return self.boundary[i]
            return self.draw(rng)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                [min_value, max_value],
                lambda rng: rng.randint(min_value, max_value),
            )

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                [min_value, max_value],
                lambda rng: rng.uniform(min_value, max_value),
            )

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                elements, lambda rng: elements[rng.randrange(len(elements))]
            )

    strategies = _StrategiesModule()

    def settings(max_examples=20, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            max_examples = getattr(fn, "_max_examples", 20)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(0x5EED)
                for i in range(max_examples):
                    drawn = {k: s.example(i, rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            # pytest reads the signature to decide what is a fixture: hide
            # the strategy-filled params (and the __wrapped__ pass-through).
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p for name, p in sig.parameters.items() if name not in strats
                ]
            )
            del wrapper.__wrapped__
            return wrapper

        return deco
