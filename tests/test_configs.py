"""Locks every assigned architecture to the assignment table's exact numbers
and validates derived parameter counts against the public model sizes."""

import numpy as np
import pytest

from repro.configs import ARCH_NAMES, SHAPES, cell_supported, get_config, input_specs
from repro.models import transformer as tf
from repro.utils import pytree as ptu

# (layers, d_model, heads, kv, d_ff, vocab) straight from the assignment
ASSIGNED = {
    "internvl2-1b": (24, 896, 14, 2, 4864, 151_655),
    "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65_024),
    "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163_840),
    "dbrx-132b": (40, 6144, 48, 8, 10_752, 100_352),
    "yi-6b": (32, 4096, 32, 4, 11_008, 64_000),
    "gemma2-27b": (46, 4608, 32, 16, 36_864, 256_000),
    "qwen2-7b": (28, 3584, 28, 4, 18_944, 152_064),
    "llama3-405b": (126, 16_384, 128, 8, 53_248, 128_256),
    "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14_336, 65_536),
}

# public total parameter counts (billions) with tolerance
PARAM_SANITY = {
    "falcon-mamba-7b": (7.3, 0.5),
    "kimi-k2-1t-a32b": (1041, 40),
    "dbrx-132b": (132, 5),
    "yi-6b": (6.1, 0.3),
    "gemma2-27b": (27, 3),
    "qwen2-7b": (7.6, 0.5),
    "llama3-405b": (405, 10),
    "jamba-v0.1-52b": (52, 3),
    "hubert-xlarge": (1.0, 0.2),
}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_assignment_numbers(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = ASSIGNED[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


@pytest.mark.parametrize("arch", sorted(PARAM_SANITY))
def test_param_counts_match_public_sizes(arch):
    cfg = get_config(arch)
    specs = tf.param_specs(cfg)
    n = ptu.tree_count(specs) / 1e9
    want, tol = PARAM_SANITY[arch]
    assert abs(n - want) <= tol, f"{arch}: {n:.2f}B vs {want}B"


def test_arch_features():
    assert get_config("qwen2-7b").qkv_bias
    g = get_config("gemma2-27b")
    assert g.attn_softcap == 50.0 and g.final_softcap == 30.0
    assert g.pattern == ("attn_local", "attn") and g.window == 4096
    k = get_config("kimi-k2-1t-a32b")
    assert k.num_experts == 384 and k.top_k == 8
    j = get_config("jamba-v0.1-52b")
    assert j.pattern.count("attn") == 1 and len(j.pattern) == 8  # 1:7
    assert j.ffn_pattern.count("moe") == 4  # every other layer
    assert not get_config("hubert-xlarge").causal
    assert get_config("internvl2-1b").input_mode == "embeddings"
    assert get_config("falcon-mamba-7b").pattern == ("mamba",)


def test_cell_matrix_counts():
    run = skip = 0
    for a in ARCH_NAMES:
        cfg = get_config(a)
        for s in SHAPES:
            ok, why = cell_supported(a, s, cfg.causal)
            run += ok
            skip += not ok
            if not ok:
                assert why  # every skip carries a reason
    assert run == 32 and skip == 8  # 40 assigned cells


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_input_specs_all_supported_shapes(arch):
    cfg = get_config(arch)
    for sname, shape in SHAPES.items():
        ok, _ = cell_supported(arch, sname, cfg.causal)
        if not ok:
            continue
        specs = input_specs(cfg, shape)
        assert specs  # builds ShapeDtypeStructs without allocation
        if shape.kind == "decode":
            assert "cache" in specs and "tokens" in specs
        else:
            assert "batch" in specs
