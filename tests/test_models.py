"""Model-zoo parity and invariant tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import transformer as tf
from repro.models.attention import attention, flash_attention

KEY = jax.random.key(0)


def _tiny(**kw) -> ModelConfig:
    base = dict(
        name="tiny", family="dense", num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=97, param_dtype="float32",
        compute_dtype="float32", xent_chunk=16, remat=False,
    )
    base.update(kw)
    return ModelConfig(**base)


class TestFlashAttention:
    @pytest.mark.parametrize("causal,window,softcap", [
        (True, None, None), (True, 8, None), (True, None, 30.0), (False, None, None),
    ])
    def test_fwd_bwd_vs_dense(self, causal, window, softcap):
        q = jax.random.normal(jax.random.key(1), (2, 64, 4, 16))
        k = jax.random.normal(jax.random.key(2), (2, 64, 2, 16))
        v = jax.random.normal(jax.random.key(3), (2, 64, 2, 16))
        o_d = attention(q, k, v, causal=causal, window=window, softcap=softcap)
        o_f = flash_attention(q, k, v, causal, window, softcap, 16, 32)
        np.testing.assert_allclose(np.asarray(o_d), np.asarray(o_f), atol=2e-5)
        gd = jax.grad(lambda *a: attention(*a, causal=causal, window=window,
                                           softcap=softcap).sum(), argnums=(0, 1, 2))(q, k, v)
        gf = jax.grad(lambda *a: flash_attention(*a, causal, window, softcap,
                                                 16, 32).sum(), argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gd, gf):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


class TestChunkedXent:
    def test_matches_naive(self):
        x = jax.random.normal(jax.random.key(4), (2, 32, 16))
        kern = jax.random.normal(jax.random.key(5), (16, 51)) * 0.1
        tgt = jax.random.randint(jax.random.key(6), (2, 32), 0, 51)

        def naive(x, k):
            logits = (x @ k).astype(jnp.float32)
            lse = jax.scipy.special.logsumexp(logits, -1)
            t = jnp.take_along_axis(logits, tgt[..., None], -1)[..., 0]
            return jnp.mean(lse - t)

        l1 = naive(x, kern)
        l2 = tf.xent_chunked(x, kern, tgt, 8, None)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
        g1 = jax.grad(naive, argnums=(0, 1))(x, kern)
        g2 = jax.grad(lambda a, b: tf.xent_chunked(a, b, tgt, 8, None), argnums=(0, 1))(x, kern)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


class TestMoE:
    def test_apply_matches_reference(self):
        p = moe_lib.moe_init(KEY, 32, 64, 8)
        x = jax.random.normal(jax.random.key(7), (2, 16, 32))
        y1, _ = moe_lib.moe_apply(p, x, top_k=2, capacity_factor=8.0, groups=2)
        y2 = moe_lib.moe_reference(p, x, top_k=2)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)

    def test_group_invariance(self):
        p = moe_lib.moe_init(KEY, 16, 32, 4)
        x = jax.random.normal(jax.random.key(8), (4, 8, 16))
        y1, _ = moe_lib.moe_apply(p, x, top_k=2, capacity_factor=8.0, groups=1)
        y2, _ = moe_lib.moe_apply(p, x, top_k=2, capacity_factor=8.0, groups=4)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)

    def test_capacity_drops_are_bounded(self):
        """With tight capacity some tokens drop — output stays finite and
        close in norm."""
        p = moe_lib.moe_init(KEY, 16, 32, 4)
        x = jax.random.normal(jax.random.key(9), (2, 32, 16))
        y, aux = moe_lib.moe_apply(p, x, top_k=2, capacity_factor=1.0, groups=1)
        assert np.all(np.isfinite(np.asarray(y)))
        assert float(aux) > 0

    def test_grads_flow(self):
        p = moe_lib.moe_init(KEY, 16, 32, 4)
        x = jax.random.normal(jax.random.key(10), (2, 8, 16))
        g = jax.grad(lambda pp: moe_lib.moe_apply(pp, x, top_k=2, capacity_factor=4.0)[0].sum())(p)
        for leaf in jax.tree.leaves(g):
            assert np.all(np.isfinite(np.asarray(leaf)))


class TestMamba:
    def test_chunked_scan_invariant(self):
        p = ssm_lib.mamba_init(KEY, 32, d_state=8)
        x = jax.random.normal(jax.random.key(11), (2, 32, 32))
        y1 = ssm_lib.mamba_apply(p, x, d_state=8, chunk=4)
        y2 = ssm_lib.mamba_apply(p, x, d_state=8, chunk=32)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)

    def test_decode_matches_prefill(self):
        p = ssm_lib.mamba_init(KEY, 16, d_state=4, conv_dim=4)
        x = jax.random.normal(jax.random.key(12), (2, 12, 16))
        y_full = ssm_lib.mamba_apply(p, x, d_state=4, chunk=4)
        state = ssm_lib.mamba_decode_init(2, 16, 4, 2, 4)
        outs = []
        for t in range(12):
            o, state = ssm_lib.mamba_decode_step(p, state, x[:, t : t + 1], d_state=4)
            outs.append(o)
        y_step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step), atol=1e-4)


class TestTransformer:
    def test_scan_eager_parity(self):
        cfg = _tiny(scan_layers=True)
        params = tf.init_params(cfg, KEY)
        batch = {"tokens": jnp.ones((2, 16), jnp.int32),
                 "targets": jnp.ones((2, 16), jnp.int32)}
        l1, _ = tf.loss_fn(cfg, params, batch)
        l2, _ = tf.loss_fn(cfg.replace(scan_layers=False), params, batch)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)

    def test_remat_parity(self):
        cfg = _tiny(remat=True)
        params = tf.init_params(cfg, KEY)
        batch = {"tokens": jnp.ones((2, 16), jnp.int32),
                 "targets": jnp.ones((2, 16), jnp.int32)}
        l1, _ = tf.loss_fn(cfg, params, batch)
        l2, _ = tf.loss_fn(cfg.replace(remat=False), params, batch)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)

    @pytest.mark.parametrize("cfg_kw", [
        {},  # dense GQA
        {"pattern": ("attn_local", "attn"), "window": 8,
         "attn_softcap": 50.0, "final_softcap": 30.0},  # gemma2-style
        {"qkv_bias": True},  # qwen2-style
    ])
    def test_decode_matches_prefill(self, cfg_kw):
        cfg = _tiny(**cfg_kw)
        params = tf.init_params(cfg, KEY)
        toks = jax.random.randint(jax.random.key(13), (2, 16), 0, 97)
        logits_pre, _ = tf.prefill_step(cfg, params, {"tokens": toks})
        cache = tf.init_cache(cfg, 2, 16)
        for t in range(16):
            logits_dec, cache = tf.decode_step(cfg, params, cache, toks[:, t : t + 1])
        np.testing.assert_allclose(
            np.asarray(logits_dec), np.asarray(logits_pre), atol=3e-3
        )

    def test_encoder_mode(self):
        cfg = _tiny(causal=False, input_mode="embeddings", norm_type="layer",
                    ffn_glu=False, ffn_act="gelu")
        params = tf.init_params(cfg, KEY)
        batch = {"embeddings": jax.random.normal(KEY, (2, 16, 64)),
                 "targets": jnp.ones((2, 16), jnp.int32)}
        loss, _ = tf.loss_fn(cfg, params, batch)
        assert np.isfinite(float(loss))
        logits, cache = tf.prefill_step(cfg, params, batch)
        assert logits.shape == (2, 16, 97)
        assert cache == {}
