"""Property + unit tests for the gradient-diversity estimators (paper §2.2/§4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import diversity


def _accumulate_all(g: np.ndarray, micro: int, exact: bool):
    params = {"w": jnp.zeros(g.shape[1])}
    st_ = diversity.init_state(params)
    for i in range(0, len(g), micro):
        mb = g[i : i + micro]
        psn = jnp.asarray(np.sum(mb**2)) if exact else None
        st_ = diversity.accumulate(st_, {"w": jnp.asarray(mb.mean(0))}, len(mb), psn)
    return st_


def _true_delta(g: np.ndarray) -> float:
    return float(np.sum(np.sum(g**2, -1)) / np.sum(np.sum(g, 0) ** 2))


class TestExactEstimator:
    def test_matches_definition(self):
        g = np.random.default_rng(0).normal(0.3, 1.0, (64, 16)).astype(np.float32)
        st_ = _accumulate_all(g, 8, exact=True)
        assert np.isclose(float(diversity.diversity_exact(st_)), _true_delta(g), rtol=1e-5)

    @given(
        n=st.sampled_from([8, 32, 64]),
        d=st.sampled_from([3, 17]),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=20, deadline=None)
    def test_bounds(self, n, d, seed):
        """Cauchy-Schwarz: n * Delta >= 1 always; equality iff all equal."""
        g = np.random.default_rng(seed).normal(0.5, 1.0, (n, d)).astype(np.float64)
        delta = _true_delta(g)
        assert n * delta >= 1.0 - 1e-9

    @given(c=st.floats(0.1, 10.0), seed=st.integers(0, 2**16))
    @settings(max_examples=20, deadline=None)
    def test_scale_invariance(self, c, seed):
        g = np.random.default_rng(seed).normal(0.2, 1.0, (32, 8)).astype(np.float64)
        assert np.isclose(_true_delta(g), _true_delta(c * g), rtol=1e-9)

    def test_identical_gradients(self):
        """All-equal gradients -> Delta = 1/n (no diversity)."""
        g = np.tile(np.ones((1, 5), np.float32), (20, 1))
        assert np.isclose(_true_delta(g), 1 / 20)

    def test_orthogonal_gradients(self):
        """Orthogonal gradients -> Delta = 1 (max diversity, m can be ~n)."""
        g = np.eye(16, dtype=np.float32)
        assert np.isclose(_true_delta(g), 1.0)


class TestMomentEstimator:
    def test_unbiased_on_gaussian(self):
        rng = np.random.default_rng(1)
        ratios = []
        for _ in range(40):
            g = rng.normal(0.3, 1.0, (512, 12)).astype(np.float32)
            st_ = _accumulate_all(g, 32, exact=False)
            ratios.append(float(diversity.diversity_moment(st_)) / _true_delta(g))
        assert abs(np.mean(ratios) - 1.0) < 0.05, np.mean(ratios)

    def test_single_microbatch_degenerate(self):
        g = np.random.default_rng(2).normal(size=(32, 8)).astype(np.float32)
        st_ = _accumulate_all(g, 32, exact=False)  # one microbatch == epoch
        val = float(diversity.diversity_moment(st_))
        assert np.isfinite(val) and val > 0

    def test_moment_vs_exact_tracks(self):
        """Across parameter scales the two tiers must order the same way."""
        rng = np.random.default_rng(3)
        exact, moment = [], []
        for mean in (0.05, 0.3, 1.0):
            g = rng.normal(mean, 1.0, (256, 10)).astype(np.float32)
            st_e = _accumulate_all(g, 16, exact=True)
            st_m = _accumulate_all(g, 16, exact=False)
            exact.append(float(diversity.diversity_exact(st_e)))
            moment.append(float(diversity.diversity_moment(st_m)))
        assert np.argsort(exact).tolist() == np.argsort(moment).tolist()


class TestPersampleHelpers:
    def test_vmap_grads_match_manual(self):
        def loss(params, ex):
            return jnp.sum((params["w"] * ex["x"] - ex["y"]) ** 2)

        params = {"w": jnp.asarray([1.0, 2.0])}
        batch = {"x": jnp.asarray([[1.0, 0.0], [0.0, 1.0]]),
                 "y": jnp.asarray([[0.0, 0.0], [0.0, 0.0]])}
        sq = diversity.persample_sq_norms(loss, params, batch)
        # grads: sample0 d/dw = [2*1*1, 0] -> norm^2 4; sample1 [0, 2*2] -> 16
        np.testing.assert_allclose(np.asarray(sq), [4.0, 16.0], rtol=1e-6)

    def test_oracle_dataset_diversity(self):
        def loss(params, ex):
            return jnp.mean((params["w"] @ ex["x"] - ex["y"]) ** 2)

        rng = np.random.default_rng(4)
        x = rng.normal(size=(50, 6)).astype(np.float32)
        y = rng.normal(size=(50,)).astype(np.float32)
        params = {"w": jnp.asarray(rng.normal(size=6).astype(np.float32))}
        batches = [
            {"x": jnp.asarray(x[i : i + 10]), "y": jnp.asarray(y[i : i + 10])}
            for i in range(0, 50, 10)
        ]
        val = diversity.dataset_diversity(loss, params, batches)
        grads = np.asarray(
            jax.vmap(jax.grad(loss), in_axes=(None, 0))(
                params, {"x": jnp.asarray(x), "y": jnp.asarray(y)}
            )["w"]
        )
        assert np.isclose(float(val), _true_delta(grads), rtol=1e-4)


class TestResetAndState:
    def test_reset(self):
        params = {"w": jnp.ones(3)}
        st_ = diversity.init_state(params)
        st_ = diversity.accumulate(st_, {"w": jnp.ones(3)}, 4, None)
        st_ = diversity.reset_state(st_)
        assert float(st_.sq_norm_sum) == 0.0
        assert float(st_.sample_count) == 0.0
        assert np.all(np.asarray(st_.grad_sum["w"]) == 0)
