"""End-to-end behaviour tests for the paper's system:

1. DiveBatch reproduces the paper's qualitative claims on the synthetic task
   (convex: batch ramps to m_max with large delta; convergence comparable to
   small-batch SGD).
2. The production LM train step (microbatch accumulation + moment estimator)
   produces consistent diversity statistics with the reference loop.
3. The supervisor survives injected failures with an unchanged trajectory.
4. The serving engine decodes deterministically.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.core import AdaptiveBatchController, diversity, make_policy
from repro.data import sigmoid_synthetic
from repro.models import small
from repro.models import transformer as tf
from repro.optim import sgd
from repro.train import init_state, make_train_step
from repro.train.loop import ModelFns, Trainer

# whole-system integration runs (training loops, supervisor restarts,
# decode sessions): excluded from the fast `-m "not slow"` lane
pytestmark = pytest.mark.slow


class TestPaperClaims:
    def test_convex_large_delta_ramps_to_mmax(self):
        """Paper §5.1: with delta ~ 1, batch reaches m_max within a few epochs."""
        train, val, _ = sigmoid_synthetic(n=4000, d=64, seed=0)
        ctrl = AdaptiveBatchController(
            make_policy("divebatch", m0=64, m_max=1024, delta=1.0,
                        dataset_size=len(train), granule=16),
            base_lr=1.0,
        )
        t = Trainer(
            ModelFns(small.logreg_batch_loss, small.logreg_loss,
                     lambda p, b: {"acc": small.logreg_accuracy(p, b)}),
            small.logreg_init(jax.random.key(0), 64), sgd(momentum=0.9),
            ctrl, train, val, estimator="exact",
        )
        hist = t.run(5, verbose=False)
        # rapid growth: >=8x within two epochs, m_max within five (paper
        # fig. 2: the convex run reaches m_max after a few epochs)
        assert hist[1].batch_size >= 512
        assert max(h.batch_size for h in hist) == 1024

    def test_divebatch_matches_smallbatch_accuracy(self):
        """Paper Table 1-style: final accuracy within a few points of fixed
        small-batch SGD, on the synthetic convex task."""
        train, val, _ = sigmoid_synthetic(n=4000, d=64, seed=1)

        def run(method, est):
            ctrl = AdaptiveBatchController(
                make_policy(method, m0=64, m_max=1024, delta=0.5,
                            dataset_size=len(train), granule=16),
                base_lr=1.0,
            )
            t = Trainer(
                ModelFns(small.logreg_batch_loss, small.logreg_loss,
                         lambda p, b: {"acc": small.logreg_accuracy(p, b)}),
                small.logreg_init(jax.random.key(1), 64), sgd(momentum=0.9),
                ctrl, train, val, estimator=est,
            )
            return t.run(8, verbose=False)

        sgd_hist = run("sgd", "none")
        dive_hist = run("divebatch", "exact")
        assert dive_hist[-1].val_metrics["acc"] > sgd_hist[-1].val_metrics["acc"] - 0.05


class TestProductionStepEquivalence:
    def test_accumulated_step_matches_monolithic_diversity(self):
        """The multi-pod train step's diversity statistics (accumulated over
        the microbatch scan) must be consistent with the host-loop reference."""
        cfg = get_config("yi-6b", reduced=True)
        params = tf.init_params(cfg, jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab_size)
        batch = {"tokens": toks, "targets": toks}
        opt = sgd(momentum=0.9)
        state = init_state(params, opt)
        step = make_train_step(cfg, opt, num_micro=4, diversity_on=True)
        state2, _ = jax.jit(step)(state, batch, jnp.float32(0.0))  # lr=0: pure stats

        div_ref = diversity.init_state(params)
        for i in range(4):
            mb = {k: v[i * 2 : (i + 1) * 2] for k, v in batch.items()}
            g = jax.grad(lambda p: tf.loss_fn(cfg, p, mb)[0])(params)
            div_ref = diversity.accumulate(div_ref, g, 2, None)
        a = float(diversity.diversity_moment(state2.div_state))
        b = float(diversity.diversity_moment(div_ref))
        assert np.isfinite(a) and np.isfinite(b) and a > 0
        np.testing.assert_allclose(a, b, rtol=1e-3)
        np.testing.assert_allclose(float(state2.div_state.sample_count), 8.0)

    def test_lr_zero_keeps_params(self):
        cfg = get_config("qwen2-7b", reduced=True)
        params = tf.init_params(cfg, jax.random.key(0))
        opt = sgd()  # no momentum
        state = init_state(params, opt)
        step = make_train_step(cfg, opt, num_micro=2)
        toks = jnp.ones((4, 32), jnp.int32)
        state2, _ = jax.jit(step)(state, {"tokens": toks, "targets": toks},
                                  jnp.float32(0.0))
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(state2.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestSupervisor:
    def test_watchdog_degenerate_windows(self):
        """Small and zero-variance windows must behave, not divide by ~0:
        window <= 2 can never form a spread (no flags, no crash); a constant
        history plus epsilon jitter is NOT a straggler (the old +1e-9 sd
        epsilon flagged any 4ns deviation); a genuine spike still flags, and
        windows of 3-4 observations are functional rather than mute."""
        from repro.launch.supervisor import Watchdog

        # window <= 2: z-score needs 2 prior observations for a spread
        for w in (1, 2):
            wd = Watchdog(window=w, z_thresh=1.0)
            for i, dt in enumerate([0.01, 0.01, 10.0, 0.01]):
                wd.observe(i, dt)
            assert wd.flagged == []

        # constant history: epsilon jitter passes, a real spike flags
        wd = Watchdog(window=5, z_thresh=4.0)
        for i in range(6):
            wd.observe(i, 2.0)
        wd.observe(6, 2.0 + 1e-6)
        assert wd.flagged == []  # old code: z ~ 1000 false straggler
        wd.observe(7, 10.0)
        assert len(wd.flagged) == 1 and wd.flagged[0][0] == 7

        # small windows now fire instead of never reaching the warm-up gate
        flags = []
        wd = Watchdog(window=3, z_thresh=2.0, on_flag=lambda s, z: flags.append(s))
        for i, dt in enumerate([1.0, 1.01, 0.99, 5.0]):
            wd.observe(i, dt)
        assert flags == [3] and wd.flagged[0][0] == 3

    def test_failure_injection_and_restart(self, tmp_path):
        from repro.launch.supervisor import run_supervised

        train, val, _ = sigmoid_synthetic(n=1000, d=16, seed=0)

        def make_trainer(mgr):
            ctrl = AdaptiveBatchController(
                make_policy("divebatch", m0=32, m_max=256, delta=0.5,
                            dataset_size=len(train), granule=16),
                base_lr=1.0,
            )
            return Trainer(
                ModelFns(small.logreg_batch_loss, small.logreg_loss,
                         lambda p, b: {"acc": small.logreg_accuracy(p, b)}),
                small.logreg_init(jax.random.key(0), 16), sgd(momentum=0.9),
                ctrl, train, val, estimator="exact", ckpt=mgr,
            )

        hist = run_supervised(make_trainer, total_epochs=6, fail_at=[2, 4],
                              ckpt_dir=str(tmp_path / "sup"))
        assert len(hist) == 6
        clean = run_supervised(make_trainer, total_epochs=6, fail_at=[],
                               ckpt_dir=str(tmp_path / "clean"))
        np.testing.assert_allclose(
            [h.val_loss for h in hist], [h.val_loss for h in clean], rtol=1e-5
        )

    def test_elastic_restart_lands_on_different_rung(self, tmp_path):
        """A mid-run failure after the batch has grown restarts the job onto
        a DIFFERENT (wider) ladder rung than the run started on, with the
        trajectory unchanged vs a crash-free elastic run."""
        from repro.elastic import MeshLadder
        from repro.launch.supervisor import run_supervised

        train, val, _ = sigmoid_synthetic(n=1000, d=16, seed=0)
        rungs_seen = []

        def make_trainer(mgr):
            ctrl = AdaptiveBatchController(
                make_policy("divebatch", m0=16, m_max=256, delta=0.5,
                            dataset_size=len(train), granule=16),
                base_lr=1.0,
            )
            t = Trainer(
                ModelFns(small.logreg_batch_loss, small.logreg_loss,
                         lambda p, b: {"acc": small.logreg_accuracy(p, b)}),
                small.logreg_init(jax.random.key(0), 16), sgd(momentum=0.9),
                ctrl, train, val, estimator="exact", ckpt=mgr,
                elastic=MeshLadder(granule=16),
            )
            rungs_seen.append(t.rung.index)  # rung after build (+ resume next)
            return t

        hist = run_supervised(make_trainer, total_epochs=5, fail_at=[3],
                              ckpt_dir=str(tmp_path / "sup"))
        assert len(hist) == 5
        # first build starts on the m0 rung; the post-failure rebuild's
        # resume() then re-derives a wider rung from the restored batch size
        restarted = make_trainer(CheckpointManager(str(tmp_path / "sup")))
        assert restarted.resume()
        assert restarted.rung.index > rungs_seen[0]

        clean = run_supervised(make_trainer, total_epochs=5, fail_at=[],
                               ckpt_dir=str(tmp_path / "clean"))
        np.testing.assert_allclose(
            [h.val_loss for h in hist], [h.val_loss for h in clean], rtol=1e-5
        )

    def test_runlog_reconstructs_cross_rung_restart(self, tmp_path):
        """repro.obs regression: one run log spans the whole supervised run
        (it outlives every Trainer rebuild), so the cross-rung restart — and
        a Watchdog straggler flag routed through ``Trainer.inject_event`` —
        are reconstructable from the single JSONL file afterwards."""
        from repro.elastic import MeshLadder
        from repro.launch import monitor
        from repro.launch.supervisor import Watchdog, run_supervised
        from repro.obs import RunLog, read_runlog

        train, val, _ = sigmoid_synthetic(n=1000, d=16, seed=0)

        def make_trainer(mgr):
            ctrl = AdaptiveBatchController(
                make_policy("divebatch", m0=16, m_max=256, delta=0.5,
                            dataset_size=len(train), granule=16),
                base_lr=1.0,
            )
            return Trainer(
                ModelFns(small.logreg_batch_loss, small.logreg_loss,
                         lambda p, b: {"acc": small.logreg_accuracy(p, b)}),
                small.logreg_init(jax.random.key(0), 16), sgd(momentum=0.9),
                ctrl, train, val, estimator="exact", ckpt=mgr,
                elastic=MeshLadder(granule=16),
            )

        run_dir = tmp_path / "run"
        with RunLog(str(run_dir), meta={"cmd": "supervised"}) as log:
            hist = run_supervised(make_trainer, total_epochs=5, fail_at=[3],
                                  ckpt_dir=str(tmp_path / "sup"), runlog=log)
            # the Watchdog straggler path feeds the same log via inject_event
            t = make_trainer(CheckpointManager(str(tmp_path / "sup")))
            t.bind_obs(runlog=log)
            wd = Watchdog(window=10, z_thresh=4.0,
                          on_flag=lambda step, z: t.inject_event("straggler"))
            for i, dt in enumerate([0.01] * 8 + [1.0]):
                wd.observe(i, dt)
            assert wd.flagged

        assert len(hist) == 5
        evs = read_runlog(str(run_dir))
        restarts = [e for e in evs if e["kind"] == "restart"]
        # initial start (restarts=0) + the rebuild after the epoch-3 crash,
        # which resumes at a LATER epoch, a GROWN batch, and a WIDER rung
        assert [e["restarts"] for e in restarts] == [0, 1]
        assert restarts[1]["epoch"] > restarts[0]["epoch"] == 0
        assert restarts[1]["batch_size"] > restarts[0]["batch_size"]
        assert restarts[1]["rung"] > restarts[0]["rung"]
        # the schedule rows after the restart execute on the restart's rung
        sched = monitor.schedule(evs)
        post = [r for r in sched if r["epoch"] >= restarts[1]["epoch"]]
        assert post and all(r["rung"] is not None for r in post)
        # the injected Watchdog flag is a typed event in the same file
        inj = [e for e in evs if e["kind"] == "inject"]
        assert [e["name"] for e in inj] == ["straggler"]
        # lifecycle rendering covers both
        text = monitor.summary(evs)
        assert "restart #1" in text and "inject    'straggler'" in text


class TestServing:
    def test_greedy_decode_deterministic(self):
        from repro.serve import Request, ServeEngine

        cfg = get_config("yi-6b", reduced=True)
        params = tf.init_params(cfg, jax.random.key(0))
        eng = ServeEngine(cfg, params, max_slots=4)
        reqs = [Request(prompt=np.arange(5, dtype=np.int32) + 1, max_new_tokens=8)
                for _ in range(3)]
        r1 = eng.generate(reqs)
        r2 = eng.generate(reqs)  # same engine, fresh requests: warm caches
        for a, b in zip(r1, r2):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            assert a.steps == 8
