"""Serving-substrate edge cases: sliding-window ring-buffer decode beyond the
window, SSM decode beyond any window, cache length bookkeeping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import transformer as tf

KEY = jax.random.key(0)


def _decode_all(cfg, params, toks, cache_len):
    cache = tf.init_cache(cfg, toks.shape[0], cache_len)
    logits = None
    for t in range(toks.shape[1]):
        logits, cache = tf.decode_step(cfg, params, cache, toks[:, t : t + 1])
    return logits, cache


class TestWindowedRingBuffer:
    def test_decode_beyond_window_matches_windowed_prefill(self):
        """Decoding 24 tokens with window=8 must equal the last position of a
        windowed prefill — the ring buffer evicts correctly."""
        cfg = ModelConfig(
            name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
            num_kv_heads=2, d_ff=64, vocab_size=61,
            pattern=("attn_local",), window=8,
            param_dtype="float32", compute_dtype="float32", xent_chunk=8,
            remat=False,
        )
        params = tf.init_params(cfg, KEY)
        toks = jax.random.randint(jax.random.key(1), (2, 24), 0, 61)
        logits_dec, cache = _decode_all(cfg, params, toks, cache_len=24)
        logits_pre, _ = tf.prefill_step(cfg, params, {"tokens": toks})
        np.testing.assert_allclose(
            np.asarray(logits_dec), np.asarray(logits_pre), atol=3e-3
        )
        # ring cache never exceeded the window
        assert cache["pos0"]["k"].shape[2] == 8
        assert int(cache["len"]) == 24

    def test_gemma_style_mixed_caches(self):
        """Local layers keep window-sized caches; global layers full-length."""
        cfg = ModelConfig(
            name="t", family="dense", num_layers=4, d_model=32, num_heads=4,
            num_kv_heads=2, d_ff=64, vocab_size=61,
            pattern=("attn_local", "attn"), window=4,
            param_dtype="float32", compute_dtype="float32", xent_chunk=8,
            remat=False,
        )
        cache = tf.init_cache(cfg, 2, 16)
        assert cache["pos0"]["k"].shape[2] == 4   # local: window
        assert cache["pos1"]["k"].shape[2] == 16  # global: full context


class TestSSMDecodeLong:
    def test_state_size_independent_of_context(self):
        cfg = ModelConfig(
            name="t", family="ssm", num_layers=2, d_model=32, num_heads=0,
            num_kv_heads=0, d_ff=0, vocab_size=61, pattern=("mamba",),
            param_dtype="float32", compute_dtype="float32", xent_chunk=8,
            ssm_chunk=8, remat=False,
        )
        c_small = tf.init_cache(cfg, 1, 16)
        c_huge = tf.init_cache(cfg, 1, 1 << 19)  # 524288 context
        from repro.utils import pytree as ptu

        assert ptu.tree_bytes(c_small) == ptu.tree_bytes(c_huge)  # O(1) state

    def test_long_decode_runs(self):
        cfg = ModelConfig(
            name="t", family="ssm", num_layers=2, d_model=32, num_heads=0,
            num_kv_heads=0, d_ff=0, vocab_size=61, pattern=("mamba",),
            param_dtype="float32", compute_dtype="float32", xent_chunk=8,
            ssm_chunk=8, remat=False,
        )
        params = tf.init_params(cfg, KEY)
        toks = jax.random.randint(jax.random.key(2), (1, 40), 0, 61)
        logits, cache = _decode_all(cfg, params, toks, cache_len=40)
        assert np.all(np.isfinite(np.asarray(logits)))
        assert int(cache["len"]) == 40
