"""repro.obs: the unified telemetry path.

Pins, in order: the null sinks are strict no-ops (shared span singleton, no
validation, no writes); the metrics registry semantics and the
EngineStats/ServeStats legacy surface (every scalar field is an emitting
view over the process registry — the equivalence tests here are what let
benches keep reading ``stats.compiles``); the Chrome-trace export schema
(``SCHEMA_VERSION``, event shape, per-thread span nesting); the run-log
schema (typed-event validation, NaN scrubbing, version gate) and the
monitor's reconstruction of the batch/rung/lr schedule from it
(record-for-record against ``AdaptationProgram.history``); the serve-side
span/event stream; and the overhead guard — a disabled tracer adds zero
device-to-host transfers and a bounded sliver of a step to the hot loop.
"""

import json
import math
import threading
import time

import jax
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.configs.base import ModelConfig
from repro.core import AdaptiveBatchController, make_policy
from repro.data import sigmoid_synthetic
from repro.elastic import MeshLadder
from repro.launch import monitor
from repro.models import small
from repro.models import transformer as tf
from repro.obs import from_cli, metrics, runlog, trace
from repro.obs.runlog import RunLog, read_runlog
from repro.obs.trace import Tracer
from repro.optim import sgd
from repro.serve import Request, ServeEngine
from repro.train.engine import EngineStats
from repro.train.loop import ModelFns, Trainer


def _logreg_trainer(train, val, *, m0=16, m_max=256, elastic=None, **kw):
    ctrl = AdaptiveBatchController(
        make_policy("divebatch", m0=m0, m_max=m_max, delta=0.5,
                    dataset_size=len(train), granule=16),
        base_lr=1.0,
    )
    fns = ModelFns(small.logreg_batch_loss, small.logreg_loss,
                   lambda p, b: {"acc": small.logreg_accuracy(p, b)})
    d = train.arrays["x"].shape[1]
    return Trainer(fns, small.logreg_init(jax.random.key(0), d),
                   sgd(momentum=0.9), ctrl, train, val, estimator="exact",
                   elastic=elastic, **kw)


@pytest.fixture(scope="module")
def traced_run(tmp_path_factory):
    """One fully-instrumented elastic training run, shared by the schema /
    reconstruction tests: batch growth forces a real rung transition, the
    checkpoint cadence and an injected event exercise their event kinds."""
    run_dir = str(tmp_path_factory.mktemp("obs_run"))
    train, val, _ = sigmoid_synthetic(n=1000, d=16, seed=0)
    tracer = Tracer()
    log = RunLog(run_dir, meta={"cmd": "test", "task": "sigmoid"})
    t = _logreg_trainer(
        train, val, elastic=MeshLadder(granule=16), tracer=tracer, runlog=log,
        ckpt=CheckpointManager(str(tmp_path_factory.mktemp("obs_ckpt"))),
        ckpt_every=2,
    )
    t.inject_event("probe")
    t.run(4, verbose=False)
    tracer.save(run_dir)
    log.close()
    return t, tracer, run_dir


# ---------------------------------------------------------------------------
# null sinks


class TestNullSinks:
    def test_null_tracer_is_strict_noop(self):
        tr = trace.NULL
        assert tr.enabled is False
        # one shared stateless span object — no allocation per call
        assert tr.span("a", x=1) is tr.span("b")
        with tr.span("a", x=1) as s:
            assert s is trace.NULL.span("c")
        assert tr.instant("x", y=2) is None
        assert tr.save("/nonexistent/dir") is None
        doc = tr.to_json()
        assert doc["traceEvents"] == []
        assert doc["otherData"]["schema"] == trace.SCHEMA_VERSION

    def test_null_runlog_skips_validation(self):
        # the disabled sink must not pay (or raise on) kind validation
        assert runlog.NULL.enabled is False
        assert runlog.NULL.emit("definitely_not_a_kind") is None
        assert runlog.NULL.emit("epoch") is None  # missing fields: still ok
        assert runlog.NULL.close() is None


# ---------------------------------------------------------------------------
# metrics registry + stats equivalence


class TestRegistry:
    def test_counter_gauge_histogram(self):
        reg = metrics.Registry()
        c = reg.counter("a.steps")
        c.inc()
        c.inc(4)
        assert c.value == 5
        assert reg.counter("a.steps") is c  # get-or-create
        g = reg.gauge("a.wall")
        g.set(1.5)
        assert g.value == 1.5
        h = reg.histogram("a.lat")
        for v in (1.0, 3.0, 2.0):
            h.observe(v)
        assert (h.count, h.total, h.vmin, h.vmax, h.last) == (3, 6.0, 1.0, 3.0, 2.0)
        assert h.mean == 2.0
        snap = reg.snapshot()
        assert snap["a.steps"] == 5 and snap["a.wall"] == 1.5
        assert snap["a.lat"]["count"] == 3  # histograms expand to summaries

    def test_type_conflict_raises(self):
        reg = metrics.Registry()
        reg.counter("x")
        with pytest.raises(TypeError, match="already registered"):
            reg.gauge("x")

    def test_unique_namespaces(self):
        reg = metrics.Registry()
        assert reg.unique_namespace("train.engine") != reg.unique_namespace(
            "train.engine")


class TestStatsViews:
    # the legacy dict surface, pinned key-for-key so no bench/test consumer
    # silently loses a field when the registry backing evolves
    ENGINE_KEYS = [
        "compiles", "bucket_hits", "bucket_misses", "steps", "compile_s",
        "reshards", "dispatch_wall_s", "donate", "buckets", "rungs", "tiers",
        "dispatch_steps_per_sec",
    ]
    SERVE_KEYS = [
        "compiles", "bucket_hits", "bucket_misses", "prefill_compiles",
        "aux_compiles", "steps", "slot_steps", "tokens", "prefills",
        "prefill_chunks", "shared_prefill_hits", "shared_blocks",
        "cow_copies", "pool_blocks", "peak_blocks", "block_size", "retired",
        "reshards", "resizes", "compile_s", "dispatch_wall_s",
        "tokens_per_sec", "donate", "buckets", "rungs",
    ]

    def test_engine_stats_registry_equivalence(self):
        reg = metrics.Registry()
        st = EngineStats(donate=False, registry=reg)
        st.compiles += 2
        st.steps += 7
        st.compile_s += 0.25
        st.buckets.append(64)  # plain attribute, not registry-backed
        assert st.as_dict() == dict(
            compiles=2, bucket_hits=0, bucket_misses=0, steps=7,
            compile_s=0.25, reshards=0, dispatch_wall_s=0, donate=False,
            buckets=[64], rungs=[], tiers=[], dispatch_steps_per_sec=0.0,
        )
        snap = reg.snapshot()
        for f in (*st._COUNTERS, *st._GAUGES):
            assert snap[f"{st.namespace}.{f}"] == getattr(st, f), f

    def test_as_dict_keys_pinned(self):
        from repro.serve.engine import ServeStats
        assert list(EngineStats(registry=metrics.Registry()).as_dict()) \
            == self.ENGINE_KEYS
        assert list(ServeStats(registry=metrics.Registry()).as_dict()) \
            == self.SERVE_KEYS

    def test_live_engine_emits_into_process_registry(self, traced_run):
        t, _, _ = traced_run
        st = t.engine.stats
        snap = metrics.REGISTRY.snapshot()
        assert st.namespace.startswith("train.engine.")
        for f in (*st._COUNTERS, *st._GAUGES):
            assert snap[f"{st.namespace}.{f}"] == getattr(st, f), f
        assert st.steps > 0 and st.compiles > 0

    def test_two_engines_never_collide(self):
        a = EngineStats(registry=metrics.REGISTRY)
        b = EngineStats(registry=metrics.REGISTRY)
        a.steps += 3
        assert b.steps == 0 and a.namespace != b.namespace


# ---------------------------------------------------------------------------
# trace schema


class TestTraceSchema:
    def test_schema_version_pinned(self):
        assert trace.SCHEMA_VERSION == 1
        assert runlog.SCHEMA_VERSION == 1

    def test_export_shape(self, traced_run):
        _, tracer, _ = traced_run
        doc = tracer.to_json()
        assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
        assert doc["displayTimeUnit"] == "ms"
        other = doc["otherData"]
        assert other["schema"] == trace.SCHEMA_VERSION
        assert isinstance(other["wall_origin"], float)
        for ev in doc["traceEvents"]:
            assert ev["ph"] in ("X", "i", "M")
            assert {"name", "ts", "pid", "tid"} <= set(ev)
            if ev["ph"] == "X":
                assert ev["dur"] > 0
            if ev["ph"] == "i":
                assert ev["s"] == "t"

    def test_span_taxonomy(self, traced_run):
        t, tracer, _ = traced_run
        names = {e["name"] for e in tracer.events if e["ph"] == "X"}
        assert {"compile", "dispatch", "observe", "epoch"} <= names
        # batch growth m0=16 -> m_max crosses ladder rungs: the transition
        # must be visible as a reshard span AND in the engine stats
        assert "reshard" in names
        assert t.engine.stats.reshards > 0
        dispatch = [e for e in tracer.events if e["name"] == "dispatch"]
        assert len(dispatch) == t.engine.stats.steps
        assert all("bucket" in e["args"] and "step_num" in e["args"]
                   for e in dispatch)

    def test_spans_nest_per_thread(self, traced_run):
        _, tracer, _ = traced_run
        by_tid = {}
        for ev in tracer.events:
            if ev["ph"] == "X":
                by_tid.setdefault(ev["tid"], []).append(ev)
        eps = 0.01  # µs; absorbs the 1ns min-duration clamp
        for evs in by_tid.values():
            evs.sort(key=lambda e: (e["ts"], -e["dur"]))
            stack = []  # end timestamps of open ancestors
            for ev in evs:
                t0, t1 = ev["ts"], ev["ts"] + ev["dur"]
                while stack and t0 >= stack[-1] - eps:
                    stack.pop()
                if stack:  # inside an ancestor: must end before it does
                    assert t1 <= stack[-1] + eps, (ev, stack)
                stack.append(t1)

    def test_save_roundtrip(self, tmp_path):
        tr = Tracer()
        with tr.span("outer", k="v"):
            with tr.span("inner"):
                pass
        tr.instant("mark", n=np.int64(3))  # numpy scalars must serialize
        path = tr.save(str(tmp_path))  # directory -> <dir>/trace.json
        assert path == str(tmp_path / "trace.json")
        doc = json.loads((tmp_path / "trace.json").read_text())
        names = [e["name"] for e in doc["traceEvents"]]
        # inner exits first; one thread_name metadata record per thread
        assert names == ["thread_name", "inner", "outer", "mark"]
        assert doc["traceEvents"][0]["args"]["name"] == \
            threading.current_thread().name

    def test_threads_get_own_lanes(self):
        tr = Tracer()
        def work():
            with tr.span("bg"):
                pass
        th = threading.Thread(target=work, name="bg-thread")
        th.start()
        th.join()
        with tr.span("fg"):
            pass
        evs = tr.events
        tids = {e["tid"] for e in evs if e["ph"] == "X"}
        assert len(tids) == 2
        meta = [e for e in evs if e["ph"] == "M"]
        assert {m["args"]["name"] for m in meta} >= {"bg-thread"}


# ---------------------------------------------------------------------------
# run log


class TestRunLog:
    def test_emit_validation(self, tmp_path):
        with RunLog(str(tmp_path)) as log:
            with pytest.raises(ValueError, match="unknown run-log event kind"):
                log.emit("nope", a=1)
            with pytest.raises(ValueError, match="missing required fields"):
                log.emit("epoch", epoch=0)
            with pytest.raises(ValueError, match="reserved"):
                log.emit("inject", name="x", kind="boom")
            with pytest.raises(ValueError, match="reserved"):
                log.emit("inject", name="x", t=0.0)

    def test_roundtrip_and_nan_scrub(self, tmp_path):
        with RunLog(str(tmp_path), meta={"seed": 3}) as log:
            log.emit("epoch", epoch=0, steps=5, batch_size=64, lr=0.5,
                     loss=float("nan"), gns=math.inf, diversity=np.float32(0.5))
            log.emit("checkpoint", epoch=0, step=5)
        evs = read_runlog(str(tmp_path))  # directory or file path both work
        assert [e["kind"] for e in evs] == ["run_start", "epoch", "checkpoint"]
        assert all(e["v"] == runlog.SCHEMA_VERSION for e in evs)
        assert evs[0]["run"] == {"seed": 3}
        ep = evs[1]
        assert ep["loss"] is None and ep["gns"] is None  # non-finite -> null
        assert ep["diversity"] == 0.5  # numpy scalar -> plain float
        assert evs[0]["t"] <= ep["t"] <= evs[2]["t"]

    def test_reader_rejects_newer_schema(self, tmp_path):
        p = tmp_path / "runlog.jsonl"
        p.write_text(json.dumps({"v": runlog.SCHEMA_VERSION + 1,
                                 "kind": "epoch", "t": 0.0}) + "\n")
        with pytest.raises(ValueError, match="newer"):
            read_runlog(str(p))

    def test_emit_after_close_is_dropped(self, tmp_path):
        log = RunLog(str(tmp_path))
        log.close()
        log.emit("inject", name="late")  # validated, silently dropped
        assert [e["kind"] for e in read_runlog(str(tmp_path))] == ["run_start"]

    def test_from_cli(self, tmp_path):
        assert from_cli(None, None) == (None, None)
        tr, log = from_cli(str(tmp_path / "run"), "")  # "" = into trace dir
        assert tr.enabled and log.path.endswith("runlog.jsonl")
        log.close()
        with pytest.raises(ValueError):
            from_cli(None, "")


# ---------------------------------------------------------------------------
# monitor reconstruction


class TestMonitor:
    def test_schedule_mirrors_program_history(self, traced_run):
        t, _, run_dir = traced_run
        sched = monitor.schedule(monitor.load(run_dir))
        hist = t.adapt.history
        assert len(sched) == len(hist) > 0
        for row, ap in zip(sched, hist):
            assert (row["epoch"], row["step"], row["boundary"],
                    row["batch_size"]) == (ap.epoch, ap.step, ap.boundary,
                                           ap.batch_size)
            assert row["lr"] == pytest.approx(ap.lr)
        # the rung transition is reconstructable from the same file: rows
        # after the reshard carry its destination rung
        assert sched[-1]["rung"] is not None

    def test_event_stream_shape(self, traced_run):
        t, _, run_dir = traced_run
        evs = monitor.load(run_dir)
        kinds = [e["kind"] for e in evs]
        assert kinds[0] == "run_start"
        assert kinds.count("epoch") == len(t.history)
        assert kinds.count("checkpoint") >= 1  # ckpt_every=2 over 4 epochs
        assert "inject" in kinds and "compile" in kinds
        reshards = [e for e in evs if e["kind"] == "reshard"]
        assert len(reshards) == t.engine.stats.reshards
        assert all(e["scope"] == "train" for e in reshards)
        # decision events carry the full Applied record
        dec = next(e for e in evs if e["kind"] == "decision")
        assert {"reason", "estimator", "raw_batch_size", "rescaled"} <= set(dec)

    def test_summary_and_tables(self, traced_run):
        _, _, run_dir = traced_run
        text = monitor.summary(monitor.load(run_dir))
        assert "epochs:" in text and "schedule (" in text
        assert "reshard   [train]" in text
        assert "inject    'probe'" in text

    def test_follow_drain_holds_back_torn_lines(self, tmp_path):
        """--follow must never emit (or json-parse) a half-written trailing
        record: a line flushed mid-write stays in the carry buffer and is
        re-read whole once the writer completes it."""
        rec1 = json.dumps({"v": 1, "kind": "epoch", "epoch": 0})
        rec2 = json.dumps({"v": 1, "kind": "demote", "src": 3, "dst": 2})
        path = tmp_path / "runlog.jsonl"
        with open(path, "w") as w:
            w.write(rec1 + "\n" + rec2[:10])  # torn mid-record
            w.flush()
            with open(path) as r:
                lines, buf = monitor._drain(r, "")
                assert lines == [rec1]  # the torn tail is NOT emitted
                assert buf == rec2[:10]
                # a second poll before the writer finishes yields nothing
                lines2, buf = monitor._drain(r, buf)
                assert lines2 == [] and buf == rec2[:10]
                # writer completes the record: the follower re-reads it whole
                w.write(rec2[10:] + "\n")
                w.flush()
                lines3, buf = monitor._drain(r, buf)
                assert lines3 == [rec2] and buf == ""
                assert [json.loads(l) for l in [*lines, *lines3]] == [
                    {"v": 1, "kind": "epoch", "epoch": 0},
                    {"v": 1, "kind": "demote", "src": 3, "dst": 2}]

    def test_drain_skips_blank_lines(self, tmp_path):
        path = tmp_path / "log.jsonl"
        path.write_text("a\n\n   \nb\n")
        with open(path) as r:
            lines, buf = monitor._drain(r, "")
        assert lines == ["a", "b"] and buf == ""

    def test_merge_traces(self, traced_run, tmp_path):
        _, tracer, run_dir = traced_run
        out = str(tmp_path / "merged.json")
        monitor.merge_traces(run_dir, out)
        doc = json.loads(open(out).read())
        evs = doc["traceEvents"]
        # all tracer events + one runlog lane (thread_name + one instant per
        # logged event), aligned via wall_origin
        lane = [e for e in evs if e["tid"] == -1]
        assert len(evs) == len(tracer.events) + len(lane)
        assert lane[0]["args"]["name"] == "runlog"
        assert len(lane) == 1 + len(monitor.load(run_dir))
        assert all(e["ph"] == "i" for e in lane[1:])


# ---------------------------------------------------------------------------
# serve instrumentation


class TestServeObs:
    def test_serve_spans_and_events(self, tmp_path):
        cfg = ModelConfig(
            name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
            num_kv_heads=2, d_ff=64, vocab_size=61, pattern=("attn",),
            param_dtype="float32", compute_dtype="float32", xent_chunk=8,
            remat=False,
        )
        params = tf.init_params(cfg, jax.random.key(0))
        rng = np.random.default_rng(7)
        reqs = [Request(prompt=rng.integers(1, cfg.vocab_size, size=n)
                        .astype(np.int32), max_new_tokens=m)
                for n, m in zip((20, 27, 12), (8, 6, 8))]
        tracer = Tracer()
        log = RunLog(str(tmp_path))
        eng = ServeEngine(cfg, params, max_slots=4, max_seq=64,
                          prompt_granule=8, prefill_chunk=8,
                          tracer=tracer, runlog=log, obs_window=4)
        outs = eng.generate(reqs)
        log.close()
        assert all(len(o.tokens) for o in outs)

        spans = [e["name"] for e in tracer.events if e["ph"] == "X"]
        assert {"admit", "prefill_chunk", "decode", "compile"} <= set(spans)
        assert spans.count("prefill_chunk") == eng.stats.prefill_chunks
        assert spans.count("decode") == eng.stats.steps
        # pool churn shows up as instants on the same timeline
        assert any(e["name"] == "pool_alloc" for e in tracer.events)

        evs = read_runlog(str(tmp_path))
        kinds = [e["kind"] for e in evs]
        assert kinds.count("serve_admit") == 3
        assert kinds.count("serve_retire") == 3
        assert kinds.count("serve_window") >= 1
        # the default FifoPolicy decides the identity: nothing to mirror
        assert kinds.count("serve_policy") == 0
        compiles = [e for e in evs if e["kind"] == "compile"]
        assert compiles and all(e["scope"] == "serve" for e in compiles)
        assert {c["exe_kind"] for c in compiles} >= {"decode", "prefill"}
        admit = next(e for e in evs if e["kind"] == "serve_admit")
        assert admit["prompt_len"] > 0 and admit["budget"] > 0
        win = next(e for e in evs if e["kind"] == "serve_window")
        assert win["tokens"] > 0 and "tokens_per_sec" in win
        # serve table renders from the same stream
        assert "tokens_per_sec" in monitor.serve_table(evs)

        st = eng.stats
        snap = metrics.REGISTRY.snapshot()
        assert st.namespace.startswith("serve.engine.")
        for f in (*st._COUNTERS, *st._GAUGES):
            assert snap[f"{st.namespace}.{f}"] == getattr(st, f), f

    def test_serve_policy_event(self, tmp_path):
        """An applied ServePolicy decision mirrors into the typed
        ``serve_policy`` run-log event, and only when it changed something
        (a reorder here: 6 inverted-priority requests into 2 slots)."""
        cfg = ModelConfig(
            name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
            num_kv_heads=2, d_ff=64, vocab_size=61, pattern=("attn",),
            param_dtype="float32", compute_dtype="float32", xent_chunk=8,
            remat=False,
        )
        params = tf.init_params(cfg, jax.random.key(0))
        rng = np.random.default_rng(8)

        def reqs():
            return [Request(prompt=rng.integers(1, 61, size=4)
                            .astype(np.int32), max_new_tokens=4,
                            tenant=f"t{i % 2}", priority=i)
                    for i in range(6)]

        log = RunLog(str(tmp_path))
        eng = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                          prompt_granule=8, policy="priority", runlog=log)
        eng.generate(reqs())
        log.close()
        evs = read_runlog(str(tmp_path))
        pol = [e for e in evs if e["kind"] == "serve_policy"]
        assert pol  # ascending priorities vs FIFO: a genuine reorder
        for e in pol:
            assert e["reason"] == "priority"
            assert e["step"] >= 0 and e["queue_depth"] > 0
            # emitted ONLY when the decision changed something — here that
            # can only be the reorder (no budget/patience in the decision)
            assert e["reordered"] is True
            assert e["slot_budget"] is None
        # the monitor renders the decision stream as lifecycle lines
        assert "policy    'priority'" in monitor.lifecycle(evs)


# ---------------------------------------------------------------------------
# overhead guard


class TestOverheadGuard:
    def _engine_and_batch(self):
        train, val, _ = sigmoid_synthetic(n=512, d=16, seed=0)
        t = _logreg_trainer(train, val, m0=64, m_max=64)
        batch = jax.tree.map(jax.numpy.asarray, train.get(np.arange(64)))
        return t.engine, t.state, batch

    def test_disabled_tracer_zero_device_to_host_transfers(self):
        """The ISSUE's contract, enforced mechanically: with the default
        (disabled) sinks the engine hot loop performs NO device-to-host
        transfer per step — jax's transfer guard turns any implicit D2H
        into an error.  The enabled tracer holds the same property (spans
        record host-side wall time and python scalars only)."""
        eng, state, batch = self._engine_and_batch()
        assert eng.tracer is trace.NULL and eng.runlog is runlog.NULL
        state, _ = eng.step(state, batch, 0.5)  # warm the compile cache
        with jax.transfer_guard_device_to_host("disallow"):
            for _ in range(3):
                state, _ = eng.step(state, batch, 0.5)
            eng.tracer = Tracer()
            for _ in range(3):
                state, _ = eng.step(state, batch, 0.5)
        assert len([e for e in eng.tracer.events if e["ph"] == "X"]) == 3

    def test_disabled_path_cost_is_a_sliver_of_a_step(self):
        """Deterministic micro-ratio (no flaky wall A/B: that lives in
        benchmarks/bench_engine.py as the engine_obs_overhead row): the
        disabled path adds one attribute load + enabled-branch per step,
        measured here against the measured warm step time."""
        eng, state, batch = self._engine_and_batch()
        state, _ = eng.step(state, batch, 0.5)  # compile outside the timing
        walls = []
        for _ in range(10):
            t0 = time.perf_counter()
            state, out = eng.step(state, batch, 0.5)
            jax.block_until_ready(out)
            walls.append(time.perf_counter() - t0)
        step_s = sorted(walls)[len(walls) // 2]

        n = 100_000
        t0 = time.perf_counter()
        for _ in range(n):
            tr = eng.tracer  # exactly the per-step disabled-path work
            if tr.enabled:
                pass  # pragma: no cover
        per_step = (time.perf_counter() - t0) / n
        assert per_step / step_s < 0.03, (per_step, step_s)
