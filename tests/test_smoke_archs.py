"""Per-arch smoke tests: every assigned architecture instantiates a REDUCED
config of the same family and runs one forward + one train step on CPU,
asserting output shapes and no NaNs (full configs are exercised only by the
dry-run via ShapeDtypeStructs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as tf
from repro.optim import sgd
from repro.train import init_state, make_train_step

B, S = 2, 32


def _batch(cfg):
    if cfg.input_mode == "tokens":
        toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
        return {"tokens": toks, "targets": toks}
    emb = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model), jnp.float32)
    tgt = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    return {"embeddings": emb, "targets": tgt}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_loss(arch):
    cfg = get_config(arch, reduced=True)
    params = tf.init_params(cfg, jax.random.key(0))
    loss, metrics = jax.jit(lambda p, b: tf.loss_fn(cfg, p, b))(params, _batch(cfg))
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step(arch):
    cfg = get_config(arch, reduced=True)
    params = tf.init_params(cfg, jax.random.key(0))
    opt = sgd(momentum=0.9)
    state = init_state(params, opt)
    step = make_train_step(cfg, opt, num_micro=2, diversity_on=True)
    state2, metrics = jax.jit(step)(state, _batch(cfg), jnp.float32(0.01))
    assert np.isfinite(float(metrics["loss"])), arch
    assert int(state2.step) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(state2.params))
    )
    assert moved, arch
    # diversity accumulators advanced
    assert float(state2.div_state.sample_count) == B
    assert float(state2.div_state.sq_norm_sum) > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES if a != "hubert-xlarge"])
def test_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    params = tf.init_params(cfg, jax.random.key(0))
    cache = tf.init_cache(cfg, B, 16)
    if cfg.input_mode == "tokens":
        tok = jnp.ones((B, 1), jnp.int32)
    else:
        tok = jnp.ones((B, 1, cfg.d_model), jnp.float32)
    logits, cache2 = jax.jit(lambda p, c, t: tf.decode_step(cfg, p, c, t))(params, cache, tok)
    assert logits.shape == (B, 1, cfg.vocab_size), arch
    assert np.all(np.isfinite(np.asarray(logits))), arch
    assert int(cache2["len"]) == 1
