"""Optimizers, schedules, data pipeline determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.data import EpochLoader, TokenStream, epoch_permutation, sigmoid_synthetic
from repro.optim import adamw, apply_updates, make_schedule, sgd


class TestSGD:
    def test_matches_manual_momentum(self):
        opt = sgd(momentum=0.9)
        p = {"w": jnp.asarray([1.0, 2.0])}
        s = opt.init(p)
        g = {"w": jnp.asarray([0.5, -0.5])}
        upd, s = opt.update(g, s, p, 0.1)
        np.testing.assert_allclose(np.asarray(upd["w"]), [-0.05, 0.05])
        upd, s = opt.update(g, s, p, 0.1)
        # momentum: m = 0.9*0.5 + 0.5 = 0.95 -> upd = -0.095
        np.testing.assert_allclose(np.asarray(upd["w"]), [-0.095, 0.095], rtol=1e-6)

    def test_weight_decay(self):
        opt = sgd(weight_decay=0.1)
        p = {"w": jnp.asarray([2.0])}
        upd, _ = opt.update({"w": jnp.asarray([0.0])}, opt.init(p), p, 1.0)
        np.testing.assert_allclose(np.asarray(upd["w"]), [-0.2])

    def test_quadratic_convergence(self):
        opt = sgd(momentum=0.9)
        p = {"w": jnp.asarray([5.0])}
        s = opt.init(p)
        for _ in range(300):
            g = jax.grad(lambda pp: 0.5 * jnp.sum(pp["w"] ** 2))(p)
            upd, s = opt.update(g, s, p, 0.05)
            p = apply_updates(p, upd)
        assert abs(float(p["w"][0])) < 1e-3


class TestAdamW:
    def test_quadratic_convergence(self):
        opt = adamw(weight_decay=0.0)
        p = {"w": jnp.asarray([5.0])}
        s = opt.init(p)
        for _ in range(300):
            g = jax.grad(lambda pp: 0.5 * jnp.sum(pp["w"] ** 2))(p)
            upd, s = opt.update(g, s, p, 0.1)
            p = apply_updates(p, upd)
        assert abs(float(p["w"][0])) < 1e-2

    def test_state_dtype(self):
        opt = adamw(state_dtype=jnp.bfloat16)
        s = opt.init({"w": jnp.zeros(3, jnp.bfloat16)})
        assert s.mu["w"].dtype == jnp.bfloat16


class TestSchedules:
    def test_warmup_cosine(self):
        f = make_schedule("warmup_cosine", warmup_steps=10, total_steps=100)
        assert float(f(jnp.asarray(0))) == 0.0
        assert float(f(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(f(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)

    def test_step_decay(self):
        f = make_schedule("step_decay", decay_factor=0.5, every_steps=10)
        assert float(f(jnp.asarray(9))) == 1.0
        assert float(f(jnp.asarray(10))) == 0.5


class TestData:
    def test_permutation_deterministic(self):
        a = epoch_permutation(100, seed=3, epoch=5)
        b = epoch_permutation(100, seed=3, epoch=5)
        np.testing.assert_array_equal(a, b)
        c = epoch_permutation(100, seed=3, epoch=6)
        assert not np.array_equal(a, c)

    def test_loader_covers_each_sample_once(self):
        train, _, _ = sigmoid_synthetic(n=640, d=4, seed=0)
        seen = []
        for batch in EpochLoader(train, 64, epoch=0, seed=0):
            seen.append(batch["x"])
        # 512 train samples (80%), batch 64 -> 8 batches, distinct rows
        x = np.concatenate(seen)
        assert x.shape[0] == 512
        assert len(np.unique(x[:, 0])) > 500  # all-but-certainly unique

    def test_resume_mid_epoch(self):
        train, _, _ = sigmoid_synthetic(n=640, d=4, seed=0)
        full = list(EpochLoader(train, 64, epoch=2, seed=1))
        resumed = list(EpochLoader(train, 64, epoch=2, seed=1, start_batch=5))
        np.testing.assert_array_equal(full[5]["x"], resumed[0]["x"])

    def test_sharded_loader_partitions(self):
        train, _, _ = sigmoid_synthetic(n=640, d=4, seed=0)
        b0 = next(iter(EpochLoader(train, 64, 0, 0, shard_index=0, shard_count=4)))
        b1 = next(iter(EpochLoader(train, 64, 0, 0, shard_index=1, shard_count=4)))
        assert b0["x"].shape[0] == 16
        assert not np.array_equal(b0["x"], b1["x"])

    @given(step=st.integers(0, 50))
    @settings(max_examples=10, deadline=None)
    def test_token_stream_deterministic(self, step):
        ts1 = TokenStream(vocab_size=500, seed=9)
        ts2 = TokenStream(vocab_size=500, seed=9)
        b1 = ts1.batch(step, 2, 16)
        b2 = ts2.batch(step, 2, 16)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert b1["tokens"].max() < 500
