"""Beyond-paper extensions the paper names as future directions (§6):

1. DiveBatch ∘ AdamW — "DiveBatch could complement these optimizers" —
   the controller is optimizer-agnostic; verify adaptation + convergence.
2. Quantisation ↑ gradient diversity (Yin et al., cited in §3/§6): int8
   rounding noise is (approximately) independent per sample, so it grows
   Σ‖gᵢ‖² relatively more than ‖Σgᵢ‖² — measured here with our own
   compression kernel, closing the loop with dist/compression.py.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AdaptiveBatchController, make_policy
from repro.data import sigmoid_synthetic
from repro.kernels.quant import dequantize_int8, quantize_int8
from repro.models import small
from repro.optim import adamw
from repro.train.loop import ModelFns, Trainer


def test_divebatch_composes_with_adamw():
    train, val, _ = sigmoid_synthetic(n=2000, d=32, seed=0)
    ctrl = AdaptiveBatchController(
        make_policy("divebatch", m0=64, m_max=512, delta=0.5,
                    dataset_size=len(train), granule=16),
        base_lr=0.01,
    )
    t = Trainer(
        ModelFns(small.mlp_batch_loss, small.mlp_loss,
                 lambda p, b: {"acc": small.mlp_accuracy(p, b)}),
        small.mlp_init(jax.random.key(0), 32),
        adamw(weight_decay=1e-4), ctrl, train, val, estimator="exact",
    )
    hist = t.run(5, verbose=False)
    assert hist[-1].val_metrics["acc"] > 0.85
    assert hist[-1].batch_size > 64  # adaptation active under AdamW


def _diversity(g: np.ndarray) -> float:
    return float(np.sum(np.sum(g ** 2, -1)) / np.sum(np.sum(g, 0) ** 2))


def test_quantization_increases_gradient_diversity():
    rng = np.random.default_rng(0)
    # correlated per-sample gradients (shared mean => low diversity)
    g = (rng.standard_normal((256, 128)) * 0.3 + rng.standard_normal(128)).astype(np.float32)
    d_before = _diversity(g)
    q, s = quantize_int8(jnp.asarray(g) * 0.05)  # coarse quantisation grid
    g_q = np.asarray(dequantize_int8(q, s)) / 0.05
    d_after = _diversity(g_q)
    assert d_after > d_before  # Yin et al.: quantisation promotes diversity
    # and the DiveBatch batch-size rule therefore allows a LARGER batch:
    assert int(0.1 * 256 * d_after * 256) >= int(0.1 * 256 * d_before * 256)
