"""CI smoke for the kernel-lane benchmark: the --smoke variant runs in
seconds and must emit a well-formed BENCH_kernels.json whose paged-decode
section carries the fused-moves-fewer-bytes invariant."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import bench_kernels  # noqa: E402


def test_bench_kernels_smoke(tmp_path):
    out = tmp_path / "BENCH_kernels.json"
    rows = bench_kernels.run(smoke=True, out_path=str(out))
    record = json.loads(out.read_text())
    assert record["workload"]["smoke"] is True
    assert record["workload"]["task"] == "kernel-lane-microbench"
    # off-TPU the lane runs in interpret mode (the platform switch)
    assert record["workload"]["interpret"] is True

    pg = record["paged_decode"]
    for key in ("batch", "n_max", "block", "kv_heads", "head_dim", "lengths",
                "fused_us", "materialised_us", "fused_bytes",
                "materialised_bytes", "bytes_ratio"):
        assert key in pg, key
    # the PR's acceptance invariant: the fused gather-in-kernel lane moves
    # measurably fewer bytes than materialise-then-attend
    assert 0 < pg["fused_bytes"] < pg["materialised_bytes"]
    assert pg["bytes_ratio"] < 1.0
    assert len(pg["lengths"]) == pg["batch"]

    names = [name for name, _, _ in rows]
    assert any(n.startswith("flash_pallas_b") for n in names)
    assert any(n.startswith("flash_pallas_bwd_") for n in names)
    assert any(n.startswith("paged_decode_fused_") for n in names)
    assert any(n.startswith("psgn_fused_") for n in names)
    assert any(n.startswith("psgn_direct_") for n in names)
    assert "quant_int8_1024x1024" in names
    # json mirrors the CSV rows one-to-one
    assert [r["name"] for r in record["rows"]] == names
    for _, us, _ in rows:
        assert us >= 0.0
