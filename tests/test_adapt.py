"""repro.adapt: the signal-driven adaptation API.

Covers the tentpole acceptance criteria — golden equivalence of the
adapt-driven run vs the legacy AdaptiveBatchController shim, mid-epoch
tick/event decisions that resize + reshard BETWEEN steps with exact loader
cursor continuity (visited-sample multiset equality), v1 checkpoint
restore — plus the combinator family (Hysteresis no-flap property test),
the gradient-noise signal/policy, and the threaded prefetch satellite.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.adapt import (
    AdaBatchPolicy,
    AdaptationProgram,
    Chain,
    Clamped,
    Clock,
    Decision,
    DiveBatchPolicy,
    FixedPolicy,
    FromBatchPolicy,
    GradNoisePolicy,
    Hysteresis,
    LrCoupling,
    PolicyBase,
    Signals,
    Switch,
    Warmup,
    gns_from_accumulators,
    read_signals,
)
from repro.ckpt import CheckpointManager
from repro.core import (
    AdaptiveBatchController,
    DiveBatch,
    OracleDiveBatch,
    bucket,
    diversity,
    make_policy,
    step_decay,
)
from repro.data import sigmoid_synthetic
from repro.elastic import MeshLadder
from repro.models import small
from repro.optim import sgd
from repro.train import init_state
from repro.train.loop import ModelFns, Trainer

SEED, N, D = 3, 2048, 32


def _fns():
    return ModelFns(
        batch_loss=small.mlp_batch_loss,
        example_loss=small.mlp_loss,
        metrics=lambda p, b: {"acc": small.mlp_accuracy(p, b)},
    )


def _pow2_data(seed=SEED):
    """sigmoid_synthetic splits 80/20, so n=2560 gives a TRAIN set of 2048 —
    divisible by every pow2 lattice point <= 256, which the mid-epoch
    multiset tests rely on (full-permutation coverage at any mix of
    sizes)."""
    return sigmoid_synthetic(n=2560, d=D, seed=seed)


def _trainer(policy_or_prog, *, estimator="exact", elastic=None, seed=SEED,
             ckpt=None, prefetch=True, base_lr=0.5, data=None, **prog_kw):
    train, val, _ = data if data is not None else sigmoid_synthetic(
        n=N, d=D, seed=seed)
    prog = (
        policy_or_prog
        if isinstance(policy_or_prog, (AdaptationProgram, AdaptiveBatchController))
        else AdaptationProgram(policy_or_prog, base_lr=base_lr, **prog_kw)
    )
    return Trainer(_fns(), small.mlp_init(jax.random.key(seed), D),
                   sgd(momentum=0.9), prog, train, val, estimator=estimator,
                   seed=seed, elastic=elastic, ckpt=ckpt, prefetch=prefetch)


def _record_visited(trainer, sink):
    """Capture the first feature column of every batch the engine steps on
    (consumed samples — prefetch pull-ahead that a resize drops must NOT
    appear)."""
    orig = trainer.engine.step

    def step(state, batch, lr):
        sink.append(np.asarray(batch["x"][:, 0]).copy())
        return orig(state, batch, lr)

    trainer.engine.step = step


# ---------------------------------------------------------------------------
# satellite: the oracle registry fix
# ---------------------------------------------------------------------------


class TestOracleRegistry:
    def test_oracle_maps_to_its_own_class(self):
        p = make_policy("oracle", m0=128, m_max=2048, delta=0.1,
                        dataset_size=50_000, granule=16)
        assert type(p) is OracleDiveBatch
        assert isinstance(p, DiveBatch)  # same resize rule
        assert p.on_epoch_end(0, 0.05).reason == "oracle"

    def test_divebatch_is_not_oracle(self):
        p = make_policy("divebatch", m0=128, m_max=2048, delta=0.1,
                        dataset_size=50_000)
        assert type(p) is DiveBatch
        assert p.on_epoch_end(0, 0.05).reason == "divebatch"

    def test_same_rule_same_schedule(self):
        kw = dict(m0=128, m_max=2048, delta=0.1, dataset_size=50_000, granule=16)
        a, b = make_policy("divebatch", **kw), make_policy("oracle", **kw)
        for d in (0.05, 0.2, 0.01):
            assert a.on_epoch_end(0, d).batch_size == b.on_epoch_end(0, d).batch_size


# ---------------------------------------------------------------------------
# golden equivalence: adapt program == legacy controller shim, bit-identical
# ---------------------------------------------------------------------------


class TestGoldenEquivalence:
    def _run_pair(self, legacy, program, epochs=4, estimator="exact"):
        t_old = _trainer(legacy, estimator=estimator)
        h_old = t_old.run(epochs, verbose=False)
        t_new = _trainer(program, estimator=estimator)
        h_new = t_new.run(epochs, verbose=False)
        assert [h.batch_size for h in h_old] == [h.batch_size for h in h_new]
        assert [h.lr for h in h_old] == [h.lr for h in h_new]
        assert [h.train_loss for h in h_old] == [h.train_loss for h in h_new]
        for a, b in zip(jax.tree.leaves(t_old.state.params),
                        jax.tree.leaves(t_new.state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_divebatch(self):
        legacy = AdaptiveBatchController(
            make_policy("divebatch", m0=32, m_max=256, delta=0.08,
                        dataset_size=N, granule=16),
            base_lr=0.5,
        )
        program = AdaptationProgram(
            DiveBatchPolicy(m0=32, m_max=256, delta=0.08, dataset_size=N,
                            granule=16),
            base_lr=0.5, estimator="exact",
        )
        self._run_pair(legacy, program)

    def test_adabatch_with_lr_coupling(self):
        legacy = AdaptiveBatchController(
            make_policy("adabatch", m0=32, m_max=256, resize_freq=2, granule=16),
            base_lr=0.5, lr_rule="linear", lr_schedule=step_decay(0.5, 3),
        )
        program = AdaptationProgram(
            AdaBatchPolicy(m0=32, m_max=256, resize_freq=2, granule=16),
            base_lr=0.5,
            coupling=LrCoupling.linear(decay=step_decay(0.5, 3)),
        )
        self._run_pair(legacy, program, estimator="none")


# ---------------------------------------------------------------------------
# mid-epoch decisions: resize + reshard between steps, exact loader cursor
# ---------------------------------------------------------------------------


class ScriptedGrow(PolicyBase):
    """Resize to ``target`` on the first tick/event; hold otherwise."""

    def __init__(self, m0, target, **flags):
        super().__init__(**flags)
        self.m = m0
        self.target = target
        self.fired = False

    def _decide(self, signals, clock):
        if clock.boundary in ("tick", "event") and not self.fired:
            self.fired = True
            self.m = self.target
            return Decision(batch_size=self.m, reason="scripted")
        return None

    @property
    def batch_size(self):
        return self.m

    def set_batch_size(self, m):
        self.m = int(m)

    def state_dict(self):
        return {"m": self.m, "fired": self.fired}

    def load_state_dict(self, state):
        self.m, self.fired = int(state["m"]), bool(state["fired"])


@pytest.mark.parametrize("prefetch", [True, "thread", False])
def test_mid_epoch_tick_resize_visits_same_sample_multiset(prefetch):
    """A tick-fired mid-epoch resize (16 -> 64 after 4 steps) must reshard
    onto the wider rung BETWEEN steps and continue the epoch's permutation
    exactly: the visited-sample multiset equals the boundary-only run's."""
    visited_mid, visited_ref = [], []
    data = _pow2_data()

    t_mid = _trainer(ScriptedGrow(16, 64, on_tick=True),
                     estimator="none", elastic=MeshLadder(granule=16),
                     tick_every=4, prefetch=prefetch, data=data)
    _record_visited(t_mid, visited_mid)
    start_rung = t_mid.rung.index
    t_mid.run(1, verbose=False)

    t_ref = _trainer(FixedPolicy(16), estimator="none",
                     elastic=MeshLadder(granule=16), prefetch=prefetch,
                     data=data)
    _record_visited(t_ref, visited_ref)
    t_ref.run(1, verbose=False)

    # the resize actually happened mid-epoch, on the rung ladder
    assert t_mid.engine.stats.reshards == 1
    assert t_mid.rung.index > start_rung
    sizes = [len(v) for v in visited_mid]
    assert sizes[0] == 16 and sizes[-1] == 64  # both sizes ran this epoch
    assert sum(sizes) == N  # full epoch coverage despite the switch
    # the tick decision is on the program history with its boundary kind
    mid = [a for a in t_mid.adapt.history if a.boundary == "tick"]
    assert len(mid) == 1 and mid[0].batch_size == 64 and mid[0].rescaled

    # THE acceptance property: identical visited-sample multiset
    np.testing.assert_array_equal(
        np.sort(np.concatenate(visited_mid)),
        np.sort(np.concatenate(visited_ref)),
    )


def test_injected_watchdog_event_resizes_between_steps():
    """An injected event (the supervisor Watchdog path) fires the policy at
    an 'event' boundary between steps: batch + rung change mid-epoch."""
    t = _trainer(ScriptedGrow(16, 128, on_event=True), estimator="none",
                 elastic=MeshLadder(granule=16), data=_pow2_data())
    visited = []
    _record_visited(t, visited)
    t.inject_event("straggler")
    t.run(1, verbose=False)
    assert t.engine.stats.reshards == 1
    ev = [a for a in t.adapt.history if a.boundary == "event"]
    assert len(ev) == 1 and ev[0].batch_size == 128 and ev[0].rescaled
    sizes = [len(v) for v in visited]
    assert sizes[0] == 16 and sizes[-1] == 128
    assert sum(sizes) == N  # permutation coverage preserved
    # (bucket, rung) cache: both segments compiled on their own rung
    stats = t.engine.stats
    assert set(zip(stats.buckets, stats.rungs)) == {(16, 0), (128, 3)}


def test_divebatch_on_tick_same_multiset_as_epoch_only():
    """A real (non-scripted) DiveBatch firing on ticks mid-epoch keeps full
    permutation coverage: every visited multiset equals the fixed-size
    run's, for any sequence of phase-aligned lattice resizes."""
    visited_tick, visited_ref = [], []
    data = _pow2_data()
    t_tick = _trainer(
        DiveBatchPolicy(m0=16, m_max=256, delta=0.08, dataset_size=N,
                        granule=16, on_tick=True),
        estimator="exact", tick_every=8, data=data,
    )
    _record_visited(t_tick, visited_tick)
    t_tick.run(2, verbose=False)
    assert any(a.boundary == "tick" and a.rescaled for a in t_tick.adapt.history)

    t_ref = _trainer(FixedPolicy(16, 256), estimator="none", data=data)
    _record_visited(t_ref, visited_ref)
    t_ref.run(1, verbose=False)
    # epoch 0 of the tick run covers the same multiset as a fixed epoch 0
    epoch0 = [v for v in visited_tick]
    total = 0
    cut = 0
    for cut, v in enumerate(epoch0):
        total += len(v)
        if total == N:
            break
    np.testing.assert_array_equal(
        np.sort(np.concatenate(epoch0[: cut + 1])),
        np.sort(np.concatenate(visited_ref)),
    )


class ScriptedRungMove(PolicyBase):
    """Emit an explicit-rung Decision (batch unchanged) on the first event."""

    def __init__(self, m0, rung):
        super().__init__(on_event=True)
        self.m = m0
        self.rung = rung
        self.fired = False

    def _decide(self, signals, clock):
        if clock.boundary == "event" and not self.fired:
            self.fired = True
            return Decision(rung=self.rung, reason="evacuate")
        return None

    @property
    def batch_size(self):
        return self.m

    def set_batch_size(self, m):
        self.m = int(m)


def test_explicit_rung_decision_rebuilds_feed_mid_epoch():
    """A Decision carrying only a rung (straggler evacuation) must reshard
    AND rebuild the prefetch feed: buffered batches were device_put on the
    old rung's plan and must not reach the resharded step."""
    t = _trainer(ScriptedRungMove(128, rung=0), estimator="none",
                 elastic=MeshLadder(granule=16), data=_pow2_data(),
                 prefetch=True)
    visited = []
    _record_visited(t, visited)
    assert t.rung.index == 3  # batch 128 starts on the widest rung
    t.inject_event("straggler")
    t.run(1, verbose=False)
    assert t.engine.stats.reshards == 1
    assert t.rung.index == 0  # evacuated to the narrowest rung mid-epoch
    ev = [a for a in t.adapt.history if a.boundary == "event"]
    assert len(ev) == 1 and ev[0].rung == 0 and not ev[0].rescaled
    assert sum(len(v) for v in visited) == N  # coverage unaffected
    # both rungs compiled for the same bucket (the evacuation is mid-epoch)
    assert set(zip(t.engine.stats.buckets, t.engine.stats.rungs)) == \
        {(128, 3), (128, 0)}


def test_epoch_only_policy_pays_no_tick_reads(monkeypatch):
    """--tick-every with a policy that cannot fire on ticks (AdaBatch) must
    not pay a per-tick device read/sync."""
    import repro.train.loop as loop_mod

    calls = []
    real = loop_mod.read_signals

    def counting(*a, **kw):
        calls.append(kw.get("event"))
        return real(*a, **kw)

    monkeypatch.setattr(loop_mod, "read_signals", counting)
    t = _trainer(AdaBatchPolicy(m0=32, m_max=256, resize_freq=2, granule=16),
                 estimator="none", tick_every=4)
    t.run(2, verbose=False)
    assert calls == []  # no mid-epoch reads, no epoch reads (no diversity)


def test_dropped_event_does_not_swallow_coincident_tick():
    """An injected event the policy cannot fire on is dropped (logged, not
    silent) and must NOT claim the boundary from a due tick."""
    t = _trainer(ScriptedGrow(16, 64, on_tick=True),  # on_event=False
                 estimator="none", tick_every=4, data=_pow2_data())
    t.inject_event("straggler")  # dropped at step 1: policy is tick-only
    t.run(1, verbose=False)
    assert [a.boundary for a in t.adapt.history if a.boundary != "epoch"] \
        == ["tick"]
    assert t.adapt.batch_size == 64  # the tick still fired and resized


def test_hysteresis_set_batch_size_syncs_held():
    stub = _RawStub(m0=64)
    hys = Hysteresis(stub, band=0.1)
    clock = Clock(epoch=0, step=0, boundary="tick")
    stub.next_raw = 512.0
    assert hys.observe(Signals(), clock).batch_size == 512
    hys.set_batch_size(128)  # Switch handover / Chain write-back path
    assert hys.batch_size == 128 and stub.m == 128


def test_deferred_resize_defers_coupled_lr():
    """A linear-coupled grow decided off phase must keep the OLD lr on the
    remaining old-size steps and land the rescaled lr exactly with the new
    batch (the lr was scaled FOR that batch)."""
    lrs_per_step = []

    prog = AdaptationProgram(
        ScriptedGrow(16, 64, on_tick=True), base_lr=0.5,
        coupling=LrCoupling.linear(), tick_every=3, estimator="moment",
    )
    t = _trainer(prog, estimator="none", data=_pow2_data())
    orig = t.engine.step

    def step(state, batch, lr):
        lrs_per_step.append((len(np.asarray(batch["x"])), float(lr)))
        return orig(state, batch, lr)

    t.engine.step = step
    t.run(1, verbose=False)
    # decided at step 3 (consumed 48, not % 64): steps 4 stays (16, 0.5);
    # the switch lands at consumed 64 with the rescaled lr
    for size, lr in lrs_per_step:
        assert (size, lr) in ((16, 0.5), (64, 2.0)), lrs_per_step
    assert (16, 0.5) in lrs_per_step and (64, 2.0) in lrs_per_step
    assert lrs_per_step[3] == (16, 0.5)  # the off-phase step kept the old lr


def test_mid_epoch_decision_changes_lr_immediately():
    """A tick decision's lr coupling applies to the very next step, not the
    next epoch."""
    prog = AdaptationProgram(
        ScriptedGrow(16, 64, on_tick=True), base_lr=0.5,
        coupling=LrCoupling.linear(), tick_every=4, estimator="moment",
    )
    t = _trainer(prog, estimator="none")
    t.run(1, verbose=False)
    tick = [a for a in prog.history if a.boundary == "tick"][0]
    assert tick.lr == pytest.approx(0.5 * 64 / 16)
    assert prog.lr == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# checkpoint schema: v1 (pre-redesign) restores; v2 round-trips
# ---------------------------------------------------------------------------


class TestCheckpointSchemas:
    def test_shim_loads_v1_controller_dict(self):
        c = AdaptiveBatchController(
            make_policy("divebatch", m0=64, m_max=1024, delta=0.1,
                        dataset_size=N, granule=16),
            base_lr=0.5, lr_rule="linear",
        )
        v1 = {  # exactly what the pre-redesign controller emitted
            "policy": {"m": 256},
            "lr": 0.125,
            "epoch": 5,
            "history": [
                {"epoch": 4, "batch_size": 256, "lr": 0.125, "diversity": 0.03,
                 "raw_batch_size": 245.8, "rescaled": True},
            ],
        }
        c.load_state_dict(v1)
        assert c.batch_size == 256 and c.lr == 0.125 and c.epoch == 5
        assert len(c.history) == 1 and c.history[0].raw_batch_size == 245.8
        # and keeps adapting from the restored state
        assert c.on_epoch_end(0.05).epoch == 5

    def test_trainer_restores_pre_redesign_checkpoint(self, tmp_path):
        """A full checkpoint whose extra.json carries the v1 controller dict
        and a v1 cursor (no sample_index) must resume with the identical
        remaining trajectory."""

        def build(mgr):
            return _trainer(
                AdaptiveBatchController(
                    make_policy("divebatch", m0=32, m_max=256, delta=0.08,
                                dataset_size=N, granule=16),
                    base_lr=0.5),
                estimator="exact", ckpt=mgr)

        t_full = build(None)
        full = t_full.run(5, verbose=False)

        mgr = CheckpointManager(str(tmp_path / "ck"), keep=2)
        t1 = build(mgr)
        t1.run(3, verbose=False)
        t1.save()

        # rewrite the on-disk extra.json into the pre-redesign (v1) schema
        step_dir = os.path.join(mgr.root, f"step_{mgr.latest_step():010d}")
        with open(os.path.join(step_dir, "extra.json")) as f:
            extra = json.load(f)
        v2 = extra["controller"]
        assert v2["version"] == 2  # what we write today
        extra["controller"] = {
            "policy": v2["policy"],
            "lr": v2["lr"],
            "epoch": v2["epoch"],
            "history": [
                {"epoch": a["epoch"], "batch_size": a["batch_size"],
                 "lr": a["lr"], "diversity": a["diversity"],
                 "raw_batch_size": a["raw_batch_size"],
                 "rescaled": a["rescaled"]}
                for a in v2["history"]
            ],
        }
        del extra["cursor"]["sample_index"]  # v1 cursors had no such field
        with open(os.path.join(step_dir, "extra.json"), "w") as f:
            json.dump(extra, f)

        t2 = build(mgr)
        assert t2.resume()
        resumed = t2.run(2, verbose=False)[3:]
        np.testing.assert_allclose([h.val_loss for h in full[3:]],
                                   [h.val_loss for h in resumed], rtol=1e-5)
        assert [h.batch_size for h in full[3:]] == [h.batch_size for h in resumed]

    def test_program_v2_roundtrip_with_combinators(self):
        def make():
            return AdaptationProgram(
                Hysteresis(GradNoisePolicy(32, 512, granule=16, alpha=0.5),
                           band=0.1),
                base_lr=1.0, coupling=LrCoupling.sqrt(), tick_every=4,
            )

        p1 = make()
        p1.observe(Signals(gns=200.0, batch_size=32),
                   Clock(epoch=0, step=4, boundary="tick"))
        p1.observe(Signals(diversity=0.1, gns=180.0, batch_size=p1.batch_size),
                   Clock(epoch=0, step=8, boundary="epoch"))
        state = p1.state_dict()
        assert state["version"] == 2
        p2 = make()
        p2.load_state_dict(json.loads(json.dumps(state)))  # JSON-clean
        assert p2.batch_size == p1.batch_size
        assert p2.lr == p1.lr and p2.epoch == p1.epoch
        assert len(p2.history) == len(p1.history)
        assert p2.history[0].boundary == "tick"


# ---------------------------------------------------------------------------
# hysteresis: the no-flap property
# ---------------------------------------------------------------------------


class _RawStub:
    """Inner policy emitting a pre-set raw target each observation."""

    def __init__(self, m0=64, granule=16, m_max=8192):
        self.m = m0
        self.granule = granule
        self.m_max = m_max
        self.next_raw = float(m0)
        self.needs_diversity = False

    def fires(self, clock):
        return True

    def observe(self, signals, clock):
        self.m = bucket(int(max(self.next_raw, 1)), self.granule,
                        m_max=self.m_max)
        return Decision(batch_size=self.m, raw_batch_size=self.next_raw)

    @property
    def batch_size(self):
        return self.m

    def set_batch_size(self, m):
        self.m = int(m)

    def state_dict(self):
        return {"m": self.m}

    def load_state_dict(self, state):
        self.m = int(state["m"])


class TestHysteresis:
    @given(
        r0=st.floats(20.0, 4000.0),
        band=st.sampled_from([0.05, 0.1, 0.2]),
        seed=st.integers(0, 10_000),
    )
    @settings(max_examples=60, deadline=None)
    def test_never_flaps_within_band(self, r0, band, seed):
        """For ANY raw-estimate walk whose consecutive ratio stays within
        [1/(1+band), 1+band] (the jitter the band is sized for), the held
        schedule must never go A -> B -> A across consecutive boundaries."""
        rng = np.random.default_rng(seed)
        stub = _RawStub()
        hys = Hysteresis(stub, band=band)
        clock = Clock(epoch=0, step=0, boundary="tick")
        held, r = [], float(r0)
        for _ in range(60):
            stub.next_raw = r
            d = hys.observe(Signals(), clock)
            held.append(d.batch_size)
            r *= float(rng.uniform(1.0 / (1.0 + band), 1.0 + band))
        for a, b, c in zip(held, held[1:], held[2:]):
            assert not (b != a and c == a), (a, b, c, held)

    def test_band_zero_passes_everything_through(self):
        stub = _RawStub()
        hys = Hysteresis(stub, band=0.0)
        clock = Clock(epoch=0, step=0, boundary="tick")
        # raw well past the sqrt(2) rounding threshold: accepted even at 0 band
        stub.next_raw = 64.0
        assert hys.observe(Signals(), clock).batch_size == 64
        stub.next_raw = 256.0
        assert hys.observe(Signals(), clock).batch_size == 256

    def test_within_band_holds_and_syncs_inner(self):
        stub = _RawStub(m0=64)
        hys = Hysteresis(stub, band=0.1)
        clock = Clock(epoch=0, step=0, boundary="tick")
        stub.next_raw = 64.0
        assert hys.observe(Signals(), clock).batch_size == 64
        # 95 buckets to 128 (past 64*sqrt(2)=90.5) but NOT past the band edge
        # 90.5*1.1=99.6 -> held at 64, and the inner policy is written back
        stub.next_raw = 95.0
        d = hys.observe(Signals(), clock)
        assert d.batch_size == 64 and d.reason.endswith("+hold")
        assert stub.m == 64 and hys.batch_size == 64
        # clearing the band edge moves
        stub.next_raw = 101.0
        assert hys.observe(Signals(), clock).batch_size == 128


# ---------------------------------------------------------------------------
# other combinators
# ---------------------------------------------------------------------------


class TestCombinators:
    def _tick(self, step=0, epoch=0):
        return Clock(epoch=epoch, step=step, boundary="tick")

    def test_warmup_suppresses_until_release(self):
        inner = DiveBatchPolicy(m0=64, m_max=1024, delta=1.0, dataset_size=N,
                                granule=16)
        w = Warmup(inner, epochs=2)
        assert w.observe(Signals(diversity=0.5),
                         Clock(epoch=0, step=10, boundary="epoch")) is None
        assert w.batch_size == 64  # untouched during warmup
        d = w.observe(Signals(diversity=0.5),
                      Clock(epoch=2, step=30, boundary="epoch"))
        assert d is not None and d.batch_size > 64

    def test_warmup_inside_program_still_advances_epochs(self):
        prog = AdaptationProgram(
            Warmup(FixedPolicy(32), epochs=3), base_lr=1.0,
            coupling=LrCoupling(decay=step_decay(0.5, 1)),
        )
        prog.observe(Signals(), Clock(epoch=0, step=1, boundary="epoch"))
        assert prog.epoch == 1 and prog.lr == 0.5  # background decay ran

    def test_clamped_bounds_and_syncs_inner(self):
        inner = _RawStub(m0=64)
        c = Clamped(inner, m_min=32, m_max=128)
        inner.next_raw = 4096.0
        d = c.observe(Signals(), self._tick())
        assert d.batch_size == 128 and inner.m == 128
        inner.next_raw = 4.0
        d = c.observe(Signals(), self._tick())
        assert d.batch_size == 32 and inner.m == 32

    def test_chain_merges_first_non_none_fields(self):
        class LrOnly(PolicyBase):
            def __init__(self):
                super().__init__(on_tick=True)

            def _decide(self, signals, clock):
                return Decision(lr=0.01, reason="lr")

            batch_size = property(lambda self: 0)

            def set_batch_size(self, m):
                pass

        batch = _RawStub(m0=64)
        batch.next_raw = 256.0
        chain = Chain(batch, LrOnly())
        d = chain.observe(Signals(), self._tick())
        assert d.batch_size == 256 and d.lr == 0.01
        assert "lr" in d.reason
        assert chain.batch_size == 256
        assert chain.needs_diversity is False

    def test_switch_hands_over_batch_size(self):
        a, b = FixedPolicy(32), FixedPolicy(512)
        sw = Switch.at_epochs([2], [a, b])
        d = sw.observe(Signals(), Clock(epoch=0, step=0, boundary="epoch"))
        assert d.batch_size == 32
        # at the handover the incoming policy inherits the live size: a
        # FixedBatch keeps whatever it holds, so no teleport to 512
        d = sw.observe(Signals(), Clock(epoch=2, step=0, boundary="epoch"))
        assert d.batch_size == 32 and sw.batch_size == 32

    def test_lr_coupling_rules(self):
        assert LrCoupling.linear().rescale(0.1, 128, 256) == pytest.approx(0.2)
        assert LrCoupling.sqrt().rescale(0.1, 128, 512) == pytest.approx(0.2)
        assert LrCoupling().rescale(0.1, 128, 512) == pytest.approx(0.1)
        with pytest.raises(ValueError, match="rule"):
            LrCoupling(rule="cubic")


# ---------------------------------------------------------------------------
# signals: the GNS proxy and the single-transfer read
# ---------------------------------------------------------------------------


class TestSignals:
    def test_gns_zero_for_identical_gradients(self):
        """All samples sharing one gradient direction => tr(Sigma) ~ 0."""
        g = {"w": jnp.ones(8)}
        st_ = diversity.init_state(g)
        for _ in range(4):
            st_ = diversity.accumulate(st_, g, 16)  # moment tier statistic
        gns = float(gns_from_accumulators(st_, "moment"))
        assert gns == pytest.approx(0.0, abs=1e-3)

    def test_gns_large_for_zero_mean_noise(self):
        rng = np.random.default_rng(0)
        st_ = diversity.init_state({"w": jnp.zeros(64)})
        for _ in range(8):
            mean_g = {"w": jnp.asarray(
                rng.standard_normal(64).astype(np.float32) / np.sqrt(16))}
            st_ = diversity.accumulate(st_, mean_g, 16)
        gns = float(gns_from_accumulators(st_, "moment"))
        assert gns > 10.0  # noise-dominated: critical batch >> 1

    def test_empty_accumulators_are_degenerate_zero(self):
        st_ = diversity.init_state({"w": jnp.zeros(4)})
        assert float(gns_from_accumulators(st_, "moment")) == 0.0

    def test_read_signals_reset_semantics(self):
        params = {"w": jnp.ones(8)}
        state = init_state(params, sgd())
        state = state._replace(
            div_state=diversity.accumulate(state.div_state, params, 16))
        sig, kept = read_signals(state, "moment", reset=False, batch_size=16)
        assert sig.samples == 16.0 and sig.batch_size == 16
        assert float(kept.div_state.sample_count) == 16.0  # untouched
        sig2, reset = read_signals(kept, "moment", reset=True)
        assert sig2.samples == 16.0
        assert float(reset.div_state.sample_count) == 0.0

    def test_clock_rejects_unknown_boundary(self):
        with pytest.raises(ValueError, match="boundary"):
            Clock(epoch=0, step=0, boundary="sometimes")


# ---------------------------------------------------------------------------
# gradient-noise policy end to end + estimator-tier decisions
# ---------------------------------------------------------------------------


def test_gradnoise_policy_trains_on_lattice():
    t = _trainer(GradNoisePolicy(16, 256, granule=16, alpha=0.25, ema=0.3),
                 estimator="moment")
    hist = t.run(3, verbose=False)
    lattice = {16 * 2 ** i for i in range(5)}
    assert all(h.batch_size in lattice for h in hist)
    assert all(np.isfinite(h.val_loss) for h in hist)
    assert t.engine.stats.compiles <= t.adapt.compile_bound


def test_decision_estimator_switches_tier_mid_run():
    class TierSwitch(PolicyBase):
        def _decide(self, signals, clock):
            if clock.epoch == 1:
                return Decision(estimator="moment", reason="tier")
            return None

        batch_size = property(lambda self: 32)

        def set_batch_size(self, m):
            pass

        @property
        def needs_diversity(self):
            return True

    t = _trainer(Chain(DiveBatchPolicy(32, 256, 0.08, N, granule=16),
                       TierSwitch()), estimator="exact")
    hist = t.run(3, verbose=False)
    assert t.estimator == "moment"
    assert t.adapt.estimator == "moment"
    assert all(np.isfinite(h.val_loss) for h in hist)


# ---------------------------------------------------------------------------
# satellite: threaded prefetch (host-side gather overlap)
# ---------------------------------------------------------------------------


class TestThreadedPrefetch:
    def test_trainer_trajectory_bit_identical(self):
        t_thr = _trainer(DiveBatchPolicy(32, 256, 0.08, N, granule=16),
                         estimator="exact", prefetch="thread")
        h_thr = t_thr.run(3, verbose=False)
        t_sync = _trainer(DiveBatchPolicy(32, 256, 0.08, N, granule=16),
                          estimator="exact", prefetch=False)
        h_sync = t_sync.run(3, verbose=False)
        assert [h.batch_size for h in h_thr] == [h.batch_size for h in h_sync]
        assert [h.train_loss for h in h_thr] == [h.train_loss for h in h_sync]
        for a, b in zip(jax.tree.leaves(t_thr.state.params),
                        jax.tree.leaves(t_sync.state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_order_and_exception_propagation(self):
        from repro.data import prefetch

        out = list(prefetch(range(7), put=lambda b: b * 2, host_overlap=True))
        assert out == [0, 2, 4, 6, 8, 10, 12]

        def boom(b):
            if b == 3:
                raise RuntimeError("gather failed")
            return b

        gen = prefetch(range(5), put=boom, host_overlap=True)
        with pytest.raises(RuntimeError, match="gather failed"):
            list(gen)

    def test_early_close_stops_producer(self):
        import threading

        from repro.data import prefetch

        before = threading.active_count()
        gen = prefetch(range(10_000), put=lambda b: b, host_overlap=True)
        assert next(gen) == 0
        gen.close()  # the mid-epoch-resize path abandons the feed like this
        assert threading.active_count() <= before + 1

    def test_invalid_trainer_prefetch_mode_rejected(self):
        with pytest.raises(ValueError, match="prefetch"):
            _trainer(FixedPolicy(32), estimator="none", prefetch="turbo")


# ---------------------------------------------------------------------------
# loader: start_sample continuity (the cursor unit mid-epoch resize needs)
# ---------------------------------------------------------------------------


class TestLoaderStartSample:
    @staticmethod
    def _ds(n=512):
        from repro.data import ArrayDataset

        return ArrayDataset({"x": np.arange(n, dtype=np.float32).reshape(n, 1)})

    def test_mixed_sizes_tile_the_permutation(self):
        from repro.data import EpochLoader, epoch_permutation

        train = self._ds(512)
        a = list(EpochLoader(train, 16, epoch=1, seed=9))[:4]  # 64 samples
        b = list(EpochLoader(train, 64, epoch=1, seed=9, start_sample=64))
        perm = epoch_permutation(512, 9, 1)
        ref = train.get(perm)["x"][:, 0]
        got = np.concatenate([v["x"][:, 0] for v in a + b])
        np.testing.assert_array_equal(got, ref)

    def test_default_matches_start_batch(self):
        from repro.data import EpochLoader

        train = self._ds(512)
        via_batch = list(EpochLoader(train, 32, epoch=0, seed=1, start_batch=3))
        via_sample = list(EpochLoader(train, 32, epoch=0, seed=1, start_sample=96))
        assert len(via_batch) == len(via_sample)
        for x, y in zip(via_batch, via_sample):
            np.testing.assert_array_equal(x["x"], y["x"])


# ---------------------------------------------------------------------------
# shim surface: FromBatchPolicy passthrough
# ---------------------------------------------------------------------------


def test_from_batch_policy_state_dict_is_legacy_schema():
    p = FromBatchPolicy(make_policy("divebatch", m0=64, m_max=512, delta=0.1,
                                    dataset_size=N, granule=16))
    assert p.state_dict() == {"m": 64}  # byte-compatible with v1 checkpoints
    p.load_state_dict({"m": 128})
    assert p.batch_size == 128 and p.inner.m == 128
    assert p.needs_diversity and p.max_buckets == p.inner.max_buckets


# ---------------------------------------------------------------------------
# satellite: windowed throughput (Signals.throughput / ServeStats reuse)
# ---------------------------------------------------------------------------


class TestThroughputWindow:
    def test_partial_window_divides_by_elapsed(self):
        from repro.adapt import ThroughputWindow

        w = ThroughputWindow(window_s=10.0, clock=lambda: 0.0)
        assert w.rate(now=0.0) is None  # nothing measured yet
        # a zero-span burst has no measurable elapsed time: charge the full
        # window — a finite conservative lower bound, not None/inf (the old
        # code answered None, as if the burst never happened)
        w.add(5, now=0.0)
        assert w.rate(now=0.0) == pytest.approx(0.5)
        w.add(5, now=5.0)
        # 10 events over the 5 s elapsed so far — NOT diluted over the
        # still-unfilled 10 s window
        assert w.rate(now=5.0) == pytest.approx(2.0)

    def test_old_events_fall_out_of_the_window(self):
        from repro.adapt import ThroughputWindow

        w = ThroughputWindow(window_s=10.0, clock=lambda: 0.0)
        w.add(5, now=0.0)
        w.add(5, now=5.0)
        # the trailing window is the CLOSED interval [0, 10]: the sample
        # exactly window_s old still counts — the denominator charges those
        # 10 seconds, so dropping the sample (the old <=) deflated the rate
        assert w.rate(now=10.0) == pytest.approx(1.0)
        # a straggler stall shows up as a collapsing rate
        assert w.rate(now=14.9) == pytest.approx(0.5)
        assert w.rate(now=20.0) == pytest.approx(0.0)

    def test_window_edge_is_inclusive(self):
        from repro.adapt import ThroughputWindow

        w = ThroughputWindow(window_s=4.0, clock=lambda: 0.0)
        w.add(8, now=0.0)
        # exactly window_s old: inside the closed window
        assert w.rate(now=4.0) == pytest.approx(2.0)
        # a hair past: evicted
        assert w.rate(now=4.001) == pytest.approx(0.0)

    def test_counts_accumulate_within_the_window(self):
        from repro.adapt import ThroughputWindow

        w = ThroughputWindow(window_s=4.0, clock=lambda: 0.0)
        for t in range(8):
            w.add(2, now=float(t))
        # closed window [3, 7]: samples at t=3,4,5,6,7 -> 10 events / 4 s
        assert w.rate(now=7.0) == pytest.approx(2.5)

    def test_bad_window_raises(self):
        from repro.adapt import ThroughputWindow

        with pytest.raises(ValueError, match="window_s"):
            ThroughputWindow(window_s=0.0)

    def test_trainer_signals_carry_windowed_rate(self):
        """Signals.throughput comes from the Trainer's ThroughputWindow (a
        positive recent rate after any steps), not a None placeholder."""
        seen = []

        class Rec(PolicyBase):
            def _decide(self, signals, clock):
                seen.append(signals.throughput)
                return None

            batch_size = property(lambda self: 32)

            def set_batch_size(self, m):
                pass

        t = _trainer(Rec(), estimator="none")
        t.run(2, verbose=False)
        assert len(seen) == 2
        assert all(isinstance(x, float) and x > 0 for x in seen)
        assert t._thru.rate() is not None
