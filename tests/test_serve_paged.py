"""Paged serving: chunked prefill golden vs the re-prefill oracle, prefix
sharing (the shared-system-prompt case costs ONE prefill), pool-footprint
scaling, pool-gated admission, and the PR 6 bugfix satellites (shrink-streak
reset on drain, cfg.attn_impl honored in prefill, decode budget from the
TRUE prompt length)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.serve import Request, ServeEngine, padded_prompt_len

MAX_SEQ = 64
GRANULE = 8


def _cfg(**kw):
    base = dict(
        name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=2, d_ff=64, vocab_size=61, pattern=("attn",),
        param_dtype="float32", compute_dtype="float32", xent_chunk=8,
        remat=False,
    )
    base.update(kw)
    return ModelConfig(**base)


CFG = _cfg()
PARAMS = tf.init_params(CFG, jax.random.key(0))


def _oracle(cfg, params, req, max_seq=MAX_SEQ, granule=GRANULE):
    """Greedy re-prefill reference with the satellite-3 budget semantics:
    headroom from the TRUE prompt length (padding costs table entries in the
    paged layout, not decode budget)."""
    prompt = np.asarray(req.prompt, np.int32)
    plen = padded_prompt_len(len(prompt), granule)
    seq = np.zeros(plen, np.int32)
    seq[plen - len(prompt):] = prompt
    seq = list(seq)
    budget = min(req.max_new_tokens, max_seq - len(prompt) + 1)
    pref = jax.jit(lambda p, b: tf.prefill_step(cfg, p, b)[0])
    out = []
    while len(out) < budget:
        logits = pref(params, {"tokens": jnp.asarray(np.asarray(seq)[None])})
        out.append(int(jnp.argmax(logits[0, -1])))
        if req.eos_id is not None and out[-1] == req.eos_id:
            break
        seq.append(out[-1])
    return out


def _decode_oracle(cfg, params, req, max_seq=MAX_SEQ, granule=GRANULE):
    """Token-by-token decode_step reference (mamba's chunked prefill scan
    needs chunk-multiple lengths, so hybrid configs are checked against the
    scalar recurrence instead of re-prefill)."""
    prompt = np.asarray(req.prompt, np.int32)
    plen = padded_prompt_len(len(prompt), granule)
    padded = np.zeros(plen, np.int32)
    padded[plen - len(prompt):] = prompt
    budget = min(req.max_new_tokens, max_seq - len(prompt) + 1)
    cache = tf.init_cache(cfg, 1, max_seq)
    dec = jax.jit(lambda p, c, t: tf.decode_step(cfg, p, c, t))
    logits = None
    for t in padded:
        logits, cache = dec(params, cache, jnp.asarray([[int(t)]], jnp.int32))
    out = [int(jnp.argmax(logits[0, -1]))]
    while len(out) < budget:
        logits, cache = dec(params, cache,
                            jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def _tokens(results):
    return [r.tokens.tolist() for r in results]


def _reqs(lens, max_new, seed=7, shared_prefix=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, CFG.vocab_size, size=shared_prefix).astype(np.int32)
    out = []
    for n, m in zip(lens, max_new):
        tail = rng.integers(1, CFG.vocab_size, size=n - shared_prefix)
        out.append(Request(
            prompt=np.concatenate([prefix, tail.astype(np.int32)]),
            max_new_tokens=m,
        ))
    return out


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------


def test_chunked_prefill_matches_oracle():
    reqs = _reqs([20, 27, 12], [8, 6, 8])
    eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, prefill_chunk=8)
    assert _tokens(eng.generate(reqs)) == [_oracle(CFG, PARAMS, r) for r in reqs]
    # plens 32, 32, 16 at chunk 8 -> 4 + 4 + 2 chunk programs executed
    assert eng.stats.prefill_chunks == 10
    assert eng.stats.prefills == 3
    # paging adds ZERO decode compile keys (pool shape is engine-lifetime)
    st = eng.stats
    assert st.compiles == len(set(zip(st.buckets, st.rungs)))


def test_chunk_boundaries_interleave_with_decode():
    """A long prompt loads one chunk per boundary; the already-running
    request keeps decoding every one of those boundaries."""
    short, long = _reqs([4, 30], [12, 4], seed=3)
    eng = ServeEngine(CFG, PARAMS, max_slots=2, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, prefill_chunk=8)
    r0 = eng.submit(short)
    eng.step()  # prefill short (token 1) + decode (token 2)
    r1 = eng.submit(long)  # plen 32 -> 4 chunks -> 4 boundaries to load
    for k in range(3):
        grew = len(eng.sched._tokens[r0])
        eng.step()
        assert len(eng._jobs) == 1  # still loading...
        assert len(eng.sched._tokens[r0]) == grew + 1  # ...but decode ran
        assert len(eng.sched._tokens[r1]) == 0
    eng.step()  # final chunk: token 1 (prefill) + token 2 (same-boundary decode)
    assert len(eng._jobs) == 0 and len(eng.sched._tokens[r1]) == 2
    eng.drain()
    assert [eng.result(r).tokens.tolist() for r in (r0, r1)] == \
        [_oracle(CFG, PARAMS, r) for r in (short, long)]
    assert eng.stats.prefill_chunks == 1 + 4


# ---------------------------------------------------------------------------
# prefix sharing
# ---------------------------------------------------------------------------


def test_shared_full_prompt_costs_one_prefill():
    """N requests with the same prompt: one prefill total — later arrivals
    replay the cached end-of-prompt state (instant admission)."""
    first = _reqs([12], [6], seed=5)[0]
    eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE)
    base = _tokens(eng.generate([first]))[0]
    assert eng.stats.prefill_chunks == 1
    again = [Request(prompt=first.prompt.copy(), max_new_tokens=m)
             for m in (4, 6, 2)]
    got = _tokens(eng.generate(again))
    assert eng.stats.prefill_chunks == 1  # STILL one: zero recompute
    assert eng.stats.shared_prefill_hits == 3
    assert eng.stats.prefills == 4
    assert got == [base[:4], base, base[:2]]  # greedy: same stream, truncated
    assert _tokens(eng.generate([again[0]]))[0] == base[:4]  # survives drains


def test_shared_prefix_prefills_only_the_tail():
    """Same-length prompts sharing a raw prefix share the (pad + prefix)
    blocks; only the divergent tail chunk is computed for the second."""
    a, b = _reqs([24, 24], [5, 5], seed=9, shared_prefix=16)
    assert a.prompt[:16].tolist() == b.prompt[:16].tolist()
    assert a.prompt[16:].tolist() != b.prompt[16:].tolist()
    eng = ServeEngine(CFG, PARAMS, max_slots=2, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, prefill_chunk=8)
    got_a = _tokens(eng.generate([a]))[0]
    assert eng.stats.prefill_chunks == 4  # plen 32
    got_b = _tokens(eng.generate([b]))[0]
    # 8 pad + 16 shared = 3 adopted blocks; only the last chunk runs
    assert eng.stats.prefill_chunks == 5
    assert eng.stats.shared_blocks == 3
    assert got_a == _oracle(CFG, PARAMS, a)
    assert got_b == _oracle(CFG, PARAMS, b)


def test_prefix_sharing_disabled_recomputes():
    first = _reqs([12], [6], seed=5)[0]
    eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, prefix_sharing=False)
    base = _tokens(eng.generate([first]))[0]
    rep = Request(prompt=first.prompt.copy(), max_new_tokens=6)
    assert _tokens(eng.generate([rep]))[0] == base
    assert eng.stats.prefill_chunks == 2  # no sharing: both computed
    assert eng.stats.shared_prefill_hits == 0


def test_hybrid_shared_prompt_replays_ring_and_ssm_state():
    """Non-paged state (windowed ring, SSM) lives in the cached row snapshot
    — a full-prompt hit must replay it bit-exactly."""
    cfg = _cfg(pattern=("attn", "attn_local", "mamba"), num_layers=3,
               window=6, ssm_chunk=8)
    params = tf.init_params(cfg, jax.random.key(4))
    req = _reqs([20], [6], seed=11)[0]
    eng = ServeEngine(cfg, params, max_slots=2, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE)
    base = _tokens(eng.generate([req]))[0]
    assert base == _decode_oracle(cfg, params, req)
    rep = Request(prompt=req.prompt.copy(), max_new_tokens=6)
    assert _tokens(eng.generate([rep]))[0] == base
    assert eng.stats.shared_prefill_hits == 1
    assert eng.stats.prefill_chunks == 1


def test_hybrid_chunked_prefill_matches_whole_prompt():
    """Chunked prefill threads ring rotations and SSM (h, conv) state across
    chunk boundaries: 8-token chunks == whole-prompt prefill == oracle."""
    cfg = _cfg(pattern=("attn", "attn_local", "mamba"), num_layers=3,
               window=6, ssm_chunk=8)
    params = tf.init_params(cfg, jax.random.key(4))
    reqs = _reqs([20, 13], [6, 8], seed=12)
    expected = [_decode_oracle(cfg, params, r) for r in reqs]
    for chunk in (0, 8):
        eng = ServeEngine(cfg, params, max_slots=2, max_seq=MAX_SEQ,
                          prompt_granule=GRANULE, prefill_chunk=chunk)
        assert _tokens(eng.generate(reqs)) == expected, f"chunk={chunk}"


# ---------------------------------------------------------------------------
# pool footprint
# ---------------------------------------------------------------------------


def test_peak_blocks_tracks_resident_tokens():
    """The acceptance bound: peak pool usage scales with tokens actually
    resident, far below the dense max_slots * max_seq preallocation."""
    reqs = _reqs([8, 8, 8, 8], [8, 8, 8, 8], seed=13)
    eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, prefix_sharing=False)
    eng.generate(reqs)
    st = eng.stats
    # 4 concurrent requests x (1 prompt block + 1 decode block)
    assert 4 <= st.peak_blocks <= 8
    assert st.peak_blocks * st.block_size <= (4 * MAX_SEQ) // 4
    assert st.pool_blocks > st.peak_blocks
    eng.pool.check()
    assert eng.pool.live == 0  # zero leaked blocks after drain


def test_small_pool_gates_admission_without_exhaustion():
    """A pool too small for two concurrent requests serializes them through
    the admission gate — never an exhausted pool mid-decode."""
    reqs = _reqs([8, 8], [8, 8], seed=14)
    eng = ServeEngine(CFG, PARAMS, max_slots=2, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, pool_blocks=4,
                      prefix_sharing=False)  # 3 usable; each request needs 2
    assert _tokens(eng.generate(reqs)) == [_oracle(CFG, PARAMS, r) for r in reqs]
    assert eng.stats.peak_blocks <= 3
    eng.pool.check()


def test_single_request_larger_than_pool_raises():
    eng = ServeEngine(CFG, PARAMS, max_slots=2, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, pool_blocks=2)
    with pytest.raises(ValueError, match="pool"):
        eng.submit(_reqs([20], [8])[0])


# ---------------------------------------------------------------------------
# satellite 3: decode budget from the TRUE prompt length
# ---------------------------------------------------------------------------


def test_budget_from_true_prompt_length_near_max_seq():
    """A 60-token prompt pads to plen 64 == max_seq; the padded-length budget
    ``max_seq - plen + 1`` used to truncate it to ONE token.  The paged
    layout charges padding to table entries, so the request keeps
    ``max_seq - 60 + 1 = 5``."""
    req = _reqs([60], [5], seed=15)[0]
    eng = ServeEngine(CFG, PARAMS, max_slots=2, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE)
    got = _tokens(eng.generate([req]))[0]
    assert len(got) == 5
    assert got == _oracle(CFG, PARAMS, req)


def test_budget_boundary_full_length_prompt():
    req = _reqs([64], [9], seed=16)[0]  # no padding: budget == 1
    eng = ServeEngine(CFG, PARAMS, max_slots=2, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE)
    got = _tokens(eng.generate([req]))[0]
    assert len(got) == 1
    assert got == _oracle(CFG, PARAMS, req)
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(_reqs([65], [2], seed=16)[0])


# ---------------------------------------------------------------------------
# satellite 1: shrink streak resets when the engine drains
# ---------------------------------------------------------------------------


def test_shrink_streak_resets_on_drain():
    """Trace A drains mid-streak (a dip was being ridden out when the last
    request retired).  Trace B's first boundaries dip again: the patience
    budget must start FRESH, not inherit trace A's streak and shrink early."""
    eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, shrink_patience=2)
    eng.generate(_reqs([4, 4], [2, 4], seed=17))  # retire at different steps
    assert eng.sched.capacity == 2  # bucket persists across the drain

    eng.submit(_reqs([4], [8], seed=18)[0])  # target 1 < bucket 2: a dip
    for boundary in range(2):
        eng.step()
        assert eng.sched.capacity == 2, f"shrank early at boundary {boundary}"
    eng.step()  # patience exhausted on the THIRD consecutive dip
    assert eng.sched.capacity == 1
    eng.drain()


# ---------------------------------------------------------------------------
# satellite 2: prefill honors cfg.attn_impl
# ---------------------------------------------------------------------------


def test_prefill_honors_attn_impl(monkeypatch):
    """prefill_step used to hardcode the auto heuristic; a pinned
    ``attn_impl='flash'``/'pallas' must actually take that path (and agree
    with dense numerically)."""
    flash_calls, pallas_calls = [], []
    orig_flash = tf.attn_lib.flash_attention
    orig_pallas = tf.kernels_attn.flash_attention

    monkeypatch.setattr(tf.attn_lib, "flash_attention",
                        lambda *a, **kw: (flash_calls.append(1), orig_flash(*a, **kw))[1])
    monkeypatch.setattr(tf.kernels_attn, "flash_attention",
                        lambda *a, **kw: (pallas_calls.append(1), orig_pallas(*a, **kw))[1])
    rng = np.random.default_rng(19)
    batch = {"tokens": jnp.asarray(
        rng.integers(1, CFG.vocab_size, size=(1, 128)).astype(np.int32))}
    out = {}
    for impl in ("dense", "flash", "auto", "pallas"):
        cfg = _cfg(attn_impl=impl, flash_q_block=64, flash_kv_block=64)
        before_f, before_p = len(flash_calls), len(pallas_calls)
        logits, _ = tf.prefill_step(cfg, PARAMS, batch)
        out[impl] = np.asarray(logits)
        # auto picks dense at s=128 (<= FLASH_THRESHOLD); pinned impls obeyed
        assert (len(flash_calls) > before_f) == (impl == "flash"), impl
        assert (len(pallas_calls) > before_p) == (impl == "pallas"), impl
    np.testing.assert_allclose(out["flash"], out["dense"], atol=2e-4, rtol=2e-5)
    np.testing.assert_allclose(out["pallas"], out["dense"], atol=2e-4, rtol=2e-5)
    np.testing.assert_array_equal(out["auto"], out["dense"])


def test_auto_threshold_unified_on_config_constant():
    """Satellite: the auto fork reads ONE constant — choose_attention and
    resolve_impl flip at the same configured threshold."""
    from repro.configs.base import FLASH_THRESHOLD
    from repro.models import attention as attn_lib

    assert attn_lib.choose_attention(FLASH_THRESHOLD, FLASH_THRESHOLD) \
        is not attn_lib.flash_attention  # at threshold: dense
    assert attn_lib.choose_attention(FLASH_THRESHOLD + 1, 1) \
        is attn_lib.flash_attention     # past it: flash
    cfg = _cfg(flash_q_block=8)
    assert attn_lib.resolve_impl(cfg, FLASH_THRESHOLD) == "dense"
    assert attn_lib.resolve_impl(cfg, FLASH_THRESHOLD + 8) == "flash"
    lowered = _cfg(flash_threshold=64, flash_q_block=8)
    assert attn_lib.resolve_impl(lowered, 72) == "flash"
    assert attn_lib.resolve_impl(lowered.replace(attn_impl="pallas"), 8) == "pallas"


# ---------------------------------------------------------------------------
# PR 7: the Pallas kernel lane on the serving hot loop
# ---------------------------------------------------------------------------


def test_pallas_engine_token_identity():
    """attn_impl='pallas' — fused paged-decode attention + Pallas chunked
    prefill — must be TOKEN-IDENTICAL to the re-prefill oracle and to the
    XLA engine on the same workload (the lane is a drop-in, not an
    approximation)."""
    reqs = _reqs([20, 27, 12, 5], [8, 6, 8, 10], seed=23)
    expected = [_oracle(CFG, PARAMS, r) for r in reqs]
    eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, prefill_chunk=8,
                      attn_impl="pallas")
    assert eng.cfg.attn_impl == "pallas"
    assert _tokens(eng.generate(reqs)) == expected
    xla = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, prefill_chunk=8)
    assert _tokens(xla.generate(reqs)) == expected


def test_pallas_engine_softcap_prefix_sharing():
    """The fused lane under attention softcap AND copy-on-write prefix
    sharing: same tokens as the XLA engine, and sharing still skips real
    prefill work."""
    cfg = _cfg(attn_softcap=30.0)
    params = tf.init_params(cfg, jax.random.key(1))
    reqs = _reqs([18, 18, 22], [6, 6, 5], seed=5, shared_prefix=16)
    xla = ServeEngine(cfg, params, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, prefill_chunk=8)
    want = _tokens(xla.generate(reqs))
    eng = ServeEngine(cfg, params, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, prefill_chunk=8,
                      attn_impl="pallas")
    assert _tokens(eng.generate(reqs)) == want
    # sharing accounting is lane-independent: the kernel lane skipped the
    # same prefill work the XLA lane did
    assert eng.stats.shared_prefill_hits == xla.stats.shared_prefill_hits
    assert eng.stats.prefill_chunks == xla.stats.prefill_chunks
