"""serve/policy.py — the ServePolicy observe/decide hook.

Policy-level unit tests on synthetic ServeSignals (ordering semantics of
fifo/priority/fair), scheduler-level property tests that NO admission
ordering can drop or double-assign a request (and that the gated-head rule
survives reordering), engine-level golden lanes (FifoPolicy — the default —
is token-identical to the pre-hook engine against the re-prefill oracle,
dense and paged; priority/fair reorder admissions without perturbing any
request's tokens), the slot-budget / shrink-patience decision plumbing, and
the FREE_RID free-lane sentinel regression.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.adapt.signals import Clock
from repro.serve import (
    FREE_RID,
    FairSharePolicy,
    FifoPolicy,
    PriorityPolicy,
    QueuedRequest,
    Request,
    Scheduler,
    ServeDecision,
    ServeEngine,
    ServePolicy,
    ServeSignals,
    make_serve_policy,
)

# the PR 6 golden lane: same config/params/trace/oracle as the elastic and
# paged golden tests, so "FifoPolicy reproduces the pre-hook engine" is
# pinned against the exact trace those PRs pinned
from test_serve_elastic import (  # noqa: F401
    CFG,
    GRANULE,
    MAX_SEQ,
    PARAMS,
    _oracle,
    _requests,
    _tokens,
)

CLOCK = Clock(epoch=0, step=0, boundary="tick")


def _sig(entries, **kw):
    """ServeSignals with a queue of (rid, tenant, priority) entries."""
    queued = tuple(
        QueuedRequest(rid=r, tenant=t, priority=p, age=0.0, prompt_len=4)
        for r, t, p in entries
    )
    return ServeSignals(queue_depth=len(queued), queued=queued, **kw)


# ---------------------------------------------------------------------------
# policy ordering semantics (pure, no engine)
# ---------------------------------------------------------------------------


def test_registry_and_protocol():
    for name in ("fifo", "priority", "fair"):
        assert isinstance(make_serve_policy(name), ServePolicy)
    with pytest.raises(ValueError, match="unknown serve policy"):
        make_serve_policy("lifo")
    with pytest.raises(ValueError, match="quantum"):
        FairSharePolicy(quantum=0)


def test_fifo_returns_queue_order_and_none_on_empty():
    p = FifoPolicy()
    assert p.observe(_sig([]), CLOCK) is None
    d = p.observe(_sig([(3, None, 0), (5, None, 0), (4, None, 0)]), CLOCK)
    assert d.order == (3, 5, 4)  # the identity: queue order itself
    assert d.slot_budget is None and d.shrink_patience is None
    assert d.reason == "fifo"


def test_priority_sorts_high_first_stable_within_class():
    p = PriorityPolicy()
    d = p.observe(
        _sig([(0, None, 0), (1, None, 2), (2, None, 1),
              (3, None, 2), (4, None, 0)]),
        CLOCK,
    )
    # class 2 first (FIFO within: 1 before 3), then 1, then 0 (0 before 4)
    assert d.order == (1, 3, 2, 0, 4)
    assert p.observe(_sig([]), CLOCK) is None


def test_fair_share_interleaves_a_burst():
    p = FairSharePolicy()
    # tenant "big" bursts rids 0..5; "small" queues rids 6,7 behind it
    d = p.observe(
        _sig([(r, "big", 0) for r in range(6)]
             + [(6, "small", 0), (7, "small", 0)]),
        CLOCK,
    )
    # deficit round-robin: tenants alternate, FIFO within a tenant
    assert d.order == (0, 6, 1, 7, 2, 3, 4, 5)
    assert d.reason == "fair"


def test_fair_share_tracks_admissions_across_observations():
    p = FairSharePolicy()
    p.observe(_sig([(0, "big", 0), (1, "big", 0), (2, "small", 0)]), CLOCK)
    # rid 0 left the queue (admitted): tenant big's virtual time advances,
    # so small's head now ranks ahead of big's
    d = p.observe(_sig([(1, "big", 0), (2, "small", 0)]), CLOCK)
    assert d.order == (2, 1)


def test_fair_share_equal_traffic_reduces_to_fifo():
    p = FairSharePolicy()
    d = p.observe(
        _sig([(0, "a", 0), (1, "b", 0), (2, "a", 0), (3, "b", 0)]), CLOCK
    )
    assert d.order == (0, 1, 2, 3)  # ties break by arrival order


def test_fair_share_quantum_batches_turns():
    p = FairSharePolicy(quantum=2)
    d = p.observe(
        _sig([(0, "a", 0), (1, "a", 0), (2, "a", 0), (3, "b", 0)]), CLOCK
    )
    # quantum 2: a's first TWO requests share virtual time 0 with b's first
    assert d.order == (0, 1, 3, 2)


# ---------------------------------------------------------------------------
# scheduler: no ordering can drop or double-assign (the no-drop invariant)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=9999))
def test_admit_arbitrary_orderings_never_drop_or_double_assign(seed):
    """Adversarial orderings — permuted subsets, stale rids, duplicates,
    unknown rids — against random arrival traces: every request still
    retires at exactly its token budget, every slot assignment is unique,
    and each request is admitted exactly once."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 12))
    max_slots = int(rng.integers(1, 6))
    budgets = [int(rng.integers(1, 6)) for _ in range(n)]
    arrivals = sorted(int(rng.integers(0, 8)) for _ in range(n))

    sched = Scheduler(max_slots)
    admissions: list[int] = []
    submitted = 0
    for t in range(10_000):
        while submitted < n and arrivals[submitted] <= t:
            sched.submit(Request(prompt=np.zeros(2, np.int32),
                                 max_new_tokens=budgets[submitted]))
            submitted += 1
        if submitted == n and not sched.has_work:
            break
        sched.resize(sched.target_slots())
        # an adversarial ordering: shuffled queued subset + junk
        queued = [rid for rid, _, _ in sched.queued()]
        rng.shuffle(queued)
        order = queued[: int(rng.integers(0, len(queued) + 1))]
        order += [999 + int(rng.integers(0, 5))]  # never-submitted rid
        order += admissions[-2:]  # stale rids (already admitted)
        order += order[:1]  # a duplicate
        adms = sched.admit(order=order)
        assert len({a.slot for a in adms}) == len(adms)
        assert len({a.rid for a in adms}) == len(adms)
        for a in adms:
            assert a.rid not in admissions  # admitted at most once, ever
            admissions.append(a.rid)
        for slot, rid in sched.live_slots():
            sched.record(slot, 11)
    else:
        pytest.fail("trace did not drain")

    assert sorted(admissions) == list(range(n))  # nobody dropped
    assert sched.retired == n
    for rid in range(n):
        assert sched.result(rid).steps == budgets[rid]


def test_gated_head_stops_the_pass_under_any_ordering():
    """A gate veto on the ORDERED head stops the whole admission pass — a
    policy promoting a large request cannot have smaller ones slip past it
    (reservation gating stays starvation-free)."""
    sched = Scheduler(4)
    rids = [sched.submit(Request(prompt=np.zeros(2, np.int32),
                                 max_new_tokens=2)) for _ in range(3)]
    sched.resize(4)
    gate = lambda rid, req: rid != rids[2]  # noqa: E731
    adms = sched.admit(gate=gate, order=[rids[2], rids[0], rids[1]])
    assert adms == []  # the gated head blocked everyone behind it
    assert sched.pending == 3  # nothing silently dropped
    # FIFO order under the same gate admits the two ungated heads
    adms = sched.admit(gate=gate)
    assert [a.rid for a in adms] == [rids[0], rids[1]]
    assert sched.pending == 1


# ---------------------------------------------------------------------------
# engine golden lanes: fifo is the pre-hook engine; reordering never
# perturbs a request's tokens
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def golden():
    reqs = _requests()
    return reqs, [_oracle(CFG, PARAMS, r) for r in reqs]


def test_fifo_default_matches_oracle_dense_and_paged(golden):
    """The tentpole acceptance lane: the default policy (and policy='fifo'
    explicitly) reproduces the PR 6 golden trace token-for-token, on the
    dense path and on the paged/chunked path."""
    reqs, expected = golden
    eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE)
    assert isinstance(eng.policy, FifoPolicy)  # the default
    assert _tokens(eng.generate(reqs)) == expected

    eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, policy="fifo")
    assert _tokens(eng.generate(_requests())) == expected

    eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, policy="fifo",
                      block_size=8, prefill_chunk=8)
    assert _tokens(eng.generate(_requests())) == expected


def test_reordering_policies_never_perturb_tokens(golden):
    """priority/fair change WHEN a request is admitted, never WHAT it
    decodes: per-slot timelines are independent, so every request still
    matches the single-request oracle."""
    _, expected = golden
    for policy in ("priority", "fair"):
        reqs = _requests()
        for i, r in enumerate(reqs):  # adversarial metadata: reverse classes
            r.tenant = f"t{i % 2}"
            r.priority = len(reqs) - i
        eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                          prompt_granule=GRANULE, policy=policy)
        assert _tokens(eng.generate(reqs)) == expected, policy
        assert eng.stats.retired == len(reqs)


def test_priority_admits_high_class_first():
    rng = np.random.default_rng(12)
    mk = lambda pr: Request(  # noqa: E731
        prompt=rng.integers(1, 61, size=4).astype(np.int32),
        max_new_tokens=3, priority=pr,
    )
    eng = ServeEngine(CFG, PARAMS, max_slots=2, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, policy="priority")
    # 6 requests into 2 slots: rids 4,5 carry the high class
    rids = [eng.submit(mk(pr)) for pr in (0, 0, 0, 0, 9, 9)]
    order = []
    seen = set(rids)
    while eng.step():
        queued = {rid for rid, _, _ in eng.sched.queued()}
        for rid in rids:
            if rid in seen and rid not in queued:
                order.append(rid)
                seen.discard(rid)
    # everything queued at once into 2 slots: the high class goes first
    assert set(order[:2]) == {rids[4], rids[5]}
    assert eng.sched.retired == 6


# ---------------------------------------------------------------------------
# slot budget / shrink patience decisions
# ---------------------------------------------------------------------------


class _Throttle:
    """Admit-one-at-a-time: cap the slot table at 1 from the first boundary."""

    def observe(self, signals, clock):
        return ServeDecision(slot_budget=1, reason="throttle")


class _OneShot:
    """Decide once, then go silent — pins that applied budgets PERSIST."""

    def __init__(self, **fields):
        self._fields = fields

    def observe(self, signals, clock):
        fields, self._fields = self._fields, {}
        return ServeDecision(**fields) if fields else None


def test_slot_budget_caps_capacity_without_stalling(golden):
    reqs, expected = golden
    eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, policy=_Throttle())
    for r in reqs:
        eng.submit(r)
    while eng.step():
        assert eng.sched.capacity <= 1  # the budget held at every boundary
    assert eng.sched.retired == len(reqs)  # a budget never stalls the drain
    assert max(eng.stats.buckets) == 1
    # serialized admission is still token-identical (slot independence)
    assert _tokens([eng.result(i) for i in range(len(reqs))]) == expected


def test_slot_budget_persists_until_changed():
    rng = np.random.default_rng(13)
    reqs = [Request(prompt=rng.integers(1, 61, size=4).astype(np.int32),
                    max_new_tokens=4) for _ in range(6)]
    eng = ServeEngine(CFG, PARAMS, max_slots=8, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE,
                      policy=_OneShot(slot_budget=2, reason="once"))
    for r in reqs:
        eng.submit(r)
    while eng.step():
        assert eng.sched.capacity <= 2  # sticky across silent boundaries
    assert eng.sched.retired == 6


class _Deferred:
    """Silent for ``after`` boundaries, then one decision — lets requests
    go live BEFORE the budget lands."""

    def __init__(self, after, **fields):
        self.after = after
        self._fields = fields

    def observe(self, signals, clock):
        if self.after > 0:
            self.after -= 1
            return None
        fields, self._fields = self._fields, {}
        return ServeDecision(**fields) if fields else None


def test_slot_budget_never_evicts_live_requests():
    """A budget landing BELOW the live count clamps to the live count — it
    throttles future admission but cannot shrink under running requests or
    stall the drain."""
    rng = np.random.default_rng(14)
    reqs = [Request(prompt=rng.integers(1, 61, size=4).astype(np.int32),
                    max_new_tokens=6) for _ in range(4)]
    eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE,
                      policy=_Deferred(1, slot_budget=1, reason="squeeze"))
    for r in reqs:
        eng.submit(r)
    eng.step()  # boundary 1 (policy silent): all 4 go live
    assert eng.sched.live == 4
    while eng.step():  # boundary 2 lands budget=1 under 4 live requests
        assert eng.sched.capacity >= eng.sched.live
    assert eng.sched.retired == 4
    assert all(eng.result(i).steps == 6 for i in range(4))  # nobody evicted


def test_shrink_patience_decision_applies():
    eng = ServeEngine(CFG, PARAMS, max_slots=2, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, shrink_patience=2,
                      policy=_OneShot(shrink_patience=5, reason="damp"))
    assert eng.shrink_patience == 2
    eng.submit(Request(prompt=np.ones(4, np.int32), max_new_tokens=2))
    eng.step()
    assert eng.shrink_patience == 5  # the decision landed
    eng.drain()
    assert eng.shrink_patience == 5  # and persists


# ---------------------------------------------------------------------------
# the FREE_RID sentinel (satellite 1 regression)
# ---------------------------------------------------------------------------


def test_free_lanes_carry_sentinel_not_rid_zero():
    sched = Scheduler(4)
    rid = sched.submit(Request(prompt=np.zeros(2, np.int32), max_new_tokens=2))
    sched.resize(2)
    sched.admit()
    assert rid == 0  # the collision case: the first request's rid IS 0
    assert sched.slot_rids().tolist() == [0, FREE_RID]
    assert FREE_RID == -1
    assert sched.slot_rids().dtype == np.int32


def test_live_lane_tokens_invariant_to_free_lane_count():
    """Categorical decode with free lanes present (a retired sibling leaves
    a vacancy that shrink_patience keeps alive) must emit the same tokens
    as the same request decoding alone with no free lanes: a free lane's
    sampling-key material can never alias a live request's."""
    rng = np.random.default_rng(15)
    prompt = rng.integers(1, 61, size=5).astype(np.int32)
    sibling = rng.integers(1, 61, size=4).astype(np.int32)

    def run(with_sibling):
        eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                          prompt_granule=GRANULE, sampler="categorical",
                          temperature=0.7, seed=21, shrink_patience=100)
        rid = eng.submit(Request(prompt=prompt, max_new_tokens=10))
        free_seen = 0
        if with_sibling:
            eng.submit(Request(prompt=sibling, max_new_tokens=2))
        while eng.step():
            if eng.sched.live:  # free lanes co-resident with live decode
                free_seen += eng.sched.capacity - eng.sched.live
        return eng.result(rid).tokens.tolist(), free_seen

    alone, free_alone = run(False)
    shared, free_shared = run(True)
    assert free_alone == 0  # capacity 1 throughout: no free lanes at all
    assert free_shared > 0  # the sibling retired and left a live vacancy
    assert shared == alone  # rid 0's stream untouched by the free lane
