"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU; TPU is the execution target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.psgn import psgn_direct, psgn_gram
from repro.kernels.quant import dequantize_int8, quantize_int8

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


PSGN_SHAPES = [
    (2, 64, 32, 48),
    (3, 128, 16, 96),
    (1, 37, 19, 23),   # ragged: exercises padding
    (2, 256, 128, 128),
    (4, 33, 7, 130),
]


@pytest.mark.parametrize("shape", PSGN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_psgn_direct_matches_ref(shape, dtype):
    b, s, di, do = shape
    x, d = _rand((b, s, di), dtype), _rand((b, s, do), dtype)
    got = psgn_direct(x, d, block_i=16, block_j=16, block_s=32)
    want = ref.psgn_ref(x, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5)


@pytest.mark.parametrize("shape", PSGN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_psgn_gram_matches_ref(shape, dtype):
    b, s, di, do = shape
    x, d = _rand((b, s, di), dtype), _rand((b, s, do), dtype)
    got = psgn_gram(x, d, block_si=32, block_sj=32)
    want = ref.psgn_ref(x, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5)


def test_gram_identity_refs_agree():
    x, d = _rand((2, 50, 12), jnp.float32), _rand((2, 50, 20), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ref.psgn_ref(x, d)), np.asarray(ref.psgn_gram_ref(x, d)), rtol=1e-5
    )


def test_ops_auto_dispatch():
    # gram wins when S tiny vs features; direct when S large vs features
    assert ops.choose_method(s=16, d_in=4096, d_out=4096) == "gram"
    assert ops.choose_method(s=4096, d_in=64, d_out=64) == "direct"


def test_ops_2d_fast_path():
    x, d = _rand((5, 33), jnp.float32), _rand((5, 7), jnp.float32)
    got = ops.persample_sq_norm(x, d)
    want = ref.psgn_ref(x[:, None, :], d[:, None, :])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_psgn_equals_vmap_grad_on_real_layer():
    """End-to-end: kernel psgn == per-sample grad norms of an actual dense
    layer computed by vmap(grad) — over a sequence model."""
    b, s, di, do = 3, 24, 10, 8
    w = _rand((di, do), jnp.float32)
    x = _rand((b, s, di), jnp.float32)
    y_target = _rand((b, s, do), jnp.float32)

    def loss_one(w, xb, yb):
        return 0.5 * jnp.sum((xb @ w - yb) ** 2)

    grads = jax.vmap(jax.grad(loss_one), in_axes=(None, 0, 0))(w, x, y_target)
    want = jnp.sum(grads.reshape(b, -1) ** 2, axis=-1)
    delta = x @ w - y_target  # dLoss/d(out)
    got = ops.persample_sq_norm(x, delta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


@pytest.mark.parametrize("shape", [(10, 64), (100, 257), (1, 7), (33, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_matches_ref(shape, dtype):
    x = _rand(shape, dtype)
    q, s = quantize_int8(x, block_rows=32)
    qr, sr = ref.quantize_int8_ref(x)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    # rounding ties at exact .5 boundaries may fall either way between the
    # fused kernel and the oracle (bf16 inputs hit them often) — allow off-
    # by-one on a tiny fraction of entries, never more.
    diff = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    assert diff.max() <= 1
    assert (diff > 0).mean() < 0.02


@pytest.mark.parametrize("case", range(12))
def test_psgn_property_random_shapes(case):
    """Property check: both Pallas factorisations agree with the pure-JAX
    reference on randomly drawn (B, S, Din, Dout) x dtype — the diversity
    numerator the batch controller consumes is kernel-verified, not just
    spot-checked on hand-picked shapes."""
    r = np.random.default_rng(1000 + case)
    b = int(r.integers(1, 5))
    s = int(r.integers(1, 97))
    di = int(r.integers(1, 90))
    do = int(r.integers(1, 90))
    dtype = (jnp.float32, jnp.bfloat16)[case % 2]
    x = jnp.asarray(r.standard_normal((b, s, di)), dtype)
    d = jnp.asarray(r.standard_normal((b, s, do)), dtype)
    want = np.asarray(ref.psgn_ref(x, d))
    direct = np.asarray(psgn_direct(x, d, block_i=16, block_j=16, block_s=32))
    gram = np.asarray(psgn_gram(x, d, block_si=32, block_sj=32))
    np.testing.assert_allclose(direct, want, rtol=3e-5, atol=1e-5)
    np.testing.assert_allclose(gram, want, rtol=3e-5, atol=1e-5)


def test_quantize_error_bound():
    x = _rand((50, 100), jnp.float32) * 10
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s)
    # max error <= scale/2 per row
    err = np.abs(np.asarray(deq - x))
    bound = np.asarray(s)[:, None] / 2 + 1e-6
    assert (err <= bound).all()
