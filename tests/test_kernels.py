"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU; TPU is the execution target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.psgn import psgn_direct, psgn_gram
from repro.kernels.quant import dequantize_int8, quantize_int8

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


PSGN_SHAPES = [
    (2, 64, 32, 48),
    (3, 128, 16, 96),
    (1, 37, 19, 23),   # ragged: exercises padding
    (2, 256, 128, 128),
    (4, 33, 7, 130),
]


@pytest.mark.parametrize("shape", PSGN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_psgn_direct_matches_ref(shape, dtype):
    b, s, di, do = shape
    x, d = _rand((b, s, di), dtype), _rand((b, s, do), dtype)
    got = psgn_direct(x, d, block_i=16, block_j=16, block_s=32)
    want = ref.psgn_ref(x, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5)


@pytest.mark.parametrize("shape", PSGN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_psgn_gram_matches_ref(shape, dtype):
    b, s, di, do = shape
    x, d = _rand((b, s, di), dtype), _rand((b, s, do), dtype)
    got = psgn_gram(x, d, block_si=32, block_sj=32)
    want = ref.psgn_ref(x, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5)


def test_gram_identity_refs_agree():
    x, d = _rand((2, 50, 12), jnp.float32), _rand((2, 50, 20), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ref.psgn_ref(x, d)), np.asarray(ref.psgn_gram_ref(x, d)), rtol=1e-5
    )


def test_ops_auto_dispatch():
    # gram wins when S tiny vs features; direct when S large vs features
    assert ops.choose_method(s=16, d_in=4096, d_out=4096) == "gram"
    assert ops.choose_method(s=4096, d_in=64, d_out=64) == "direct"


def test_ops_2d_fast_path():
    x, d = _rand((5, 33), jnp.float32), _rand((5, 7), jnp.float32)
    got = ops.persample_sq_norm(x, d)
    want = ref.psgn_ref(x[:, None, :], d[:, None, :])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_psgn_equals_vmap_grad_on_real_layer():
    """End-to-end: kernel psgn == per-sample grad norms of an actual dense
    layer computed by vmap(grad) — over a sequence model."""
    b, s, di, do = 3, 24, 10, 8
    w = _rand((di, do), jnp.float32)
    x = _rand((b, s, di), jnp.float32)
    y_target = _rand((b, s, do), jnp.float32)

    def loss_one(w, xb, yb):
        return 0.5 * jnp.sum((xb @ w - yb) ** 2)

    grads = jax.vmap(jax.grad(loss_one), in_axes=(None, 0, 0))(w, x, y_target)
    want = jnp.sum(grads.reshape(b, -1) ** 2, axis=-1)
    delta = x @ w - y_target  # dLoss/d(out)
    got = ops.persample_sq_norm(x, delta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


@pytest.mark.parametrize("shape", [(10, 64), (100, 257), (1, 7), (33, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_matches_ref(shape, dtype):
    x = _rand(shape, dtype)
    q, s = quantize_int8(x, block_rows=32)
    qr, sr = ref.quantize_int8_ref(x)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    # rounding ties at exact .5 boundaries may fall either way between the
    # fused kernel and the oracle (bf16 inputs hit them often) — allow off-
    # by-one on a tiny fraction of entries, never more.
    diff = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    assert diff.max() <= 1
    assert (diff > 0).mean() < 0.02


@pytest.mark.parametrize("case", range(12))
def test_psgn_property_random_shapes(case):
    """Property check: both Pallas factorisations agree with the pure-JAX
    reference on randomly drawn (B, S, Din, Dout) x dtype — the diversity
    numerator the batch controller consumes is kernel-verified, not just
    spot-checked on hand-picked shapes."""
    r = np.random.default_rng(1000 + case)
    b = int(r.integers(1, 5))
    s = int(r.integers(1, 97))
    di = int(r.integers(1, 90))
    do = int(r.integers(1, 90))
    dtype = (jnp.float32, jnp.bfloat16)[case % 2]
    x = jnp.asarray(r.standard_normal((b, s, di)), dtype)
    d = jnp.asarray(r.standard_normal((b, s, do)), dtype)
    want = np.asarray(ref.psgn_ref(x, d))
    direct = np.asarray(psgn_direct(x, d, block_i=16, block_j=16, block_s=32))
    gram = np.asarray(psgn_gram(x, d, block_si=32, block_sj=32))
    np.testing.assert_allclose(direct, want, rtol=3e-5, atol=1e-5)
    np.testing.assert_allclose(gram, want, rtol=3e-5, atol=1e-5)


def test_quantize_error_bound():
    x = _rand((50, 100), jnp.float32) * 10
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s)
    # max error <= scale/2 per row
    err = np.abs(np.asarray(deq - x))
    bound = np.asarray(s)[:, None] / 2 + 1e-6
    assert (err <= bound).all()


# ---------------------------------------------------------------------------
# attention kernels (PR 7): flash / chunk / paged-decode vs the jnp oracles
# ---------------------------------------------------------------------------

from _hypothesis_compat import given, settings, strategies as st  # noqa: E402

from repro.kernels import attention as kattn  # noqa: E402
from repro.models import attention as attn_lib  # noqa: E402
from repro.serve.blocks import BlockPool  # noqa: E402


def _qkv(r, b, sq, sk, h, kv, hd, dtype=jnp.float32):
    q = jnp.asarray(r.standard_normal((b, sq, h, hd)), dtype)
    k = jnp.asarray(r.standard_normal((b, sk, kv, hd)), dtype)
    v = jnp.asarray(r.standard_normal((b, sk, kv, hd)), dtype)
    return q, k, v


@settings(max_examples=14, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    sq=st.integers(min_value=1, max_value=70),
    window=st.sampled_from([None, 1, 5, 16]),
    softcap=st.sampled_from([None, 12.0]),
    causal=st.sampled_from([True, False]),
)
def test_flash_kernel_property(seed, sq, window, softcap, causal):
    """Pallas flash forward == the dense oracle across ragged lengths,
    sliding windows, softcap, and GQA (interpret mode)."""
    if not causal and window is not None:
        window = None  # the lane never windows non-causal attention
    r = np.random.default_rng(seed)
    sk = sq if causal else int(r.integers(1, 70))
    q, k, v = _qkv(r, 2, sq, sk, 4, 2, 16)
    got = kattn.flash_attention(q, k, v, causal, window, softcap, 16, 16, True)
    want = ref.flash_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    window=st.sampled_from([None, 7]),
    softcap=st.sampled_from([None, 20.0]),
)
def test_flash_kernel_backward_property(seed, window, softcap):
    """custom_vjp recompute backward == jax.grad through the oracle — the
    train path can adopt the kernel without changing gradients."""
    r = np.random.default_rng(seed)
    sq = int(r.integers(2, 40))
    q, k, v = _qkv(r, 2, sq, sq, 4, 2, 8)

    def loss_k(q, k, v):
        o = kattn.flash_attention(q, k, v, True, window, softcap, 16, 16, True)
        return jnp.sum(jnp.sin(o))

    def loss_r(q, k, v):
        o = ref.flash_ref(q, k, v, causal=True, window=window, softcap=softcap)
        return jnp.sum(jnp.sin(o))

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        assert not np.any(np.isnan(np.asarray(a)))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    window=st.sampled_from([None, 6]),
    softcap=st.sampled_from([None, 15.0]),
)
def test_chunk_kernel_property(seed, window, softcap):
    """Serving chunk attention: explicit absolute positions + garbage key
    rows (k_valid=False), exactly the gathered-pool / windowed-ring layout."""
    r = np.random.default_rng(seed)
    c = int(r.integers(1, 24))
    prior = int(r.integers(0, 40))
    off = int(r.integers(0, 30))
    sk = prior + c
    q, k, v = _qkv(r, 1, c, sk, 4, 2, 16)
    q_pos = off + jnp.arange(c)
    k_pos = jnp.concatenate([jnp.arange(prior), q_pos]).astype(jnp.int32)
    k_valid = jnp.concatenate(
        [jnp.arange(prior) < off, jnp.ones((c,), bool)]
    )
    got = kattn.chunk_attention(q, k, v, q_pos, k_pos, k_valid, window=window,
                                softcap=softcap, q_block=8, kv_block=8,
                                interpret=True)
    want = ref.attention_ref(q, k, v, q_pos, k_pos, k_valid, causal=True,
                             window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    softcap=st.sampled_from([None, 10.0]),
)
def test_paged_decode_kernel_property(seed, softcap):
    """Fused paged decode == the materialised-gather oracle over random
    tables (sentinel 0 in dead entries) and ragged per-row lengths."""
    r = np.random.default_rng(seed)
    b, blk, n_max, kv, h, hd = 3, 8, 4, 2, 4, 16
    nb = n_max * b + 1
    pool_k = jnp.asarray(r.standard_normal((nb, blk, kv, hd)), jnp.float32)
    pool_v = jnp.asarray(r.standard_normal((nb, blk, kv, hd)), jnp.float32)
    tables = np.zeros((b, n_max), np.int32)
    lengths = np.zeros((b,), np.int32)
    ids = list(range(1, nb))
    r.shuffle(ids)
    for row in range(b):
        length = int(r.integers(1, n_max * blk + 1))
        lengths[row] = length
        n_live = -(-length // blk)
        tables[row, :n_live] = ids[:n_live]
        ids = ids[n_live:]
    q = jnp.asarray(r.standard_normal((b, 1, h, hd)), jnp.float32)
    tables, lengths = jnp.asarray(tables), jnp.asarray(lengths)
    got = kattn.paged_decode_attention(q, pool_k, pool_v, tables, lengths,
                                       softcap=softcap, interpret=True)
    want = ref.paged_decode_ref(q, pool_k, pool_v, tables, lengths,
                                softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_matches_xla_gather_on_real_pool():
    """Token-level identity with the XLA lane on a REAL BlockPool table:
    allocate/free through the host accounting (so the table carries holes,
    sentinel entries, and out-of-order pool ids), then compare the fused
    kernel against decode_attention on the jnp.take gather."""
    r = np.random.default_rng(33)
    blk, n_max = 4, 6
    pool = BlockPool(num_blocks=16, block_size=blk)
    churn = [pool.alloc() for _ in range(5)]
    for bid in churn[::2]:
        pool.release(bid)  # punch holes so later allocs land out of order
    rows = []
    for length in (3, 9, 24, 1):
        n_live = -(-length // blk)
        tab = [pool.alloc() for _ in range(n_live)]
        rows.append((length, tab + [0] * (n_max - n_live)))
    tables = jnp.asarray([t for _, t in rows], jnp.int32)
    lengths = jnp.asarray([l for l, _ in rows], jnp.int32)
    b, kv, h, hd = len(rows), 2, 4, 8
    pool_k = jnp.asarray(r.standard_normal((16, blk, kv, hd)), jnp.float32)
    pool_v = jnp.asarray(r.standard_normal((16, blk, kv, hd)), jnp.float32)
    q = jnp.asarray(r.standard_normal((b, 1, h, hd)), jnp.float32)

    got = kattn.paged_decode_attention(q, pool_k, pool_v, tables, lengths,
                                       interpret=True)
    gk = jnp.take(pool_k, tables, axis=0).reshape(b, -1, kv, hd)
    gv = jnp.take(pool_v, tables, axis=0).reshape(b, -1, kv, hd)
    want = attn_lib.decode_attention(q, gk, gv, lengths, softcap=None,
                                     window=None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_kernel_matches_xla_flash():
    """The two tiled lanes (Pallas vs lax.scan flash) agree on a block-
    aligned workload — attn_impl='pallas' is a drop-in for 'flash'."""
    r = np.random.default_rng(7)
    q, k, v = _qkv(r, 2, 64, 64, 4, 2, 16)
    for window, softcap in ((None, None), (16, 30.0)):
        got = kattn.flash_attention(q, k, v, True, window, softcap, 16, 16, True)
        want = attn_lib.flash_attention(q, k, v, True, window, softcap, 16, 16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_psgn_fused_matches_per_layer():
    """One fused launch over L stacked layers == the sum of per-layer
    oracles; the tree wrapper groups same-shape layers into it and the
    bias=True terms make probe norms exact for dense+bias models."""
    r = np.random.default_rng(11)
    L, b, s, di, do = 3, 4, 24, 10, 6
    xs = jnp.asarray(r.standard_normal((L, b, s, di)), jnp.float32)
    ds = jnp.asarray(r.standard_normal((L, b, s, do)), jnp.float32)
    from repro.kernels.psgn import psgn_fused

    got = psgn_fused(xs, ds, block_i=8, block_j=8, block_s=16, interpret=True)
    want = sum(ref.psgn_ref(xs[i], ds[i]) for i in range(L))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5)

    acts = {f"l{i}": xs[i] for i in range(L)}
    dl = {f"l{i}": ds[i] for i in range(L)}
    tot = ops.persample_sq_norm_tree(acts, dl, scale=2.0, bias=True)
    want2 = sum(
        ref.psgn_ref(xs[i], ds[i] * 2.0)
        + jnp.sum(jnp.square(jnp.sum(ds[i] * 2.0, axis=1)), axis=-1)
        for i in range(L)
    )
    np.testing.assert_allclose(np.asarray(tot), np.asarray(want2), rtol=2e-5)


def test_default_interpret_and_none_flag():
    """Off-TPU the lane defaults to interpret mode, and interpret=None
    resolves through it (satellite: no more hard-coded interpret=True)."""
    assert ops.default_interpret() is (jax.default_backend() != "tpu")
    x = _rand((2, 20, 12), jnp.float32)
    d = _rand((2, 20, 8), jnp.float32)
    got = ops.persample_sq_norm(x, d, interpret=None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.psgn_ref(x, d)),
                               rtol=2e-5)
