"""CI smoke for the elastic benchmark: the `-m "not slow"`-safe variant runs
in seconds and must emit a well-formed BENCH_elastic.json."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import bench_elastic  # noqa: E402


def test_bench_elastic_smoke(tmp_path):
    out = tmp_path / "BENCH_elastic.json"
    rows = bench_elastic.run(smoke=True, out_path=str(out))
    record = json.loads(out.read_text())
    assert record["workload"]["smoke"] is True
    for kind in ("fixed_full_mesh", "elastic"):
        r = record[kind]
        assert r["steps_per_sec"] > 0
        assert r["devices"] == 8  # the conftest harness
    el = record["elastic"]
    assert el["ladder_dp"] == [1, 2, 4, 8]
    assert el["compiles"] <= record["compile_bound_bucket_x_rung"]
    assert len(el["rungs"]) == el["compiles"]
    # the adaptive run genuinely left the first rung
    assert len(set(el["rungs"])) >= 2
    assert record["elastic_vs_fixed_steps_per_sec"] > 0
    names = [name for name, _, _ in rows]
    assert "elastic_ladder" in names and "fixed_full_mesh" in names
