"""Checkpoint manager: atomicity, retention, round-trip, async."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, load_pytree, save_pytree


def _state():
    return {
        "params": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(3)},
        "opt": {"momentum": {"w": jnp.zeros((2, 3)), "b": jnp.zeros(3)}},
    }


def test_pytree_roundtrip(tmp_path):
    tree = _state()
    save_pytree(str(tmp_path / "t"), tree)
    loaded = load_pytree(str(tmp_path / "t"), tree)
    for a, b in zip(
        np.asarray(loaded["params"]["w"]), np.asarray(tree["params"]["w"])
    ):
        np.testing.assert_array_equal(a, b)


def test_load_without_target_gives_nested_dict(tmp_path):
    save_pytree(str(tmp_path / "t"), _state())
    loaded = load_pytree(str(tmp_path / "t"))
    assert "params" in loaded and "w" in loaded["params"]


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _state())
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_manager_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    s = _state()
    mgr.save(1, s, extra={"m": 128})
    s2 = {"params": {"w": jnp.full((2, 3), 9.0), "b": jnp.ones(3)},
          "opt": s["opt"]}
    mgr.save(2, s2, extra={"m": 256})
    out, extra = mgr.restore({"params": s["params"], "opt": s["opt"]}, step=1)
    assert extra["m"] == 128
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.arange(6.0).reshape(2, 3))


def test_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"params": {"w": jnp.zeros((2, 3))}})
    with pytest.raises(ValueError):
        mgr.restore({"params": {"w": jnp.zeros((3, 3))}})


def test_missing_leaf_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"params": {"w": jnp.zeros(3)}})
    with pytest.raises(KeyError):
        mgr.restore({"params": {"w": jnp.zeros(3), "extra": jnp.zeros(1)}})


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(7, _state())
    mgr.wait()
    assert mgr.latest_step() == 7


def test_no_partial_checkpoint_visible(tmp_path):
    """Tmp staging dirs must never appear as restorable steps."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(str(tmp_path / ".tmp.step_0000000099"))
    assert mgr.latest_step() is None
