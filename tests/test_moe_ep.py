"""shard_map EP MoE vs the dense oracle, on an 8-device mesh (subprocess)."""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.models import moe as moe_lib
from repro.models.moe_ep import moe_apply_ep

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
key = jax.random.key(0)
d, ff, E, topk = 32, 64, 8, 2
params = moe_lib.moe_init(key, d, ff, E)
x = jax.random.normal(jax.random.key(1), (4, 16, d))

def ep(x):
    return moe_apply_ep(params, x, top_k=topk, capacity_factor=8.0, act="silu",
                        mesh=mesh, dp_axes=("pod", "data"),
                        ep_axes=("pod", "data"), tp_axis="model")

with mesh:
    y_ep, aux = jax.jit(ep)(x)
y_ref = moe_lib.moe_reference(params, x, top_k=topk)
err = float(jnp.abs(y_ep - y_ref).max())
assert err < 2e-5, err
print("OK forward", err)

# gradients flow end to end
def loss(p, x):
    y, aux = moe_apply_ep(p, x, top_k=topk, capacity_factor=8.0, act="silu",
                          mesh=mesh, dp_axes=("pod", "data"),
                          ep_axes=("pod", "data"), tp_axis="model")
    return (y ** 2).sum() + 0.01 * aux

with mesh:
    g = jax.jit(jax.grad(loss))(params, x)
def loss_ref(p, x):
    y = moe_lib.moe_reference(p, x, top_k=topk)
    # reference aux identical formulation
    return (y ** 2).sum()
g_ref = jax.grad(loss_ref)(params, x)
for ka in ("w_gate", "w_up", "w_out"):
    e = float(jnp.abs(g[ka] - g_ref[ka]).max()) / (float(jnp.abs(g_ref[ka]).max()) + 1e-9)
    assert e < 5e-4, (ka, e)
print("OK grads")
"""


@pytest.mark.slow
def test_moe_ep_matches_reference():
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert res.stdout.count("OK") == 2, res.stdout
