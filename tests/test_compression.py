"""Gradient compression: quantisation error, error feedback, multi-device
compressed reduction (8 host devices in a subprocess)."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.compression import compress_leaf, init_error_state


def test_error_feedback_unbiased_over_time():
    """Error feedback: the ACCUMULATED transmitted signal converges to the
    accumulated true signal (residual stays bounded)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    err = jnp.zeros((16, 64), jnp.float32)
    sent = jnp.zeros_like(g_true)
    for _ in range(50):
        deq, err = compress_leaf(g_true, err)
        sent = sent + deq
    # average transmitted ~= g_true; residual bounded by one quant step
    avg = sent / 50
    assert float(jnp.abs(avg - g_true).max()) < 0.05
    assert float(jnp.abs(err).max()) < float(jnp.abs(g_true).max())


def test_compress_leaf_shapes():
    for shape in [(), (7,), (3, 5), (2, 3, 4)]:
        g = jnp.ones(shape, jnp.float32)
        err = jnp.zeros(shape, jnp.float32)
        deq, new_err = compress_leaf(g, err)
        assert deq.shape == shape and new_err.shape == shape


def test_make_compressed_pod_mean_keeps_per_pod_residuals():
    """Wrapper contract: mean replicated, residuals PER-POD (each pod must
    fold its own quantization error back, or error feedback is broken)."""
    from repro.dist.compression import make_compressed_pod_mean

    mesh = jax.make_mesh((8,), ("pod",))  # conftest forces 8 host devices
    r = np.random.default_rng(1)
    g = jnp.asarray(r.standard_normal((8, 4, 16)), jnp.float32)  # stacked
    grads, err = {"w": g}, init_error_state({"w": g})
    red, new_err = jax.jit(make_compressed_pod_mean(mesh, "pod"))(grads, err)

    np.testing.assert_allclose(np.asarray(red["w"]), np.asarray(g).mean(0),
                               atol=0.05)
    ne = np.asarray(new_err["w"])
    assert ne.shape == g.shape
    # each pod's residual is its own quant error: bounded by scale/2 and
    # distinct across pods (a pod-0 broadcast would make these identical)
    for p in range(8):
        bound = np.abs(np.asarray(g[p])).max() / 254.0 + 1e-6
        assert np.abs(ne[p]).max() <= bound
    assert not np.allclose(ne[0], ne[1])


_SUBPROCESS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.dist.compression import compressed_pod_mean, init_error_state

mesh = jax.make_mesh((8,), ("pod",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)  # per-pod grads
grads = {"w": g}
err = init_error_state({"w": g[0]})

def f(g_shard, err):
    red, new_err = compressed_pod_mean({"w": g_shard[0]}, err, "pod")
    return red["w"], new_err

fm = shard_map(f, mesh=mesh, in_specs=(P("pod"), P()), out_specs=(P(), P()),
               check_rep=False)
red, _ = jax.jit(fm)(grads["w"].reshape(8, 1, 32), err)
want = np.asarray(g).mean(0)
got = np.asarray(red)
rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
assert rel < 0.05, rel
print("OK", rel)
"""


@pytest.mark.slow
def test_multidevice_compressed_mean():
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS],
        capture_output=True, text=True,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd="/root/repo", timeout=300,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout
