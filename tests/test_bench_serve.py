"""CI smoke for the serving benchmark: the `-m "not slow"`-safe variant runs
in seconds and must emit a well-formed BENCH_serve.json."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import bench_serve  # noqa: E402


def test_bench_serve_smoke(tmp_path):
    out = tmp_path / "BENCH_serve.json"
    rows = bench_serve.run(smoke=True, out_path=str(out))
    record = json.loads(out.read_text())
    assert record["workload"]["smoke"] is True
    for kind in ("fixed_full_mesh", "elastic"):
        r = record[kind]
        assert r["tokens_per_sec"] > 0
        assert r["devices"] == 8  # the conftest harness
        assert r["compiles_in_measured_pass"] == 0  # warm pass really warmed
    el = record["elastic"]
    assert el["ladder_dp"] == [1, 2, 4, 8]
    assert el["compiles"] <= record["compile_bound_bucket_x_rung"]
    assert len(el["rungs"]) == el["compiles"]
    # the ramping trace genuinely moved across rungs
    assert el["reshards"] >= 2 and len(set(el["rungs"])) >= 2
    # both arms decode the same trace: identical lane counts
    assert el["slot_steps"] == record["fixed_full_mesh"]["slot_steps"]
    assert record["elastic_vs_fixed_tokens_per_sec"] > 0
    names = [name for name, _, _ in rows]
    assert "serve_elastic_ladder" in names and "serve_fixed_full_mesh" in names
    assert "serve_paged_prefix_sharing" in names
    assert "serve_policy_fairness" in names
    # the throughput arms record which ServePolicy drove them
    assert record["fixed_full_mesh"]["policy"] == "fifo"
    assert record["elastic"]["policy"] == "fifo"
    # the paged section: pool footprint + prefix-sharing schema
    pg = record["paged"]
    for key in ("block_size", "pool_blocks", "peak_blocks",
                "peak_resident_tokens", "dense_resident_tokens",
                "memory_vs_dense", "cow_copies", "shared_prefix",
                "no_sharing", "sharing_vs_dense_tokens_per_sec"):
        assert key in pg, key
    # paged memory tracks resident tokens, far under the dense preallocation
    assert 0 < pg["peak_resident_tokens"] < pg["dense_resident_tokens"]
    assert pg["memory_vs_dense"] < 0.5
    sh, ns = pg["shared_prefix"], pg["no_sharing"]
    # both arms delivered the same tokens; sharing skipped real prefill work
    assert sh["tokens"] == ns["tokens"] > 0
    assert 0 < sh["prefill_chunks"] < ns["prefill_chunks"]
    assert sh["shared_prefill_hits"] > 0 and ns["shared_prefill_hits"] == 0
    assert sh["compiles_in_measured_pass"] == 0
    assert sh["tokens_per_sec"] > 0 and ns["tokens_per_sec"] > 0
    # the policy section: per-tenant queue-wait percentiles per ServePolicy
    pol = record["policy"]
    assert pol["workload"]["task"] == "two-tenant-burst"
    for name in ("fifo", "priority", "fair"):
        for tenant in ("big", "small"):
            arm = pol[name][tenant]
            for key in ("n", "p50_wait_steps", "p95_wait_steps",
                        "mean_wait_steps"):
                assert key in arm, (name, tenant, key)
            assert arm["n"] > 0
            assert arm["p50_wait_steps"] <= arm["p95_wait_steps"]
    # the acceptance invariant: fair share strictly cuts the minority
    # tenant's tail wait vs queueing behind the majority burst
    assert (pol["fair"]["small"]["p95_wait_steps"]
            < pol["fifo"]["small"]["p95_wait_steps"])
    assert 0 <= pol["fair_vs_fifo_minority_p95"] < 1
