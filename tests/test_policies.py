"""Batch policies + controller (paper Algorithm 1 line 11, AdaBatch baseline)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import AdaBatch, AdaptiveBatchController, DiveBatch, FixedBatch, bucket, lr_rescale, step_decay


class TestBucket:
    @given(
        m=st.integers(1, 100_000),
        granule=st.sampled_from([1, 16, 128]),
        m_max=st.sampled_from([512, 2048, 8192]),
    )
    @settings(max_examples=100, deadline=None)
    def test_invariants(self, m, granule, m_max):
        out = bucket(m, granule, "pow2", m_max=m_max)
        assert granule <= out <= max(m_max, granule)
        # pow2 lattice: out / granule is a power of two
        ratio = out / granule
        assert ratio == 2 ** int(np.log2(ratio))

    def test_monotone(self):
        outs = [bucket(m, 16, "pow2", m_max=4096) for m in range(16, 5000, 7)]
        assert all(b >= a for a, b in zip(outs, outs[1:]))

    @given(
        m=st.integers(1, 100_000),
        granule=st.sampled_from([1, 16, 24, 128]),
        m_min=st.integers(1, 300),
        m_max=st.sampled_from([512, 2048, 8192]),
        mode=st.sampled_from(["pow2", "none"]),
    )
    @settings(max_examples=200, deadline=None)
    def test_every_output_on_lattice_within_bounds(self, m, granule, m_min, m_max, mode):
        """An off-lattice m_min must snap UP to the next lattice point, never
        leak through as a bucket of its own (it would silently exceed the
        num_buckets compile bound)."""
        out = bucket(m, granule, mode, m_min=m_min, m_max=m_max)
        if mode == "pow2":
            ratio = out / granule
            assert ratio == 2 ** int(np.log2(ratio)), (out, granule)
        else:
            assert out % granule == 0
        assert out <= max(m_max, granule)
        # the floor holds whenever a lattice point exists in [m_min, m_max]
        if mode == "pow2":
            pt = granule
            while pt < max(m_min, granule):
                pt *= 2
        else:
            pt = max(-(-max(m_min, granule) // granule) * granule, granule)
        if pt <= m_max:
            assert out >= min(m_min, pt)

    def test_off_lattice_m_min_snaps_up(self):
        assert bucket(1, 16, "pow2", m_min=24, m_max=256) == 32
        assert bucket(1, 16, "none", m_min=24, m_max=256) == 32
        # no lattice point in [m_min, m_max]: the lattice wins over the floor
        assert bucket(1, 16, "pow2", m_min=250, m_max=255) == 128


class TestDiveBatchPolicy:
    def test_paper_rule(self):
        # m = min(m_max, delta * n * Delta): 0.1 * 50000 * 0.05 = 250 -> 256
        p = DiveBatch(m0=128, m_max=2048, delta=0.1, dataset_size=50_000, granule=16)
        assert p.on_epoch_end(0, 0.05).batch_size == 256

    def test_cap_at_m_max(self):
        p = DiveBatch(m0=128, m_max=2048, delta=1.0, dataset_size=50_000)
        assert p.on_epoch_end(0, 0.9).batch_size == 2048

    def test_can_shrink_when_not_monotone(self):
        p = DiveBatch(m0=1024, m_max=2048, delta=0.1, dataset_size=50_000)
        p.m = 1024
        assert p.on_epoch_end(0, 0.01).batch_size < 1024

    def test_monotone_flag(self):
        p = DiveBatch(m0=1024, m_max=2048, delta=0.1, dataset_size=50_000, monotone=True)
        assert p.on_epoch_end(0, 0.0001).batch_size >= 1024

    def test_requires_diversity(self):
        p = DiveBatch(m0=128, m_max=2048, delta=0.1, dataset_size=50_000)
        with pytest.raises(ValueError):
            p.on_epoch_end(0, None)


class TestAdaBatchPolicy:
    def test_doubles_on_schedule(self):
        p = AdaBatch(m0=128, m_max=2048, resize_factor=2, resize_freq=20)
        sizes = [p.on_epoch_end(e).batch_size for e in range(60)]
        assert sizes[18] == 128 and sizes[19] == 256
        assert sizes[38] == 256 and sizes[39] == 512
        assert max(sizes) <= 2048


class TestController:
    def test_linear_lr_coupling(self):
        c = AdaptiveBatchController(
            DiveBatch(128, 4096, 1.0, 16_000, granule=16),
            base_lr=0.1, lr_rule="linear",
        )
        d = c.on_epoch_end(0.9)  # jumps to m_max
        assert d.batch_size == 4096
        assert np.isclose(d.lr, 0.1 * 4096 / 128)

    def test_step_decay(self):
        c = AdaptiveBatchController(
            FixedBatch(128, 128), base_lr=1.0, lr_schedule=step_decay(0.75, 2),
        )
        c.on_epoch_end()
        d = c.on_epoch_end()
        assert np.isclose(d.lr, 0.75)

    def test_state_roundtrip(self):
        c = AdaptiveBatchController(
            DiveBatch(128, 2048, 0.1, 50_000, granule=16), base_lr=0.1, lr_rule="linear",
        )
        c.on_epoch_end(0.05)
        c.on_epoch_end(0.2)
        saved = c.state_dict()
        c2 = AdaptiveBatchController(
            DiveBatch(128, 2048, 0.1, 50_000, granule=16), base_lr=0.1, lr_rule="linear",
        )
        c2.load_state_dict(saved)
        assert c2.batch_size == c.batch_size
        assert c2.lr == c.lr
        assert c2.epoch == c.epoch

    def test_lr_rescale_rules(self):
        assert lr_rescale("linear", 0.1, 128, 256) == pytest.approx(0.2)
        assert lr_rescale("sqrt", 0.1, 128, 512) == pytest.approx(0.2)
        assert lr_rescale("none", 0.1, 128, 512) == pytest.approx(0.1)
