"""repro.elastic: the mesh ladder, exact resharding, the (bucket, rung)
compile cache, cross-rung checkpoint round-trips, and the golden elastic
trajectory vs the fixed-full-mesh run."""

import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.ckpt import CheckpointManager
from repro.core import AdaptiveBatchController, make_policy
from repro.core.batch_policy import num_buckets
from repro.data import sigmoid_synthetic
from repro.dist.plan import ShardingPlan, use_plan
from repro.elastic import MeshLadder, place, reshard, same_plan
from repro.models import small
from repro.optim import sgd
from repro.train import init_state
from repro.train.loop import ModelFns, Trainer

SEED, N, D = 3, 2048, 32


def _fns():
    return ModelFns(
        batch_loss=small.mlp_batch_loss,
        example_loss=small.mlp_loss,
        metrics=lambda p, b: {"acc": small.mlp_accuracy(p, b)},
    )


def _controller(m0=16, m_max=256, delta=0.08, granule=16):
    return AdaptiveBatchController(
        make_policy("divebatch", m0=m0, m_max=m_max, delta=delta,
                    dataset_size=N, granule=granule),
        base_lr=0.5,
    )


# ---------------------------------------------------------------------------
# MeshLadder
# ---------------------------------------------------------------------------


class TestMeshLadder:
    def test_pow2_rungs_over_test_mesh(self):
        ladder = MeshLadder(granule=16)  # the 8-device conftest harness
        assert ladder.widths == [1, 2, 4, 8]
        assert ladder.num_rungs == 4
        assert ladder.full.dp == 8

    def test_rung_devices_are_nested_prefixes(self):
        ladder = MeshLadder(granule=1)
        ids = [
            [d.id for d in r.plan.mesh.devices.flat] for r in ladder
        ]
        for narrow, wide in zip(ids, ids[1:]):
            assert wide[: len(narrow)] == narrow

    def test_plan_for_batch_keeps_granule_per_device(self):
        ladder = MeshLadder(granule=16)
        assert ladder.rung_for_batch(16).dp == 1
        assert ladder.rung_for_batch(32).dp == 2
        assert ladder.rung_for_batch(64).dp == 4
        assert ladder.rung_for_batch(128).dp == 8
        assert ladder.rung_for_batch(256).dp == 8  # tops out at the mesh
        assert ladder.plan_for_batch(64).dp_size == 4

    def test_sub_granule_batch_runs_narrowest_rung(self):
        ladder = MeshLadder(granule=16)
        assert ladder.rung_for_batch(8).dp == 1
        assert ladder.rung_for_batch(13).dp == 1  # indivisible too

    def test_model_axes_held_fixed(self):
        ladder = MeshLadder(granule=1, model_axes=(("model", 2),))
        assert ladder.widths == [1, 2, 4]
        for rung in ladder:
            assert rung.plan.mesh.shape["model"] == 2
            assert rung.plan.tp_size == 2
        assert ladder.rung_for_batch(4).dp == 4
        assert ladder.full.devices == 8

    def test_explicit_dp_widths(self):
        ladder = MeshLadder(granule=1, dp_widths=[1, 8])
        assert ladder.widths == [1, 8]
        assert ladder.rung_for_batch(4).dp == 1  # 8 does not divide 4

    def test_too_few_devices_for_model_axes_raises(self):
        with pytest.raises(ValueError, match="cannot carry"):
            MeshLadder(jax.devices()[:1], model_axes=(("model", 2),))

    @settings(max_examples=24)
    @given(ndev=st.integers(1, 8), granule=st.integers(1, 32))
    def test_default_dp_widths_property(self, ndev, granule):
        """For ANY device count (non-pow2 included) the default widths are a
        sorted deduped pow2 chain topped by the device count, every rung's
        devices are a prefix of the flat list, and the selected dp width is
        monotone non-decreasing over the batch lattice m = granule * 2^k."""
        ladder = MeshLadder(jax.devices()[:ndev], granule=granule)
        widths = ladder.widths
        assert widths == sorted(set(widths)) and widths[-1] == ndev
        pow2 = [1 << i for i in range(ndev.bit_length()) if 1 << i <= ndev]
        assert [w for w in widths if w & (w - 1) == 0] == pow2
        assert all(w in pow2 or w == ndev for w in widths)
        for r in ladder:
            assert [d.id for d in r.plan.mesh.devices.flat] == \
                   [d.id for d in jax.devices()[: r.dp]]
        dps = [ladder.rung_for_batch(granule << k).dp for k in range(8)]
        assert dps == sorted(dps)  # growing the batch never narrows the mesh
        for k, d in enumerate(dps):
            assert ladder.plan_for_batch(granule << k).dp_size == d


# ---------------------------------------------------------------------------
# reshard / place
# ---------------------------------------------------------------------------


class TestReshard:
    def _state(self):
        return init_state(small.mlp_init(jax.random.key(0), D), sgd(momentum=0.9))

    def test_same_rung_is_strict_noop(self):
        ladder = MeshLadder(granule=16)
        state = place(self._state(), ladder.rungs[1].plan)
        # an equal plan built separately still counts as the same rung
        clone = MeshLadder(granule=16).rungs[1].plan
        assert same_plan(ladder.rungs[1].plan, clone)
        assert reshard(state, ladder.rungs[1].plan, clone) is state

    def test_cross_rung_is_value_exact(self):
        ladder = MeshLadder(granule=16)
        state = self._state()
        host = [np.asarray(x) for x in jax.tree.leaves(state)]
        wide = place(state, ladder.full.plan)
        narrow = reshard(wide, ladder.full.plan, ladder.rungs[0].plan,
                         donate=False)
        for ref, leaf in zip(host, jax.tree.leaves(narrow)):
            np.testing.assert_array_equal(ref, np.asarray(leaf))
        mesh_dev = narrow.params["fc1"]["kernel"].sharding.mesh.devices
        assert mesh_dev.size == 1  # genuinely moved to the 1-wide rung

    def test_reshard_to_none_gathers_single_device(self):
        ladder = MeshLadder(granule=16)
        state = place(self._state(), ladder.full.plan)
        gathered = reshard(state, ladder.full.plan, None, donate=False)
        leaf = jax.tree.leaves(gathered)[0]
        assert len(leaf.devices()) == 1

    def test_different_rungs_are_not_same_plan(self):
        ladder = MeshLadder(granule=16)
        assert not same_plan(ladder.rungs[0].plan, ladder.rungs[1].plan)
        assert not same_plan(ladder.rungs[0].plan, None)
        assert same_plan(None, None)

    def test_place_without_plan_is_plain_arrays(self):
        state = place(self._state(), None)
        assert all(len(x.devices()) == 1 for x in jax.tree.leaves(state))


# ---------------------------------------------------------------------------
# the golden elastic trajectory (the tentpole acceptance test)
# ---------------------------------------------------------------------------


def _run(mode, epochs=5, prefetch=True):
    train, val, _ = sigmoid_synthetic(n=N, d=D, seed=SEED)
    ladder = MeshLadder(granule=16) if mode == "elastic" else None
    if mode == "full":
        ctx = use_plan(ShardingPlan(mesh=jax.make_mesh((8,), ("data",))))
    else:
        ctx = contextlib.nullcontext()
    with ctx:
        t = Trainer(_fns(), small.mlp_init(jax.random.key(SEED), D),
                    sgd(momentum=0.9), _controller(), train, val,
                    estimator="exact", seed=SEED, elastic=ladder,
                    prefetch=prefetch)
        hist = t.run(epochs, verbose=False)
    return t, hist


def test_golden_elastic_trajectory_matches_full_mesh():
    """An elastic run crossing >= 2 rung transitions must produce the same
    schedule and numerically identical params as the identical DiveBatch run
    pinned to the full 8-device mesh, within f32 reduction-order tolerance
    (different dp widths sum microbatch gradients in different orders; the
    programs are arithmetically identical otherwise). The compile count must
    stay within the (bucket, rung) bound."""
    te, he = _run("elastic")
    tf, hf = _run("full")

    assert [h.batch_size for h in he] == [h.batch_size for h in hf]
    assert te.engine.stats.reshards >= 2  # >= 2 genuine rung transitions
    assert len(set(te.engine.stats.rungs)) >= 2
    for a, b in zip(jax.tree.leaves(te.state.params),
                    jax.tree.leaves(tf.state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose([h.val_loss for h in he],
                               [h.val_loss for h in hf], rtol=1e-4)

    # EngineStats-asserted (bucket, rung) bound
    stats = te.engine.stats
    ladder = MeshLadder(granule=16)
    bound = num_buckets(256, 16) * ladder.num_rungs
    assert stats.compiles <= bound
    assert stats.compiles == len(set(zip(stats.buckets, stats.rungs)))
    # rung is a function of the bucket here: one compile per bucket, so the
    # practical count is far below the worst case
    assert stats.compiles == len(set(stats.buckets))
    # every compile's rung is the ladder's choice for its bucket
    for bucket, rung in zip(stats.buckets, stats.rungs):
        assert rung == ladder.rung_for_batch(bucket).index


def test_elastic_rung_tokens_key_the_engine_cache():
    """Returning to an already-visited (bucket, rung) must be a cache hit;
    the same bucket on a different rung must not be."""
    train, _, _ = sigmoid_synthetic(n=512, d=16, seed=0)
    from repro.train import StepEngine

    fns = ModelFns(batch_loss=small.logreg_batch_loss,
                   example_loss=small.logreg_loss)
    ladder = MeshLadder(granule=16)
    eng = StepEngine.for_model_fns(fns, sgd(), estimator="moment",
                                   donate=False)
    state = init_state(small.logreg_init(jax.random.key(0), 16), sgd())

    def put(idx, rung):
        from jax.sharding import NamedSharding, PartitionSpec as P

        sh = NamedSharding(rung.plan.mesh, P(("data",)))
        return {k: jax.device_put(jnp.asarray(v), sh)
                for k, v in train.get(idx).items()}

    r1, r3 = ladder.rungs[1], ladder.rungs[3]
    batch = np.arange(64)
    state = place(state, r1.plan)
    eng.rung = r1.index
    state, _ = eng.step(state, put(batch, r1), 0.1)
    state, _ = eng.step(state, put(batch, r1), 0.1)
    assert eng.stats.compiles == 1 and eng.stats.bucket_hits == 1
    # same bucket (64), different rung: its own compile
    state = reshard(state, r1.plan, r3.plan, donate=False)
    eng.rung = r3.index
    state, _ = eng.step(state, put(batch, r3), 0.1)
    assert eng.stats.compiles == 2
    assert list(zip(eng.stats.buckets, eng.stats.rungs)) == [(64, 1), (64, 3)]
    # back to the first rung: hit, not compile
    state = reshard(state, r3.plan, r1.plan, donate=False)
    eng.rung = r1.index
    state, _ = eng.step(state, put(batch, r1), 0.1)
    assert eng.stats.compiles == 2 and eng.stats.bucket_hits == 2


def test_elastic_init_does_not_donate_caller_params():
    """The initial rung placement must not invalidate the arrays the caller
    handed in (init_state aliases them); only rung TRANSITIONS may donate."""
    train, val, _ = sigmoid_synthetic(n=256, d=16, seed=0)
    params = jax.tree.map(jnp.asarray, small.logreg_init(jax.random.key(0), 16))
    fns = ModelFns(batch_loss=small.logreg_batch_loss)
    Trainer(fns, params, sgd(), _controller(), train, val, estimator="none",
            elastic=MeshLadder(granule=16))
    assert not any(x.is_deleted() for x in jax.tree.leaves(params))
    float(fns.batch_loss(params, {k: jnp.asarray(v) for k, v in
                                  train.get(np.arange(16)).items()}))


def test_elastic_under_ambient_plan_raises():
    train, val, _ = sigmoid_synthetic(n=256, d=16, seed=0)
    fns = ModelFns(batch_loss=small.logreg_batch_loss)
    with use_plan(ShardingPlan(mesh=jax.make_mesh((8,), ("data",)))):
        with pytest.raises(ValueError, match="ambig"):
            Trainer(fns, small.logreg_init(jax.random.key(0), 16), sgd(),
                    _controller(), train, val, estimator="none",
                    elastic=MeshLadder(granule=16))


# ---------------------------------------------------------------------------
# checkpoint round-trips across sharding plans
# ---------------------------------------------------------------------------


class TestCheckpointAcrossPlans:
    def _trainer(self, mgr, plan=None, elastic=None):
        train, val, _ = sigmoid_synthetic(n=N, d=D, seed=SEED)
        ctx = use_plan(plan) if plan is not None else contextlib.nullcontext()
        with ctx:
            return Trainer(_fns(), small.mlp_init(jax.random.key(SEED), D),
                           sgd(momentum=0.9), _controller(), train, val,
                           estimator="exact", seed=SEED, ckpt=mgr,
                           elastic=elastic)

    def _dp8(self):
        return ShardingPlan(mesh=jax.make_mesh((8,), ("data",)))

    def test_save_unsharded_restore_dp8_and_reverse(self, tmp_path):
        """A checkpoint is topology-free: save under no plan -> restore under
        --dp 8 (and the reverse) with identical params and a correctly
        resumed cursor."""
        mgr = CheckpointManager(str(tmp_path / "a"), keep=2)
        t1 = self._trainer(mgr)
        t1.run(2, verbose=False)
        t1.save()
        ref = [np.asarray(x) for x in jax.tree.leaves(t1.state.params)]

        t2 = self._trainer(mgr, plan=self._dp8())
        assert t2.resume()
        assert t2.cursor.epoch == 2 and t2.cursor.batch_index == 0
        assert t2.controller.epoch == 2
        for a, b in zip(ref, jax.tree.leaves(t2.state.params)):
            np.testing.assert_array_equal(a, np.asarray(b))
        # restored onto the live 8-device plan, batches shard over it
        assert t2.state.params["fc1"]["kernel"].sharding.mesh.devices.size == 8

        # reverse: save under dp8, restore unsharded
        t2.run(1, verbose=False)
        t2.save()
        t3 = self._trainer(mgr)
        assert t3.resume()
        assert t3.cursor.epoch == 3
        for a, b in zip(jax.tree.leaves(t2.state.params),
                        jax.tree.leaves(t3.state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert len(t3.state.params["fc1"]["kernel"].devices()) == 1

    def test_restore_with_plan_kwarg_places_trees(self, tmp_path):
        """CheckpointManager.restore(plan=...) reuses elastic.reshard.place:
        the restored trees land on the plan's inferred shardings."""
        mgr = CheckpointManager(str(tmp_path), keep=2)
        params = {"w": jnp.arange(16.0).reshape(2, 8), "b": jnp.ones(8)}
        mgr.save(1, {"params": params}, extra={"m": 64})
        plan = self._dp8()
        out, extra = mgr.restore({"params": params}, plan=plan)
        assert extra["m"] == 64
        np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                      np.asarray(params["w"]))
        assert out["params"]["w"].sharding.mesh.devices.size == 8

    def test_elastic_resume_lands_on_checkpointed_rung(self, tmp_path):
        """Saved on one rung, resumed on another: a fresh elastic Trainer
        starts on the ladder's rung for ITS m0, then resume() re-derives the
        rung from the restored controller state (supervisor restart path)."""
        mgr = CheckpointManager(str(tmp_path), keep=3)
        ladder = MeshLadder(granule=16)
        t1 = self._trainer(mgr, elastic=ladder)
        start_rung = t1.rung.index
        t1.run(2, verbose=False)  # diversity growth moves m well past m0
        t1.save()
        # the rung the NEXT epoch will run on: derived from the restored
        # controller's batch size, not from whatever rung the saver was on
        next_rung = ladder.rung_for_batch(t1.controller.batch_size).index

        t2 = self._trainer(mgr, elastic=MeshLadder(granule=16))
        assert t2.resume()
        assert t2.rung.index == next_rung != start_rung
        for a, b in zip(jax.tree.leaves(t1.state.params),
                        jax.tree.leaves(t2.state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # and the resumed trajectory continues exactly like an uncrashed one
        t3 = self._trainer(CheckpointManager(str(tmp_path / "c")),
                           elastic=MeshLadder(granule=16))
        t3.run(4, verbose=False)
        t2.run(2, verbose=False)
        np.testing.assert_allclose(
            [h.val_loss for h in t3.history[2:]],
            [h.val_loss for h in t2.history[2:]], rtol=1e-4)


# ---------------------------------------------------------------------------
# prefetch (satellite): trajectory bit-identical with and without
# ---------------------------------------------------------------------------


def test_prefetch_trajectory_bit_identical():
    t_pre, h_pre = _run("plain", epochs=3, prefetch=True)
    t_sync, h_sync = _run("plain", epochs=3, prefetch=False)
    assert [h.batch_size for h in h_pre] == [h.batch_size for h in h_sync]
    assert [h.train_loss for h in h_pre] == [h.train_loss for h in h_sync]
    for a, b in zip(jax.tree.leaves(t_pre.state.params),
                    jax.tree.leaves(t_sync.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prefetch_iterator_order_and_depth():
    from repro.data import prefetch

    puts = []
    out = list(prefetch(range(5), put=lambda b: (puts.append(b), b)[1], depth=2))
    assert out == [0, 1, 2, 3, 4]
    assert puts == [0, 1, 2, 3, 4]
    with pytest.raises(ValueError, match="depth"):
        list(prefetch([1], put=lambda b: b, depth=0))


def test_prefetch_stays_ahead_of_consumer():
    """With depth=2 the put of batch b+1 is issued before batch b is
    consumed (that is the double buffer)."""
    from repro.data import prefetch

    events = []
    gen = prefetch(range(3), put=lambda b: (events.append(("put", b)), b)[1])
    first = next(gen)
    events.append(("consume", first))
    second = next(gen)
    events.append(("consume", second))
    assert events[:3] == [("put", 0), ("put", 1), ("consume", 0)]
