"""repro.dist.plan: use_plan/current_plan nesting + re-entrancy, and the
constrain() no-op contract (exact identity, nothing added to the jaxpr) when
no plan is active."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.plan import (
    ShardingPlan,
    abstract_mesh,
    constrain,
    current_act_specs,
    current_plan,
    use_plan,
)


def _plan(tag="data"):
    mesh = abstract_mesh((2, 4), ("pod", tag))
    return ShardingPlan(mesh=mesh, dp=("pod", tag), fsdp=(tag,), tp=tag,
                        ep=(tag,))


class TestPlanContext:
    def test_no_plan_by_default(self):
        assert current_plan() is None
        assert current_act_specs() == {}

    def test_use_plan_sets_and_restores(self):
        plan = _plan()
        with use_plan(plan, {"residual": P(None)}) as active:
            assert active is plan
            assert current_plan() is plan
            assert current_act_specs() == {"residual": P(None)}
        assert current_plan() is None

    def test_nesting_restores_outer(self):
        outer, inner = _plan(), _plan("model")
        with use_plan(outer):
            with use_plan(inner):
                assert current_plan() is inner
            assert current_plan() is outer
        assert current_plan() is None

    def test_reentrant_same_plan(self):
        plan = _plan()
        with use_plan(plan):
            with use_plan(plan):
                assert current_plan() is plan
            assert current_plan() is plan

    def test_restored_after_exception(self):
        plan = _plan()
        with pytest.raises(RuntimeError):
            with use_plan(plan):
                raise RuntimeError("boom")
        assert current_plan() is None

    def test_axis_sizes(self):
        plan = _plan()
        assert plan.dp_size == 8
        assert plan.tp_size == 4
        assert plan.axis_size(None) == 1


class TestConstrainNoOp:
    def test_identity_without_plan(self):
        x = jnp.arange(8.0)
        assert constrain(x, "residual") is x

    def test_identity_for_unknown_name(self):
        x = jnp.arange(8.0)
        with use_plan(_plan(), {"residual": P(None, None)}):
            assert constrain(x, "not_registered") is x

    def test_identity_for_rank_mismatch(self):
        x = jnp.arange(8.0)  # 1-D vs a 3-D spec: nothing to say, exact no-op
        with use_plan(_plan(), {"residual": P(("pod", "data"), None, "data")}):
            assert constrain(x, "residual") is x

    def test_identity_for_indivisible_dims(self):
        x = jnp.zeros((7, 5))  # neither dim divides the 2x4 mesh axes
        with use_plan(_plan(), {"residual": P(("pod", "data"), "data")}):
            assert constrain(x, "residual") is x

    def test_no_trace_residue_without_plan(self):
        jaxpr = jax.make_jaxpr(lambda x: constrain(x, "residual"))(jnp.ones((4,)))
        assert jaxpr.eqns == []  # identity: no tracer leaks, no inserted ops

    def test_constraint_applies_on_real_mesh(self):
        # conftest forces 8 host devices, so a real (8,)-mesh exists here
        mesh = jax.make_mesh((8,), ("data",))
        plan = ShardingPlan(mesh=mesh, dp=("data",), fsdp=("data",),
                            tp="data", ep=("data",))
        with use_plan(plan, {"residual": P("data")}):
            out = jax.jit(lambda x: constrain(x, "residual"))(jnp.arange(16.0))
        assert len(out.sharding.device_set) == 8
        # indivisible input under the same plan degrades to a working no-op
        with use_plan(plan, {"residual": P("data")}):
            out = jax.jit(lambda x: constrain(x, "residual"))(jnp.arange(7.0))
        assert out.shape == (7,)
