"""Scheduler property tests (model-free, no jax): random arrival/length
traces must never double-assign a slot, never drop a request, retire every
request at exactly its EOS/max-token step, and keep every capacity on the
pow2 slot lattice."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.serve.scheduler import Request, Scheduler, slots_for

EOS = 7


def _token(rid, k, eos_at):
    """Deterministic per-request stream; EOS exactly at the planned step."""
    if eos_at is not None and k == eos_at:
        return EOS
    return 10 + (rid * 31 + k) % 900  # never collides with EOS


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=9999),
       max_slots=st.sampled_from([1, 2, 3, 4, 8]),
       granule=st.sampled_from([1, 2]))
def test_scheduler_invariants(seed, max_slots, granule):
    max_slots = max(max_slots, granule)
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 14))
    reqs, eos_at = [], {}
    for rid in range(n):
        max_new = int(rng.integers(1, 9))
        has_eos = bool(rng.random() < 0.5)
        reqs.append(Request(prompt=np.zeros(4, np.int32), max_new_tokens=max_new,
                            eos_id=EOS if has_eos else None))
        eos_at[rid] = int(rng.integers(1, max_new + 1)) if has_eos else None
    arrivals = sorted(int(rng.integers(0, 12)) for _ in range(n))

    sched = Scheduler(max_slots, granule=granule)
    lattice = {granule * (1 << i) for i in range(12)}
    counts = {}  # rid -> tokens emitted so far (the test's own ledger)
    submitted = 0
    for t in range(10_000):
        while submitted < n and arrivals[submitted] <= t:
            rid = sched.submit(reqs[submitted])
            counts[rid] = 0
            submitted += 1
        if submitted == n and not sched.has_work:
            break
        target = sched.target_slots()
        assert target == 0 or target in lattice  # pow2 lattice, always
        assert target <= max_slots
        if target != sched.capacity:
            live_before = [rid for _, rid in sched.live_slots()]
            idx = sched.resize(target)
            assert len(idx) == target
            # compaction preserves the live slots and their order
            assert [rid for _, rid in sched.live_slots()] == live_before
            assert sched.capacity == target
        while True:  # admissions (instant retirements free slots again)
            adms = sched.admit()
            if not adms:
                break
            taken = set()
            for a in adms:
                assert a.slot not in taken  # never double-assigned
                taken.add(a.slot)
                counts[a.rid] += 1
                sched.record(a.slot, _token(a.rid, counts[a.rid], eos_at[a.rid]))
        live = sched.live_slots()
        assert len({s for s, _ in live}) == len(live)
        assert len({r for _, r in live}) == len(live)  # one slot per request
        for slot, rid in live:  # one decode step
            counts[rid] += 1
            sched.record(slot, _token(rid, counts[rid], eos_at[rid]))
    else:
        pytest.fail("trace did not drain")

    # no request dropped; every request retired at exactly its stop step
    assert sched.retired == n
    assert set(sched.results()) == set(range(n))
    for rid in range(n):
        res = sched.result(rid)
        expect = eos_at[rid] if eos_at[rid] is not None else reqs[rid].max_new_tokens
        assert res.steps == expect == len(res.tokens)
        if eos_at[rid] is not None:
            assert res.tokens[-1] == EOS
            assert EOS not in res.tokens[:-1]
        else:
            assert EOS not in res.tokens


def test_slots_for_lattice():
    assert slots_for(0, 1, 8) == 0
    assert slots_for(1, 1, 8) == 1
    assert slots_for(3, 1, 8) == 4  # ceil onto the lattice, never starve
    assert slots_for(5, 1, 8) == 8
    assert slots_for(9, 1, 8) == 8  # capped; the rest queue
    assert slots_for(3, 2, 8) == 4  # granule-anchored lattice
    assert slots_for(1, 2, 8) == 2
    assert slots_for(7, 1, 6) == 4  # largest lattice point under a non-pow2 cap


@settings(max_examples=200, deadline=None)
@given(need=st.integers(min_value=0, max_value=64),
       granule=st.integers(min_value=1, max_value=8),
       max_slots=st.integers(min_value=1, max_value=48))
def test_slots_for_properties(need, granule, max_slots):
    """slots_for over the full domain — non-pow2 caps and need > cap
    included: the result is on the granule*2^k lattice, covers
    min(need, largest-lattice-point-under-cap), and never exceeds the cap.
    (core.batch_policy.bucket can snap DOWN mid-lattice; the doubling loop
    in slots_for must compensate, which is exactly what this pins.)"""
    if max_slots < granule:
        max_slots = granule
    s = slots_for(need, granule, max_slots)
    lattice = {granule * (1 << i) for i in range(12)}
    cap = max(p for p in lattice if p <= max_slots)
    if need <= 0:
        assert s == 0
        return
    assert s in lattice
    assert s <= cap
    assert s >= min(need, cap)  # whatever fits under the cap gets a slot
    # minimal: the next lattice point down would not cover the need
    if s > granule:
        assert s // 2 < min(need, cap)


def test_resize_below_live_raises():
    sched = Scheduler(4)
    for _ in range(3):
        sched.submit(Request(prompt=np.zeros(2, np.int32), max_new_tokens=4))
    sched.resize(4)
    sched.admit()
    with pytest.raises(ValueError, match="shrink"):
        sched.resize(2)


def test_record_on_free_slot_raises():
    sched = Scheduler(2)
    sched.resize(2)
    with pytest.raises(ValueError, match="free"):
        sched.record(0, 5)


def test_submit_rejects_empty_budget():
    sched = Scheduler(2)
    with pytest.raises(ValueError, match="budget"):
        sched.submit(Request(prompt=np.zeros(2, np.int32), max_new_tokens=0))
