"""repro.serve: the rebuilt ServeEngine — golden decode equivalence on a
fixed full mesh, on every ladder rung, and across live rung transitions;
(bucket, rung) compile-cache accounting via ServeStats; the continuous
batching retire/refill fix for the old chunked-generate waste; and the
ring/SSM slot-insertion substrate."""

import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.dist.plan import ShardingPlan, use_plan
from repro.elastic import MeshLadder
from repro.models import transformer as tf
from repro.serve import Request, ServeEngine, padded_prompt_len

MAX_SEQ = 64
GRANULE = 8  # prompt granule: every test prompt pads into the 8-bucket


def _cfg(**kw):
    base = dict(
        name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=2, d_ff=64, vocab_size=61, pattern=("attn",),
        param_dtype="float32", compute_dtype="float32", xent_chunk=8,
        remat=False,
    )
    base.update(kw)
    return ModelConfig(**base)


CFG = _cfg()
PARAMS = tf.init_params(CFG, jax.random.key(0))

# the golden trace: r0 long enough to stay live across every arrival wave,
# prompts all inside the single pow2 prompt bucket (lens <= 8)
_LENS = [5, 3, 8, 2, 6, 4, 7, 5]
_MAX_NEW = [24, 12, 12, 6, 6, 6, 6, 6]


def _requests():
    rng = np.random.default_rng(7)
    return [
        Request(prompt=rng.integers(1, CFG.vocab_size, size=n).astype(np.int32),
                max_new_tokens=m)
        for n, m in zip(_LENS, _MAX_NEW)
    ]


def _oracle(cfg, params, req, max_seq=MAX_SEQ, granule=GRANULE):
    """Fully independent single-request reference: greedy continuation by
    re-prefilling the whole (padded prompt + generated prefix) each step —
    no serve engine, no scheduler, no decode cache."""
    prompt = np.asarray(req.prompt, np.int32)
    plen = padded_prompt_len(len(prompt), granule)
    seq = np.zeros(plen, np.int32)
    seq[plen - len(prompt):] = prompt
    seq = list(seq)
    budget = min(req.max_new_tokens, max_seq - plen + 1)
    pref = jax.jit(lambda p, b: tf.prefill_step(cfg, p, b)[0])
    out = []
    while len(out) < budget:
        logits = pref(params, {"tokens": jnp.asarray(np.asarray(seq)[None])})
        out.append(int(jnp.argmax(logits[0, -1])))
        if req.eos_id is not None and out[-1] == req.eos_id:
            break
        seq.append(out[-1])
    return out


@pytest.fixture(scope="module")
def golden():
    reqs = _requests()
    return reqs, [_oracle(CFG, PARAMS, r) for r in reqs]


def _tokens(results):
    return [r.tokens.tolist() for r in results]


# ---------------------------------------------------------------------------
# golden decode equivalence (the tentpole acceptance tests)
# ---------------------------------------------------------------------------


def test_single_device_matches_oracle_with_cache_accounting(golden):
    reqs, expected = golden
    eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE)
    assert _tokens(eng.generate(reqs)) == expected
    stats = eng.stats
    assert stats.retired == len(reqs)
    assert stats.tokens == sum(len(t) for t in expected)
    assert stats.tokens_per_sec > 0  # the windowed ThroughputWindow rate
    # (bucket, rung) accounting mirrors EngineStats
    assert stats.compiles == len(set(zip(stats.buckets, stats.rungs)))
    assert all(b in (1, 2, 4) for b in stats.buckets)  # pow2 slot lattice
    assert stats.bucket_hits + stats.bucket_misses == stats.steps
    assert stats.bucket_misses == stats.compiles


def test_fixed_full_mesh_matches_oracle(golden):
    reqs, expected = golden
    mesh = jax.make_mesh((8,), ("data",))
    with use_plan(ShardingPlan(mesh=mesh, tp=None)):
        eng = ServeEngine(CFG, PARAMS, max_slots=8, max_seq=MAX_SEQ,
                          prompt_granule=GRANULE)
        assert _tokens(eng.generate(reqs)) == expected
    assert eng.stats.reshards == 0  # pinned mesh: no ladder, no transitions


@pytest.mark.slow
def test_every_rung_matches_oracle(golden):
    """Token-identical outputs on EACH ladder rung individually (the serving
    analogue of PR 3's golden elastic trajectory test)."""
    reqs, expected = golden
    ladder = MeshLadder(granule=1)
    assert ladder.widths == [1, 2, 4, 8]
    for rung in ladder:
        with use_plan(rung.plan):
            eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                              prompt_granule=GRANULE)
            assert _tokens(eng.generate(reqs)) == expected, f"rung dp{rung.dp}"


def test_elastic_live_rung_transitions_golden(golden):
    """A ramping arrival trace drives >= 2 LIVE rung transitions (grow with
    the wave, shrink on the drain) — outputs stay token-identical and the
    compile cache stays within the (bucket, rung) accounting."""
    reqs, expected = golden
    ladder = MeshLadder(granule=1)
    eng = ServeEngine(CFG, PARAMS, max_slots=8, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, elastic=ladder)
    rids = [eng.submit(reqs[0])]
    for _ in range(2):
        eng.step()
    rungs_seen = {eng.rung.index}
    rids += [eng.submit(r) for r in reqs[1:3]]
    for _ in range(2):
        eng.step()
    rungs_seen.add(eng.rung.index)
    rids += [eng.submit(r) for r in reqs[3:]]
    while eng.step():
        rungs_seen.add(eng.rung.index)

    assert _tokens([eng.result(rid) for rid in rids]) == expected
    stats = eng.stats
    assert stats.reshards >= 2  # >= 2 genuine live transitions
    assert len(rungs_seen) >= 2
    assert len(set(stats.rungs)) >= 2
    # (bucket, rung) cache accounting via ServeStats
    assert stats.compiles == len(set(zip(stats.buckets, stats.rungs)))
    assert stats.bucket_hits > 0  # revisited (bucket, rung) on the drain
    for bucket, rung in zip(stats.buckets, stats.rungs):
        assert bucket in (1, 2, 4, 8)
        assert rung == ladder.rung_for_batch(bucket).index


def test_elastic_under_ambient_plan_raises():
    mesh = jax.make_mesh((8,), ("data",))
    with use_plan(ShardingPlan(mesh=mesh, tp=None)):
        with pytest.raises(ValueError, match="ambig"):
            ServeEngine(CFG, PARAMS, elastic=MeshLadder(granule=1))


# ---------------------------------------------------------------------------
# the continuous-batching fix: retire/refill instead of chunk hostage-taking
# ---------------------------------------------------------------------------


def test_mid_batch_retirement_bounds_decode_work():
    """The old ``_generate_batch`` decoded every slot for the chunk-max
    ``max_new`` (one long request held the whole chunk; a finished slot kept
    being decoded).  The Scheduler retires/refills per slot: total decoded
    lanes must track the per-request work, not slots x chunk-max."""
    rng = np.random.default_rng(3)
    long = Request(prompt=rng.integers(1, 61, size=5).astype(np.int32),
                   max_new_tokens=40)
    shorts = [Request(prompt=rng.integers(1, 61, size=4).astype(np.int32),
                      max_new_tokens=4) for _ in range(7)]
    eng = ServeEngine(CFG, PARAMS, max_slots=8, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE, shrink_patience=0)
    results = eng.generate([long] + shorts)
    decode_steps = [r.steps - 1 for r in results]  # token 1 is from prefill
    assert results[0].steps == 40
    assert all(r.steps == 4 for r in results[1:])
    # decoded lanes <= per-request decode steps + refill slack
    assert eng.stats.slot_steps <= sum(decode_steps) + eng.sched.max_slots
    # and strictly far below the old chunked cost (8 slots x 39 steps)
    assert eng.stats.slot_steps < (8 * max(decode_steps)) // 2
    assert eng.stats.resizes >= 2  # shrank after the shorts retired


def test_queue_refills_freed_slots():
    rng = np.random.default_rng(4)
    reqs = [Request(prompt=rng.integers(1, 61, size=3).astype(np.int32),
                    max_new_tokens=3) for _ in range(10)]
    eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE)
    results = eng.generate(reqs)
    assert all(r.steps == 3 for r in results)
    assert eng.stats.prefills == 10  # every request admitted exactly once
    assert max(eng.stats.buckets) <= 4  # capacity never exceeded max_slots


def test_eos_retires_slot_early_without_disturbing_neighbours():
    reqs = _requests()[:4]
    eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE)
    base = _tokens(eng.generate(reqs))
    eos = base[0][2]  # retire request 0 exactly at its 3rd token
    reqs2 = _requests()[:4]
    reqs2[0].eos_id = int(eos)
    eng2 = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                       prompt_granule=GRANULE)
    got = _tokens(eng2.generate(reqs2))
    assert got[0] == base[0][:3]  # stopped at EOS, token-identically
    assert got[1:] == base[1:]  # slot retirement never perturbs neighbours


# ---------------------------------------------------------------------------
# slot-insertion substrate: windowed ring buffers and SSM state
# ---------------------------------------------------------------------------


def test_windowed_ring_insertion_matches_full_recompute():
    """A non-pow2 window forces a genuine ring rotation on slot insertion
    (pow2 prompts make ``plen % window == 0`` whenever window is pow2)."""
    cfg = _cfg(pattern=("attn_local",), window=6)
    params = tf.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(5)
    req = Request(prompt=rng.integers(1, 61, size=12).astype(np.int32),
                  max_new_tokens=6)
    eng = ServeEngine(cfg, params, max_slots=2, max_seq=48,
                      prompt_granule=GRANULE)
    got = _tokens(eng.generate([req]))[0]
    assert got == _oracle(cfg, params, req, max_seq=48)


def test_ssm_slot_state_matches_scalar_decode():
    cfg = ModelConfig(name="t", family="ssm", num_layers=2, d_model=32,
                      num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=61,
                      pattern=("mamba",), param_dtype="float32",
                      compute_dtype="float32", xent_chunk=8, ssm_chunk=8,
                      remat=False)
    params = tf.init_params(cfg, jax.random.key(2))
    rng = np.random.default_rng(6)
    prompt = rng.integers(1, 61, size=12).astype(np.int32)
    eng = ServeEngine(cfg, params, max_slots=2, max_seq=48, prompt_granule=8)
    got = _tokens(eng.generate([Request(prompt=prompt, max_new_tokens=6)]))[0]

    # scalar-path reference: feed the padded prompt token by token
    plen = padded_prompt_len(len(prompt), 8)
    padded = np.zeros(plen, np.int32)
    padded[plen - len(prompt):] = prompt
    cache = tf.init_cache(cfg, 1, 48)
    dec = jax.jit(lambda p, c, t: tf.decode_step(cfg, p, c, t))
    logits = None
    for t in padded:
        logits, cache = dec(params, cache, jnp.asarray([[t]], jnp.int32))
    ref = []
    for _ in range(6):
        tok = int(jnp.argmax(logits[0, -1]))
        ref.append(tok)
        logits, cache = dec(params, cache, jnp.asarray([[tok]], jnp.int32))
    assert got == ref


# ---------------------------------------------------------------------------
# sampling + guards
# ---------------------------------------------------------------------------


def test_categorical_sampling_is_per_request_deterministic():
    """Sampled decode derives its key from (engine seed, request id,
    position) — the slot layout / co-batching must not change a request's
    tokens (request ids follow submit order, so identical traces at
    different slot counts compare key-for-key)."""
    rng = np.random.default_rng(8)
    reqs = [Request(prompt=rng.integers(1, 61, size=4).astype(np.int32),
                    max_new_tokens=5) for _ in range(3)]

    def run(slots):
        eng = ServeEngine(CFG, PARAMS, max_slots=slots, max_seq=MAX_SEQ,
                          prompt_granule=GRANULE, sampler="categorical",
                          temperature=0.8, seed=11)
        return _tokens(eng.generate(reqs))

    wide, narrow = run(4), run(1)
    assert wide == narrow
    assert all(0 <= t < CFG.vocab_size for toks in wide for t in toks)


def test_prefill_only_requests_never_decode():
    """max_new_tokens=1 is satisfied by the prefill logits alone: the slot
    retires at admission and the batch never pays a decode step for it."""
    rng = np.random.default_rng(9)
    reqs = [Request(prompt=rng.integers(1, 61, size=4).astype(np.int32),
                    max_new_tokens=1) for _ in range(3)]
    eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                      prompt_granule=GRANULE)
    results = eng.generate(reqs)
    assert all(r.steps == 1 for r in results)
    assert eng.stats.steps == 0 and eng.stats.retired == 3
    assert eng.stats.tokens_per_sec > 0  # prefill tokens feed the rate too
    assert _tokens(results) == [_oracle(CFG, PARAMS, r) for r in reqs]


def test_prompt_beyond_max_seq_raises():
    eng = ServeEngine(CFG, PARAMS, max_slots=2, max_seq=16, prompt_granule=8)
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(Request(prompt=np.ones(17, np.int32), max_new_tokens=2))


def test_unknown_sampler_raises():
    with pytest.raises(ValueError, match="sampler"):
        ServeEngine(CFG, PARAMS, sampler="beam")


@pytest.mark.slow
def test_every_rung_matches_oracle_pallas(golden):
    """The PR 7 kernel lane under the elastic ladder: every rung, with
    attn_impl='pallas' (fused paged decode + Pallas prefill), stays
    token-identical to the single-device XLA oracle."""
    reqs, expected = golden
    ladder = MeshLadder(granule=1)
    for rung in ladder:
        with use_plan(rung.plan):
            eng = ServeEngine(CFG, PARAMS, max_slots=4, max_seq=MAX_SEQ,
                              prompt_granule=GRANULE, attn_impl="pallas")
            assert _tokens(eng.generate(reqs)) == expected, f"rung dp{rung.dp}"
