"""Gram tier on transformers: probe forward == plain forward; kernel-based
per-sample grad norms == vmap(grad) restricted to covered parameters."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import probes as probes_lib
from repro.models import transformer as tf

CFG = ModelConfig(
    name="tiny", family="dense", num_layers=2, d_model=32, num_heads=4,
    num_kv_heads=2, d_ff=64, vocab_size=61, param_dtype="float32",
    compute_dtype="float32", xent_chunk=8, scan_layers=False, remat=False,
)


def _batch(b=3, s=16):
    key = jax.random.key(1)
    toks = jax.random.randint(key, (b, s), 0, CFG.vocab_size)
    return {"tokens": toks, "targets": toks}


def test_probe_forward_matches_plain():
    params = tf.init_params(CFG, jax.random.key(0))
    batch = _batch()
    probes = probes_lib.probe_specs(CFG, 3, 16)
    loss_p, acts = probes_lib.loss_with_probes(CFG, params, probes, batch)
    loss, _ = tf.loss_fn(CFG, params, batch)
    np.testing.assert_allclose(float(loss_p), float(loss), rtol=1e-6)
    assert len(acts) == len(probes)


def test_gram_matches_vmap_on_covered_params():
    params = tf.init_params(CFG, jax.random.key(0))
    batch = _batch()
    got = probes_lib.persample_sq_norms_gram(CFG, params, batch)

    # exact reference: vmap per-sequence grads, sq-norm over covered leaves
    def seq_loss(p, tokens, targets):
        mb = {"tokens": tokens[None], "targets": targets[None]}
        return tf.loss_fn(CFG, p, mb)[0]

    grads = jax.vmap(seq_loss and jax.grad(seq_loss), in_axes=(None, 0, 0))(
        params, batch["tokens"], batch["targets"]
    )
    covered = 0.0
    for p in range(CFG.period):
        blk = grads[f"pos{p}"]
        for path in ("attn/q", "attn/k", "attn/v", "attn/o"):
            g = blk["attn"][path.split("/")[1]]["kernel"]
            covered += jnp.sum(g.reshape(g.shape[0], -1) ** 2, axis=-1)
        for name in ("w_gate", "w_up", "w_out"):
            g = blk["ffn"][name]["kernel"]
            covered += jnp.sum(g.reshape(g.shape[0], -1) ** 2, axis=-1)
    # grads leading axis is the vmapped batch? vmap over sequences puts batch
    # first; block leaves are (B, R, ...) -> fold R into the norm
    # (handled above by reshape(B, -1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(covered), rtol=2e-4)


def test_coverage_reported():
    c = probes_lib.coverage(CFG)
    assert 0.3 < c < 1.0  # embeddings/lm_head excluded on this tiny config


def test_gram_on_gemma_style_pattern():
    cfg = CFG.replace(pattern=("attn_local", "attn"), window=4,
                      attn_softcap=30.0)
    params = tf.init_params(cfg, jax.random.key(0))
    batch = _batch()
    got = probes_lib.persample_sq_norms_gram(cfg, params, batch)
    assert got.shape == (3,)
    assert bool(jnp.all(got > 0)) and bool(jnp.all(jnp.isfinite(got)))
