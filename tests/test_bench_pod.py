"""CI smoke for the cross-pod benchmark: the `-m "not slow"`-safe variant
runs in seconds, must emit a well-formed BENCH_pod.json, and carries the
in-bench acceptance asserts (wire ratio <= 0.30x, EF residuals live)."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import bench_pod  # noqa: E402


def test_bench_pod_smoke(tmp_path):
    out = tmp_path / "BENCH_pod.json"
    rows = bench_pod.run(smoke=True, out_path=str(out))
    record = json.loads(out.read_text())
    assert record["workload"]["smoke"] is True
    for kind in ("uncompressed_pmean", "compressed_int8_ef"):
        r = record[kind]
        assert r["steps_per_sec"] > 0
        assert r["pods"] == 2 and r["rung_dp"] == 8  # the cross-pod rung
    wire = record["wire"]
    assert wire["wire_ratio"] <= record["wire_ratio_max"] == 0.30
    assert wire["compressed_bytes_per_exchange"] < wire["f32_bytes_per_exchange"]
    assert record["ef_residual_l1"] > 0
    assert record["val_loss_rel_err"] <= 0.10
    names = [name for name, _, _ in rows]
    assert "pod_compressed_int8_ef" in names and "pod_wire_ratio" in names
