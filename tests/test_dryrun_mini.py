"""Mini dry-run: the full lower+compile pipeline on an 8-device host mesh
(subprocess, since device count locks at first jax init). Exercises exactly
the code paths of the 512-chip production dry-run at test-friendly scale."""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, SHAPES, input_specs
from repro.configs.base import ShapeConfig
from repro.dist import sharding as shd
from repro.dist.plan import ShardingPlan, use_plan
from repro.models import transformer as tf
from repro.optim import sgd
from repro.train.state import init_state
from repro.train.engine import StepEngine
from repro.utils import hlo as hlo_lib

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
plan = ShardingPlan(mesh=mesh, dp=("pod", "data"), fsdp=("pod", "data"),
                    tp="model", ep=("pod", "data"))

for arch in ["qwen2-7b", "kimi-k2-1t-a32b", "jamba-v0.1-52b"]:
    cfg = get_config(arch, reduced=True).replace(scan_layers=True, remat=True)
    shape = ShapeConfig("mini_train", "train", 64, 16)
    opt = sgd(momentum=0.9)
    params_specs = tf.param_specs(cfg)
    state_specs = jax.eval_shape(lambda p: init_state(p, opt), params_specs)
    state_sh = shd.shardings_of(shd.infer_pspecs(state_specs, plan), plan)
    batch_specs = input_specs(cfg, shape)["batch"]
    batch_sh = shd.shardings_of(shd.batch_pspecs(batch_specs, plan), plan)
    # same engine path as launch/dryrun.py::build_train
    engine = StepEngine.for_lm(cfg, opt, dp_size=plan.dp_size,
                               moe_groups=plan.dp_size if cfg.num_experts else 1,
                               in_shardings=(state_sh, batch_sh, None),
                               out_shardings=(state_sh, None))
    with use_plan(plan, {"residual": P(("pod", "data"), None, "model")}):
        with mesh:
            lowered = engine.jitted(2).lower(
                state_specs, batch_specs, jax.ShapeDtypeStruct((), jnp.float32))
            compiled = lowered.compile()
    mem = compiled.memory_analysis()
    analysis = hlo_lib.analyze_hlo(compiled.as_text())
    assert analysis["flops"] > 0, arch
    assert mem.temp_size_in_bytes > 0, arch
    print("OK", arch, analysis["flops"], analysis["collectives"]["total_operand_bytes"])

# decode path on one arch
cfg = get_config("yi-6b", reduced=True)
cache_specs = tf.cache_specs(cfg, 16, 64)
cache_sh = shd.shardings_of(shd.cache_pspecs(cache_specs, plan), plan)
params_specs = tf.param_specs(cfg)
params_sh = shd.shardings_of(shd.infer_pspecs(params_specs, plan), plan)
tok = jax.ShapeDtypeStruct((16, 1), jnp.int32)
tok_sh = NamedSharding(mesh, P(("pod", "data"), None))
with mesh:
    compiled = jax.jit(lambda p, c, t: tf.decode_step(cfg, p, c, t),
                       in_shardings=(params_sh, cache_sh, tok_sh),
                       out_shardings=(None, cache_sh)).lower(
        params_specs, cache_specs, tok).compile()
print("OK decode", compiled.memory_analysis().temp_size_in_bytes)
"""


@pytest.mark.slow
def test_mini_dryrun_compiles():
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert res.stdout.count("OK") == 4, res.stdout
