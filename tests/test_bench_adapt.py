"""CI smoke for the adapt benchmark: the `-m "not slow"`-safe variant runs
in seconds and must emit a well-formed BENCH_adapt.json."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import bench_adapt  # noqa: E402


def test_bench_adapt_smoke(tmp_path):
    out = tmp_path / "BENCH_adapt.json"
    rows = bench_adapt.run(smoke=True, out_path=str(out))
    record = json.loads(out.read_text())
    assert record["workload"]["smoke"] is True
    for kind in ("epoch_boundary", "mid_epoch_tick", "gns"):
        r = record[kind]
        assert r["steps_per_sec"] > 0
        assert r["end_batch"] >= record["workload"]["granule"]
    # the tick run genuinely adapted mid-epoch; the epoch run did not
    assert record["mid_epoch_tick"]["mid_epoch_decisions"] > 0
    assert record["mid_epoch_tick"]["mid_epoch_resizes"] >= 1
    assert record["epoch_boundary"]["mid_epoch_decisions"] == 0
    # both schedules are recorded for the GNS-vs-DiveBatch comparison
    assert len(record["divebatch_schedule"]) == record["workload"]["epochs"]
    assert len(record["gns_schedule"]) == record["workload"]["epochs"]
    assert record["tick_vs_epoch_steps_per_sec"] > 0
    names = [name for name, _, _ in rows]
    assert {"adapt_epoch_boundary", "adapt_mid_epoch_tick",
            "adapt_gns", "adapt_tick_overhead"} <= set(names)
