"""Integration: Algorithm 1 end-to-end — adaptation, estimators, resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.core import AdaptiveBatchController, diversity, make_policy
from repro.data import sigmoid_synthetic
from repro.models import small
from repro.optim import sgd
from repro.train.loop import ModelFns, Trainer


def _mlp_setup(seed=0, n=2000, d=32):
    train, val, _ = sigmoid_synthetic(n=n, d=d, seed=seed)
    params = small.mlp_init(jax.random.key(seed), d)
    fns = ModelFns(
        batch_loss=small.mlp_batch_loss,
        example_loss=small.mlp_loss,
        metrics=lambda p, b: {"acc": small.mlp_accuracy(p, b)},
        probe_loss=small.mlp_batch_loss_with_probes,
        probe_specs=small.mlp_probe_specs,
    )
    return fns, params, train, val


def _controller(method="divebatch", n=2000, m0=64, m_max=512, delta=0.5, lr=0.5):
    return AdaptiveBatchController(
        make_policy(method, m0=m0, m_max=m_max, delta=delta, dataset_size=n, granule=16),
        base_lr=lr,
    )


def test_divebatch_grows_batch():
    fns, params, train, val = _mlp_setup()
    t = Trainer(fns, params, sgd(momentum=0.9), _controller(), train, val,
                estimator="exact")
    hist = t.run(3, verbose=False)
    assert hist[-1].batch_size > 64  # diversity-driven growth
    assert all(np.isfinite(h.val_loss) for h in hist)


def test_estimator_tiers_agree():
    """exact / gram / moment must produce comparable Delta_hat on the same
    trajectory (gram covers all MLP params = dense kernels+biases; biases
    make gram slightly lower; moment is stochastic)."""
    deltas = {}
    for est in ("exact", "gram", "moment"):
        fns, params, train, val = _mlp_setup(seed=1)
        t = Trainer(fns, params, sgd(), _controller(), train, val, estimator=est)
        hist = t.run(2, verbose=False)
        deltas[est] = hist[0].diversity
    # same order of magnitude; gram >= ~half of exact (kernel-only coverage)
    assert 0.3 < deltas["gram"] / deltas["exact"] < 1.05, deltas
    assert 0.5 < deltas["moment"] / deltas["exact"] < 2.0, deltas


def test_fixed_sgd_keeps_batch():
    fns, params, train, val = _mlp_setup()
    t = Trainer(fns, params, sgd(), _controller("sgd"), train, val, estimator="none")
    hist = t.run(2, verbose=False)
    assert all(h.batch_size == 64 for h in hist)


def test_adabatch_schedule():
    fns, params, train, val = _mlp_setup()
    c = AdaptiveBatchController(
        make_policy("adabatch", m0=64, m_max=512, resize_freq=2, granule=16),
        base_lr=0.5,
    )
    t = Trainer(fns, params, sgd(), c, train, val, estimator="none")
    hist = t.run(4, verbose=False)
    assert hist[0].batch_size == 64 and hist[1].batch_size == 128


def test_resume_reproduces_trajectory(tmp_path):
    """Fault tolerance: train 6 epochs straight vs 3 + crash + resume + 3 —
    identical loss trajectory (checkpoint carries ALL adaptive state)."""

    def build(mgr):
        fns, params, train, val = _mlp_setup(seed=2)
        return Trainer(fns, params, sgd(momentum=0.9), _controller(), train, val,
                       estimator="exact", ckpt=mgr, seed=7)

    t_full = build(None)
    full = t_full.run(6, verbose=False)

    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2)
    t1 = build(mgr)
    t1.run(3, verbose=False)
    t1.save()

    t2 = build(mgr)
    assert t2.resume()
    resumed = t2.run(3, verbose=False)[3:]  # run() returns full history incl. restored

    np.testing.assert_allclose(
        [h.val_loss for h in full[3:]], [h.val_loss for h in resumed], rtol=1e-5
    )
    assert [h.batch_size for h in full[3:]] == [h.batch_size for h in resumed]
    # the step counter survives the restart (checkpointed via extra)
    assert int(t2.state.step) == int(t_full.state.step)


def test_oracle_estimator_runs():
    fns, params, train, val = _mlp_setup(n=500)
    t = Trainer(fns, params, sgd(), _controller(n=500), train, val, estimator="oracle")
    hist = t.run(2, verbose=False)
    assert hist[0].diversity is not None and hist[0].diversity > 0
