"""PartitionSpec inference rules — pure logic, no devices required beyond 1.

Builds a fake multi-axis Mesh cheaply via an AbstractMesh so divisibility
resolution can be tested against the production (16,16)/(2,16,16) shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist.plan import ShardingPlan, abstract_mesh
from repro.dist.sharding import _fit_axes, batch_pspecs, cache_pspecs, infer_pspecs
from repro.models import transformer as tf


def _plan(multi=False):
    if multi:
        mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
        return ShardingPlan(mesh=mesh, dp=("pod", "data"), fsdp=("pod", "data"),
                            tp="model", ep=("pod", "data"))
    mesh = abstract_mesh((16, 16), ("data", "model"))
    return ShardingPlan(mesh=mesh, dp=("data",), fsdp=("data",), tp="model",
                        ep=("data",))


def _find(pspecs, path_frag):
    from repro.utils import pytree as ptu

    out = {}
    flat = ptu.tree_flatten_with_paths(
        jax.tree.map(lambda x: x, pspecs, is_leaf=lambda x: isinstance(x, P))
    )
    for path, leaf in flat:
        if path_frag in path:
            out[path] = leaf
    return out


class TestParamRules:
    def test_dense_arch_rules(self):
        cfg = get_config("qwen2-7b")
        specs = tf.param_specs(cfg)
        ps = infer_pspecs(specs, _plan())
        qk = _find(ps, "attn/q/kernel")
        assert list(qk.values())[0] == P(None, "data", "model")  # (R, d, H*hd)
        ok = _find(ps, "attn/o/kernel")
        assert list(ok.values())[0] == P(None, "model", "data")
        lm = _find(ps, "lm_head/kernel")
        assert list(lm.values())[0] == P(None, "model")  # d replicated, V tp
        norm = _find(ps, "final_norm/scale")
        assert list(norm.values())[0] == P(None)

    def test_vocab_not_divisible_stays_replicated(self):
        cfg = get_config("internvl2-1b")  # vocab 151655 (odd)
        specs = tf.param_specs(cfg)
        ps = infer_pspecs(specs, _plan())
        lm = list(_find(ps, "lm_head/kernel").values())[0]
        assert lm == P(None, None)

    def test_moe_expert_parallel(self):
        cfg = get_config("kimi-k2-1t-a32b")
        specs = tf.param_specs(cfg)
        ps = infer_pspecs(specs, _plan())
        wg = list(_find(ps, "ffn/w_gate").values())[0]
        assert wg == P(None, "data", None, "model")  # (R, E, d, ff)

    def test_moe_ep_over_pod_multi(self):
        cfg = get_config("kimi-k2-1t-a32b")
        specs = tf.param_specs(cfg)
        ps = infer_pspecs(specs, _plan(multi=True))
        wg = list(_find(ps, "ffn/w_gate").values())[0]
        assert wg == P(None, ("pod", "data"), None, "model")

    def test_mamba_channel_tp(self):
        cfg = get_config("falcon-mamba-7b")
        specs = tf.param_specs(cfg)
        ps = infer_pspecs(specs, _plan())
        a = list(_find(ps, "mamba/A_log").values())[0]
        assert a == P(None, "model", None)  # (R, d_inner, ds)

    def test_state_trees_shard_like_params(self):
        """momentum / grad_sum leaves match the param rules by suffix."""
        from repro.optim import sgd
        from repro.train.state import init_state

        cfg = get_config("yi-6b", reduced=True).replace(
            d_model=64, num_heads=4, num_kv_heads=2)
        specs = jax.eval_shape(
            lambda k: init_state(tf.init_params(cfg, k), sgd(momentum=0.9)),
            jax.random.key(0),
        )
        ps = infer_pspecs(specs, _plan())
        mom = _find(ps, "momentum/pos0/attn/q/kernel")
        par = _find(ps, "params/pos0/attn/q/kernel")
        assert list(mom.values())[0] == list(par.values())[0]
        div = _find(ps, "grad_sum/pos0/attn/q/kernel")
        assert list(div.values())[0] == list(par.values())[0]


class TestBatchCacheRules:
    def test_batch_sharded_over_dp(self):
        specs = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
        ps = batch_pspecs(specs, _plan(multi=True))
        assert ps["tokens"] == P(("pod", "data"), None)

    def test_batch_indivisible_replicated(self):
        specs = {"tokens": jax.ShapeDtypeStruct((3, 128), jnp.int32)}
        ps = batch_pspecs(specs, _plan())
        assert ps["tokens"] == P(None, None)

    def test_kv_cache_rules(self):
        cache = {
            "pos0": {
                "k": jax.ShapeDtypeStruct((32, 128, 32768, 8, 128), jnp.bfloat16),
                "v": jax.ShapeDtypeStruct((32, 128, 32768, 8, 128), jnp.bfloat16),
            },
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }
        ps = cache_pspecs(cache, _plan())
        # batch shards over data; kv=8 not divisible by 16 -> head_dim takes tp
        assert ps["pos0"]["k"] == P(None, "data", None, None, "model")

    def test_long_context_batch1_shards_sequence(self):
        cache = {
            "pos0": {
                "k": jax.ShapeDtypeStruct((4, 1, 524288, 8, 128), jnp.bfloat16),
                "v": jax.ShapeDtypeStruct((4, 1, 524288, 8, 128), jnp.bfloat16),
            },
        }
        ps = cache_pspecs(cache, _plan())
        assert ps["pos0"]["k"] == P(None, None, "data", None, "model")


class TestFitAxes:
    """Divisibility resolution: the largest-product axis subset that divides
    the dim wins; anything indivisible degrades to replication (None)."""

    def test_exact_single_axis(self):
        assert _fit_axes(32, ("data",), _plan()) == "data"

    def test_prime_dim_replicates(self):
        assert _fit_axes(7, ("data",), _plan()) is None
        assert _fit_axes(151655, ("data",), _plan()) is None  # odd vocab

    def test_prime_dim_multi_axis_replicates(self):
        assert _fit_axes(3, ("pod", "data"), _plan(multi=True)) is None

    def test_dim_smaller_than_axis_product(self):
        plan = _plan(multi=True)  # pod=2, data=16 -> product 32
        # 16 < 32: the 16-way 'data' axis alone divides and beats 'pod'
        assert _fit_axes(16, ("pod", "data"), plan) == "data"
        # 8: only the 2-way 'pod' axis divides
        assert _fit_axes(8, ("pod", "data"), plan) == "pod"
        # 2: exactly the pod axis
        assert _fit_axes(2, ("pod", "data"), plan) == "pod"

    def test_multi_axis_factorization(self):
        plan = _plan(multi=True)
        assert _fit_axes(64, ("pod", "data"), plan) == ("pod", "data")
        assert _fit_axes(256, ("pod", "data"), plan) == ("pod", "data")

    def test_zero_and_one_replicate(self):
        plan = _plan()
        assert _fit_axes(1, ("data",), plan) is None
        assert _fit_axes(0, ("data",), plan) is None

    def test_string_axes_accepted(self):
        assert _fit_axes(64, "model", _plan()) == "model"
        assert _fit_axes(9, "model", _plan()) is None
