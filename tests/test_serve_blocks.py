"""BlockPool property tests (model-free, no jax): random request-lifecycle
walks must never double-assign a block, never drive a refcount negative,
never leak a block after drain, and never re-prefill a registered shared
prefix.  Mirrors tests/test_serve_sched.py for the pool half of the paged
serving substrate."""

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.serve.blocks import BlockPool, PoolExhausted, chain_keys


def _stream(rid, nblocks, block, shared_prefix=0):
    """Deterministic per-request token stream; the first ``shared_prefix``
    blocks are request-independent (a shared system prompt)."""
    out = []
    for i in range(nblocks * block):
        salt = 0 if i < shared_prefix * block else rid * 131
        out.append(1 + (salt + i * 7) % 997)
    return out


# ---------------------------------------------------------------------------
# chain keys
# ---------------------------------------------------------------------------


def test_chain_keys_are_prefix_commitments():
    a = chain_keys(_stream(1, 4, 8, shared_prefix=2), 8)
    b = chain_keys(_stream(2, 4, 8, shared_prefix=2), 8)
    assert len(a) == len(b) == 4
    assert a[:2] == b[:2]  # shared blocks hash identically
    assert a[2] != b[2] and a[3] != b[3]  # divergence poisons the chain
    # same tokens, different block boundary -> different keys
    assert chain_keys(_stream(1, 4, 8), 4)[-1] != a[-1]


def test_chain_keys_reject_partial_blocks():
    with pytest.raises(ValueError, match="block-multiple"):
        chain_keys([1, 2, 3], 2)


# ---------------------------------------------------------------------------
# targeted unit invariants
# ---------------------------------------------------------------------------


def test_release_refuses_negative_refcount():
    pool = BlockPool(4, 8)
    bid = pool.alloc()
    pool.release(bid)
    with pytest.raises(ValueError, match="below 0"):
        pool.release(bid)


def test_alloc_never_double_assigns():
    pool = BlockPool(8, 4)
    ids = [pool.alloc() for _ in range(7)]
    assert len(set(ids)) == 7 and 0 not in ids  # distinct, sentinel excluded
    with pytest.raises(PoolExhausted):
        pool.alloc()


def test_reservation_credits():
    pool = BlockPool(6, 4)  # 5 usable
    pool.reserve(5)
    assert pool.available() == 0
    with pytest.raises(PoolExhausted):
        pool.reserve(1)
    with pytest.raises(PoolExhausted):
        pool.alloc()  # unreserved alloc cannot eat promised blocks
    ids = [pool.alloc(reserved=True) for _ in range(5)]
    assert len(set(ids)) == 5
    with pytest.raises(ValueError, match="no outstanding reservation"):
        pool.alloc(reserved=True)
    for b in ids:
        pool.release(b)
    with pytest.raises(ValueError, match="exceeds"):
        pool.unreserve(1)
    pool.check()


def test_cow_guards_shared_and_registered_blocks():
    pool = BlockPool(8, 4)
    keys = chain_keys(_stream(0, 1, 4), 4)
    bid = pool.alloc()
    assert pool.writable(bid)
    pool.register(keys[0], bid)
    assert not pool.writable(bid)  # registered: an in-place write would
    pool.retain(bid)  # corrupt the shared prefix
    new = pool.cow(bid)
    assert new != bid and pool.writable(new)
    assert pool.cow_copies == 1
    with pytest.raises(ValueError, match="exclusively"):
        pool.cow(new)
    pool.release(new)
    pool.release(bid)  # cow dropped the writer's ref; this is the last one
    assert pool.cached == 1  # registered: cached, not freed
    pool.check()


def test_shared_prefix_admission_never_reprefills():
    """Once a prompt chain is registered, an identical prompt matches every
    block — the engine adopts them instead of recomputing (and a cached
    block revived by ``retain`` keeps its contents matchable)."""
    pool = BlockPool(16, 8)
    toks = _stream(3, 4, 8)
    keys = chain_keys(toks, 8)
    ids = []
    for k in keys:
        b = pool.alloc()
        pool.register(k, b)
        ids.append(b)
    matched, ok = pool.admit_need(keys, 6)
    assert matched == ids and ok  # full match: zero blocks to prefill
    for b in ids:
        pool.release(b)  # request retires -> blocks park in the LRU cache
    assert pool.cached == 4 and pool.live == 0
    matched, ok = pool.admit_need(keys, 6)
    assert matched == ids  # sharing survives across non-overlapping requests
    for b in matched:
        pool.retain(b)
    assert pool.live == 4 and pool.cached == 0
    for b in matched:
        pool.release(b)
    pool.check()


def test_eviction_deregisters_lru_first():
    pool = BlockPool(4, 2)  # 3 usable
    keys = chain_keys(_stream(1, 3, 2), 2)
    ids = []
    for k in keys:
        b = pool.alloc()
        pool.register(k, b)
        ids.append(b)
    for b in ids:
        pool.release(b)  # all cached, LRU order = release order
    got = pool.alloc()  # must evict ids[0] (least recently cached)
    assert got == ids[0]
    assert pool.match(keys) == []  # chain broken at block 0
    pool.release(got)
    pool.check()


# ---------------------------------------------------------------------------
# random lifecycle walks
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(num_blocks=st.sampled_from([4, 8, 17, 40]),
       block=st.sampled_from([1, 4, 8]),
       seed=st.integers(min_value=0, max_value=9999))
def test_pool_walk_invariants(num_blocks, block, seed):
    import random

    rng = random.Random(seed)
    pool = BlockPool(num_blocks, block)
    live = {}  # rid -> {"table": [bid], "reserved": n, "decode_left": n}
    next_rid = 0
    for _ in range(200):
        op = rng.random()
        if op < 0.45:  # try to admit a request
            nb_prompt = rng.randint(1, max(1, (num_blocks - 1) // 2))
            decode = rng.randint(0, 3)
            shared = rng.randint(0, 2)
            toks = _stream(next_rid % 5, nb_prompt, block, shared_prefix=shared)
            keys = chain_keys(toks, block)
            total = nb_prompt + decode
            matched, ok = pool.admit_need(keys, total)
            assert len(matched) <= nb_prompt
            if not ok or total > num_blocks - 1:
                continue
            for b in matched:
                pool.retain(b)
            pool.reserve(total - len(matched))
            table = list(matched)
            while len(table) < nb_prompt:
                b = pool.alloc(reserved=True)
                # no double-assignment: a fresh block is in NO other table
                assert all(b not in st_["table"] for st_ in live.values())
                assert b not in table
                table.append(b)
            for k, b in zip(keys, table):
                pool.register(k, b)
            live[next_rid] = {
                "table": table,
                "reserved": total - nb_prompt,
                "decode_left": decode,
            }
            next_rid += 1
        elif op < 0.75 and live:  # one decode-block step for a random request
            rid = rng.choice(list(live))
            st_ = live[rid]
            if st_["decode_left"] > 0:
                b = pool.alloc(reserved=True)
                assert all(b not in o["table"] for o in live.values())
                st_["table"].append(b)
                st_["reserved"] -= 1
                st_["decode_left"] -= 1
        elif live:  # retire a random request
            rid = rng.choice(list(live))
            st_ = live.pop(rid)
            for b in st_["table"]:
                pool.release(b)
            if st_["reserved"]:
                pool.unreserve(st_["reserved"])
        pool.check()  # conservation + disjointness at every step
        assert pool.available() >= 0

    for rid, st_ in list(live.items()):  # drain
        for b in st_["table"]:
            pool.release(b)
        if st_["reserved"]:
            pool.unreserve(st_["reserved"])
    pool.check()
    # zero leaks: nothing live or promised once every request retired
    assert pool.live == 0 and pool.reserved == 0
    assert pool.free + pool.cached == pool.num_blocks - 1
