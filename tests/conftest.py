"""Pytest config: deterministic CPU test environment.

XLA_FLAGS must be set BEFORE the first jax import anywhere in the test
process — the device count locks at backend init.  Eight host devices let
the sharding/compression tests build real multi-device meshes in-process on
any machine; single-device code paths are unaffected (unsharded arrays live
on device 0).  Subprocess tests (dryrun, compression's shard_map case) still
set their own XLA_FLAGS first thing in the child.
"""

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
# XLA takes the LAST occurrence of a repeated flag: strip any pre-existing
# device-count setting so ours actually wins, then append.
_FLAG = "--xla_force_host_platform_device_count=8"
_rest = " ".join(
    f for f in os.environ.get("XLA_FLAGS", "").split()
    if not f.startswith("--xla_force_host_platform_device_count")
)
os.environ["XLA_FLAGS"] = (_rest + " " + _FLAG).strip()

import numpy as np  # noqa: E402
import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration tests (subprocess / multi-device)"
    )


@pytest.fixture(autouse=True)
def _seed_global_rngs():
    """Make tests that reach for np.random.* deterministic per-test."""
    np.random.seed(0)
    yield


@pytest.fixture
def rng():
    """Seeded NumPy Generator for tests that take randomness as input."""
    return np.random.default_rng(0)
