"""Pytest config. NOTE: no XLA_FLAGS here — smoke tests and benches must see
1 device; multi-device tests spawn subprocesses that set their own flags."""

import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")
