"""StepEngine: bucketed compile cache, donation, golden trajectory vs the
pre-engine host loop, and the in-jit diversity tiers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AdaptiveBatchController, diversity, make_policy
from repro.core.batch_policy import num_buckets
from repro.data import EpochLoader, sigmoid_synthetic
from repro.models import small
from repro.optim import apply_updates, sgd
from repro.train import StepEngine, init_state, make_train_step
from repro.train.loop import EpochRecord, ModelFns, Trainer
from repro.train.step import _to_micro

SEED, N, D = 3, 2048, 32


def _fns():
    return ModelFns(
        batch_loss=small.mlp_batch_loss,
        example_loss=small.mlp_loss,
        metrics=lambda p, b: {"acc": small.mlp_accuracy(p, b)},
        probe_loss=small.mlp_batch_loss_with_probes,
        probe_specs=small.mlp_probe_specs,
    )


def _controller(delta=0.08, m0=32, m_max=256):
    return AdaptiveBatchController(
        make_policy("divebatch", m0=m0, m_max=m_max, delta=delta,
                    dataset_size=N, granule=16),
        base_lr=0.5,
    )


def _reference_run(fns, train, epochs):
    """The pre-engine Trainer loop STRUCTURE: one host-side jit per batch
    (`value_and_grad` + update), separate psn/accumulate jits, no donation,
    per-step host round-trips. One deliberate semantic difference from the
    deleted loop: per-sample norms are evaluated at the same params the
    gradient used (the paper's Delta_S(theta): numerator and denominator
    share theta), where the old loop evaluated exact/gram psn at POST-update
    params, inconsistently with its own moment tier. The engine's in-jit
    tiers use the consistent pre-update theta, so this reference pins the
    engine's (corrected) semantics — see CHANGES.md."""
    params = small.mlp_init(jax.random.key(SEED), D)
    opt = sgd(momentum=0.9)
    opt_state = opt.init(params)
    div = diversity.init_state(params)
    ctrl = _controller()

    @jax.jit
    def sgd_step(p, o, b, lr):
        loss, grads = jax.value_and_grad(fns.batch_loss)(p, b)
        updates, o = opt.update(grads, o, p, lr)
        return apply_updates(p, updates), o, loss, grads

    psn_fn = jax.jit(
        lambda p, b: jnp.sum(diversity.persample_sq_norms(fns.example_loss, p, b))
    )
    acc_fn = jax.jit(diversity.accumulate)

    sizes = []
    for ep in range(epochs):
        bsz, lr = ctrl.batch_size, jnp.float32(ctrl.lr)
        for batch_np in EpochLoader(train, bsz, epoch=ep, seed=SEED):
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            psn = psn_fn(params, batch)
            params, opt_state, _, grads = sgd_step(params, opt_state, batch, lr)
            div = acc_fn(div, grads, bsz, psn)
        decision = ctrl.on_epoch_end(float(diversity.diversity_exact(div)))
        div = diversity.reset_state(div)
        sizes.append(decision.batch_size)
    return params, sizes


def test_golden_trajectory_bit_identical_across_buckets():
    """A DiveBatch run resizing across >=3 buckets through the engine must
    produce bit-identical params to the pre-engine host loop, with the
    compile count bounded by the bucket-lattice size."""
    train, val, _ = sigmoid_synthetic(n=N, d=D, seed=SEED)
    fns = _fns()
    ref_params, ref_sizes = _reference_run(fns, train, epochs=6)

    ctrl = _controller()
    t = Trainer(fns, small.mlp_init(jax.random.key(SEED), D), sgd(momentum=0.9),
                ctrl, train, val, estimator="exact", seed=SEED)
    hist = t.run(6, verbose=False)

    assert [h.batch_size for h in hist] == ref_sizes
    assert len(set(t.engine.stats.buckets)) >= 3  # genuinely spans >=3 buckets
    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(t.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # compile bound: <= log2(m_max/granule) + 1, via EngineStats
    assert t.engine.stats.compiles <= ctrl.compile_bound
    assert t.engine.stats.compiles == len(set(t.engine.stats.buckets))
    assert t.engine.stats.donate


def test_bucket_cache_hit_miss_accounting():
    train, val, _ = sigmoid_synthetic(n=512, d=16, seed=0)
    fns = ModelFns(batch_loss=small.logreg_batch_loss,
                   example_loss=small.logreg_loss)
    eng = StepEngine.for_model_fns(fns, sgd(), estimator="moment")
    state = init_state(small.logreg_init(jax.random.key(0), 16), sgd())
    batch = {k: jnp.asarray(v) for k, v in train.get(np.arange(64)).items()}
    state, _ = eng.step(state, batch, 0.1)
    state, _ = eng.step(state, batch, 0.1)
    assert eng.stats.compiles == 1 and eng.stats.bucket_misses == 1
    assert eng.stats.bucket_hits == 1 and eng.stats.steps == 2
    big = {k: jnp.asarray(v) for k, v in train.get(np.arange(128)).items()}
    state, _ = eng.step(state, big, 0.1)
    assert eng.stats.compiles == 2 and eng.stats.buckets == [64, 128]
    # returning to a seen bucket never recompiles
    batch = {k: jnp.asarray(v) for k, v in train.get(np.arange(64)).items()}
    state, _ = eng.step(state, batch, 0.1)
    assert eng.stats.compiles == 2 and eng.stats.bucket_hits == 2


def test_state_buffers_are_donated():
    train, _, _ = sigmoid_synthetic(n=256, d=16, seed=0)
    fns = ModelFns(batch_loss=small.logreg_batch_loss)
    eng = StepEngine.for_model_fns(fns, sgd(momentum=0.9), estimator="moment")
    state = init_state(small.logreg_init(jax.random.key(0), 16), sgd(momentum=0.9))
    batch = {k: jnp.asarray(v) for k, v in train.get(np.arange(32)).items()}
    old = state
    state, _ = eng.step(state, batch, 0.1)
    # donate_argnums=(0,) aliased the old state's buffers into the output
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(old.params))
    assert not any(leaf.is_deleted() for leaf in jax.tree.leaves(state.params))
    # and an engine built with donate=False keeps them alive
    eng2 = StepEngine.for_model_fns(fns, sgd(momentum=0.9), estimator="moment",
                                    donate=False)
    old = state
    state, _ = eng2.step(state, batch, 0.1)
    assert not any(leaf.is_deleted() for leaf in jax.tree.leaves(old.params))


def test_to_micro_rejects_off_lattice_batch():
    x = jnp.zeros((12, 4))
    with pytest.raises(ValueError, match="num_micro bucket 8"):
        _to_micro(x, 8, 1)
    # and through a full step build: batch of 12 cannot split into 8 micros
    step = make_train_step(None, sgd(), num_micro=8,
                           loss_fn=lambda p, b: jnp.sum(p["w"] * b["x"]),
                           diversity_on=False)
    with pytest.raises(ValueError, match="not divisible"):
        jax.eval_shape(step, {"w": x}, {"x": x}, jnp.float32(0.1))


def test_exact_tier_psn_chunking_matches_unchunked():
    """psn_chunk bounds the in-jit vmap width without changing the result
    (the Trainer's psn_microbatch still has its pre-engine meaning)."""
    train, _, _ = sigmoid_synthetic(n=256, d=32, seed=1)
    batch = {k: jnp.asarray(v) for k, v in train.get(np.arange(64)).items()}
    params = small.mlp_init(jax.random.key(1), 32)

    def delta(chunk):
        eng = StepEngine.for_model_fns(_fns(), sgd(), estimator="exact",
                                       donate=False, psn_chunk=chunk)
        state, _ = eng.step(init_state(params, sgd()), batch, 0.0)
        return np.asarray(state.div_state.sq_norm_sum)

    full, chunked = delta(None), delta(16)
    np.testing.assert_allclose(chunked, full, rtol=1e-6)


def test_for_lm_rejects_off_lattice_batch():
    """for_lm's bucket key is shape-exact: a batch not divisible by
    micro_batch must raise, never alias another bucket's executable."""
    eng = StepEngine.for_lm(None, sgd(), micro_batch=32)
    bad = {"tokens": jnp.zeros((48, 8), jnp.int32)}
    with pytest.raises(ValueError, match="micro_batch 32"):
        eng.step(None, bad, 0.1)


def test_epoch_end_host_jits_are_cached():
    from repro.train.step import _estimate_jit, _reset_jit

    assert _estimate_jit("moment") is _estimate_jit("moment")
    assert _reset_jit() is _reset_jit()


def test_num_buckets_lattice_size():
    assert num_buckets(256, 16) == 5   # {16, 32, 64, 128, 256}
    assert num_buckets(16, 16) == 1
    assert num_buckets(2048, 16) == 8
    assert _controller(m0=32, m_max=256).compile_bound == 5


def test_compile_bound_tracks_bucket_mode_and_m_min():
    """The bound must stay a HARD bound for every supported policy config,
    not just the pow2 default."""
    none_mode = AdaptiveBatchController(
        make_policy("divebatch", m0=16, m_max=256, delta=0.5, dataset_size=N,
                    granule=16, bucket_mode="none"),
        base_lr=0.5,
    )
    assert none_mode.compile_bound == 16  # every multiple of 16 up to 256
    off_lattice_min = AdaptiveBatchController(
        make_policy("divebatch", m0=32, m_max=256, delta=0.5, dataset_size=N,
                    granule=16, m_min=24),
        base_lr=0.5,
    )
    # an off-lattice m_min snaps UP to the next lattice point (32), so the
    # bound is exactly the lattice size — no extra clamp bucket
    assert off_lattice_min.compile_bound == 5
    assert off_lattice_min.policy.on_epoch_end(0, 0.0).batch_size == 32


def test_trainer_accepts_injected_engine_without_eval_fn():
    """Trainer owns the ModelFns, so a hand-built engine with no eval_fn must
    still evaluate at epoch boundaries."""
    train, val, _ = sigmoid_synthetic(n=256, d=16, seed=0)
    fns = ModelFns(batch_loss=small.logreg_batch_loss,
                   metrics=lambda p, b: {"acc": small.logreg_accuracy(p, b)})
    bare = StepEngine(
        lambda key: make_train_step(None, sgd(), num_micro=1,
                                    loss_fn=fns.batch_loss, diversity_on=False)
    )
    t = Trainer(fns, small.logreg_init(jax.random.key(0), 16), sgd(),
                AdaptiveBatchController(
                    make_policy("sgd", m0=32, m_max=32, granule=16),
                    base_lr=0.5),
                train, val, estimator="none", engine=bare)
    hist = t.run(1, verbose=False)
    assert np.isfinite(hist[0].val_loss) and "acc" in hist[0].val_metrics


def test_cache_key_includes_full_batch_signature():
    """Two batches with the same leading dim but different trailing shape
    must not share an AOT executable (shape-exact dispatch)."""
    fns = ModelFns(batch_loss=lambda p, b: jnp.mean((b["x"] @ p["w"]) ** 2))
    eng = StepEngine.for_model_fns(fns, sgd(), estimator="moment",
                                   diversity_on=False, donate=False)
    params = {"w": jnp.ones((8, 1))}
    state = init_state(params, sgd())
    state, _ = eng.step(state, {"x": jnp.ones((16, 8))}, 0.0)
    # same leading dim, wider feature dim: recompiles instead of crashing
    params2 = {"w": jnp.ones((12, 1))}
    state2 = init_state(params2, sgd())
    state2, _ = eng.step(state2, {"x": jnp.ones((16, 12))}, 0.0)
    assert eng.stats.compiles == 2 and eng.stats.bucket_hits == 0


def test_estimator_tiers_in_jit_consistent():
    """exact/gram/moment folded inside the jitted step must agree with the
    host-side estimators on identical data (one step, lr=0)."""
    train, _, _ = sigmoid_synthetic(n=256, d=32, seed=1)
    fns = _fns()
    batch = {k: jnp.asarray(v) for k, v in train.get(np.arange(64)).items()}
    params = small.mlp_init(jax.random.key(1), 32)

    deltas = {}
    for est in ("exact", "gram", "moment"):
        eng = StepEngine.for_model_fns(fns, sgd(), estimator=est, donate=False)
        state = init_state(params, sgd())
        state, _ = eng.step(state, batch, 0.0)
        fn = diversity.diversity_moment if est == "moment" else diversity.diversity_exact
        deltas[est] = float(fn(state.div_state))
    ref = float(diversity.diversity_exact(
        diversity.accumulate(
            diversity.init_state(params),
            jax.grad(lambda p: small.mlp_batch_loss(p, batch))(params), 64,
            jnp.sum(diversity.persample_sq_norms(small.mlp_loss, params, batch)),
        )
    ))
    np.testing.assert_allclose(deltas["exact"], ref, rtol=1e-5)
    assert 0.3 < deltas["gram"] / deltas["exact"] < 1.05
    assert deltas["moment"] > 0


def test_estimator_tier_flips_key_the_compile_cache():
    """(bucket, rung, tier) cache: a Decision.estimator flip compiles the
    new tier's bucket once, and flipping BACK onto a seen tier is a cache
    hit, not a recompile (closes the ROADMAP open item)."""
    train, _, _ = sigmoid_synthetic(n=512, d=32, seed=0)
    eng = StepEngine.for_model_fns(_fns(), sgd(), estimator="exact",
                                   donate=False)
    assert eng.tiered and eng.tier == "exact"
    params = small.mlp_init(jax.random.key(0), 32)
    batch = {k: jnp.asarray(v) for k, v in train.get(np.arange(64)).items()}
    state = init_state(params, sgd())
    state, _ = eng.step(state, batch, 0.1)
    eng.tier = "moment"
    state, _ = eng.step(state, batch, 0.1)
    assert eng.stats.compiles == 2
    eng.tier = "exact"
    state, _ = eng.step(state, batch, 0.1)
    assert eng.stats.compiles == 2 and eng.stats.bucket_hits == 1
    assert eng.stats.tiers == ["exact", "moment"]
    # the tier-extended accounting bound
    assert eng.stats.compiles == len(
        set(zip(eng.stats.buckets, eng.stats.rungs, eng.stats.tiers))
    )


def test_trainer_tier_flip_keeps_engine_and_cache():
    """On a tiered engine the Trainer applies a Decision.estimator by
    setting ``engine.tier`` — same engine object, jit family intact."""
    train, val, _ = sigmoid_synthetic(n=512, d=16, seed=0)
    fns = ModelFns(batch_loss=small.logreg_batch_loss,
                   example_loss=small.logreg_loss)
    t = Trainer(fns, small.logreg_init(jax.random.key(0), 16), sgd(),
                _controller(m0=32, m_max=64), train, val, estimator="exact")
    engine = t.engine
    t.run(1, verbose=False)
    jits_before = dict(engine._jits)
    t._apply_estimator("moment")
    assert t.engine is engine and engine.tier == "moment"
    assert t.estimator == "moment"
    for key, fn in jits_before.items():  # old tier's programs stay warm
        assert engine._jits[key] is fn
    t.run(1, verbose=False)
    assert set(engine.stats.tiers) == {"exact", "moment"}


def test_kwargs_build_counts_as_untiered():
    """Only genuinely positional parameters make a build tiered: a
    (key, **opts) build must not be handed a positional tier argument."""
    eng = StepEngine(
        lambda key, **opts: make_train_step(
            None, sgd(), num_micro=1,
            loss_fn=lambda p, b: jnp.sum(p["w"] * b["x"]),
            diversity_on=False)
    )
    assert not eng.tiered
    eng.jitted(1)  # would TypeError if misclassified as tiered


def test_for_lm_names_its_default_tier():
    """for_lm seeds engine.tier with the starting tier so a flip away and
    back is a cache hit, matching for_model_fns."""
    assert StepEngine.for_lm(None, sgd(), micro_batch=32).tier == "moment"
    assert StepEngine.for_lm(None, sgd(), micro_batch=32,
                             diversity_on=False).tier is None


def test_untiered_build_rejects_tier():
    """A hand-built engine whose build takes only (key) cannot honor a tier
    token — setting one must fail loudly, not silently ignore the flip."""
    eng = StepEngine(
        lambda key: make_train_step(None, sgd(), num_micro=1,
                                    loss_fn=lambda p, b: jnp.sum(p["w"]),
                                    diversity_on=False)
    )
    assert not eng.tiered
    eng.tier = "moment"
    with pytest.raises(ValueError, match="tier"):
        eng.jitted(1)


def test_trainer_under_dist_plan_matches_unsharded():
    """The same Trainer/engine code runs under a dist plan (dp-sharded
    batches on the 8-device test mesh) with an equivalent trajectory."""
    from repro.dist.plan import ShardingPlan, use_plan

    train, val, _ = sigmoid_synthetic(n=1024, d=16, seed=0)
    fns = ModelFns(batch_loss=small.logreg_batch_loss,
                   example_loss=small.logreg_loss,
                   metrics=lambda p, b: {"acc": small.logreg_accuracy(p, b)})

    def run(plan):
        ctx = use_plan(plan) if plan else _null()
        with ctx:
            t = Trainer(fns, small.logreg_init(jax.random.key(0), 16),
                        sgd(momentum=0.9), _controller(delta=0.2, m0=32, m_max=128),
                        train, val, estimator="exact", seed=0)
            return t.run(3, verbose=False)

    import contextlib as _ctl
    _null = _ctl.nullcontext
    base = run(None)
    mesh = jax.make_mesh((8,), ("data",))
    sharded = run(ShardingPlan(mesh=mesh))
    assert [h.batch_size for h in base] == [h.batch_size for h in sharded]
    np.testing.assert_allclose([h.val_loss for h in base],
                               [h.val_loss for h in sharded], rtol=1e-4)


def test_estimator_none_with_divebatch_degenerates_gracefully():
    """estimator='none' under a diversity-driven policy must not crash: the
    accumulators are never fed, so the estimate is a legitimate 0.0 (matches
    the pre-engine loop) and the policy collapses to its minimum bucket."""
    train, val, _ = sigmoid_synthetic(n=512, d=16, seed=0)
    fns = ModelFns(batch_loss=small.logreg_batch_loss)
    t = Trainer(fns, small.logreg_init(jax.random.key(0), 16), sgd(),
                _controller(m0=32, m_max=128), train, val, estimator="none")
    hist = t.run(2, verbose=False)
    assert hist[0].diversity == 0.0
    assert hist[-1].batch_size == 16  # bucket(0) -> granule floor


def test_run_logs_zero_diversity(monkeypatch):
    """A legitimate diversity of 0.0 must print as 0, not '-' (None)."""
    train, val, _ = sigmoid_synthetic(n=256, d=16, seed=0)
    fns = ModelFns(batch_loss=small.logreg_batch_loss)
    t = Trainer(fns, small.logreg_init(jax.random.key(0), 16), sgd(),
                _controller(m0=32, m_max=64), train, val, estimator="moment")
    rec = EpochRecord(epoch=0, batch_size=32, lr=0.5, train_loss=1.0,
                      val_loss=1.0, val_metrics={}, diversity=0.0, steps=8,
                      wall_s=0.1)
    lines = []
    monkeypatch.setattr(t, "run_epoch", lambda: rec)
    monkeypatch.setattr("repro.train.loop.log",
                        type("L", (), {"info": lambda *a: lines.append(a[-1])})())
    t.run(1, verbose=True)
    assert lines == ["0"]  # rendered via %s of the formatted diversity
    rec2 = EpochRecord(**{**rec.__dict__, "diversity": None})
    monkeypatch.setattr(t, "run_epoch", lambda: rec2)
    t.run(1, verbose=True)
    assert lines[-1] == "-"


def test_exact_tier_kernel_psn_matches_vmap():
    """psn_impl='kernel' replaces vmap-of-grad per-sample norms with one
    probe-gradient pass through the fused psgn lane. The MLP is
    bias-complete dense (every param sits in a probed kernel or bias), so
    the kernel path is mathematically exact — same sq_norm_sum, same
    trajectory."""
    train, _, _ = sigmoid_synthetic(n=256, d=32, seed=2)
    params = small.mlp_init(jax.random.key(2), 32)

    def run(impl):
        eng = StepEngine.for_model_fns(_fns(), sgd(), estimator="exact",
                                       donate=False, psn_impl=impl)
        state = init_state(params, sgd())
        for lo in (0, 64):
            batch = {k: jnp.asarray(v)
                     for k, v in train.get(np.arange(lo, lo + 64)).items()}
            state, _ = eng.step(state, batch, 0.1)
        return state

    ref, ker = run("vmap"), run("kernel")
    np.testing.assert_allclose(np.asarray(ker.div_state.sq_norm_sum),
                               np.asarray(ref.div_state.sq_norm_sum),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(ker.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_exact_tier_kernel_requires_probes():
    fns = ModelFns(batch_loss=small.mlp_batch_loss,
                   example_loss=small.mlp_loss)
    with pytest.raises(ValueError, match="probe_loss"):
        make_train_step(None, sgd(), num_micro=1, loss_fn=fns.batch_loss,
                        estimator="exact", psn_impl="kernel")
    with pytest.raises(ValueError, match="unknown psn_impl"):
        make_train_step(None, sgd(), num_micro=1, loss_fn=fns.batch_loss,
                        diversity_on=False, psn_impl="pallas?")


def test_for_lm_pallas_matches_dense_trajectory():
    """attn_impl='pallas' routes the training forward AND the recompute
    backward through kernels/attention.flash_attention; the trajectory must
    match the XLA dense path to float tolerance and be deterministic."""
    from repro.configs.base import ModelConfig
    from repro.models import transformer as tf

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=97,
                      param_dtype="float32", compute_dtype="float32",
                      xent_chunk=32, remat=False)
    rng = np.random.default_rng(7)
    toks = rng.integers(0, 97, size=(8, 17), dtype=np.int64)
    batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "targets": jnp.asarray(toks[:, 1:], jnp.int32)}
    params = tf.init_params(cfg, jax.random.key(5))

    def run(attn_impl):
        eng = StepEngine.for_lm(cfg, sgd(momentum=0.9), micro_batch=4,
                                donate=False, attn_impl=attn_impl)
        state = init_state(params, sgd(momentum=0.9))
        losses = []
        for _ in range(3):
            state, m = eng.step(state, batch, 0.05)
            losses.append(float(m["loss"]))
        return state, losses

    st_d, loss_d = run(None)
    st_p, loss_p = run("pallas")
    st_p2, loss_p2 = run("pallas")
    assert loss_p == loss_p2  # kernel lane is deterministic
    np.testing.assert_allclose(loss_p, loss_d, rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(st_d.params), jax.tree.leaves(st_p.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
