"""HLO analyzer: trip-count propagation, dot flops, collective accounting."""

import numpy as np

from repro.utils.hlo import HloProgram, model_flops, roofline_terms

_SAMPLE = """\
HloModule jit_f, is_scheduled=true, num_partitions=4

%add (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add.1 = f32[] add(%x, %y)
}

%body (p: (s32[], f32[8,16], f32[16,32])) -> (s32[], f32[8,16], f32[16,32]) {
  %p = (s32[], f32[8,16]{1,0}, f32[16,32]{1,0}) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %niv = s32[] add(%iv, %one)
  %a = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,32]{1,0} get-tuple-element(%p), index=2
  %dot.1 = f32[8,32]{1,0} dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,32]{1,0} all-reduce(%dot.1), replica_groups=[2,2]<=[4], to_apply=%add
  %na = f32[8,16]{1,0} slice(%ar), slice={[0:8], [0:16]}
  ROOT %t = (s32[], f32[8,16]{1,0}, f32[16,32]{1,0}) tuple(%niv, %na, %w)
}

%cond (p: (s32[], f32[8,16], f32[16,32])) -> pred[] {
  %p = (s32[], f32[8,16]{1,0}, f32[16,32]{1,0}) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv, %n), direction=LT
}

ENTRY %main (a: f32[8,16], w: f32[16,32]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %w = f32[16,32]{1,0} parameter(1)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]{1,0}, f32[16,32]{1,0}) tuple(%zero, %a, %w)
  %loop = (s32[], f32[8,16]{1,0}, f32[16,32]{1,0}) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%loop), index=1
}
"""


def test_trip_count_scaled_flops():
    prog = HloProgram(_SAMPLE)
    res = prog.analyze()
    # dot: 2*8*32*16 = 8192 flops, x5 trips = 40960
    assert res["flops"] == 2 * 8 * 32 * 16 * 5


def test_collective_counted_with_trips():
    prog = HloProgram(_SAMPLE)
    res = prog.analyze()
    ar = res["collectives"]["by_kind"]["all-reduce"]
    assert ar["count"] == 5
    assert ar["operand_bytes"] == 5 * 8 * 32 * 4


def test_group_size_parsed():
    prog = HloProgram(_SAMPLE)
    # iota format [2,2]<=[4] -> group size 2 -> ring factor 1/2, x2 for AR
    res = prog.analyze()
    ar = res["collectives"]["by_kind"]["all-reduce"]
    expect = 5 * (2 * 0.5 * 8 * 32 * 4 / 50e9)
    np.testing.assert_allclose(ar["time_s"], expect, rtol=1e-6)


def test_roofline_terms():
    t = roofline_terms(flops=197e12, hbm_bytes=819e9, collective_time_s=0.5)
    np.testing.assert_allclose(t["compute_s"], 1.0)
    np.testing.assert_allclose(t["memory_s"], 1.0)
    assert t["dominant"] in ("compute_s", "memory_s")
    assert t["step_time_lower_bound_s"] == 1.0


def test_model_flops():
    assert model_flops(1e9, 1e6, "train") == 6e15
    assert model_flops(1e9, 1e6, "infer") == 2e15
