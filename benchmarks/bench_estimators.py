"""Beyond-paper: estimator-tier study — accuracy (vs exact) and cost of the
gram and moment estimators that make DiveBatch viable at 7B..1T scale."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diversity
from repro.data import sigmoid_synthetic
from repro.kernels import ops as kernel_ops
from repro.models import small


def run() -> list[tuple[str, float, str]]:
    rows = []
    train, _, _ = sigmoid_synthetic(n=2048, d=256, seed=0)
    params = small.mlp_init(jax.random.key(0), 256)
    batch = {k: jnp.asarray(v) for k, v in train.get(np.arange(1024)).items()}

    # exact tier
    psn_exact = jax.jit(lambda p, b: diversity.persample_sq_norms(small.mlp_loss, p, b))
    psn_exact(params, batch).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        e = psn_exact(params, batch)
    e.block_until_ready()
    t_exact = (time.time() - t0) / 5
    exact_sum = float(jnp.sum(e))

    # gram tier (probe trick + Pallas psgn kernels)
    @jax.jit
    def psn_gram(p, b):
        probes = small.mlp_probe_specs(p, 1024)
        (loss, acts), pg = jax.value_and_grad(
            small.mlp_batch_loss_with_probes, argnums=1, has_aux=True
        )(p, probes, b)
        return kernel_ops.persample_sq_norm_tree(acts, pg, scale=1024.0)

    psn_gram(params, batch).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        g = psn_gram(params, batch)
    g.block_until_ready()
    t_gram = (time.time() - t0) / 5
    gram_sum = float(jnp.sum(g))

    # moment tier: statistical agreement over microbatched epoch
    div = diversity.init_state(params)
    div_m = diversity.init_state(params)
    grad_fn = jax.jit(jax.grad(small.mlp_batch_loss))
    t0 = time.time()
    for i in range(0, 1024, 64):
        mb = {k: v[i : i + 64] for k, v in batch.items()}
        gr = grad_fn(params, mb)
        psn = psn_exact(params, mb).sum()
        div = diversity.accumulate(div, gr, 64, psn)
        div_m = diversity.accumulate(div_m, gr, 64, None)
    t_moment = time.time() - t0
    d_exact = float(diversity.diversity_exact(div))
    d_moment = float(diversity.diversity_moment(div_m))

    rows.append(("estimator_exact", t_exact * 1e6,
                 f"sum_psn={exact_sum:.4g}"))
    rows.append(("estimator_gram", t_gram * 1e6,
                 f"coverage_ratio={gram_sum/exact_sum:.4f};speedup_vs_exact={t_exact/t_gram:.2f}x"))
    rows.append(("estimator_moment", 0.0,
                 f"delta_ratio_vs_exact={d_moment/d_exact:.4f}"))
    return rows
