"""Paper Table 2: peak memory by method. Two views:
  * measured: RSS delta around one epoch of each method (CPU process);
  * modelled: analytic accumulator/estimator bytes — the structural cost the
    paper attributes to BackPACK (2x peak), vs this system's estimator tiers
    (probe/gram: O(activations); moment: O(1) extra).
"""

from __future__ import annotations

import resource
import time

import jax
import numpy as np

from repro.core import AdaptiveBatchController, make_policy
from repro.data import sigmoid_synthetic
from repro.models import small
from repro.optim import sgd
from repro.train.loop import ModelFns, Trainer
from repro.utils import pytree as ptu


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024


def run() -> list[tuple[str, float, str]]:
    train, val, _ = sigmoid_synthetic(n=8000, d=256, seed=0)
    rows = []
    for name, method, est in [
        ("sgd", "sgd", "none"),
        ("divebatch_exact", "divebatch", "exact"),
        ("divebatch_gram", "divebatch", "gram"),
        ("divebatch_moment", "divebatch", "moment"),
    ]:
        params = small.mlp_init(jax.random.key(0), 256)
        fns = ModelFns(small.mlp_batch_loss, small.mlp_loss,
                       lambda p, b: {"acc": small.mlp_accuracy(p, b)},
                       probe_loss=small.mlp_batch_loss_with_probes,
                       probe_specs=small.mlp_probe_specs)
        ctrl = AdaptiveBatchController(
            make_policy(method, m0=256, m_max=2048, delta=0.5,
                        dataset_size=len(train), granule=16),
            base_lr=0.5,
        )
        t = Trainer(fns, params, sgd(momentum=0.9), ctrl, train, val,
                    estimator=est, psn_microbatch=512)
        rss0 = _rss_mb()
        t0 = time.time()
        t.run(2, verbose=False)
        wall = time.time() - t0
        # modelled extra bytes for the diversity machinery
        p_bytes = ptu.tree_bytes(params)
        if est == "exact":
            extra = 512 * p_bytes  # vmap per-sample grads (psn microbatch)
        elif est == "gram":
            extra = 2 * 256 * (256 + 33) * 4  # probes+acts per microbatch
        elif est == "moment":
            extra = p_bytes  # grad_sum accumulator only
        else:
            extra = 0
        rows.append((
            f"table2_{name}",
            wall / 2 * 1e6,
            f"rss_peak_mb={_rss_mb():.1f};rss_delta_mb={_rss_mb()-rss0:.1f};"
            f"modelled_extra_bytes={extra};param_bytes={p_bytes}",
        ))
    return rows
