"""Paper Figure 1/2: synthetic convex + nonconvex convergence, DiveBatch vs
fixed-batch SGD vs Oracle. CPU-scaled (d=128, n=4000) but same protocol:
grid of methods, batch-size trajectories, epochs-to-threshold."""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import AdaptiveBatchController, make_policy, step_decay
from repro.data import sigmoid_synthetic
from repro.models import small
from repro.optim import sgd
from repro.train.loop import ModelFns, Trainer

EPOCHS = 12


def _run(task: str, method: str, estimator: str, seed: int = 0,
         delta: float | None = None, lr_rule: str = "none"):
    train, val, _ = sigmoid_synthetic(n=4000, d=128, seed=seed)
    if task == "convex":
        params = small.logreg_init(jax.random.key(seed), 128)
        fns = ModelFns(small.logreg_batch_loss, small.logreg_loss,
                       lambda p, b: {"acc": small.logreg_accuracy(p, b)})
    else:
        params = small.mlp_init(jax.random.key(seed), 128)
        fns = ModelFns(small.mlp_batch_loss, small.mlp_loss,
                       lambda p, b: {"acc": small.mlp_accuracy(p, b)})
    if delta is None:
        delta = 1.0 if task == "convex" else 0.1
    ctrl = AdaptiveBatchController(
        make_policy(method if method != "oracle" else "divebatch",
                    m0=64, m_max=1024, delta=delta,
                    dataset_size=len(train), granule=16),
        base_lr=2.0 if task == "convex" else 0.5,
        lr_rule=lr_rule,
        lr_schedule=step_decay(0.75, 20),
    )
    t = Trainer(fns, params, sgd(momentum=0.9), ctrl, train, val,
                estimator=estimator, seed=seed)
    t0 = time.time()
    hist = t.run(EPOCHS, verbose=False)
    return hist, time.time() - t0


def _epochs_to_within(hist, tol=0.01):
    final = hist[-1].val_metrics["acc"]
    for h in hist:
        if h.val_metrics["acc"] >= final - tol:
            return h.epoch + 1
    return len(hist)


def run() -> list[tuple[str, float, str]]:
    rows = []
    for task in ("convex", "nonconvex"):
        results = {}
        for method, est in [("sgd", "none"), ("divebatch", "exact"), ("oracle", "oracle")]:
            hist, wall = _run(task, method, est)
            results[method] = hist
            ep = _epochs_to_within(hist)
            rows.append((
                f"synthetic_{task}_{method}",
                wall / EPOCHS * 1e6,
                f"final_acc={hist[-1].val_metrics['acc']:.4f};"
                f"epochs_to_1pct={ep};end_batch={hist[-1].batch_size}",
            ))
        # estimate vs oracle diversity agreement (paper fig. 2)
        dd = [h.diversity for h in results["divebatch"] if h.diversity]
        do = [h.diversity for h in results["oracle"] if h.diversity]
        if dd and do:
            k = min(len(dd), len(do))
            corr = np.corrcoef(dd[:k], do[:k])[0, 1] if k > 2 else float("nan")
            rows.append((
                f"synthetic_{task}_estimate_vs_oracle", 0.0,
                f"corr={corr:.3f};mean_ratio={np.mean(np.array(dd[:k])/np.array(do[:k])):.3f}",
            ))

    # paper's delta grid (§5.1: "surprisingly, large delta performs better"):
    # end batch + accuracy across delta, convex case
    for delta in (0.01, 0.1, 1.0):
        hist, _ = _run("convex", "divebatch", "exact", delta=delta)
        rows.append((
            f"synthetic_delta_grid_{delta}", 0.0,
            f"end_batch={hist[-1].batch_size};final_acc={hist[-1].val_metrics['acc']:.4f}",
        ))
    # appendix E ablation: linear LR rescaling destabilises the trajectory
    hist, _ = _run("convex", "divebatch", "exact", lr_rule="linear")
    accs = [h.val_metrics["acc"] for h in hist]
    rows.append((
        "synthetic_lr_rescaling_ablation", 0.0,
        f"final_acc={accs[-1]:.4f};min_acc={min(accs):.4f};acc_std={np.std(accs):.4f}",
    ))
    return rows
