"""Cross-pod compressed vs uncompressed gradient exchange: end-to-end
steps/sec and the DCN wire-byte model on the 8-device (2-virtual-pod) CPU
harness. Writes ``BENCH_pod.json`` at the repo root.

Both runs sit on the same 2-pod ``PodLadder`` rung with the same FixedPolicy
schedule; the only difference is the cross-pod reduction: an exact f32
``pmean`` vs the error-feedback int8 compressor (``dist/compression.py``).
The wire model counts what each pod actually all-gathers per step over the
pod (DCN) axis — f32 leaves vs int8 payload + one f32 scale per leaf — and
the bench ASSERTS the compressed exchange moves <= 0.30x the uncompressed
bytes, plus that the compressed trajectory stays within quantization
tolerance of the exact one (error feedback keeps the bias from compounding).

  PYTHONPATH=src python -m benchmarks.bench_pod [--smoke] [--out PATH]

``run(smoke=True)`` is the CI variant (seconds, not minutes).
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.utils.xla_env import force_host_device_count

# Cross-pod rungs need the multi-device harness. Effective only before the
# first jax backend init (a no-op under pytest, where conftest already
# forced 8 devices).
force_host_device_count(8)

import jax
import numpy as np

from repro.adapt import AdaptationProgram, FixedPolicy
from repro.data import sigmoid_synthetic
from repro.models import small
from repro.optim import sgd
from repro.pod import PodLadder
from repro.train.loop import ModelFns, Trainer

_DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_pod.json")

#: acceptance ceiling: compressed DCN bytes per exchange vs uncompressed f32
WIRE_RATIO_MAX = 0.30


def _wire_model(params) -> dict:
    """Bytes ONE pod ships over the pod (DCN) axis per cross-pod exchange.

    Uncompressed: every gradient leaf as f32.  Compressed: the int8 payload
    plus one f32 absmax scale per leaf (the exact wire format
    ``compressed_pod_mean`` all-gathers).  Error-feedback residuals stay
    pod-local — they cost memory, never wire bytes.
    """
    sizes = [int(np.prod(np.shape(p))) for p in jax.tree.leaves(params)]
    f32_bytes = sum(s * 4 for s in sizes)
    comp_bytes = sum(s * 1 + 4 for s in sizes)
    return {
        "leaves": len(sizes),
        "grad_elements": sum(sizes),
        "f32_bytes_per_exchange": f32_bytes,
        "compressed_bytes_per_exchange": comp_bytes,
        "wire_ratio": round(comp_bytes / f32_bytes, 4),
    }


def _train(compress: bool, *, n: int, d: int, m: int, epochs: int,
           seed: int = 0):
    """One FixedPolicy run pinned to the 2-pod cross rung."""
    train, val, _ = sigmoid_synthetic(n=n, d=d, seed=seed)
    fns = ModelFns(
        batch_loss=small.mlp_batch_loss,
        example_loss=small.mlp_loss,
        metrics=lambda p, b: {"acc": small.mlp_accuracy(p, b)},
    )
    ladder = PodLadder(pods=2, granule=16, compress=compress)
    program = AdaptationProgram(FixedPolicy(m, m, granule=16), base_lr=0.5)
    t = Trainer(fns, small.mlp_init(jax.random.key(seed), d),
                sgd(momentum=0.9), program, train, val, estimator="exact",
                seed=seed, elastic=ladder)
    assert t.rung.pods == 2, f"batch {m} must land on the cross-pod rung"
    t0 = time.time()
    hist = t.run(epochs, verbose=False)
    wall = time.time() - t0
    steps = sum(h.steps for h in hist)
    return t, {
        "compress": compress,
        "devices": len(jax.devices()),
        "pods": t.rung.pods,
        "rung_dp": t.rung.dp,
        "steps": steps,
        "wall_s": round(wall, 3),
        "steps_per_sec": round(steps / wall, 2) if wall > 0 else 0.0,
        "compiles": t.engine.stats.compiles,
        "final_train_loss": round(hist[-1].train_loss, 6),
        "final_val_loss": round(hist[-1].val_loss, 6),
    }


def run(smoke: bool = False, out_path: str | None = None):
    """Returns benchmark CSV rows; writes the JSON record as a side effect."""
    scale = dict(n=2048, d=32, m=128, epochs=2) if smoke \
        else dict(n=16384, d=64, m=256, epochs=6)

    t_exact, exact = _train(False, **scale)
    t_comp, comp = _train(True, **scale)

    wire = _wire_model(t_comp.state.params)
    # max param drift vs the exact-pmean run, relative to each tensor's scale
    drift = max(
        float(np.max(np.abs(np.asarray(a) - np.asarray(b)))
              / max(float(np.max(np.abs(np.asarray(b)))), 1.0))
        for a, b in zip(jax.tree.leaves(t_comp.state.params),
                        jax.tree.leaves(t_exact.state.params))
    )
    err_l1 = sum(float(np.abs(np.asarray(e)).sum())
                 for e in jax.tree.leaves(t_comp.state.err_state))

    record = {
        "workload": {"task": "synthetic-nonconvex-mlp", **scale,
                     "estimator": "exact", "smoke": smoke},
        "uncompressed_pmean": exact,
        "compressed_int8_ef": comp,
        "wire": wire,
        "wire_ratio_max": WIRE_RATIO_MAX,
        "param_drift_vs_exact": round(drift, 6),
        "ef_residual_l1": round(err_l1, 6),
        "val_loss_rel_err": round(
            abs(comp["final_val_loss"] - exact["final_val_loss"])
            / max(abs(exact["final_val_loss"]), 1e-9), 6),
    }
    path = os.path.abspath(out_path or _DEFAULT_OUT)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)

    # acceptance: cross-pod rungs move <= 0.30x the uncompressed bytes ...
    assert wire["wire_ratio"] <= WIRE_RATIO_MAX, wire
    # ... without the quantization noise derailing convergence (per-tensor
    # drift is recorded but not asserted: a nonconvex trajectory amplifies
    # any perturbation over hundreds of steps while the loss still agrees)
    assert record["val_loss_rel_err"] <= 0.10, record
    assert err_l1 > 0.0, "error-feedback residuals are silently zero"

    rows = []
    for name, r in (("pod_uncompressed_pmean", exact),
                    ("pod_compressed_int8_ef", comp)):
        rows.append((
            name,
            1e6 / r["steps_per_sec"] if r["steps_per_sec"] else 0.0,
            f"steps_per_sec={r['steps_per_sec']};"
            f"final_val_loss={r['final_val_loss']}",
        ))
    rows.append((
        "pod_wire_ratio", 0.0,
        f"wire_ratio={wire['wire_ratio']};max={WIRE_RATIO_MAX};"
        f"param_drift={record['param_drift_vs_exact']};"
        f"json={os.path.basename(path)}",
    ))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    for name, us, derived in run(smoke=args.smoke, out_path=args.out):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
