"""StepEngine perf trajectory: steps/sec + recompile counts, fixed vs
adaptive batch, on the synthetic workload. Writes ``BENCH_engine.json`` at
the repo root — the record future engine/scaling PRs regress against.

  PYTHONPATH=src python -m benchmarks.bench_engine [--smoke] [--out PATH]

``run(smoke=True)`` is the CI variant (seconds, not minutes); the fast test
lane exercises it via tests/test_bench_engine.py.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.core import AdaptiveBatchController, make_policy
from repro.data import sigmoid_synthetic
from repro.models import small
from repro.obs import Tracer
from repro.optim import sgd
from repro.train.loop import ModelFns, Trainer

_DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json")


def _train(method: str, *, n: int, d: int, m0: int, m_max: int, epochs: int,
           estimator: str, seed: int = 0, tracer=None):
    train, val, _ = sigmoid_synthetic(n=n, d=d, seed=seed)
    fns = ModelFns(
        batch_loss=small.mlp_batch_loss,
        example_loss=small.mlp_loss,
        metrics=lambda p, b: {"acc": small.mlp_accuracy(p, b)},
    )
    ctrl = AdaptiveBatchController(
        make_policy(method, m0=m0, m_max=m_max, delta=0.08, dataset_size=n,
                    granule=16),
        base_lr=0.5,
    )
    t = Trainer(fns, small.mlp_init(jax.random.key(seed), d), sgd(momentum=0.9),
                ctrl, train, val,
                estimator=estimator if method == "divebatch" else "none",
                seed=seed, tracer=tracer)
    t0 = time.time()
    hist = t.run(epochs, verbose=False)
    wall = time.time() - t0
    stats = t.engine.stats
    steps = sum(h.steps for h in hist)
    return {
        "steps": steps,
        "wall_s": round(wall, 3),
        # end-to-end (includes epoch-boundary eval + controller work) ...
        "steps_per_sec": round(steps / wall, 2) if wall > 0 else 0.0,
        # ... and dispatch-only, from the engine's own accounting
        "dispatch_steps_per_sec": round(stats.dispatch_steps_per_sec, 2),
        "compiles": stats.compiles,
        "compile_bound": ctrl.compile_bound,
        "compile_s": round(stats.compile_s, 3),
        "bucket_hits": stats.bucket_hits,
        "bucket_misses": stats.bucket_misses,
        "buckets": stats.buckets,
        "donated": stats.donate,
        "end_batch": hist[-1].batch_size,
        "final_val_loss": round(hist[-1].val_loss, 6),
    }


def run(smoke: bool = False, out_path: str | None = None):
    """Returns benchmark CSV rows; writes the JSON record as a side effect."""
    scale = dict(n=1024, d=32, m0=32, m_max=128, epochs=2) if smoke else \
        dict(n=8192, d=128, m0=64, m_max=1024, epochs=10)
    fixed = _train("sgd", estimator="none", **scale)
    adaptive = _train("divebatch", estimator="exact", **scale)
    # same adaptive workload with a LIVE repro.obs tracer recording every
    # dispatch/compile/observe span — the enabled-telemetry cost ceiling
    # (the disabled-path cost, one branch per step, is pinned separately by
    # the deterministic overhead guard in tests/test_obs.py)
    traced = _train("divebatch", estimator="exact", tracer=Tracer(), **scale)
    obs_overhead = (
        adaptive["steps_per_sec"] / traced["steps_per_sec"]
        if traced["steps_per_sec"] else 0.0
    )

    record = {
        "workload": {"task": "synthetic-nonconvex-mlp", **scale, "smoke": smoke},
        "fixed": fixed,
        "adaptive": adaptive,
        "traced": traced,
        "obs_overhead": round(obs_overhead, 4),
    }
    path = os.path.abspath(out_path or _DEFAULT_OUT)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)

    rows = []
    for name, r in (("engine_fixed_batch", fixed), ("engine_adaptive_batch", adaptive)):
        assert r["compiles"] <= r["compile_bound"], (name, r)
        rows.append((
            name,
            1e6 / r["steps_per_sec"] if r["steps_per_sec"] else 0.0,
            f"steps_per_sec={r['steps_per_sec']};compiles={r['compiles']}"
            f"/bound{r['compile_bound']};end_batch={r['end_batch']}",
        ))
    rows.append((
        "engine_adaptive_overhead", 0.0,
        f"adaptive_vs_fixed_steps_per_sec="
        f"{adaptive['steps_per_sec'] / max(fixed['steps_per_sec'], 1e-9):.3f};"
        f"recompiles={adaptive['compiles']};json={os.path.basename(path)}",
    ))
    # informational wall ratio (noisy on shared CI — the deterministic
    # disabled-path guard lives in tests/test_obs.py); the loose bound only
    # catches an enabled tracer going pathological
    assert obs_overhead < 1.5, f"enabled tracer cost blew up: {obs_overhead:.3f}x"
    rows.append((
        "engine_obs_overhead", 0.0,
        f"untraced_vs_traced_steps_per_sec={obs_overhead:.3f};"
        f"traced_steps_per_sec={traced['steps_per_sec']}",
    ))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    for name, us, derived in run(smoke=args.smoke, out_path=args.out):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
