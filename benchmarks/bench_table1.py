"""Paper Table 1: validation accuracy at 25/50/75/100% of training + time to
within ±1% of final accuracy, for SGD(small), SGD(large), AdaBatch, DiveBatch
on the CIFAR-shaped procedural task (ResNet-GN, CPU-scaled)."""

from __future__ import annotations

import time

import jax

from repro.core import AdaptiveBatchController, make_policy
from repro.data import imagelike_classification
from repro.models import resnet
from repro.optim import sgd
from repro.train.loop import ModelFns, Trainer

EPOCHS = 12
M0, MMAX = 64, 512


def _trainer(method: str, m0: int, m_max: int, estimator: str, train, val, seed=0):
    params = resnet.resnet_init(jax.random.key(seed), depth=8, width=8,
                                num_classes=10)
    fns = ModelFns(resnet.resnet_batch_loss, resnet.resnet_loss,
                   lambda p, b: {"acc": resnet.resnet_accuracy(p, b)})
    ctrl = AdaptiveBatchController(
        make_policy(method, m0=m0, m_max=m_max, delta=0.1,
                    dataset_size=len(train), granule=16, resize_freq=3),
        base_lr=0.1,
    )
    return Trainer(fns, params, sgd(momentum=0.9, weight_decay=5e-4), ctrl,
                   train, val, estimator=estimator, seed=seed, psn_microbatch=64)


def _time_to_final(hist, wall_per_epoch, tol=0.01):
    final = hist[-1].val_metrics["acc"]
    for h in hist:
        if h.val_metrics["acc"] >= final - tol:
            return (h.epoch + 1) * wall_per_epoch, h.epoch + 1
    return len(hist) * wall_per_epoch, len(hist)


def run() -> list[tuple[str, float, str]]:
    train, val = imagelike_classification(n=4000, hw=16, num_classes=10,
                                          noise=0.7, template_rank=4, seed=0)
    rows = []
    for name, method, m0, mmax, est in [
        ("sgd_small", "sgd", M0, M0, "none"),
        ("sgd_large", "sgd", MMAX, MMAX, "none"),
        ("adabatch", "adabatch", M0, MMAX, "none"),
        ("divebatch", "divebatch", M0, MMAX, "exact"),
    ]:
        t = _trainer(method, m0, mmax, est, train, val)
        t0 = time.time()
        hist = t.run(EPOCHS, verbose=False)
        wall = time.time() - t0
        accs = [h.val_metrics["acc"] for h in hist]
        q = lambda f: accs[max(int(len(accs) * f) - 1, 0)]
        tt, ep = _time_to_final(hist, wall / EPOCHS)
        rows.append((
            f"table1_{name}",
            wall / EPOCHS * 1e6,
            f"acc25={q(.25):.3f};acc50={q(.5):.3f};acc75={q(.75):.3f};"
            f"acc100={q(1.):.3f};time_to_1pct_s={tt:.1f};epochs_to_1pct={ep};"
            f"end_batch={hist[-1].batch_size}",
        ))
    return rows
