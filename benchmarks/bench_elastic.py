"""Elastic vs fixed-full-mesh adaptive training: end-to-end steps/sec on the
8-device CPU harness. Writes ``BENCH_elastic.json`` at the repo root.

The comparison both baselines run the SAME DiveBatch schedule (same seeds,
same policy, same diversity estimator); the only difference is the sharding
plan: the fixed baseline pins the full data-parallel mesh for the whole run
(today's ``--dp N`` behaviour), the elastic run lets a ``repro.elastic``
``MeshLadder`` pick the widest rung whose per-device microbatch stays >= the
granule. Early small-batch epochs are where the fixed mesh pays: a batch of
32 over 8 CPU devices is 4 samples per device plus a cross-device reduce,
while the ladder runs it 16-per-device on 2 devices.

  PYTHONPATH=src python -m benchmarks.bench_elastic [--smoke] [--out PATH]

``run(smoke=True)`` is the CI variant (seconds, not minutes); the fast test
lane exercises it via tests/test_bench_elastic.py.
"""

from __future__ import annotations

import argparse
import contextlib
import json
import os
import time

from repro.utils.xla_env import force_host_device_count

# The elastic ladder needs a multi-device harness. Effective only before the
# first jax backend init (a no-op under pytest, where conftest already
# forced 8 devices; standalone `python -m benchmarks.bench_elastic` and the
# run.py subprocess land here first).
force_host_device_count(8)

import jax

from repro.core import AdaptiveBatchController, make_policy
from repro.data import sigmoid_synthetic
from repro.dist.plan import ShardingPlan, use_plan
from repro.elastic import MeshLadder
from repro.models import small
from repro.optim import sgd
from repro.train.loop import ModelFns, Trainer

_DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_elastic.json")


def _controller(*, method: str, n: int, m0: int, m_max: int, granule: int):
    return AdaptiveBatchController(
        make_policy(method, m0=m0, m_max=m_max, delta=0.08, dataset_size=n,
                    granule=granule),
        base_lr=0.5,
    )


def _train(mode: str, *, n: int, d: int, m0: int, m_max: int, granule: int,
           epochs: int, estimator: str, seed: int = 0):
    """One adaptive run. mode: 'elastic' (MeshLadder) or 'fixed' (full mesh
    pinned for the whole run)."""
    train, val, _ = sigmoid_synthetic(n=n, d=d, seed=seed)
    fns = ModelFns(
        batch_loss=small.mlp_batch_loss,
        example_loss=small.mlp_loss,
        metrics=lambda p, b: {"acc": small.mlp_accuracy(p, b)},
    )
    devices = jax.devices()
    ladder = None
    ctx = contextlib.nullcontext()
    if mode == "elastic":
        ladder = MeshLadder(devices, granule=granule)
    elif mode == "fixed":
        mesh = jax.make_mesh((len(devices),), ("data",))
        ctx = use_plan(ShardingPlan(mesh=mesh))
    else:
        raise ValueError(mode)
    with ctx:
        t = Trainer(fns, small.mlp_init(jax.random.key(seed), d),
                    sgd(momentum=0.9),
                    _controller(method="divebatch", n=n, m0=m0, m_max=m_max,
                                granule=granule),
                    train, val, estimator=estimator, seed=seed, elastic=ladder)
        t0 = time.time()
        hist = t.run(epochs, verbose=False)
        wall = time.time() - t0
    stats = t.engine.stats
    steps = sum(h.steps for h in hist)
    return {
        "devices": len(devices),
        "steps": steps,
        "wall_s": round(wall, 3),
        "steps_per_sec": round(steps / wall, 2) if wall > 0 else 0.0,
        "dispatch_steps_per_sec": round(stats.dispatch_steps_per_sec, 2),
        "compiles": stats.compiles,
        "buckets": stats.buckets,
        "rungs": stats.rungs,
        "reshards": stats.reshards,
        "ladder_dp": ladder.widths if ladder else None,
        "num_rungs": ladder.num_rungs if ladder else 1,
        "batch_sizes": [h.batch_size for h in hist],
        "end_batch": hist[-1].batch_size,
        "final_val_loss": round(hist[-1].val_loss, 6),
    }


def run(smoke: bool = False, out_path: str | None = None):
    """Returns benchmark CSV rows; writes the JSON record as a side effect."""
    scale = dict(n=2048, d=32, m0=16, m_max=128, granule=16, epochs=3) if smoke \
        else dict(n=16384, d=64, m0=16, m_max=1024, granule=16, epochs=8)
    estimator = "exact"
    fixed = _train("fixed", estimator=estimator, **scale)
    elastic = _train("elastic", estimator=estimator, **scale)

    # the compile-cache bound: num_buckets x num_rungs worst case
    from repro.core.batch_policy import num_buckets

    bound = num_buckets(scale["m_max"], scale["granule"]) * elastic["num_rungs"]
    ratio = elastic["steps_per_sec"] / max(fixed["steps_per_sec"], 1e-9)
    record = {
        "workload": {"task": "synthetic-nonconvex-mlp", **scale,
                     "estimator": estimator, "smoke": smoke},
        "fixed_full_mesh": fixed,
        "elastic": elastic,
        "elastic_vs_fixed_steps_per_sec": round(ratio, 3),
        "compile_bound_bucket_x_rung": bound,
        # the ladder changes the plan, never the update rule — but a
        # diversity estimate landing exactly on a pow2 rounding threshold can
        # bucket differently under a different dp reduction order, so
        # schedule agreement is recorded, not asserted (the golden test in
        # tests/test_elastic.py asserts it at a scale where it is robust)
        "schedules_match": elastic["batch_sizes"] == fixed["batch_sizes"],
    }
    path = os.path.abspath(out_path or _DEFAULT_OUT)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)

    assert elastic["compiles"] <= bound, (elastic, bound)

    rows = []
    for name, r in (("elastic_ladder", elastic), ("fixed_full_mesh", fixed)):
        rows.append((
            name,
            1e6 / r["steps_per_sec"] if r["steps_per_sec"] else 0.0,
            f"steps_per_sec={r['steps_per_sec']};compiles={r['compiles']};"
            f"end_batch={r['end_batch']}",
        ))
    rows.append((
        "elastic_speedup", 0.0,
        f"elastic_vs_fixed_steps_per_sec={ratio:.3f};"
        f"reshards={elastic['reshards']};ladder={elastic['ladder_dp']};"
        f"json={os.path.basename(path)}",
    ))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    for name, us, derived in run(smoke=args.smoke, out_path=args.out):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
