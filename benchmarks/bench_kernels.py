"""Kernel microbenchmarks: per-sample-grad-norm kernels vs the materialising
oracle (interpret mode on CPU — numbers are correctness-path timings; the
derived column carries the structural FLOP/byte model used for TPU)."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.quant import quantize_int8

SHAPES = [
    (4, 256, 256, 256),
    (2, 512, 128, 512),
    (8, 128, 512, 64),
]


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jnp.asarray(out).block_until_ready()
    return (time.time() - t0) / reps


def run() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    rows = []
    for b, s, di, do in SHAPES:
        x = jnp.asarray(rng.standard_normal((b, s, di)), jnp.float32)
        d = jnp.asarray(rng.standard_normal((b, s, do)), jnp.float32)
        t_ref = _time(lambda a, c: ref.psgn_ref(a, c), x, d)
        t_dir = _time(lambda a, c: ops.persample_sq_norm(a, c, method="direct"), x, d)
        t_gram = _time(lambda a, c: ops.persample_sq_norm(a, c, method="gram"), x, d)
        flops_direct = 2 * b * s * di * do
        flops_gram = 2 * b * s * s * (di + do)
        # bytes the ORACLE materialises that the kernels never do
        oracle_bytes = b * di * do * 4
        rows.append((
            f"psgn_direct_b{b}s{s}_{di}x{do}", t_dir * 1e6,
            f"flops={flops_direct:.3g};oracle_materialises={oracle_bytes}B;"
            f"ref_us={t_ref*1e6:.0f}",
        ))
        rows.append((
            f"psgn_gram_b{b}s{s}_{di}x{do}", t_gram * 1e6,
            f"flops={flops_gram:.3g};chosen={ops.choose_method(s, di, do)}",
        ))
    g = jnp.asarray(rng.standard_normal((1024, 1024)), jnp.float32)
    t_q = _time(lambda a: quantize_int8(a)[0], g)
    rows.append((
        "quant_int8_1024x1024", t_q * 1e6,
        f"wire_ratio={(1024*1024 + 1024*4)/(1024*1024*4):.3f}",
    ))
    return rows
