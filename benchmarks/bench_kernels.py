"""Kernel-lane microbenchmarks: the Pallas attention + psgn kernels vs
their XLA counterparts (interpret mode on CPU — timings are
correctness-path numbers; the derived column carries the structural
FLOP/bytes-moved model that holds on TPU).  Writes ``BENCH_kernels.json``.

The headline row is the FUSED paged decode: the XLA lane materialises the
``jnp.take(pool, tables)`` gather — every slot's table window, dead tail
included, written to a fresh (B, n_max*block, KV, hd) buffer and then
re-read by attention — while the Pallas kernel streams pool blocks through
the BlockSpec index_map and never materialises the gathered context.  The
bytes-moved model for both lanes is computed here and the fused lane is
ASSERTED to move fewer bytes (the PR's acceptance invariant).

  PYTHONPATH=src python -m benchmarks.bench_kernels [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import attention as kattn
from repro.kernels import ops, psgn, ref
from repro.kernels.quant import quantize_int8
from repro.models import attention as attn_lib

_DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernels.json")

PSGN_SHAPES = [
    (4, 256, 256, 256),
    (2, 512, 128, 512),
    (8, 128, 512, 64),
]


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def _psgn_rows(rng, smoke: bool):
    rows = []
    shapes = PSGN_SHAPES[:1] if smoke else PSGN_SHAPES
    for b, s, di, do in shapes:
        x = jnp.asarray(rng.standard_normal((b, s, di)), jnp.float32)
        d = jnp.asarray(rng.standard_normal((b, s, do)), jnp.float32)
        t_ref = _time(lambda a, c: ref.psgn_ref(a, c), x, d)
        t_dir = _time(lambda a, c: ops.persample_sq_norm(a, c, method="direct"), x, d)
        t_gram = _time(lambda a, c: ops.persample_sq_norm(a, c, method="gram"), x, d)
        flops_direct = 2 * b * s * di * do
        flops_gram = 2 * b * s * s * (di + do)
        # bytes the ORACLE materialises that the kernels never do
        oracle_bytes = b * di * do * 4
        rows.append((
            f"psgn_direct_b{b}s{s}_{di}x{do}", t_dir * 1e6,
            f"flops={flops_direct:.3g};oracle_materialises={oracle_bytes}B;"
            f"ref_us={t_ref*1e6:.0f}",
        ))
        rows.append((
            f"psgn_gram_b{b}s{s}_{di}x{do}", t_gram * 1e6,
            f"flops={flops_gram:.3g};chosen={ops.choose_method(s, di, do)}",
        ))
    # fused multi-layer launch: L same-shape layers in ONE kernel vs L
    # separate persample_sq_norm launches
    L, b, s, di, do = (2, 2, 128, 64, 64) if smoke else (4, 4, 256, 128, 128)
    xs = jnp.asarray(rng.standard_normal((L, b, s, di)), jnp.float32)
    ds = jnp.asarray(rng.standard_normal((L, b, s, do)), jnp.float32)
    t_fused = _time(lambda a, c: psgn.psgn_fused(a, c), xs, ds)
    t_loop = _time(
        lambda a, c: sum(
            ops.persample_sq_norm(a[i], c[i], method="direct") for i in range(L)
        ),
        xs, ds,
    )
    rows.append((
        f"psgn_fused_L{L}_b{b}s{s}_{di}x{do}", t_fused * 1e6,
        f"launches=1_vs_{L};per_layer_us={t_loop*1e6:.0f}",
    ))
    return rows


def _flash_rows(rng, smoke: bool):
    b, s, h, kv, hd = (2, 128, 4, 2, 32) if smoke else (2, 256, 8, 2, 64)
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    blk = 64 if smoke else 128
    # reps=1: interpret mode walks the grid in python — one measured call
    # is representative and keeps the full bench bounded
    t_pal = _time(lambda *a: kattn.flash_attention(*a, True, None, None, blk, blk),
                  q, k, v, reps=1)
    t_xla = _time(lambda *a: attn_lib.flash_attention(*a, True, None, None, blk, blk),
                  q, k, v)
    t_dense = _time(lambda *a: attn_lib.attention(*a, causal=True), q, k, v)
    # streaming softmax never materialises the (b, h, s, s) score matrix
    dense_scores = b * h * s * s * 4
    flash_live = b * h * blk * blk * 4
    rows = [(
        f"flash_pallas_b{b}s{s}h{h}", t_pal * 1e6,
        f"xla_flash_us={t_xla*1e6:.0f};xla_dense_us={t_dense*1e6:.0f};"
        f"dense_scores={dense_scores}B;live_tile={flash_live}B",
    )]
    # backward: the recompute custom_vjp vs XLA flash autodiff
    loss_p = lambda *a: jnp.sum(
        jnp.sin(kattn.flash_attention(*a, True, None, None, blk, blk)))
    loss_x = lambda *a: jnp.sum(
        jnp.sin(attn_lib.flash_attention(*a, True, None, None, blk, blk)))
    t_pb = _time(jax.jit(jax.grad(loss_p, argnums=(0, 1, 2))), q, k, v, reps=1)
    t_xb = _time(jax.jit(jax.grad(loss_x, argnums=(0, 1, 2))), q, k, v)
    rows.append((
        f"flash_pallas_bwd_b{b}s{s}h{h}", t_pb * 1e6,
        f"xla_flash_bwd_us={t_xb*1e6:.0f};recompute=fwd_logits",
    ))
    return rows


def _paged_bytes(lengths, n_max, blk, kv, hd, itemsize=4):
    """Bytes-moved model for one decode step over the KV pool (k + v).

    fused: the kernel DMAs exactly the live blocks of each row straight from
    the pool (dead-tail grid steps hit the sentinel block, which stays
    resident).  materialised: the XLA lane reads the same pool rows, then
    WRITES the full (B, n_max*block) gathered buffer — dead tail included —
    and attention RE-READS it."""
    per_block = blk * kv * hd * itemsize * 2  # k and v
    live = sum(-(-int(l) // blk) for l in lengths)
    fused = live * per_block
    gathered = len(lengths) * n_max * per_block
    materialised = live * per_block + 2 * gathered  # read pool + write + re-read
    return fused, materialised


def _paged_rows(rng, smoke: bool):
    blk, kv, h, hd = 16, 2, 4, 32
    b, n_max, num_blocks = (4, 4, 32) if smoke else (8, 8, 128)
    pool_k = jnp.asarray(rng.standard_normal((num_blocks, blk, kv, hd)), jnp.float32)
    pool_v = jnp.asarray(rng.standard_normal((num_blocks, blk, kv, hd)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((b, 1, h, hd)), jnp.float32)
    # ragged lengths — most rows use a fraction of their table window
    lengths = rng.integers(1, n_max * blk, size=b)
    tables = np.zeros((b, n_max), np.int32)
    ids = rng.permutation(np.arange(1, num_blocks))
    nxt = 0
    for r in range(b):
        for i in range(-(-int(lengths[r]) // blk)):
            tables[r, i] = ids[nxt]
            nxt += 1
    tables_j = jnp.asarray(tables)
    lengths_j = jnp.asarray(lengths, jnp.int32)

    t_fused = _time(
        lambda *a: kattn.paged_decode_attention(*a),
        q, pool_k, pool_v, tables_j, lengths_j, reps=1,
    )

    @jax.jit
    def xla_gather(q, pk, pv, t, ln):
        kc = jnp.take(pk, t, axis=0).reshape(b, n_max * blk, kv, hd)
        vc = jnp.take(pv, t, axis=0).reshape(b, n_max * blk, kv, hd)
        return attn_lib.decode_attention(q, kc, vc, ln)

    t_mat = _time(xla_gather, q, pool_k, pool_v, tables_j, lengths_j)

    fused_b, mat_b = _paged_bytes(lengths, n_max, blk, kv, hd)
    # the acceptance invariant: fusing the gather into the KV loop moves
    # measurably fewer bytes than materialise-then-attend
    assert fused_b < mat_b, (fused_b, mat_b)
    rows = [(
        f"paged_decode_fused_b{b}n{n_max}blk{blk}", t_fused * 1e6,
        f"materialised_us={t_mat*1e6:.0f};fused_bytes={fused_b};"
        f"materialised_bytes={mat_b};bytes_ratio={fused_b/mat_b:.3f}",
    )]
    record = {
        "batch": b, "n_max": n_max, "block": blk, "kv_heads": kv,
        "head_dim": hd, "lengths": [int(x) for x in lengths],
        "fused_us": round(t_fused * 1e6, 1),
        "materialised_us": round(t_mat * 1e6, 1),
        "fused_bytes": fused_b, "materialised_bytes": mat_b,
        "bytes_ratio": round(fused_b / mat_b, 4),
    }
    return rows, record


def _quant_row(rng):
    g = jnp.asarray(rng.standard_normal((1024, 1024)), jnp.float32)
    t_q = _time(lambda a: quantize_int8(a)[0], g)
    return (
        "quant_int8_1024x1024", t_q * 1e6,
        f"wire_ratio={(1024*1024 + 1024*4)/(1024*1024*4):.3f}",
    )


def run(smoke: bool = False, out_path: str | None = None):
    """Returns benchmark CSV rows; writes BENCH_kernels.json as a side
    effect (paged bytes-moved model + every row, schema pinned by
    tests/test_bench_kernels.py)."""
    rng = np.random.default_rng(0)
    rows = []
    rows += _flash_rows(rng, smoke)
    paged_rows, paged_record = _paged_rows(rng, smoke)
    rows += paged_rows
    rows += _psgn_rows(rng, smoke)
    rows.append(_quant_row(rng))

    record = {
        "workload": {
            "task": "kernel-lane-microbench", "smoke": smoke,
            "interpret": ops.default_interpret(),
            "backend": jax.default_backend(),
        },
        "paged_decode": paged_record,
        "rows": [
            {"name": n, "us": round(us, 1), "derived": d} for n, us, d in rows
        ],
    }
    path = os.path.abspath(out_path or _DEFAULT_OUT)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    for name, us, derived in run(smoke=args.smoke, out_path=args.out):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
