"""Roofline report: reads the dry-run JSONs (runs/dryrun/*.json) and emits
the per-(arch x shape x mesh) three-term table for EXPERIMENTS.md §Roofline.

  python -m benchmarks.roofline [--dir runs/dryrun] [--markdown out.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load_records(d: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r: dict) -> str:
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | — | — | — | — | — | "
                f"{r['reason']} |")
    if r["status"] == "error":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | — | — | — | — | — | "
                f"{r['error'][:60]} |")
    t = r["roofline"]
    c = r["cost"]
    mem = r["memory"]
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} "
        f"| {t['compute_s']:.3f} | {t['memory_s']:.3f} | {t['collective_s']:.3f} "
        f"| {t['dominant'].replace('_s','')} "
        f"| {c['useful_flops_ratio']:.2f} "
        f"| {mem['hbm_per_device_adjusted_gib']:.1f} "
        f"| {_note(r)} |"
    )


def _note(r: dict) -> str:
    t = r["roofline"]
    dom = t["dominant"]
    if dom == "compute_s":
        return "near compute roofline; cut remat/flash recompute to go further"
    if dom == "memory_s":
        return "HBM-bound: fuse attention tiles (Pallas) / larger xent chunks"
    return "collective-bound: overlap FSDP gathers; compress pod axis"


HEADER = (
    "| arch | shape | mesh | compute_s | memory_s | collective_s | dominant "
    "| useful_ratio | HBM GiB/dev (adj) | what would move the dominant term |\n"
    "|---|---|---|---|---|---|---|---|---|---|"
)


def summarize(recs: list[dict]) -> dict:
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    err = [r for r in recs if r["status"] == "error"]
    dominant = {}
    for r in ok:
        dominant[r["roofline"]["dominant"]] = dominant.get(r["roofline"]["dominant"], 0) + 1
    return {"ok": len(ok), "skipped": len(skipped), "error": len(err), "dominant": dominant}


def _paged_decode_row() -> tuple[str, float, str]:
    """Bytes-moved roofline for one paged decode step: the fused kernel
    (gather folded into the BlockSpec index_map) vs the XLA lane's
    materialise-then-attend, on a representative serving shape (half-full
    ragged table windows).  Same model as benchmarks/bench_kernels.py."""
    from benchmarks.bench_kernels import _paged_bytes

    b, n_max, blk, kv, hd = 64, 32, 16, 8, 128
    lengths = [(r * 37) % (n_max * blk) + 1 for r in range(b)]  # ragged
    fused, mat = _paged_bytes(lengths, n_max, blk, kv, hd, itemsize=2)
    assert fused < mat, (fused, mat)
    return (
        "roofline_paged_decode_bytes", 0.0,
        f"fused={fused}B;materialised={mat}B;ratio={fused/mat:.3f};"
        f"shape=b{b}n{n_max}blk{blk}kv{kv}hd{hd}bf16",
    )


def run() -> list[tuple[str, float, str]]:
    recs = load_records("runs/dryrun_final")
    s = summarize(recs)
    rows = [(
        "roofline_summary", 0.0,
        f"ok={s['ok']};skipped={s['skipped']};error={s['error']};dominant={s['dominant']}",
    ), _paged_decode_row()]
    # three headline cells
    for key in [("llama3-405b", "train_4k", "pod16x16"),
                ("kimi-k2-1t-a32b", "train_4k", "pod16x16"),
                ("qwen2-7b", "train_4k", "pod16x16")]:
        for r in recs:
            if (r["arch"], r["shape"], r["mesh"]) == key and r["status"] == "ok":
                t = r["roofline"]
                rows.append((
                    f"roofline_{r['arch']}_{r['shape']}", 0.0,
                    f"compute={t['compute_s']:.3f}s;memory={t['memory_s']:.3f}s;"
                    f"collective={t['collective_s']:.3f}s;dominant={t['dominant']};"
                    f"useful={r['cost']['useful_flops_ratio']:.2f}",
                ))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun_final")
    ap.add_argument("--markdown", default=None)
    args = ap.parse_args()
    recs = load_records(args.dir)
    lines = [HEADER]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["mesh"], r["arch"], order.get(r["shape"], 9)))
    for r in recs:
        lines.append(fmt_row(r))
    text = "\n".join(lines)
    print(text)
    print("\nsummary:", summarize(recs))
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
