"""Hillclimb profiler: compile one cell and print the top collective / HBM
instructions with execution counts (the 'profile' of the dry-run world).

  PYTHONPATH=src python -m benchmarks.whales --arch kimi-k2-1t-a32b --shape train_4k
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import re  # noqa: E402

import jax  # noqa: E402

from repro.configs import SHAPES  # noqa: E402
from repro.dist.plan import use_plan  # noqa: E402
from repro.launch import dryrun as dr  # noqa: E402
from repro.launch.mesh import make_plan, make_production_mesh  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.utils import pytree as ptu  # noqa: E402
from repro.utils.hlo import COLLECTIVE_KINDS, HloProgram, _CALL_TARGET_RE  # noqa: E402


def compile_cell(arch: str, shape_name: str, multi_pod: bool = False,
                 tuning: dict | None = None):
    cfg = dr.dryrun_config(arch)
    tuning = tuning or {}
    if "config" in tuning:
        cfg = cfg.replace(**tuning["config"])
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_plan(mesh, param_bytes=ptu.tree_bytes(tf.param_specs(cfg)))
    builders = {"train": dr.build_train, "prefill": dr.build_prefill,
                "decode": dr.build_decode}
    with use_plan(plan, dr.act_specs_for(cfg, plan, shape.kind)):
        jitted, args, info = builders[shape.kind](cfg, shape, plan, tuning)
        with mesh:
            compiled = jitted.lower(*args).compile()
    return compiled, info


def report(prog: HloProgram, top: int = 12):
    coll, byts = [], []

    def walk(cname, mult, top_level):
        comp = prog.computations.get(cname)
        if comp is None:
            return
        for ins in comp.instrs:
            op = ins.opcode[:-6] if ins.opcode.endswith("-start") else ins.opcode
            if ins.opcode.endswith("-done"):
                continue
            if op == "while":
                body = cond = None
                for key, tgt in re.findall(r"(body|condition)=%?([\w.\-_]+)", ins.line):
                    if key == "body":
                        body = tgt
                    else:
                        cond = tgt
                trips = prog.trip_count(cond) if cond else 1
                walk(body, mult * trips, top_level)
            elif op in ("fusion", "call"):
                m = _CALL_TARGET_RE.search(ins.line)
                if m:
                    walk(m.group(1), mult, False)
            elif op in COLLECTIVE_KINDS:
                ob = sum(prog.sizes.get(o, 0) for o in ins.operands)
                rb = prog.sizes.get(ins.name, 0)
                coll.append((mult * max(rb, ob), mult, ob, rb, op, ins.line[:150]))
            if top_level and op not in (
                "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
                "while", "after-all", "partition-id", "iota",
            ):
                io = ins.result_bytes + sum(prog.sizes.get(o, 0) for o in ins.operands)
                byts.append((io * mult, mult, io, op, ins.line[:130]))

    walk(prog.entry, 1.0, True)
    coll.sort(reverse=True)
    print("== collectives (result-weighted bytes x execs) ==")
    for c in coll[:top]:
        print(f"{c[0]:.2e} x{c[1]:6.0f} op={c[2]:.1e} res={c[3]:.1e} {c[4]:14s} {c[5][:110]}")
    byts.sort(reverse=True)
    print("== HBM traffic ==")
    for b in byts[:top]:
        print(f"{b[0]:.2e} x{b[1]:6.0f} {b[2]:.1e}B {b[3]:14s} {b[4][:115]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--num-micro", type=int)
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()
    tuning = {"num_micro": args.num_micro} if args.num_micro else {}
    compiled, info = compile_cell(args.arch, args.shape, args.multi_pod, tuning)
    print("info:", info)
    report(HloProgram(compiled.as_text()), args.top)


if __name__ == "__main__":
    main()
