"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows."""

from __future__ import annotations

import subprocess
import sys
import traceback


def _subprocess_module(module: str):
    """bench_elastic/bench_serve force an 8-device CPU harness pre-jax-init,
    which must not leak into the other benches' (default-device)
    measurements — each gets its own process, exactly like the CI
    invocation."""

    def run():
        proc = subprocess.run(
            [sys.executable, "-m", module],
            capture_output=True, text=True,
        )
        if proc.returncode:
            sys.stderr.write(proc.stderr)  # surface the child's actual error
            raise RuntimeError(
                f"{module} subprocess failed (exit {proc.returncode})"
            )
        for line in proc.stdout.splitlines():
            parts = line.split(",", 2)
            if len(parts) == 3:
                name, us, derived = parts
                yield name, float(us), derived

    return type("_SubprocessModule", (), {"run": staticmethod(run)})


def main() -> None:
    from benchmarks import (
        bench_adapt,
        bench_engine,
        bench_estimators,
        bench_kernels,
        bench_synthetic,
        bench_table1,
        bench_table2_memory,
        roofline,
    )

    modules = [
        ("engine", bench_engine),
        ("adapt", bench_adapt),
        ("elastic", _subprocess_module("benchmarks.bench_elastic")),
        ("serve", _subprocess_module("benchmarks.bench_serve")),
        ("synthetic(fig1/2)", bench_synthetic),
        ("table1", bench_table1),
        ("table2(memory)", bench_table2_memory),
        ("estimators", bench_estimators),
        ("kernels", bench_kernels),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for label, mod in modules:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{label},0,ERROR:{type(e).__name__}:{e}", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
