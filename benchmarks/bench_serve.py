"""Elastic vs fixed-full-mesh continuous-batching decode throughput, plus
the paged-KV prefix-sharing section, on the 8-device CPU harness.  Writes
``BENCH_serve.json`` at the repo root.

The elastic arms run the SAME ramping arrival trace through the same
ServeEngine / Scheduler; the only difference is the sharding: the fixed arm
pins the full 8-wide data-parallel mesh for every decode step, the elastic
arm lets a ``repro.elastic.MeshLadder`` pick the rung from the live slot
count.  A ramping trace spends most of its steps at low concurrency —
exactly where a full mesh pays collective/dispatch overhead for 1-2 live
slots while the ladder runs them on 1-2 devices.

The ``paged`` section drives a shared-system-prompt ramping trace (every
request opens with the same prefix) through the block-pool engine twice —
prefix sharing on vs off.  Sharing-off re-prefills every prompt in full
(the old dense-cache engine's compute profile); sharing-on computes the
shared prefix blocks EXACTLY ONCE and each request only its divergent tail
(asserted).  The section also records the paged-vs-dense MEMORY footprint:
peak live pool blocks x block size against the dense engine's
``max_slots * max_seq`` preallocation.

The ``policy`` section drives a two-tenant burst trace (a big tenant's
burst up front, a small tenant trickling in just behind it) through one
engine per ``serve.policy`` ServePolicy and scores per-tenant queue wait
(decode steps between submit and admission, p50/p95).  Under FIFO the
small tenant queues behind the entire burst; fair-share deficit
round-robin admits it at the first post-burst boundary — the section
asserts the strict minority-p95 reduction.

Each arm drives the trace twice: pass 1 warms the (bucket, rung) compile
caches, pass 2 is measured (tokens/s excludes compilation, like the other
benches' warmup convention).

  PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] [--policy fair]
"""

from __future__ import annotations

import argparse
import contextlib
import json
import os
import time

from repro.utils.xla_env import force_host_device_count

# Effective only before the first jax backend init (a no-op under pytest,
# where conftest already forced 8 devices).
force_host_device_count(8)

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batch_policy import num_buckets
from repro.dist.plan import ShardingPlan, use_plan
from repro.elastic import MeshLadder
from repro.models import transformer as tf
from repro.serve import POLICIES, Request, ServeEngine, padded_prompt_len

_DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

MAX_SLOTS = 8


def _cfg():
    return ModelConfig(
        name="bench-serve", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=256,
        pattern=("attn",), param_dtype="float32", compute_dtype="float32",
        xent_chunk=32, remat=False,
    )


def _trace(smoke: bool, seed: int = 0):
    """(arrival_step, Request) pairs: the arrival gap shrinks over the trace
    (the ramp), so concurrency climbs from ~1 toward the full slot count."""
    rng = np.random.default_rng(seed)
    gaps = [12, 12, 12, 12, 8, 8, 8, 8, 6, 6, 4, 4, 2, 2, 1, 1, 0, 0, 0, 0]
    max_new = 16
    if smoke:
        gaps = [8, 8, 6, 6, 4, 2, 0, 0]
        max_new = 8
    trace, step = [], 0
    for gap in gaps:
        trace.append((step, Request(
            prompt=rng.integers(1, 256, size=int(rng.integers(4, 8))).astype(np.int32),
            max_new_tokens=max_new,
        )))
        step += gap
    return trace


def _drive(engine: ServeEngine, trace) -> tuple[list, float]:
    """Submit each request when the engine's decode-step clock reaches its
    arrival step; drain; return (results, wall seconds)."""
    start = engine.stats.steps
    pending = list(trace)
    rids = []
    t0 = time.time()
    while pending or engine.busy:
        while pending and engine.stats.steps - start >= pending[0][0]:
            rids.append(engine.submit(pending.pop(0)[1]))
        if not engine.step() and pending:
            # idle gap in the arrival schedule: jump to the next arrival
            rids.append(engine.submit(pending.pop(0)[1]))
    wall = time.time() - t0
    return [engine.result(rid) for rid in rids], wall


def _serve(mode: str, smoke: bool, policy: str = "fifo"):
    cfg = _cfg()
    params = tf.init_params(cfg, jax.random.key(0))
    devices = jax.devices()
    ladder = None
    ctx = contextlib.nullcontext()
    if mode == "elastic":
        ladder = MeshLadder(devices, granule=1)
    elif mode == "fixed":
        mesh = jax.make_mesh((len(devices),), ("data",))
        ctx = use_plan(ShardingPlan(mesh=mesh, tp=None))
    else:
        raise ValueError(mode)
    with ctx:
        engine = ServeEngine(cfg, params, max_slots=MAX_SLOTS, max_seq=128,
                             elastic=ladder, policy=policy)
        _drive(engine, _trace(smoke))  # pass 1: warm the compile caches
        warm_compiles = engine.stats.compiles
        warm_stats = engine.stats.as_dict()
        results, wall = _drive(engine, _trace(smoke))  # pass 2: measured
    stats = engine.stats
    tokens = sum(r.steps for r in results)
    return {
        "devices": len(devices),
        "policy": policy,
        "tokens": tokens,
        "wall_s": round(wall, 3),
        "tokens_per_sec": round(tokens / wall, 2) if wall > 0 else 0.0,
        "windowed_tokens_per_sec": round(stats.tokens_per_sec, 2),
        "decode_steps": stats.steps - warm_stats["steps"],
        "slot_steps": stats.slot_steps - warm_stats["slot_steps"],
        "compiles": stats.compiles,
        "compiles_in_measured_pass": stats.compiles - warm_compiles,
        "buckets": stats.buckets,
        "rungs": stats.rungs,
        "reshards": stats.reshards,
        "resizes": stats.resizes,
        "ladder_dp": ladder.widths if ladder else None,
        "num_rungs": ladder.num_rungs if ladder else 1,
    }


def _shared_trace(smoke: bool, seed: int = 1):
    """Shared-system-prompt ramp: every prompt = common prefix + distinct
    tail, all the SAME raw length (prompts are left-padded, so equal length
    keeps the padded streams — and their chain hashes — aligned)."""
    rng = np.random.default_rng(seed)
    n, raw, pre = (6, 12, 8) if smoke else (12, 24, 16)
    max_new = 8 if smoke else 16
    prefix = rng.integers(1, 256, size=pre).astype(np.int32)
    trace, step = [], 0
    for _ in range(n):
        tail = rng.integers(1, 256, size=raw - pre).astype(np.int32)
        trace.append((step, Request(prompt=np.concatenate([prefix, tail]),
                                    max_new_tokens=max_new)))
        step += 4  # staggered: the head request's prefill lands first
    return trace, n, raw, pre


def _paged(smoke: bool):
    """The prefix-sharing section: sharing on vs off on the SAME trace and
    engine geometry (both paged; sharing-off's full re-prefill per prompt is
    the old dense engine's compute profile)."""
    cfg = _cfg()
    params = tf.init_params(cfg, jax.random.key(0))
    block = 8
    arms = {}
    for name, sharing in (("shared_prefix", True), ("no_sharing", False)):
        trace, n, raw, pre = _shared_trace(smoke)
        engine = ServeEngine(cfg, params, max_slots=MAX_SLOTS, max_seq=128,
                             prompt_granule=8, block_size=block,
                             prefill_chunk=block, prefix_sharing=sharing)
        _drive(engine, trace)  # pass 1: warm compiles + (if on) the registry
        warm = engine.stats.as_dict()
        results, wall = _drive(engine, _shared_trace(smoke)[0])  # measured
        st = engine.stats
        plen = padded_prompt_len(raw, 8)
        first_chunks = plen // block
        tail_chunks = (plen - ((plen - raw + pre) // block) * block) // block
        if sharing:
            # the acceptance invariant: the shared prefix prefilled ONCE —
            # request 1 in full, every other request only its tail (pass 2
            # replays full-prompt cache hits: zero chunks)
            expect = first_chunks + (n - 1) * tail_chunks
            assert st.prefill_chunks == expect, (st.prefill_chunks, expect)
            assert st.shared_prefill_hits == n  # pass 2: all instant
        else:
            assert st.prefill_chunks == 2 * n * first_chunks
        tokens = sum(r.steps for r in results)
        arms[name] = {
            "tokens": tokens,
            "wall_s": round(wall, 3),
            "tokens_per_sec": round(tokens / wall, 2) if wall > 0 else 0.0,
            "prefill_chunks": st.prefill_chunks,
            "prefill_chunks_measured_pass": st.prefill_chunks
            - warm["prefill_chunks"],
            "shared_prefill_hits": st.shared_prefill_hits,
            "shared_blocks": st.shared_blocks,
            "peak_blocks": st.peak_blocks,
            "compiles_in_measured_pass": st.compiles - warm["compiles"],
        }
        engine.pool.check()
        pool_blocks, cow = engine.pool.num_blocks, st.cow_copies
    dense_tokens = MAX_SLOTS * 128  # the dense layout's per-slot max_seq rows
    peak = max(arms[a]["peak_blocks"] for a in arms)
    ratio = arms["shared_prefix"]["tokens_per_sec"] / max(
        arms["no_sharing"]["tokens_per_sec"], 1e-9)
    return {
        "block_size": block,
        "pool_blocks": pool_blocks,
        "peak_blocks": peak,
        "peak_resident_tokens": peak * block,
        "dense_resident_tokens": dense_tokens,
        "memory_vs_dense": round(peak * block / dense_tokens, 4),
        "cow_copies": cow,
        "shared_prefix": arms["shared_prefix"],
        "no_sharing": arms["no_sharing"],
        "sharing_vs_dense_tokens_per_sec": round(ratio, 3),
    }


def _burst_trace(smoke: bool, seed: int = 2):
    """Two-tenant contention trace: tenant ``big`` bursts every request at
    step 0; tenant ``small`` trickles in one per step just behind it, so the
    burst is already slot-resident when the small tenant queues.  Every
    request generates the same token count — admissions happen in clean
    waves, which makes the per-policy queue waits directly comparable."""
    rng = np.random.default_rng(seed)
    n_big, n_small = (10, 3) if smoke else (16, 4)
    max_new = 8 if smoke else 16

    def _req(tenant: str, priority: int) -> Request:
        return Request(
            prompt=rng.integers(1, 256, size=int(rng.integers(4, 8))).astype(
                np.int32),
            max_new_tokens=max_new, tenant=tenant, priority=priority,
        )

    trace = [(0, _req("big", 0)) for _ in range(n_big)]
    trace += [(1 + i, _req("small", 1)) for i in range(n_small)]
    return trace


def _drive_waits(engine: ServeEngine, trace):
    """Like :func:`_drive` but also scores queue wait per request: decode
    steps between submit and the boundary that admitted it (a rid leaving
    ``Scheduler.queued()`` has been assigned a slot)."""
    start = engine.stats.steps
    pending = list(trace)
    submit_step: dict[int, int] = {}
    admit_step: dict[int, int] = {}
    tenant_of: dict[int, str] = {}
    waiting: set[int] = set()

    def _submit(item):
        rid = engine.submit(item[1])
        submit_step[rid] = engine.stats.steps - start
        tenant_of[rid] = item[1].tenant
        waiting.add(rid)

    def _settle():
        still = {rid for rid, _, _ in engine.sched.queued()}
        for rid in [r for r in waiting if r not in still]:
            admit_step[rid] = engine.stats.steps - start
            waiting.discard(rid)

    while pending or engine.busy:
        while pending and engine.stats.steps - start >= pending[0][0]:
            _submit(pending.pop(0))
        if not engine.step() and pending:
            _submit(pending.pop(0))
        _settle()
    assert not waiting, f"requests never admitted: {sorted(waiting)}"
    waits: dict[str, list[int]] = {}
    for rid, t in submit_step.items():
        waits.setdefault(tenant_of[rid], []).append(admit_step[rid] - t)
    return waits


def _policy(smoke: bool):
    """The policy section: the same burst trace through one engine per
    ServePolicy, scored on per-tenant queue wait.  Slot capacity is held
    below the burst size so admission ORDER is the only thing the policies
    can differ on — tokens decoded are identical across arms."""
    cfg = _cfg()
    params = tf.init_params(cfg, jax.random.key(0))
    slots = 4
    out = {"workload": {"task": "two-tenant-burst", "max_slots": slots,
                        "tenants": ["big", "small"], "smoke": smoke}}
    for name in POLICIES:
        engine = ServeEngine(cfg, params, max_slots=slots, max_seq=64,
                             policy=name)
        waits = _drive_waits(engine, _burst_trace(smoke))
        out[name] = {
            tenant: {
                "n": len(w),
                "p50_wait_steps": round(float(np.percentile(w, 50)), 2),
                "p95_wait_steps": round(float(np.percentile(w, 95)), 2),
                "mean_wait_steps": round(float(np.mean(w)), 2),
            }
            for tenant, w in sorted(waits.items())
        }
    fifo_p95 = out["fifo"]["small"]["p95_wait_steps"]
    fair_p95 = out["fair"]["small"]["p95_wait_steps"]
    # the acceptance invariant: deficit round-robin strictly cuts the
    # minority tenant's tail wait vs queueing behind the whole burst
    assert fair_p95 < fifo_p95, (fair_p95, fifo_p95)
    out["fair_vs_fifo_minority_p95"] = round(fair_p95 / max(fifo_p95, 1e-9), 4)
    return out


def run(smoke: bool = False, out_path: str | None = None, policy: str = "fifo"):
    """Returns benchmark CSV rows; writes the JSON record as a side effect."""
    fixed = _serve("fixed", smoke, policy=policy)
    elastic = _serve("elastic", smoke, policy=policy)
    paged = _paged(smoke)
    pol = _policy(smoke)

    bound = num_buckets(MAX_SLOTS, 1) * elastic["num_rungs"]
    ratio = elastic["tokens_per_sec"] / max(fixed["tokens_per_sec"], 1e-9)
    record = {
        "workload": {"task": "ramping-request-trace", "max_slots": MAX_SLOTS,
                     "max_seq": 128, "smoke": smoke},
        "fixed_full_mesh": fixed,
        "elastic": elastic,
        "paged": paged,
        "policy": pol,
        "elastic_vs_fixed_tokens_per_sec": round(ratio, 3),
        "compile_bound_bucket_x_rung": bound,
    }
    path = os.path.abspath(out_path or _DEFAULT_OUT)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)

    assert elastic["compiles"] <= bound, (elastic, bound)

    rows = []
    for name, r in (("elastic_ladder", elastic), ("fixed_full_mesh", fixed)):
        rows.append((
            f"serve_{name}",
            1e6 / r["tokens_per_sec"] if r["tokens_per_sec"] else 0.0,
            f"tokens_per_sec={r['tokens_per_sec']};compiles={r['compiles']};"
            f"slot_steps={r['slot_steps']}",
        ))
    rows.append((
        "serve_elastic_speedup", 0.0,
        f"elastic_vs_fixed_tokens_per_sec={ratio:.3f};"
        f"reshards={elastic['reshards']};ladder={elastic['ladder_dp']};"
        f"json={os.path.basename(path)}",
    ))
    rows.append((
        "serve_paged_prefix_sharing", 0.0,
        f"sharing_vs_dense_tokens_per_sec={paged['sharing_vs_dense_tokens_per_sec']};"
        f"memory_vs_dense={paged['memory_vs_dense']};"
        f"prefill_chunks={paged['shared_prefix']['prefill_chunks']};"
        f"peak_blocks={paged['peak_blocks']}/{paged['pool_blocks']}",
    ))
    rows.append((
        "serve_policy_fairness", 0.0,
        f"fair_vs_fifo_minority_p95={pol['fair_vs_fifo_minority_p95']};"
        f"fifo_small_p95={pol['fifo']['small']['p95_wait_steps']};"
        f"fair_small_p95={pol['fair']['small']['p95_wait_steps']};"
        f"priority_small_p95={pol['priority']['small']['p95_wait_steps']}",
    ))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--policy", default="fifo", choices=list(POLICIES),
                    help="ServePolicy for the elastic/fixed throughput arms "
                         "(the policy section always sweeps all of them)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke, out_path=args.out, policy=args.policy)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
