"""repro.adapt benchmark: epoch-boundary vs mid-epoch-tick adaptation, and
the gradient-noise policy family vs DiveBatch. Writes ``BENCH_adapt.json``
at the repo root.

Three runs over the same synthetic MLP workload (same seeds, same engine):

  epoch_boundary   DiveBatch deciding only at epoch ends (the legacy
                   cadence) — the baseline.
  mid_epoch_tick   the same DiveBatch rule fired every ``tick_every`` steps
                   on the RUNNING accumulators: measures the overhead of
                   tick reads (one stacked scalar transfer each) plus
                   mid-epoch resizes, and how much earlier the batch ramps.
  gns              GradNoisePolicy (Sievert/AdAdaGrad family) on the same
                   tick cadence — schedule comparison vs DiveBatch.

  PYTHONPATH=src python -m benchmarks.bench_adapt [--smoke] [--out PATH]

``run(smoke=True)`` is the CI variant (seconds); the fast test lane
exercises it via tests/test_bench_adapt.py.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.adapt import AdaptationProgram, DiveBatchPolicy, GradNoisePolicy
from repro.data import sigmoid_synthetic
from repro.models import small
from repro.optim import sgd
from repro.train.loop import ModelFns, Trainer

_DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_adapt.json")


def _program(mode: str, *, n: int, m0: int, m_max: int, granule: int,
             tick_every: int) -> AdaptationProgram:
    if mode == "gns":
        policy = GradNoisePolicy(m0, m_max, granule=granule, alpha=0.25,
                                 on_tick=True)
        return AdaptationProgram(policy, base_lr=0.5, estimator="moment",
                                 tick_every=tick_every)
    policy = DiveBatchPolicy(m0, m_max, delta=0.08, dataset_size=n,
                             granule=granule, on_tick=mode == "tick")
    return AdaptationProgram(policy, base_lr=0.5, estimator="moment",
                             tick_every=tick_every if mode == "tick" else 0)


def _train(mode: str, *, n: int, d: int, m0: int, m_max: int, granule: int,
           epochs: int, tick_every: int, seed: int = 0):
    train, val, _ = sigmoid_synthetic(n=n, d=d, seed=seed)
    fns = ModelFns(
        batch_loss=small.mlp_batch_loss,
        example_loss=small.mlp_loss,
        metrics=lambda p, b: {"acc": small.mlp_accuracy(p, b)},
    )
    program = _program(mode, n=n, m0=m0, m_max=m_max, granule=granule,
                       tick_every=tick_every)
    t = Trainer(fns, small.mlp_init(jax.random.key(seed), d),
                sgd(momentum=0.9), program, train, val, estimator="moment",
                seed=seed)
    t0 = time.time()
    hist = t.run(epochs, verbose=False)
    wall = time.time() - t0
    stats = t.engine.stats
    steps = sum(h.steps for h in hist)
    mid = [a for a in program.history if a.boundary != "epoch"]
    return {
        "steps": steps,
        "wall_s": round(wall, 3),
        "steps_per_sec": round(steps / wall, 2) if wall > 0 else 0.0,
        "compiles": stats.compiles,
        "buckets": stats.buckets,
        "mid_epoch_decisions": len(mid),
        "mid_epoch_resizes": sum(a.rescaled for a in mid),
        "batch_sizes": [h.batch_size for h in hist],
        "end_batch": hist[-1].batch_size,
        "final_val_loss": round(hist[-1].val_loss, 6),
    }


def run(smoke: bool = False, out_path: str | None = None):
    """Returns benchmark CSV rows; writes the JSON record as a side effect."""
    scale = dict(n=2048, d=32, m0=16, m_max=256, granule=16, epochs=3,
                 tick_every=8) if smoke \
        else dict(n=16384, d=64, m0=16, m_max=1024, granule=16, epochs=8,
                  tick_every=16)
    epoch = _train("epoch", **scale)
    tick = _train("tick", **scale)
    gns = _train("gns", **scale)

    ratio = tick["steps_per_sec"] / max(epoch["steps_per_sec"], 1e-9)
    record = {
        "workload": {"task": "synthetic-nonconvex-mlp", **scale,
                     "smoke": smoke},
        "epoch_boundary": epoch,
        "mid_epoch_tick": tick,
        "gns": gns,
        "tick_vs_epoch_steps_per_sec": round(ratio, 3),
        # the schedules the two policy families produced on the same data —
        # recorded, not asserted: GNS targets the critical batch, DiveBatch
        # targets delta*n*diversity, so they legitimately differ
        "divebatch_schedule": epoch["batch_sizes"],
        "gns_schedule": gns["batch_sizes"],
        "schedules_match": epoch["batch_sizes"] == gns["batch_sizes"],
    }
    path = os.path.abspath(out_path or _DEFAULT_OUT)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)

    rows = []
    for name, r in (("adapt_epoch_boundary", epoch),
                    ("adapt_mid_epoch_tick", tick), ("adapt_gns", gns)):
        rows.append((
            name,
            1e6 / r["steps_per_sec"] if r["steps_per_sec"] else 0.0,
            f"steps_per_sec={r['steps_per_sec']};compiles={r['compiles']};"
            f"end_batch={r['end_batch']};mid_epoch_resizes={r['mid_epoch_resizes']}",
        ))
    rows.append((
        "adapt_tick_overhead", 0.0,
        f"tick_vs_epoch_steps_per_sec={ratio:.3f};"
        f"gns_end_batch={gns['end_batch']};json={os.path.basename(path)}",
    ))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    for name, us, derived in run(smoke=args.smoke, out_path=args.out):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
