"""Serve-side adaptation policies: ``observe(signals, clock) -> decision``.

The serving mirror of :mod:`repro.adapt.policy`.  Training already routes
every batch-size/lr/rung decision through the ``AdaptationPolicy`` protocol;
this module gives the :class:`~repro.serve.engine.ServeEngine` the same
observe→decide boundary for the decisions it used to hard-code — admission
order, slot budget, and shrink patience become policy outputs the same way
the train batch size did (the AdaBatch → Sievert-2019 lineage of
signal-driven schedules, applied to the decode batch).

At every step boundary the engine builds a :class:`ServeSignals` snapshot
(queue depth, live/pending counts, windowed tokens/s, block-pool headroom,
per-request queue age) and calls ``policy.observe(signals, clock)`` with the
same :class:`~repro.adapt.signals.Clock` type the train side uses
(``boundary='tick'``, ``step`` = decode-step count).  A ``None`` return — or
``None`` fields on the :class:`ServeDecision` — leaves the engine's default
behaviour untouched, exactly like a train-side ``Decision``.

Implementations:

  FifoPolicy       the default: admission order IS the queue order and the
                   slot budget stays with the scheduler's own
                   ``target_slots`` rule — golden token-identical to the
                   pre-hook engine on every lane (it returns the identity
                   ordering, so the engine takes the legacy FIFO path).
  PriorityPolicy   per-request priority classes (``Request.priority``,
                   higher first); FIFO-stable within a class.
  FairSharePolicy  per-tenant deficit round-robin (``Request.tenant``):
                   each tenant's next request is scheduled at a virtual
                   time of (requests already admitted for that tenant +
                   its position in the tenant's own FIFO), so one tenant's
                   burst queues behind other tenants' steady arrivals
                   instead of starving them.

Whatever the ordering says, ``Scheduler.admit`` keeps the gated-head
semantics: a pick vetoed by the block-pool reservation gate STOPS the
admission pass, so reservation gating stays starvation-free under any
policy — a large request is never starved by smaller ones slipping past it.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

from repro.adapt.signals import Clock


@dataclasses.dataclass(frozen=True)
class QueuedRequest:
    """One queue entry as a policy sees it.

    age is seconds spent in the queue (scheduler clock — injectable in
    tests); tenant/priority mirror the optional ``Request`` metadata.
    """

    rid: int
    tenant: str | None
    priority: int
    age: float
    prompt_len: int


@dataclasses.dataclass(frozen=True)
class ServeSignals:
    """What a serve policy observes at a step boundary.

    queue_depth      pending (unadmitted) request count.
    live             occupied slots.
    capacity         current slot-table capacity (the pow2 bucket).
    tokens_per_sec   windowed delivery rate (``adapt.signals
                     .ThroughputWindow``); None before the first token.
    free_blocks      unreserved free blocks in the KV pool.
    reserved_blocks  outstanding admission-reservation credits.
    queued           the queue in FIFO order, with per-request age/metadata.
    step             the engine's decode-step count (same value as
                     ``clock.step``).
    """

    queue_depth: int = 0
    live: int = 0
    capacity: int = 0
    tokens_per_sec: float | None = None
    free_blocks: int = 0
    reserved_blocks: int = 0
    queued: tuple[QueuedRequest, ...] = ()
    step: int = 0


@dataclasses.dataclass(frozen=True)
class ServeDecision:
    """One typed serve-policy decision.  ``None`` fields = leave unchanged.

    slot_budget      cap on the slot-table capacity (snapped onto the pow2
                     slot lattice by the engine; clamped so live requests
                     are never evicted and progress never stalls — the
                     effective cap is at least max(live, 1)).  Persists
                     until a later decision changes it.
    order            admission order over the queued rids.  Rids missing
                     from the ordering follow in FIFO order; rids no longer
                     queued are ignored — a policy can rank a subset without
                     being able to drop anyone.
    shrink_patience  boundaries a smaller slot target must persist before
                     the engine shrinks (the reshard-thrash hysteresis).
                     Persists until changed.
    reason           provenance string ("fifo", "priority", "fair", ...).
    """

    slot_budget: int | None = None
    order: tuple[int, ...] | None = None
    shrink_patience: int | None = None
    reason: str = ""


@runtime_checkable
class ServePolicy(Protocol):
    """Structural protocol every serve policy satisfies."""

    def observe(
        self, signals: ServeSignals, clock: Clock
    ) -> ServeDecision | None: ...


class FifoPolicy:
    """Strict first-in-first-out admission — the default, and exactly the
    pre-hook engine's behaviour: the returned ordering is the queue order
    itself, and slot budget / shrink patience stay untouched."""

    def observe(self, signals: ServeSignals, clock: Clock) -> ServeDecision | None:
        if not signals.queued:
            return None
        return ServeDecision(
            order=tuple(q.rid for q in signals.queued), reason="fifo"
        )


class PriorityPolicy:
    """Admit by priority class (``Request.priority``, higher first), FIFO
    within a class (``sorted`` is stable over the FIFO-ordered queue view).
    The gated-head rule still applies to the REORDERED head: a gated
    high-priority request blocks lower classes rather than being starved by
    them."""

    def observe(self, signals: ServeSignals, clock: Clock) -> ServeDecision | None:
        if not signals.queued:
            return None
        order = tuple(
            q.rid
            for q in sorted(signals.queued, key=lambda q: -q.priority)
        )
        return ServeDecision(order=order, reason="priority")


class FairSharePolicy:
    """Per-tenant deficit round-robin over ``Request.tenant``.

    Each tenant owns a virtual-time counter equal to the number of its
    requests already admitted (tracked by watching rids leave the queue
    between observations).  A queued request's virtual finish time is
    ``(admitted[tenant] + its position in the tenant's own FIFO) //
    quantum`` — so tenants alternate admission slots (``quantum`` per turn)
    regardless of how deep any one tenant's backlog runs: a burst from one
    tenant queues behind the others' steady arrivals instead of starving
    them.  FIFO order is preserved within a tenant, and ties between
    tenants break by queue (arrival) order, so equal-share traffic reduces
    to plain FIFO.

    Requests with ``tenant=None`` form their own share class.
    """

    def __init__(self, quantum: int = 1):
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        self.quantum = int(quantum)
        self._admitted: dict[str | None, int] = {}
        self._pending: dict[int, str | None] = {}  # rid -> tenant, last seen

    def observe(self, signals: ServeSignals, clock: Clock) -> ServeDecision | None:
        current = {q.rid for q in signals.queued}
        # rids that left the queue were admitted (the scheduler never drops)
        for rid in [r for r in self._pending if r not in current]:
            tenant = self._pending.pop(rid)
            self._admitted[tenant] = self._admitted.get(tenant, 0) + 1
        if not signals.queued:
            return None
        for q in signals.queued:
            self._pending[q.rid] = q.tenant
        depth: dict[str | None, int] = {}
        ranked = []
        for fifo_idx, q in enumerate(signals.queued):
            k = depth.get(q.tenant, 0)
            depth[q.tenant] = k + 1
            vtime = (self._admitted.get(q.tenant, 0) + k) // self.quantum
            ranked.append((vtime, fifo_idx, q.rid))
        ranked.sort()
        return ServeDecision(
            order=tuple(rid for _, _, rid in ranked), reason="fair"
        )


#: CLI-facing registry (``launch/serve.py --policy``, benches)
POLICIES = ("fifo", "priority", "fair")


def make_serve_policy(name: str) -> ServePolicy:
    """Build a registry policy by name (``fifo`` | ``priority`` | ``fair``)."""
    if name == "fifo":
        return FifoPolicy()
    if name == "priority":
        return PriorityPolicy()
    if name == "fair":
        return FairSharePolicy()
    raise ValueError(f"unknown serve policy {name!r}; known: {POLICIES}")
