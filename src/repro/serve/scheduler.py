"""Continuous-batching scheduler: admission queue + slot table.

The scheduler is a HOST-side, model-free object (the property tests drive it
with synthetic token streams and no jax at all).  It owns the request
lifecycle; the :class:`~repro.serve.engine.ServeEngine` owns the device
mirror (the batched KV/SSM cache) and drives the scheduler in boundary
phases between decode steps:

  1. retire — retirement happened during the previous step's ``record``
     calls (a slot frees the moment its request hits EOS or its budget);
  2. policy observe — the engine snapshots the queue/slot/pool state into
     ``serve.policy.ServeSignals`` and asks its ``ServePolicy`` for a
     decision: the admission ORDER over the queue, a cap on the slot
     budget, and the shrink patience.  The default ``FifoPolicy`` decides
     exactly what steps 3-4 would do on their own;
  3. ``target_slots()`` -> ``resize(n)``: the slot capacity tracks the
     runnable request count on the pow2 lattice (``core/batch_policy.bucket``
     — the serving analogue of the train-side compile buckets), clamped
     under the policy's slot budget, and a shrink compacts live slots into
     the low indices (``resize`` returns the gather map the engine applies
     to the cache rows);
  4. ``admit(order=...)``: free slots are refilled from the queue in the
     policy's order (FIFO by default) — a mid-batch EOS no longer wastes
     its lane until the whole chunk drains.  A pick vetoed by the caller's
     ``gate`` (the engine's block-pool reservation check) STOPS the pass,
     whatever the ordering, so reservation gating stays starvation-free;
  5. one decode step for the whole slot table; ``record(slot, token)``
     appends each live slot's token and retires the slot the moment its
     request hits EOS or its token budget.

Invariants (property-tested in tests/test_serve_sched.py and
tests/test_serve_policy.py): a slot is never double-assigned, no submitted
request is ever dropped — under ANY admission ordering — every request
retires at exactly its EOS/max-token step, and every capacity the scheduler
asks for lies on the pow2 slot lattice.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Iterable

import numpy as np

from repro.core.batch_policy import bucket

#: ``slot_rids()`` sentinel for a free lane — a value no real request id can
#: take (rids count up from 0), so a free lane can never alias a live
#: request's per-rid sampling-key material in the decode program
FREE_RID = -1


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    eos_id: int | None = None
    # optional policy metadata (serve/policy.py): share class, priority
    # class (higher admits sooner under PriorityPolicy), and an explicit
    # submission timestamp (defaults to the scheduler clock at submit)
    tenant: str | None = None
    priority: int = 0
    submit_time: float | None = None


@dataclasses.dataclass
class Result:
    tokens: np.ndarray
    steps: int


@dataclasses.dataclass(frozen=True)
class Admission:
    """One queue->slot assignment handed back by ``admit()``."""

    slot: int
    rid: int
    request: Request


def slots_for(need: int, granule: int, max_slots: int) -> int:
    """Smallest pow2-lattice slot count covering ``need``, capped at the
    largest lattice point <= ``max_slots`` (requests beyond the cap wait in
    the queue).  Always >= any live count that fit under the cap before."""
    if need <= 0:
        return 0
    cap = bucket(max_slots, granule, "pow2", m_max=max_slots)
    n = min(need, cap)
    s = bucket(n, granule, "pow2", m_max=cap)
    while s < n and s * 2 <= cap:
        s *= 2
    return s


class Scheduler:
    """Admission queue + slot table for continuous-batching decode."""

    def __init__(self, max_slots: int, *, granule: int = 1,
                 clock: Callable[[], float] = time.monotonic):
        if granule < 1 or max_slots < granule:
            raise ValueError(
                f"need max_slots >= granule >= 1, got {max_slots}, {granule}"
            )
        self.max_slots = int(max_slots)
        self.granule = int(granule)
        #: injectable wall clock — queue ages are unit-testable without
        #: sleeping (mirrors adapt.signals.ThroughputWindow)
        self.clock = clock
        self._queue: collections.deque[int] = collections.deque()
        self._reqs: dict[int, Request] = {}
        self._budget: dict[int, int] = {}
        self._tokens: dict[int, list[int]] = {}
        self._submit_t: dict[int, float] = {}
        self._slots: list[int | None] = []
        self._done: dict[int, Result] = {}
        self._next_rid = 0
        self.submitted = 0
        self.retired = 0

    # -- lifecycle -----------------------------------------------------------
    def submit(self, request: Request, *, budget: int | None = None) -> int:
        """Queue a request; ``budget`` caps its total emitted tokens (the
        engine passes ``min(max_new_tokens, cache headroom)``)."""
        budget = request.max_new_tokens if budget is None else int(budget)
        if budget < 1:
            raise ValueError(f"token budget must be >= 1, got {budget}")
        rid = self._next_rid
        self._next_rid += 1
        self._reqs[rid] = request
        self._budget[rid] = budget
        self._tokens[rid] = []
        self._submit_t[rid] = (
            self.clock() if request.submit_time is None
            else float(request.submit_time)
        )
        self._queue.append(rid)
        self.submitted += 1
        return rid

    def target_slots(self) -> int:
        """The pow2-lattice capacity for the current runnable load."""
        return slots_for(self.live + self.pending, self.granule, self.max_slots)

    def resize(self, n: int) -> list[int]:
        """Set the capacity to ``n``, compacting live slots into the low
        indices (slot order preserved).  Returns, per NEW slot, the OLD slot
        index whose device row it should take (free slots map to row 0 — the
        engine's cache gather needs a valid index; the row content of a free
        slot is never read)."""
        live = [(i, rid) for i, rid in enumerate(self._slots) if rid is not None]
        if n < len(live):
            raise ValueError(f"cannot shrink to {n} slots with {len(live)} live")
        idx = [i for i, _ in live] + [0] * (n - len(live))
        self._slots = [rid for _, rid in live] + [None] * (n - len(live))
        return idx

    def admit(self, gate=None,
              order: Iterable[int] | None = None) -> list[Admission]:
        """Fill free slots from the queue (one pass; callers loop when an
        admission retires instantly and frees its slot again).

        ``order`` is a policy-supplied admission ordering over the queued
        rids (``None`` = FIFO).  Rids in the ordering that are no longer
        queued are skipped (admitted in an earlier pass this boundary);
        queued rids the ordering omits follow at the end in FIFO order — an
        ordering can promote or rank a subset but can never DROP a request.

        ``gate(rid, request) -> bool`` vetoes admissions the caller cannot
        resource yet (the engine's block-pool reservation check).  A gated
        pick STOPS the pass — admission stays strict in the chosen order,
        so a large request is never starved by smaller ones slipping past
        it, whatever the policy's ordering.
        """
        if order is None:
            picks = list(self._queue)
        else:
            queued = set(self._queue)
            picks, seen = [], set()
            for rid in order:
                if rid in queued and rid not in seen:
                    picks.append(rid)
                    seen.add(rid)
            picks.extend(rid for rid in self._queue if rid not in seen)
        out: list[Admission] = []
        k = 0
        for i, rid in enumerate(self._slots):
            if rid is None and k < len(picks):
                nrid = picks[k]
                if gate is not None and not gate(nrid, self._reqs[nrid]):
                    break
                k += 1
                self._queue.remove(nrid)
                self._slots[i] = nrid
                out.append(Admission(slot=i, rid=nrid, request=self._reqs[nrid]))
        return out

    def record(self, slot: int, token: int) -> bool:
        """Append ``token`` to the request in ``slot``; retire the slot (and
        return True) the moment the request hits EOS or its budget."""
        rid = self._slots[slot]
        if rid is None:
            raise ValueError(f"slot {slot} is free; cannot record a token")
        token = int(token)
        toks = self._tokens[rid]
        toks.append(token)
        req = self._reqs[rid]
        done = (req.eos_id is not None and token == req.eos_id) or (
            len(toks) >= self._budget[rid]
        )
        if done:
            self._done[rid] = Result(
                tokens=np.asarray(toks, np.int32), steps=len(toks)
            )
            self._slots[slot] = None
            self.retired += 1
        return done

    # -- views ---------------------------------------------------------------
    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def live(self) -> int:
        return sum(1 for rid in self._slots if rid is not None)

    @property
    def capacity(self) -> int:
        return len(self._slots)

    @property
    def has_work(self) -> bool:
        return self.live > 0 or self.pending > 0

    def live_slots(self) -> list[tuple[int, int]]:
        """[(slot, rid)] for every occupied slot, in slot order."""
        return [(i, rid) for i, rid in enumerate(self._slots) if rid is not None]

    def running_slots(self) -> list[tuple[int, int]]:
        """[(slot, rid)] for slots that are DECODING — occupied and holding at
        least one emitted token.  An occupied slot with no tokens yet is still
        loading (chunked prefill in flight); it keeps its lane but must not
        decode or feed a stale token."""
        return [
            (i, rid)
            for i, rid in enumerate(self._slots)
            if rid is not None and self._tokens[rid]
        ]

    def queued(self) -> list[tuple[int, Request, float]]:
        """``[(rid, request, submit_time)]`` for every queued (unadmitted)
        request, in FIFO order — the policy-facing queue view (ages come
        from ``clock() - submit_time``)."""
        return [(rid, self._reqs[rid], self._submit_t[rid])
                for rid in self._queue]

    def slot_of(self, rid: int) -> int:
        """The slot currently holding ``rid`` (raises if it is not resident)."""
        for i, r in enumerate(self._slots):
            if r == rid:
                return i
        raise KeyError(f"request {rid} holds no slot")

    def next_tokens(self) -> np.ndarray:
        """(capacity,) int32 feed for the next decode step: each running
        slot's last emitted token; 0 for free or still-loading lanes."""
        out = np.zeros(len(self._slots), np.int32)
        for i, rid in enumerate(self._slots):
            if rid is not None and self._tokens[rid]:
                out[i] = self._tokens[rid][-1]
        return out

    def slot_rids(self) -> np.ndarray:
        """(capacity,) int32 request ids per slot — the per-slot
        sampling-key material fed into the decode program.  Free lanes carry
        :data:`FREE_RID` (-1), which no real request id can take: the old 0
        sentinel collided with the FIRST request's rid, feeding a free lane
        the same fold_in key material as request 0."""
        out = np.full(len(self._slots), FREE_RID, np.int32)
        for i, rid in enumerate(self._slots):
            if rid is not None:
                out[i] = rid
        return out

    def result(self, rid: int) -> Result:
        if rid not in self._done:
            raise KeyError(f"request {rid} has not finished")
        return self._done[rid]

    def results(self) -> dict[int, Result]:
        return dict(self._done)
