"""ServeEngine — elastic continuous-batching prefill/decode over a paged KV
block pool.

The serving mirror of the train stack's single path: one engine, a bucketed
``(bucket, rung)`` compile cache, and a ``MeshLadder`` that lets the live
request load drive the device footprint — DiveBatch's rule ("run as wide as
the batch justifies, no wider") applied to inference.  Since PR 6 the cache
side applies the same rule to MEMORY: KV for full-attention layers lives in a
vLLM-style block pool, so the footprint tracks resident tokens instead of
``max_slots * max_seq``.

Pieces:

  * ``Scheduler`` (serve/scheduler.py) — true continuous batching: an
    admission queue, slot free/refill at every step boundary, per-slot
    EOS/max-token retirement.  Admission is gated by the block pool's
    reservation check (worst-case blocks are promised up front, so a live
    request can never strand mid-decode on an exhausted pool).
  * ``ServePolicy`` (serve/policy.py) — the serve-side mirror of
    ``adapt.AdaptationPolicy``: at every step boundary (retire -> policy
    observe -> resize -> admit) the engine snapshots ``ServeSignals``
    (queue depth + per-request age, live/pending, windowed tokens/s, pool
    headroom) and the policy's ``ServeDecision`` sets the admission order,
    caps the slot budget, and tunes the shrink patience.  ``FifoPolicy``
    (the default) reproduces the pre-hook engine token-for-token; applied
    decisions mirror into ``serve_policy`` run-log events.
  * ``BlockPool`` (serve/blocks.py) — host accounting for the device pool:
    free list, refcounts, reservations, chain-hashed prefix registry with
    copy-on-write, LRU-evictable cached prefixes.  The device side is
    ``models/transformer.init_pages``: per full-attention pattern position, a
    flat ``(repeats, num_blocks, block, kv, hd)`` pool sharded by
    ``dist.sharding.cache_pspecs`` (block axis over dp, kv heads over tp).
    Block 0 is the sentinel: inactive decode lanes write there, reads are
    masked by per-slot validity.  Windowed rings and SSM state stay in the
    dense per-slot cache — they are O(1) per slot already.
  * per-request block tables — the engine maps each request's logical
    positions to pool blocks (host ``np`` tables rebuilt per step, sentinel
    elsewhere), so ``decode_step`` reads context through a table gather and
    writes the new token at ``table[pos // block]``.  Tables are keyed by
    request, not slot: a resize compacts cache ROWS, the tables just follow
    the request.
  * chunked prefill — prompts stream through ``prefill_chunk`` in
    block-aligned chunks, compiled per ``(chunk, prior-block bucket, rung)``;
    every pending prompt advances one chunk per boundary, interleaved with
    decode, so a long prompt never stalls the running batch.  With
    ``prefill_chunk=0`` (default) a prompt is one chunk — exactly the old
    whole-prompt schedule, which the rung-golden lane pins token-for-token.
  * prefix sharing — padded prompts chain-hash per block; a request whose
    padded prompt matches a registered chain adopts the blocks (refcounted)
    instead of recomputing them.  A FULL-prompt match replays the cached
    end-of-prompt row state + logits and skips prefill entirely (the
    N-thousand-user shared-system-prompt case costs one prefill); a partial
    match (pure full-attention configs, where the pool holds all the state)
    prefills only the tail chunks.
  * compile cache — decode programs AOT-compiled per ``(bucket, rung)`` with
    the pool shape fixed for the engine lifetime, so paging adds ZERO compile
    keys: ``compiles == len(set(zip(buckets, rungs)))`` still holds.
  * ``ServeStats`` — plus pool metrics: ``peak_blocks`` (peak live blocks —
    the resident-token footprint), ``prefill_chunks``, ``shared_prefill_hits``,
    ``cow_copies``.
"""

from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.adapt.signals import ThroughputWindow
from repro.configs.base import ModelConfig
from repro.core.batch_policy import bucket
from repro.dist.plan import current_plan
from repro.dist.sharding import cache_pspecs, shardings_of
from repro.elastic import MeshLadder, place
from repro.models import transformer as tf
from repro.obs import metrics as metrics_lib
from repro.obs import runlog as runlog_lib
from repro.obs import trace as trace_lib
from repro.adapt.signals import Clock
from repro.serve.blocks import BlockPool, chain_keys
from repro.serve.policy import (
    FifoPolicy,
    QueuedRequest,
    ServePolicy,
    ServeSignals,
    make_serve_policy,
)
from repro.serve.scheduler import Admission, Request, Result, Scheduler, slots_for

PyTree = Any

SAMPLERS = ("greedy", "categorical")


def padded_prompt_len(n: int, granule: int) -> int:
    """Smallest pow2 prompt bucket (``granule * 2^i``) holding ``n`` tokens
    — the same lattice snap-up as the slot/batch buckets
    (``core/batch_policy.bucket`` with an off-lattice ``m_min`` snaps UP).

    Prompts are LEFT-padded to their own bucket independently of what they
    are batched with, so a request's padding — and therefore its tokens —
    never depends on its co-scheduled neighbours.  Prefix sharing hashes the
    PADDED stream for the same reason: identical padded streams mean
    identical absolute positions, so shared blocks are bit-compatible."""
    return bucket(max(int(n), 1), max(int(granule), 1), "pow2",
                  m_min=max(int(n), 1))


def _insert_row(cache: PyTree, row: PyTree, j) -> PyTree:
    """Write one slot-geometry row into batch position ``j`` of the cache
    (leaf batch axis: 0 for the per-slot ``len`` vector, 1 after the stacked
    repeats axis for every layer leaf)."""
    return jax.tree.map(
        lambda full, r: jax.lax.dynamic_update_slice_in_dim(
            full, r.astype(full.dtype), j, axis=0 if full.ndim == 1 else 1
        ),
        cache,
        row,
    )


def _gather_rows(cache: PyTree, idx) -> PyTree:
    """Re-index the cache batch axis: ``new[i] = old[idx[i]]`` — one program
    covers compaction (shrink), growth, and any slot permutation."""
    return jax.tree.map(
        lambda x: jnp.take(x, idx, axis=0 if x.ndim == 1 else 1), cache
    )


def _copy_block(pages: PyTree, src, dst) -> PyTree:
    """Device side of copy-on-write: duplicate pool block ``src`` into
    ``dst`` across every paged position (block axis is 1, after repeats)."""
    return jax.tree.map(lambda x: x.at[:, dst].set(x[:, src]), pages)


@dataclasses.dataclass
class _BlockState:
    """Host bookkeeping for one request's slice of the pool."""

    tokens: np.ndarray  # the PADDED prompt (plen,)
    plen: int
    budget: int
    nb_prompt: int  # prompt blocks (plen // block_size)
    total_need: int  # worst-case blocks (prompt + decode budget)
    keys: list  # chain keys of the padded prompt ([] with sharing off)
    table: list[int] = dataclasses.field(default_factory=list)
    reserved: int = 0  # outstanding pool credits
    shared: int = 0  # blocks adopted from the prefix registry
    pos: int = 0  # tokens resident on device (mirror of cache["len"])
    ent: dict | None = None  # full-prompt cache hit staged by the gate


@dataclasses.dataclass
class _PrefillJob:
    """A prompt mid-load: one chunk advances per boundary."""

    rid: int
    off: int  # next chunk's first position (block-aligned)
    row: PyTree  # carried per-request state (len, windowed rings, SSM)
    stepped: bool = False


class ServeStats(metrics_lib.StatsView):
    """Observable serving behaviour (mirrors ``train.engine.EngineStats``).

    ``compiles`` counts decode-step compilations — one per distinct
    ``(bucket, rung)`` pair, so ``compiles == len(set(zip(buckets,
    rungs)))``; ``bucket_hits``/``bucket_misses`` count decode cache
    lookups (one per decode step).  ``prefill_compiles`` counts per-(chunk,
    prior-block bucket, rung) prefill programs, ``aux_compiles`` the
    insert/gather/sample helpers.  ``slot_steps`` is the total decoded lanes
    (capacity summed over steps); ``tokens`` counts tokens actually delivered
    to requests.  ``prefills`` counts requests whose prompt became resident
    (including shared-prefix instant hits); ``prefill_chunks`` counts chunk
    programs actually executed — a fully shared prompt runs zero;
    ``shared_prefill_hits`` counts those instant admissions and
    ``shared_blocks`` the pool blocks adopted instead of recomputed.
    ``pool_blocks``/``peak_blocks`` give the pool capacity and the peak
    LIVE (refcounted) block count — the resident-token footprint that
    replaced the dense ``max_slots * max_seq`` preallocation.
    ``tokens_per_sec`` is the windowed rate (``adapt.signals
    .ThroughputWindow``), not a run-global average.

    Like ``EngineStats``, the scalar fields are emitting views over the
    ``repro.obs.metrics`` registry under a fresh ``serve.engine.<n>``
    namespace; the attribute surface and ``as_dict()`` are unchanged.
    """

    _COUNTERS = (
        "compiles", "bucket_hits", "bucket_misses", "prefill_compiles",
        "aux_compiles", "steps", "slot_steps", "tokens", "prefills",
        "prefill_chunks", "shared_prefill_hits", "shared_blocks",
        "reshards", "resizes",
    )
    _GAUGES = (
        "cow_copies", "pool_blocks", "peak_blocks", "block_size",
        "retired", "compile_s", "dispatch_wall_s", "tokens_per_sec",
    )

    def __init__(self, donate: bool = True, pool_blocks: int = 0,
                 block_size: int = 0, *,
                 registry: metrics_lib.Registry | None = None):
        self.donate = donate
        self.buckets: list[int] = []
        self.rungs: list = []
        self._init_metrics("serve.engine", registry)
        self.pool_blocks = pool_blocks
        self.block_size = block_size

    def as_dict(self) -> dict:
        d = {f: getattr(self, f) for f in (
            "compiles", "bucket_hits", "bucket_misses", "prefill_compiles",
            "aux_compiles", "steps", "slot_steps", "tokens", "prefills",
            "prefill_chunks", "shared_prefill_hits", "shared_blocks",
            "cow_copies", "pool_blocks", "peak_blocks", "block_size",
            "retired", "reshards", "resizes", "compile_s",
            "dispatch_wall_s", "tokens_per_sec",
        )}
        d["donate"] = self.donate
        d["buckets"] = list(self.buckets)
        d["rungs"] = list(self.rungs)
        return d


class ServeEngine:
    """Continuous-batching serving over the model zoo, paged KV cache.

    ``submit``/``step`` is the streaming interface (the benches drive
    arrival traces through it); ``generate(requests)`` is the batch
    convenience wrapper (submit everything, drain, collect).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: PyTree,
        *,
        max_slots: int = 8,
        max_seq: int = 1024,
        sampler: str = "greedy",
        temperature: float = 1.0,
        seed: int = 0,
        slot_granule: int = 1,
        prompt_granule: int = 8,
        elastic: MeshLadder | None = None,
        donate: bool = True,
        shrink_patience: int = 2,
        block_size: int | None = None,
        pool_blocks: int | None = None,
        prefill_chunk: int = 0,
        prefix_sharing: bool = True,
        attn_impl: str | None = None,
        policy: ServePolicy | str | None = None,
        tracer=None,
        runlog=None,
        obs_window: int = 16,
    ):
        if sampler not in SAMPLERS:
            raise ValueError(f"sampler must be one of {SAMPLERS}, got {sampler!r}")
        # attn_impl="pallas" runs the serving hot loop (paged decode, chunked
        # prefill, full prefill) on the kernels/attention.py lane
        self.cfg = cfg.replace(remat=False) if attn_impl is None else cfg.replace(
            remat=False, attn_impl=attn_impl
        )
        self.max_slots = int(max_slots)
        self.max_seq = int(max_seq)
        self.sampler = sampler
        self.temperature = float(temperature)
        self.seed = int(seed)
        self.prompt_granule = int(prompt_granule)
        self.donate = bool(donate)
        self.prefix_sharing = bool(prefix_sharing)
        self.block_size = int(block_size) if block_size else self.prompt_granule
        if self.prompt_granule % self.block_size:
            raise ValueError(
                f"prompt_granule {self.prompt_granule} must be a multiple of "
                f"block_size {self.block_size} (prompts pad to whole blocks)"
            )
        self.prefill_chunk = int(prefill_chunk)
        if self.prefill_chunk and self.prefill_chunk % self.block_size:
            raise ValueError(
                f"prefill_chunk {self.prefill_chunk} must be a multiple of "
                f"block_size {self.block_size}"
            )
        plan = current_plan()
        if elastic is not None and plan is not None:
            raise ValueError(
                "ServeEngine(elastic=...) under an ambient dist plan is "
                "ambiguous: the ladder owns the sharding plan per rung — "
                "drop the use_plan context (or the elastic ladder)"
            )
        self._elastic = elastic
        self._plan = plan
        self._rung = elastic.rungs[0] if elastic is not None else None
        self.sched = Scheduler(self.max_slots, granule=slot_granule)
        self.params = place(params, self._live_plan)
        self._cache: PyTree | None = None
        self._bucket = 0
        # Grow immediately, shrink only once the smaller target has held for
        # ``shrink_patience`` consecutive boundaries — the serving analogue
        # of adapt.Hysteresis: a retirement followed by an arrival would
        # otherwise bounce the bucket (and with it the ladder rung) straight
        # back, paying a resize+reshard both ways.
        self.shrink_patience = int(shrink_patience)
        self._shrink_streak = 0
        # -- the adaptation policy hook (serve/policy.py) -------------------
        # observe -> decide at every boundary, mirroring the train side's
        # adapt.AdaptationPolicy; FifoPolicy is provably the pre-hook engine
        if policy is None:
            policy = FifoPolicy()
        elif isinstance(policy, str):
            policy = make_serve_policy(policy)
        self.policy = policy
        self._slot_budget: int | None = None  # persists until a decision moves it
        self._adm_order: list[int] | None = None  # this boundary's ordering
        self._sample = self._sampler_fn()
        self._exes: dict[tuple, Any] = {}
        # -- the paged pool -------------------------------------------------
        # Table capacity: the satellite-3 budget fix lets logical positions
        # run past max_seq by the prompt's padding slack (plen - raw), which
        # is < max(granule, max_seq/2) on the pow2 lattice.
        span = self.max_seq + max(self.prompt_granule, self.max_seq // 2)
        self._n_max = -(-span // self.block_size)
        self._paged = tf.paged_positions(self.cfg)
        if pool_blocks is None:
            # pow2 default: worst-case credits for every slot + the sentinel
            # (pow2 also keeps the dp sharding of the block axis even)
            pool_blocks = padded_prompt_len(1 + self.max_slots * self._n_max, 1)
        self.pool = BlockPool(int(pool_blocks), self.block_size)
        self._pages = self._place_cache(
            tf.init_pages(self.cfg, int(pool_blocks), self.block_size)
        )
        # a partial chain match only covers full-attention state (it lives in
        # the pool); configs with rings/SSM share only on full-prompt hits
        self._row_trivial = len(self._paged) == self.cfg.period
        self._req_blocks: dict[int, _BlockState] = {}
        self._jobs: list[_PrefillJob] = []
        self._prompt_cache: collections.OrderedDict = collections.OrderedDict()
        self._prompt_cache_cap = 256
        self.stats = ServeStats(
            donate=self.donate,
            pool_blocks=self.pool.num_blocks,
            block_size=self.block_size,
        )
        self._thru = ThroughputWindow()
        # telemetry sinks (repro.obs); the pool shares the engine's tracer so
        # alloc/evict instants land on the same timeline as decode spans
        self.tracer = tracer if tracer is not None else trace_lib.NULL
        self.runlog = runlog if runlog is not None else runlog_lib.NULL
        self.pool.tracer = self.tracer
        #: emit a ``serve_window`` run-log event every this many decode steps
        self.obs_window = int(obs_window)

    # -- plumbing ------------------------------------------------------------
    @property
    def _live_plan(self):
        return self._rung.plan if self._rung is not None else self._plan

    @property
    def _rung_token(self):
        return self._rung.index if self._rung is not None else None

    @property
    def rung(self):
        """The live elastic ladder rung (None outside elastic mode)."""
        return self._rung

    @property
    def busy(self) -> bool:
        return self.sched.has_work

    def _sampler_fn(self):
        if self.sampler == "greedy":

            def sample(logits, rids, pos):
                return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)

            return sample
        base, temp = self.seed, self.temperature

        def sample(logits, rids, pos):
            # per-slot keys derived from (engine seed, request id, position):
            # sampling is deterministic per request, independent of which
            # slot/bucket/neighbours the request happens to be batched with
            def one(lg, rid, p):
                k = jax.random.fold_in(
                    jax.random.fold_in(jax.random.key(base), rid), p
                )
                return jax.random.categorical(k, lg / temp)

            return jax.vmap(one)(logits[:, -1, :], rids, pos).astype(jnp.int32)

        return sample

    def _decode_fn(self):
        cfg, sample = self.cfg, self._sample

        def fn(params, cache, pages, tables, toks, rids):
            logits, cache, pages = tf.decode_step(
                cfg, params, cache, toks, pages=pages, tables=tables
            )
            return sample(logits, rids, cache["len"]), cache, pages

        return fn

    def _chunk_fn(self):
        cfg, sample = self.cfg, self._sample

        def fn(params, pages, row, toks, rid, ptab, wtab, off):
            logits, row, pages = tf.prefill_chunk(
                cfg, params, row, pages, {"tokens": toks}, off, ptab, wtab
            )
            # only the FINAL chunk's token is consumed (row["len"] == plen
            # there); intermediate chunk tokens are discarded by the caller
            tok = sample(logits, rid[None], row["len"])
            return tok, logits, row, pages

        return fn

    def _cache_shardings(self, tree):
        plan = self._live_plan
        if plan is None:
            return None
        return shardings_of(cache_pspecs(tree, plan), plan)

    def _place_cache(self, cache: PyTree) -> PyTree:
        """KV/SSM cache onto the live plan via ``dist.sharding.cache_pspecs``
        (batch rows / pool blocks over dp, kv-heads over tp; plan-free =
        leave as is)."""
        sh = self._cache_shardings(cache)
        return cache if sh is None else jax.device_put(cache, sh)

    def _exe(self, key, fn, args, *, donate=(), out_pin=None, kind="aux"):
        """AOT-compiled program for ``key`` (mirrors StepEngine._executable:
        exact compile accounting, sharding-exact executables).  ``fn`` and
        ``out_pin`` are zero-arg thunks so a cache hit — the per-step hot
        path — pays one dict lookup, not a retrace/sharding-inference;
        ``out_pin`` pins cache outputs to the canonical cache_pspecs
        shardings so every program on a rung agrees on the cache layout."""
        if key in self._exes:
            if kind == "decode":
                self.stats.bucket_hits += 1
            return self._exes[key]
        if kind == "decode":
            self.stats.bucket_misses += 1
        fn = fn()
        kwargs = {}
        if donate and self.donate:
            kwargs["donate_argnums"] = donate
        if out_pin is not None and self._live_plan is not None:
            pin = out_pin()
            if pin is not None:
                kwargs["out_shardings"] = pin
        t0 = time.perf_counter()
        with warnings.catch_warnings():
            # a grow-gather's donated (smaller) cache cannot alias the larger
            # output — partial donation is expected there, not a leak
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable"
            )
            with self.tracer.span("compile", scope="serve", kind=kind,
                                  key=str(key)):
                exe = jax.jit(fn, **kwargs).lower(*args).compile()
        dt = time.perf_counter() - t0
        if self.runlog.enabled:
            self.runlog.emit("compile", scope="serve", what=str(key),
                             seconds=dt, exe_kind=kind, rung=self._rung_token)
        self.stats.compile_s += dt
        if kind == "decode":
            self.stats.compiles += 1
            self.stats.buckets.append(self._bucket)
            self.stats.rungs.append(self._rung_token)
        elif kind == "prefill":
            self.stats.prefill_compiles += 1
        else:
            self.stats.aux_compiles += 1
        self._exes[key] = exe
        return exe

    # -- elastic -------------------------------------------------------------
    def _ensure_rung(self) -> None:
        """Move params + cache + pool + in-flight prefill state onto the
        ladder rung for the live slot count (no-op off-ladder or on an
        unchanged rung)."""
        if self._elastic is None:
            return
        rung = self._elastic.rung_for_batch(max(self._bucket, 1))
        if rung.index == self._rung.index:
            return
        src = self._rung
        with self.tracer.span("reshard", scope="serve", src=src.index,
                              dst=rung.index, dp=rung.dp):
            self._rung = rung
            self.params = place(self.params, rung.plan)
            if self._cache is not None:
                self._cache = self._place_cache(self._cache)
            self._pages = self._place_cache(self._pages)
            for job in self._jobs:
                job.row = self._place_cache(job.row)
            for ent in self._prompt_cache.values():
                ent["row"] = self._place_cache(ent["row"])
        if self.runlog.enabled:
            self.runlog.emit("reshard", scope="serve", src=src.index,
                             dst=rung.index, dp=rung.dp,
                             step=self.stats.steps)
        self.stats.reshards += 1

    def _resize(self, target: int) -> None:
        """Track the scheduler's pow2 slot capacity: grow/shrink the batched
        per-slot cache (compacting live rows via the scheduler's gather map
        — the POOL never resizes, tables just follow their requests), then
        follow with the rung transition."""
        if target == self._bucket:
            return
        idx = self.sched.resize(target)
        old = self._bucket
        self._bucket = target
        if target == 0:
            self._cache = None  # the pool (and its cached prefixes) persists
            return
        self.stats.resizes += 1
        if self._cache is None:
            self._ensure_rung()
            cache = tf.init_cache(self.cfg, target, self.max_seq, skip=self._paged)
            cache["len"] = jnp.zeros((target,), jnp.int32)  # per-slot timeline
            self._cache = self._place_cache(cache)
            return
        idx_arr = np.asarray(idx, np.int32)
        exe = self._exe(
            ("gather", old, target, self._rung_token), lambda: _gather_rows,
            (self._cache, idx_arr), donate=(0,),
            out_pin=lambda: self._cache_shardings(
                jax.eval_shape(_gather_rows, self._cache, idx_arr)
            ),
        )
        self._cache = exe(self._cache, idx_arr)
        self._ensure_rung()

    # -- admission -----------------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue a request; returns its id (``result(rid)`` after drain)."""
        prompt = np.asarray(request.prompt, np.int32).reshape(-1)
        plen = padded_prompt_len(len(prompt), self.prompt_granule)
        if plen > self.max_seq:
            raise ValueError(
                f"prompt of {len(prompt)} tokens pads to {plen} > max_seq "
                f"{self.max_seq}"
            )
        # headroom from the TRUE prompt length: with block tables the pad
        # columns cost table entries, not budget — a request near max_seq
        # keeps its full max_new_tokens (positions may pass max_seq by the
        # padding slack; _n_max sizes the tables for exactly that)
        budget = min(int(request.max_new_tokens), self.max_seq - len(prompt) + 1)
        padded = np.zeros(plen, np.int32)
        if len(prompt):
            padded[plen - len(prompt):] = prompt  # left-pad
        nb_prompt = plen // self.block_size
        total_need = nb_prompt + -(-(budget - 1) // self.block_size)
        if total_need > self.pool.num_blocks - 1:
            raise ValueError(
                f"request needs {total_need} pool blocks but the pool holds "
                f"{self.pool.num_blocks - 1}; raise pool_blocks"
            )
        rid = self.sched.submit(request, budget=budget)
        self._req_blocks[rid] = _BlockState(
            tokens=padded, plen=plen, budget=budget, nb_prompt=nb_prompt,
            total_need=total_need,
            keys=chain_keys(padded, self.block_size) if self.prefix_sharing else [],
        )
        return rid

    def _shared_prefix(self, bs: _BlockState):
        """(adoptable prefix block ids, full-prompt cache entry or None).

        A full-chain match alone cannot emit token 1 (no logits cached in the
        pool), so it is only an instant admission when the prompt cache still
        holds the end-of-prompt row + logits AND the registry still maps the
        whole chain to the entry's blocks; otherwise fall back to a partial
        match capped at nb_prompt - 1 — valid only for pure full-attention
        configs (ring/SSM state is not in the pool)."""
        if not self.prefix_sharing or not bs.keys:
            return [], None
        ent = self._prompt_cache.get(bs.keys[-1])
        if ent is not None:
            ids = self.pool.match(bs.keys)
            if len(ids) == bs.nb_prompt and ids == ent["ids"]:
                self._prompt_cache.move_to_end(bs.keys[-1])
                return ids, ent
            del self._prompt_cache[bs.keys[-1]]  # stale: blocks evicted
        if not self._row_trivial:
            return [], None
        return self.pool.match(bs.keys[:bs.nb_prompt - 1]), None

    def _gate(self, rid: int, request: Request) -> bool:
        """Admission gate AND claim: can the pool cover this request's worst
        case?  A passing gate immediately adopts the shared prefix, reserves
        the rest, and allocates the prompt blocks — the claim must land
        before the scheduler gates the NEXT queue head in the same pass, or
        two admissions would both be judged against the unclaimed pool.
        (``Scheduler.admit`` guarantees a passing gate IS admitted, so a
        claim is never orphaned.)"""
        bs = self._req_blocks[rid]
        ids, ent = self._shared_prefix(bs)
        if not self.pool.feasible(ids, bs.total_need):
            return False
        for b in ids:
            self.pool.retain(b)
        self.pool.reserve(bs.total_need - len(ids))
        bs.reserved = bs.total_need - len(ids)
        bs.shared = len(ids)
        bs.table = list(ids)
        while len(bs.table) < bs.nb_prompt:
            bs.table.append(self.pool.alloc(reserved=True))
            bs.reserved -= 1
        bs.ent = ent
        self.stats.shared_blocks += len(ids)
        self.stats.peak_blocks = self.pool.peak_live
        return True

    def _begin(self, adm: Admission) -> None:
        """Start an admitted request (blocks were claimed by ``_gate``):
        either replay a full-prompt cache hit or start a chunked prefill
        job."""
        bs = self._req_blocks[adm.rid]
        ent, bs.ent = bs.ent, None
        if self.runlog.enabled:
            self.runlog.emit("serve_admit", rid=adm.rid, prompt_len=bs.plen,
                             budget=bs.budget, shared=bs.shared,
                             full_hit=ent is not None)
        with self.tracer.span("admit", rid=adm.rid, prompt_len=bs.plen,
                              shared=bs.shared):
            if ent is not None:
                self._admit_shared(adm, bs, ent)
            else:
                self._jobs.append(_PrefillJob(
                    rid=adm.rid, off=bs.shared * self.block_size,
                    row=self._fresh_row(bs.shared * self.block_size),
                ))

    def _fresh_row(self, off: int) -> PyTree:
        """Zeroed per-request prefill carry, starting at position ``off``
        (> 0 when a shared prefix was adopted)."""
        row = tf.init_cache(self.cfg, 1, self.max_seq, skip=self._paged)
        row["len"] = jnp.full((1,), off, jnp.int32)
        return self._place_cache(row)

    def _admit_shared(self, adm: Admission, bs: _BlockState, ent: dict) -> None:
        """Full-prompt cache hit: the prompt is already resident — replay the
        cached end-of-prompt logits through the sampler (keyed by THIS
        request's rid, so categorical streams stay per-request) and insert
        the cached row.  Zero prefill compute."""
        rid = np.asarray(adm.rid, np.int32)
        pos = np.full((1,), bs.plen, np.int32)
        exe = self._exe(
            ("sample", self._rung_token), lambda: self._sample,
            (ent["logits"], rid[None], pos),
        )
        tok = exe(ent["logits"], rid[None], pos)
        self._insert(adm.slot, ent["row"])
        bs.pos = bs.plen
        self.stats.prefills += 1
        self.stats.shared_prefill_hits += 1
        self._count_token(1)
        done = self.sched.record(adm.slot, int(np.asarray(tok)[0]))
        if done:
            self._release(adm.rid)

    def _insert(self, slot: int, row: PyTree) -> None:
        j = np.asarray(slot, np.int32)
        iexe = self._exe(
            ("insert", self._bucket, self._rung_token), lambda: _insert_row,
            (self._cache, row, j), donate=(0,),
            out_pin=lambda: self._cache_shardings(self._cache),
        )
        self._cache = iexe(self._cache, row, j)

    def _count_token(self, n: int) -> None:
        self.stats.tokens += n
        self._thru.add(float(n))
        rate = self._thru.rate()
        if rate is not None:
            self.stats.tokens_per_sec = rate

    # -- the policy boundary -------------------------------------------------
    def _signals(self) -> ServeSignals:
        """Snapshot the queue/slot/pool state for ``policy.observe`` (host
        state only — zero device transfers)."""
        sch = self.sched
        now = sch.clock()
        return ServeSignals(
            queue_depth=sch.pending,
            live=sch.live,
            capacity=sch.capacity,
            tokens_per_sec=self._thru.rate(now=now),
            free_blocks=self.pool.free,
            reserved_blocks=self.pool.reserved,
            queued=tuple(
                QueuedRequest(rid=rid, tenant=req.tenant,
                              priority=req.priority,
                              age=max(now - t, 0.0),
                              prompt_len=len(req.prompt))
                for rid, req, t in sch.queued()
            ),
            step=self.stats.steps,
        )

    def _observe_policy(self) -> None:
        """The boundary's policy phase (retire -> OBSERVE -> resize ->
        admit): build signals, let the policy decide, and apply — the
        admission ordering for this boundary, the persistent slot-budget
        cap, and the shrink patience.  Applied decisions that change
        anything mirror into a ``serve_policy`` run-log event; an ordering
        equal to FIFO is the identity and takes the legacy admit path."""
        self._adm_order = None
        sig = self._signals()
        clock = Clock(epoch=0, step=self.stats.steps, boundary="tick")
        if self.tracer.enabled:
            with self.tracer.span("observe", scope="serve",
                                  step_num=self.stats.steps):
                d = self.policy.observe(sig, clock)
        else:
            d = self.policy.observe(sig, clock)
        if d is None:
            return
        reordered = False
        if d.order is not None:
            order = tuple(d.order)
            if order != tuple(q.rid for q in sig.queued):
                self._adm_order = list(order)
                reordered = True
        changed = reordered
        if d.slot_budget is not None and int(d.slot_budget) != self._slot_budget:
            self._slot_budget = int(d.slot_budget)
            changed = True
        if (d.shrink_patience is not None
                and int(d.shrink_patience) != self.shrink_patience):
            self.shrink_patience = int(d.shrink_patience)
            changed = True
        if changed and self.runlog.enabled:
            self.runlog.emit(
                "serve_policy", step=self.stats.steps,
                reason=d.reason or type(self.policy).__name__,
                reordered=reordered, slot_budget=self._slot_budget,
                shrink_patience=self.shrink_patience,
                queue_depth=sig.queue_depth,
            )

    def _target_slots(self) -> int:
        """The scheduler's pow2 slot target, clamped under the policy's
        slot budget.  The effective budget is at least ``max(live, 1)``:
        a budget can throttle admission but never evicts live requests or
        stalls the drain."""
        target = self.sched.target_slots()
        if self._slot_budget is None:
            return target
        cap = max(self._slot_budget, self.sched.live, 1)
        need = min(self.sched.live + self.sched.pending, cap)
        return min(target, slots_for(need, self.sched.granule, self.max_slots))

    # -- chunked prefill -----------------------------------------------------
    def _run_chunk(self, job: _PrefillJob) -> None:
        """Advance one prompt by one block-aligned chunk.  The prior-context
        table is padded to a pow2 block count so the compile key is
        ``(chunk, prior bucket, rung)`` — O(log max_seq) programs, not one
        per offset."""
        bs = self._req_blocks[job.rid]
        c = bs.plen - job.off
        if self.prefill_chunk:
            c = min(c, self.prefill_chunk)
        toks = bs.tokens[None, job.off:job.off + c]
        nbp_real = job.off // self.block_size
        nbp = padded_prompt_len(nbp_real, 1) if nbp_real else 0
        ptab = np.zeros((nbp,), np.int32)
        ptab[:nbp_real] = bs.table[:nbp_real]
        wtab = np.asarray(
            bs.table[nbp_real:(job.off + c) // self.block_size], np.int32
        )
        rid = np.asarray(job.rid, np.int32)
        off = np.int32(job.off)
        fn = self._chunk_fn()
        args = (self.params, self._pages, job.row, toks, rid, ptab, wtab, off)
        exe = self._exe(
            ("pfchunk", c, nbp, self._rung_token), lambda: fn, args,
            donate=(1, 2),
            out_pin=lambda: (
                None, None,
                self._cache_shardings(jax.eval_shape(fn, *args)[2]),
                self._cache_shardings(self._pages),
            ),
            kind="prefill",
        )
        tr = self.tracer
        if tr.enabled:
            with tr.span("prefill_chunk", rid=job.rid, off=job.off, chunk=c,
                         rung=self._rung_token):
                tok, logits, job.row, self._pages = exe(*args)
        else:
            tok, logits, job.row, self._pages = exe(*args)
        job.off += c
        self.stats.prefill_chunks += 1
        if job.off == bs.plen:
            self._finish_job(job, tok, logits)

    def _finish_job(self, job: _PrefillJob, tok, logits) -> None:
        """Final chunk done: register the prompt chain, cache the
        end-of-prompt state for future full-prompt hits, insert the row, and
        record token 1."""
        bs = self._req_blocks[job.rid]
        self._jobs.remove(job)
        bs.pos = bs.plen
        if self.prefix_sharing and bs.keys:
            for key, bid in zip(bs.keys, bs.table[:bs.nb_prompt]):
                self.pool.register(key, bid)  # first writer wins
            ids = self.pool.match(bs.keys)
            if len(ids) == bs.nb_prompt:
                self._prompt_cache[bs.keys[-1]] = {
                    "ids": ids,
                    "row": job.row,
                    # host copy: rung-independent, tiny (1 x vocab)
                    "logits": np.asarray(logits),
                }
                while len(self._prompt_cache) > self._prompt_cache_cap:
                    self._prompt_cache.popitem(last=False)
        slot = self.sched.slot_of(job.rid)
        self._insert(slot, job.row)
        self.stats.prefills += 1
        self._count_token(1)
        done = self.sched.record(slot, int(np.asarray(tok)[0]))
        if done:
            self._release(job.rid)

    def _prefill_work(self) -> None:
        """Admissions + one chunk per pending prompt, repeated while instant
        retirements (EOS/budget at token 1) keep freeing slots.  Each job
        advances at most one chunk per boundary — long prompts interleave
        with decode instead of stalling it."""
        for job in self._jobs:
            job.stepped = False
        while True:
            adms = self.sched.admit(gate=self._gate, order=self._adm_order)
            for adm in adms:
                self._begin(adm)
            pending = [j for j in self._jobs if not j.stepped]
            if not pending:
                if not adms:
                    return
                continue
            for job in pending:
                job.stepped = True
                self._run_chunk(job)

    # -- block tables --------------------------------------------------------
    def _release(self, rid: int) -> None:
        """Retirement: drop the request's block refs (registered prompt
        blocks fall back to the evictable prefix cache) and return unspent
        reservation credits."""
        bs = self._req_blocks.pop(rid)
        for b in bs.table:
            self.pool.release(b)
        if bs.reserved:
            self.pool.unreserve(bs.reserved)
            bs.reserved = 0
        if self.runlog.enabled:
            self.runlog.emit("serve_retire", rid=rid, pos=bs.pos,
                             live_blocks=self.pool.live)
        self.stats.peak_blocks = self.pool.peak_live
        self.stats.cow_copies = self.pool.cow_copies

    def _decode_tables(self, running) -> np.ndarray:
        """(bucket, n_max) int32 block tables for this decode step.  Rows of
        non-running lanes stay all-sentinel, so their (garbage) writes land
        in block 0.  Extends each running request's table for the token about
        to be written, spending reserved credits — and copy-on-write guards
        the (unreachable by construction: prompts pad to whole blocks) case
        of a shared write block."""
        arr = np.zeros((self._bucket, self._n_max), np.int32)
        for slot, rid in running:
            bs = self._req_blocks[rid]
            wi = bs.pos // self.block_size
            while len(bs.table) <= wi:
                bs.table.append(self.pool.alloc(reserved=True))
                bs.reserved -= 1
            if not self.pool.writable(bs.table[wi]):
                new = self.pool.cow(bs.table[wi])
                src, dst = np.int32(bs.table[wi]), np.int32(new)
                cexe = self._exe(
                    ("cow", self._rung_token), lambda: _copy_block,
                    (self._pages, src, dst), donate=(0,),
                    out_pin=lambda: self._cache_shardings(self._pages),
                )
                self._pages = cexe(self._pages, src, dst)
                bs.table[wi] = new
                self.stats.cow_copies = self.pool.cow_copies
            arr[slot, :len(bs.table)] = bs.table
        self.stats.peak_blocks = self.pool.peak_live
        return arr

    # -- the serving step ----------------------------------------------------
    def step(self) -> bool:
        """One boundary (retire happened in the previous step's records ->
        policy observe -> resize -> reshard -> admit/prefill-chunks) plus
        one decode step over the slot table.  Returns False once fully
        drained."""
        sch = self.sched
        if not sch.has_work:
            # a drained engine starts the next trace fresh: a stale shrink
            # streak would defeat shrink_patience on its first dip
            self._shrink_streak = 0
            return False
        self._observe_policy()
        target = self._target_slots()
        if 0 < target < self._bucket:
            self._shrink_streak += 1
            if self._shrink_streak <= self.shrink_patience:
                target = self._bucket  # ride out a transient dip
        else:
            self._shrink_streak = 0
        if target != self._bucket:
            self._shrink_streak = 0
        self._resize(target)
        self._prefill_work()
        self.stats.retired = sch.retired  # prefill-instant retirements count
        running = sch.running_slots()
        if not running:  # nothing decoding (drained, or all mid-prefill)
            return True
        toks = sch.next_tokens()[:, None]
        rids = sch.slot_rids()
        tables = self._decode_tables(running)
        exe = self._exe(
            ("decode", self._bucket, self._rung_token), self._decode_fn,
            (self.params, self._cache, self._pages, tables, toks, rids),
            donate=(1, 2),
            out_pin=lambda: (
                None,
                self._cache_shardings(self._cache),
                self._cache_shardings(self._pages),
            ),
            kind="decode",
        )
        tr = self.tracer
        t0 = time.perf_counter()
        # disabled path: one attribute load + branch, no extra transfers
        # (the per-step (B,) token read below predates the tracer)
        if tr.enabled:
            with tr.span("decode", bucket=self._bucket, rung=self._rung_token,
                         live=len(running), step_num=self.stats.steps):
                nxt, self._cache, self._pages = exe(
                    self.params, self._cache, self._pages, tables, toks, rids
                )
        else:
            nxt, self._cache, self._pages = exe(
                self.params, self._cache, self._pages, tables, toks, rids
            )
        self.stats.dispatch_wall_s += time.perf_counter() - t0
        nxt = np.asarray(nxt)  # the per-step host transfer: one (B,) vector
        self.stats.steps += 1
        self.stats.slot_steps += self._bucket
        for slot, rid in running:
            self._req_blocks[rid].pos += 1
            if sch.record(slot, int(nxt[slot])):
                self._release(rid)
        self._count_token(len(running))
        self.stats.retired = sch.retired
        if (self.runlog.enabled and self.obs_window
                and self.stats.steps % self.obs_window == 0):
            self.runlog.emit(
                "serve_window", step=self.stats.steps, tokens=self.stats.tokens,
                tokens_per_sec=self.stats.tokens_per_sec, live=len(running),
                live_blocks=self.pool.live, bucket=self._bucket,
                rung=self._rung_token,
            )
        return True

    def drain(self) -> None:
        while self.step():
            pass
        self.pool.check()  # drained: conservation + zero leaked blocks

    def result(self, rid: int) -> Result:
        return self.sched.result(rid)

    def generate(self, requests: list[Request]) -> list[Result]:
        """Submit, drain, and collect — results in request order."""
        rids = [self.submit(r) for r in requests]
        self.drain()
        return [self.sched.result(rid) for rid in rids]
