"""ServeEngine — elastic continuous-batching prefill/decode.

The serving mirror of the train stack's single path: one engine, a bucketed
``(bucket, rung)`` compile cache, and a ``MeshLadder`` that lets the live
request load drive the device footprint — DiveBatch's rule ("run as wide as
the batch justifies, no wider") applied to inference, where the decode batch
ebbs with arrivals and drains exactly like the train batch ebbs with the
diversity signal.

Pieces:

  * ``Scheduler`` (serve/scheduler.py) — true continuous batching: an
    admission queue, slot free/refill at every step boundary, per-slot
    EOS/max-token retirement.  The old chunked ``generate`` held the whole
    chunk hostage to its longest request and kept decoding finished slots.
  * per-slot decode — ``models/transformer.decode_step`` accepts a ``(B,)``
    per-slot position vector (``cache["len"]``): every slot lives on its own
    timeline, so admissions/retirements never synchronise the batch.  A
    request is prefilled alone at a pow2-padded prompt length and its cache
    rows are inserted into the batched cache, which makes each request's
    output a function of the request alone — token-identical across slot
    buckets, scheduling orders, mesh rungs, and live rung transitions (the
    rung-golden tests assert exactly this).
  * compile cache — decode programs are AOT-compiled per ``(bucket, rung)``
    where ``bucket`` is the pow2 slot capacity (``core/batch_policy.bucket``
    lattice, inactive slots masked via the per-row validity mask); prefill
    programs per (padded prompt length, rung); insert/gather helpers per
    shape.  Donation keeps one batched cache live.
  * elastic rungs — ``ServeEngine(elastic=MeshLadder(...))`` picks the rung
    from the live slot count; a rung transition re-places the params via
    ``elastic.reshard.place`` and the KV/SSM cache via
    ``dist.sharding.cache_pspecs``.  Without a ladder the engine runs on the
    ambient ``dist.use_plan`` plan (the fixed-full-mesh baseline) or single
    device.
  * ``ServeStats`` — compiles, bucket/rung hits, reshards, resizes, and a
    windowed tokens/s (``adapt.signals.ThroughputWindow``), mirroring
    ``EngineStats`` for benchmarks (benchmarks/bench_serve.py) and tests.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.adapt.signals import ThroughputWindow
from repro.configs.base import ModelConfig
from repro.core.batch_policy import bucket
from repro.dist.plan import current_plan
from repro.dist.sharding import cache_pspecs, shardings_of
from repro.elastic import MeshLadder, place
from repro.models import transformer as tf
from repro.serve.scheduler import Admission, Request, Result, Scheduler

PyTree = Any

SAMPLERS = ("greedy", "categorical")


def padded_prompt_len(n: int, granule: int) -> int:
    """Smallest pow2 prompt bucket (``granule * 2^i``) holding ``n`` tokens
    — the same lattice snap-up as the slot/batch buckets
    (``core/batch_policy.bucket`` with an off-lattice ``m_min`` snaps UP).

    Prompts are LEFT-padded to their own bucket independently of what they
    are batched with, so a request's padding — and therefore its tokens —
    never depends on its co-scheduled neighbours."""
    return bucket(max(int(n), 1), max(int(granule), 1), "pow2",
                  m_min=max(int(n), 1))


def _slot_cache(cfg: ModelConfig, cache: PyTree, max_seq: int, plen: int) -> PyTree:
    """Convert a batch-1 prefill cache (geometry of a ``plen`` context) to
    one row of the batched decode cache (geometry of a ``max_seq`` context).

    Full-attention layers pad with (validity-masked) zeros to the decode
    length.  Windowed layers are ring buffers indexed by ``position % window``
    in decode, while prefill emits the newest ``window`` entries in
    chronological order — the roll rotates them into ring order so later
    decode writes evict the genuinely oldest position."""
    out = {"len": jnp.reshape(cache["len"], (1,)).astype(jnp.int32)}
    for p in range(cfg.period):
        if cfg.pattern[p] == "mamba":
            out[f"pos{p}"] = cache[f"pos{p}"]  # O(1) state: row geometry already
            continue
        s_c = tf._cache_len_for(cfg, p, max_seq)

        def fit(x):
            length = x.shape[2]
            if length > s_c:
                x = x[:, :, length - s_c:]
                length = s_c
            if length == s_c:
                return jnp.roll(x, plen % s_c, axis=2)
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, s_c - length)
            return jnp.pad(x, pad)

        lc = cache[f"pos{p}"]
        out[f"pos{p}"] = {"k": fit(lc["k"]), "v": fit(lc["v"])}
    return out


def _insert_row(cache: PyTree, row: PyTree, j) -> PyTree:
    """Write one slot-geometry row into batch position ``j`` of the cache
    (leaf batch axis: 0 for the per-slot ``len`` vector, 1 after the stacked
    repeats axis for every layer leaf)."""
    return jax.tree.map(
        lambda full, r: jax.lax.dynamic_update_slice_in_dim(
            full, r.astype(full.dtype), j, axis=0 if full.ndim == 1 else 1
        ),
        cache,
        row,
    )


def _gather_rows(cache: PyTree, idx) -> PyTree:
    """Re-index the cache batch axis: ``new[i] = old[idx[i]]`` — one program
    covers compaction (shrink), growth, and any slot permutation."""
    return jax.tree.map(
        lambda x: jnp.take(x, idx, axis=0 if x.ndim == 1 else 1), cache
    )


@dataclasses.dataclass
class ServeStats:
    """Observable serving behaviour (mirrors ``train.engine.EngineStats``).

    ``compiles`` counts decode-step compilations — one per distinct
    ``(bucket, rung)`` pair, so ``compiles == len(set(zip(buckets,
    rungs)))``; ``bucket_hits``/``bucket_misses`` count decode cache
    lookups (one per decode step).  ``prefill_compiles`` counts per-(padded
    prompt length, rung) prefill programs, ``aux_compiles`` the
    insert/gather helpers.  ``slot_steps`` is the total decoded lanes
    (capacity summed over steps — the waste metric the old chunked
    ``generate`` lost to its longest request); ``tokens`` counts tokens
    actually delivered to requests.  ``tokens_per_sec`` is the windowed rate
    (``adapt.signals.ThroughputWindow``), not a run-global average.
    """

    compiles: int = 0
    bucket_hits: int = 0
    bucket_misses: int = 0
    prefill_compiles: int = 0
    aux_compiles: int = 0
    steps: int = 0
    slot_steps: int = 0
    tokens: int = 0
    prefills: int = 0
    retired: int = 0
    reshards: int = 0
    resizes: int = 0
    compile_s: float = 0.0
    dispatch_wall_s: float = 0.0
    tokens_per_sec: float = 0.0
    donate: bool = True
    buckets: list[int] = dataclasses.field(default_factory=list)
    rungs: list = dataclasses.field(default_factory=list)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ServeEngine:
    """Continuous-batching serving over the model zoo.

    ``submit``/``step`` is the streaming interface (the benches drive
    arrival traces through it); ``generate(requests)`` is the batch
    convenience wrapper (submit everything, drain, collect).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: PyTree,
        *,
        max_slots: int = 8,
        max_seq: int = 1024,
        sampler: str = "greedy",
        temperature: float = 1.0,
        seed: int = 0,
        slot_granule: int = 1,
        prompt_granule: int = 8,
        elastic: MeshLadder | None = None,
        donate: bool = True,
        shrink_patience: int = 2,
    ):
        if sampler not in SAMPLERS:
            raise ValueError(f"sampler must be one of {SAMPLERS}, got {sampler!r}")
        self.cfg = cfg.replace(remat=False)
        self.max_slots = int(max_slots)
        self.max_seq = int(max_seq)
        self.sampler = sampler
        self.temperature = float(temperature)
        self.seed = int(seed)
        self.prompt_granule = int(prompt_granule)
        self.donate = bool(donate)
        plan = current_plan()
        if elastic is not None and plan is not None:
            raise ValueError(
                "ServeEngine(elastic=...) under an ambient dist plan is "
                "ambiguous: the ladder owns the sharding plan per rung — "
                "drop the use_plan context (or the elastic ladder)"
            )
        self._elastic = elastic
        self._plan = plan
        self._rung = elastic.rungs[0] if elastic is not None else None
        self.sched = Scheduler(self.max_slots, granule=slot_granule)
        self.params = place(params, self._live_plan)
        self._cache: PyTree | None = None
        self._bucket = 0
        # Grow immediately, shrink only once the smaller target has held for
        # ``shrink_patience`` consecutive boundaries — the serving analogue
        # of adapt.Hysteresis: a retirement followed by an arrival would
        # otherwise bounce the bucket (and with it the ladder rung) straight
        # back, paying a resize+reshard both ways.
        self.shrink_patience = int(shrink_patience)
        self._shrink_streak = 0
        self._sample = self._sampler_fn()
        self._exes: dict[tuple, Any] = {}
        self.stats = ServeStats(donate=self.donate)
        self._thru = ThroughputWindow()

    # -- plumbing ------------------------------------------------------------
    @property
    def _live_plan(self):
        return self._rung.plan if self._rung is not None else self._plan

    @property
    def _rung_token(self):
        return self._rung.index if self._rung is not None else None

    @property
    def rung(self):
        """The live elastic ladder rung (None outside elastic mode)."""
        return self._rung

    @property
    def busy(self) -> bool:
        return self.sched.has_work

    def _sampler_fn(self):
        if self.sampler == "greedy":

            def sample(logits, rids, pos):
                return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)

            return sample
        base, temp = self.seed, self.temperature

        def sample(logits, rids, pos):
            # per-slot keys derived from (engine seed, request id, position):
            # sampling is deterministic per request, independent of which
            # slot/bucket/neighbours the request happens to be batched with
            def one(lg, rid, p):
                k = jax.random.fold_in(
                    jax.random.fold_in(jax.random.key(base), rid), p
                )
                return jax.random.categorical(k, lg / temp)

            return jax.vmap(one)(logits[:, -1, :], rids, pos).astype(jnp.int32)

        return sample

    def _decode_fn(self):
        cfg, sample = self.cfg, self._sample

        def fn(params, cache, toks, rids):
            logits, cache = tf.decode_step(cfg, params, cache, toks)
            return sample(logits, rids, cache["len"]), cache

        return fn

    def _prefill_fn(self, plen: int):
        cfg, sample, max_seq = self.cfg, self._sample, self.max_seq

        def fn(params, toks, rid):
            logits, cache = tf.prefill_step(cfg, params, {"tokens": toks})
            row = _slot_cache(cfg, cache, max_seq, plen)
            return sample(logits, rid[None], row["len"]), row

        return fn

    def _cache_shardings(self, tree):
        plan = self._live_plan
        if plan is None:
            return None
        return shardings_of(cache_pspecs(tree, plan), plan)

    def _place_cache(self, cache: PyTree) -> PyTree:
        """KV/SSM cache onto the live plan via ``dist.sharding.cache_pspecs``
        (batch rows over dp, kv-heads over tp; plan-free = leave as is)."""
        sh = self._cache_shardings(cache)
        return cache if sh is None else jax.device_put(cache, sh)

    def _exe(self, key, fn, args, *, donate=(), out_pin=None, kind="aux"):
        """AOT-compiled program for ``key`` (mirrors StepEngine._executable:
        exact compile accounting, sharding-exact executables).  ``fn`` and
        ``out_pin`` are zero-arg thunks so a cache hit — the per-step hot
        path — pays one dict lookup, not a retrace/sharding-inference;
        ``out_pin`` pins cache outputs to the canonical cache_pspecs
        shardings so every program on a rung agrees on the cache layout."""
        if key in self._exes:
            if kind == "decode":
                self.stats.bucket_hits += 1
            return self._exes[key]
        if kind == "decode":
            self.stats.bucket_misses += 1
        fn = fn()
        kwargs = {}
        if donate and self.donate:
            kwargs["donate_argnums"] = donate
        if out_pin is not None and self._live_plan is not None:
            pin = out_pin()
            if pin is not None:
                kwargs["out_shardings"] = pin
        t0 = time.perf_counter()
        with warnings.catch_warnings():
            # a grow-gather's donated (smaller) cache cannot alias the larger
            # output — partial donation is expected there, not a leak
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable"
            )
            exe = jax.jit(fn, **kwargs).lower(*args).compile()
        self.stats.compile_s += time.perf_counter() - t0
        if kind == "decode":
            self.stats.compiles += 1
            self.stats.buckets.append(self._bucket)
            self.stats.rungs.append(self._rung_token)
        elif kind == "prefill":
            self.stats.prefill_compiles += 1
        else:
            self.stats.aux_compiles += 1
        self._exes[key] = exe
        return exe

    # -- elastic -------------------------------------------------------------
    def _ensure_rung(self) -> None:
        """Move params + cache onto the ladder rung for the live slot count
        (no-op off-ladder or on an unchanged rung)."""
        if self._elastic is None:
            return
        rung = self._elastic.rung_for_batch(max(self._bucket, 1))
        if rung.index == self._rung.index:
            return
        self._rung = rung
        self.params = place(self.params, rung.plan)
        if self._cache is not None:
            self._cache = self._place_cache(self._cache)
        self.stats.reshards += 1

    def _resize(self, target: int) -> None:
        """Track the scheduler's pow2 slot capacity: grow/shrink the batched
        cache (compacting live rows via the scheduler's gather map), then
        follow with the rung transition."""
        if target == self._bucket:
            return
        idx = self.sched.resize(target)
        old = self._bucket
        self._bucket = target
        if target == 0:
            self._cache = None
            return
        self.stats.resizes += 1
        if self._cache is None:
            self._ensure_rung()
            cache = tf.init_cache(self.cfg, target, self.max_seq)
            cache["len"] = jnp.zeros((target,), jnp.int32)  # per-slot timeline
            self._cache = self._place_cache(cache)
            return
        idx_arr = np.asarray(idx, np.int32)
        exe = self._exe(
            ("gather", old, target, self._rung_token), lambda: _gather_rows,
            (self._cache, idx_arr), donate=(0,),
            out_pin=lambda: self._cache_shardings(
                jax.eval_shape(_gather_rows, self._cache, idx_arr)
            ),
        )
        self._cache = exe(self._cache, idx_arr)
        self._ensure_rung()

    # -- admission -----------------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue a request; returns its id (``result(rid)`` after drain)."""
        prompt = np.asarray(request.prompt, np.int32).reshape(-1)
        plen = padded_prompt_len(len(prompt), self.prompt_granule)
        if plen > self.max_seq:
            raise ValueError(
                f"prompt of {len(prompt)} tokens pads to {plen} > max_seq "
                f"{self.max_seq}"
            )
        # token 1 comes from prefill (no cache write); token k >= 2 writes
        # position plen + k - 2, which must stay inside the cache
        budget = min(int(request.max_new_tokens), self.max_seq - plen + 1)
        return self.sched.submit(request, budget=budget)

    def _prefill_into(self, adm: Admission) -> None:
        prompt = np.asarray(adm.request.prompt, np.int32).reshape(-1)
        plen = padded_prompt_len(len(prompt), self.prompt_granule)
        toks = np.zeros((1, plen), np.int32)
        if len(prompt):
            toks[0, plen - len(prompt):] = prompt  # left-pad
        rid = np.asarray(adm.rid, np.int32)
        fn = self._prefill_fn(plen)
        exe = self._exe(
            ("prefill", plen, self._rung_token), lambda: fn,
            (self.params, toks, rid),
            out_pin=lambda: (None, self._cache_shardings(
                jax.eval_shape(fn, self.params, toks, rid)[1]
            )),
            kind="prefill",
        )
        tok, row = exe(self.params, toks, rid)
        j = np.asarray(adm.slot, np.int32)
        iexe = self._exe(
            ("insert", self._bucket, self._rung_token), lambda: _insert_row,
            (self._cache, row, j), donate=(0,),
            out_pin=lambda: self._cache_shardings(self._cache),
        )
        self._cache = iexe(self._cache, row, j)
        self.stats.prefills += 1
        self.stats.tokens += 1
        self._thru.add(1.0)
        rate = self._thru.rate()
        if rate is not None:  # prefill tokens count toward the live rate too
            self.stats.tokens_per_sec = rate
        self.sched.record(adm.slot, int(np.asarray(tok)[0]))

    def _admit(self) -> None:
        while True:
            adms = self.sched.admit()
            if not adms:
                return
            for adm in adms:  # an instant (EOS-at-prefill) retirement frees
                self._prefill_into(adm)  # its slot; the loop re-admits

    # -- the serving step ----------------------------------------------------
    def step(self) -> bool:
        """One boundary (retire happened in the previous step's records ->
        resize -> reshard -> admit) plus one decode step over the slot
        table.  Returns False once fully drained."""
        sch = self.sched
        if not sch.has_work:
            return False
        target = sch.target_slots()
        if 0 < target < self._bucket:
            self._shrink_streak += 1
            if self._shrink_streak <= self.shrink_patience:
                target = self._bucket  # ride out a transient dip
        else:
            self._shrink_streak = 0
        if target != self._bucket:
            self._shrink_streak = 0
        self._resize(target)
        self._admit()
        self.stats.retired = sch.retired  # prefill-instant retirements count
        live = sch.live_slots()
        if not live:  # everything admitted retired at prefill
            return True
        toks = sch.next_tokens()[:, None]
        rids = sch.slot_rids()
        exe = self._exe(
            ("decode", self._bucket, self._rung_token), self._decode_fn,
            (self.params, self._cache, toks, rids), donate=(1,),
            out_pin=lambda: (None, self._cache_shardings(self._cache)),
            kind="decode",
        )
        t0 = time.perf_counter()
        nxt, self._cache = exe(self.params, self._cache, toks, rids)
        self.stats.dispatch_wall_s += time.perf_counter() - t0
        nxt = np.asarray(nxt)  # the per-step host transfer: one (B,) vector
        self.stats.steps += 1
        self.stats.slot_steps += self._bucket
        for slot, _ in live:
            sch.record(slot, int(nxt[slot]))
        self.stats.tokens += len(live)
        self.stats.retired = sch.retired
        self._thru.add(float(len(live)))
        rate = self._thru.rate()
        if rate is not None:
            self.stats.tokens_per_sec = rate
        return True

    def drain(self) -> None:
        while self.step():
            pass

    def result(self, rid: int) -> Result:
        return self.sched.result(rid)

    def generate(self, requests: list[Request]) -> list[Result]:
        """Submit, drain, and collect — results in request order."""
        rids = [self.submit(r) for r in requests]
        self.drain()
        return [self.sched.result(rid) for rid in rids]
