"""Serving engine: batched prefill + decode over the model zoo.

Used by examples/serve_lm.py and the inference dry-run cells. Requests are
batched up to ``max_batch``; the engine keeps one cache per slot and steps
all active slots together (continuous batching at step granularity — a slot
is freed as soon as its request hits EOS/max_tokens and can be refilled on
the next step boundary)."""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    eos_id: int | None = None


@dataclasses.dataclass
class Result:
    tokens: np.ndarray
    steps: int


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_seq: int = 1024, sampler: str = "greedy", temperature: float = 1.0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.sampler = sampler
        self.temperature = temperature

        cfg_nr = cfg.replace(remat=False)
        self._prefill = jax.jit(lambda p, b: tf.prefill_step(cfg_nr, p, b))
        self._decode = jax.jit(lambda p, c, t: tf.decode_step(cfg_nr, p, c, t))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.sampler == "greedy":
            return jnp.argmax(logits[:, -1, :], axis=-1)
        probs = jax.nn.softmax(logits[:, -1, :] / self.temperature, axis=-1)
        return jax.random.categorical(key, jnp.log(probs + 1e-9), axis=-1)

    def generate(self, requests: list[Request], seed: int = 0) -> list[Result]:
        """Pads all prompts to a common length, prefi lls once, then decodes
        the batch until every request is done."""
        out: list[Result] = []
        key = jax.random.key(seed)
        for i in range(0, len(requests), self.max_batch):
            chunk = requests[i : i + self.max_batch]
            out.extend(self._generate_batch(chunk, key))
        return out

    def _generate_batch(self, requests: list[Request], key) -> list[Result]:
        b = len(requests)
        plen = max(len(r.prompt) for r in requests)
        prompts = np.zeros((b, plen), np.int32)
        for j, r in enumerate(requests):
            prompts[j, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(prompts)}
        logits, cache = self._prefill(self.params, batch)
        max_new = max(r.max_new_tokens for r in requests)
        toks = np.zeros((b, max_new), np.int32)
        done = np.zeros(b, bool)
        steps = np.zeros(b, np.int32)
        key, sub = jax.random.split(key)
        nxt = self._sample(logits, sub)
        for t in range(max_new):
            toks[:, t] = np.asarray(nxt)
            for j, r in enumerate(requests):
                if not done[j]:
                    steps[j] = t + 1
                    if r.eos_id is not None and int(toks[j, t]) == r.eos_id:
                        done[j] = True
                    if t + 1 >= r.max_new_tokens:
                        done[j] = True
            if done.all() or plen + t + 1 >= self.max_seq:
                break
            logits, cache = self._decode(self.params, cache, nxt[:, None])
            key, sub = jax.random.split(key)
            nxt = self._sample(logits, sub)
        return [Result(tokens=toks[j, : steps[j]], steps=int(steps[j])) for j in range(b)]
