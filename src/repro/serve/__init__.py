"""repro.serve — elastic continuous-batching serving over a paged KV cache.

``ServeEngine`` (engine.py) mirrors the train stack: a bucketed
``(bucket, rung)`` compile cache over jitted chunked-prefill/decode, a
``Scheduler`` (scheduler.py) doing true continuous batching (admission
queue, slot refill at step boundaries, per-slot EOS/max-token retirement),
a ``BlockPool`` (blocks.py) paging full-attention KV into refcounted
fixed-size blocks with chain-hashed copy-on-write prefix sharing, and an
optional ``MeshLadder`` that co-adapts the device footprint with the live
decode batch — reshard via ``elastic.reshard.place`` for params and
``dist.sharding.cache_pspecs`` for the KV/SSM cache and the block pool.
``ServePolicy`` (policy.py) is the serve-side mirror of
``adapt.AdaptationPolicy``: at every step boundary the engine observes
``ServeSignals`` (queue depth/age, live load, windowed tokens/s, pool
headroom) and the policy's ``ServeDecision`` sets admission order, slot
budget, and shrink patience — ``FifoPolicy`` (default), ``PriorityPolicy``,
``FairSharePolicy``.  ``ServeStats`` mirrors ``EngineStats``.
"""

from repro.serve.blocks import BlockPool, PoolExhausted, chain_keys
from repro.serve.engine import ServeEngine, ServeStats, padded_prompt_len
from repro.serve.policy import (
    POLICIES,
    FairSharePolicy,
    FifoPolicy,
    PriorityPolicy,
    QueuedRequest,
    ServeDecision,
    ServePolicy,
    ServeSignals,
    make_serve_policy,
)
from repro.serve.scheduler import (
    FREE_RID,
    Admission,
    Request,
    Result,
    Scheduler,
    slots_for,
)

__all__ = [
    "ServeEngine",
    "ServeStats",
    "Scheduler",
    "Admission",
    "Request",
    "Result",
    "BlockPool",
    "PoolExhausted",
    "chain_keys",
    "padded_prompt_len",
    "slots_for",
    "FREE_RID",
    "ServePolicy",
    "ServeSignals",
    "ServeDecision",
    "QueuedRequest",
    "FifoPolicy",
    "PriorityPolicy",
    "FairSharePolicy",
    "make_serve_policy",
    "POLICIES",
]
