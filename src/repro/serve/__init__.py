"""repro.serve — elastic continuous-batching serving.

``ServeEngine`` (engine.py) mirrors the train stack: a bucketed
``(bucket, rung)`` compile cache over jitted prefill/decode, a
``Scheduler`` (scheduler.py) doing true continuous batching (admission
queue, slot refill at step boundaries, per-slot EOS/max-token retirement),
and an optional ``MeshLadder`` that co-adapts the device footprint with the
live decode batch — reshard via ``elastic.reshard.place`` for params and
``dist.sharding.cache_pspecs`` for the KV/SSM cache.  ``ServeStats``
mirrors ``EngineStats``.
"""

from repro.serve.engine import ServeEngine, ServeStats, padded_prompt_len
from repro.serve.scheduler import Admission, Request, Result, Scheduler

__all__ = [
    "ServeEngine",
    "ServeStats",
    "Scheduler",
    "Admission",
    "Request",
    "Result",
    "padded_prompt_len",
]
