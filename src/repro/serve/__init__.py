from repro.serve.engine import DecodeEngine, Request, Result

__all__ = ["DecodeEngine", "Request", "Result"]
