"""Paged KV-cache block pool: host-side accounting for the device pool.

The serving cache used to be slot-dense — every slot preallocated ``max_seq``
rows, so memory (not compute) bounded concurrency.  The pool replaces that
with vLLM-style paging: the device holds one flat pool of fixed-size KV
blocks per full-attention pattern position (``models/transformer.init_pages``)
and every request maps its logical token positions onto pool blocks through a
per-request block table.  This module is the HOST side of that scheme — a
model-free object (the property tests drive it with synthetic token streams
and no jax at all) mirroring the device pool block-for-block:

  * **free list / refcounts** — ``alloc``/``retain``/``release``.  A block is
    live while any request references it; refcounts never go negative
    (``release`` on a free block raises).
  * **reservations** — admission-time credits for a request's worst-case
    remaining footprint (``ceil((padded prompt + decode budget) / block)``).
    ``alloc(reserved=True)`` spends a credit; a request that retires early
    returns its unspent credits.  Reserving at admission (instead of
    allocating) is what decouples memory from ``max_seq``: the pool only ever
    holds blocks for tokens that are actually resident, yet a live request
    can never strand mid-decode on an exhausted pool.
  * **prefix registry** — full prompt blocks register under a chain hash
    (``chain_keys``: key_i = (key_{i-1}, block_i tokens), vLLM-v2 style), so
    a later request whose padded prompt shares a block-aligned prefix adopts
    the blocks instead of re-prefilling (the N-thousand-user
    shared-system-prompt case costs one prefill).  Registered blocks whose
    refcount drops to zero become *cached* — evictable LRU, still matchable —
    rather than free, so sharing survives across requests that never overlap
    in time.
  * **copy-on-write** — a block is ``writable`` only while singly-referenced
    and unregistered; ``cow`` hands the writer a private replacement block
    (the engine copies the device rows).  Engine invariant: prompts pad to a
    block multiple, so decode always writes fresh blocks and CoW never fires
    on the serve path — the machinery guards the invariant rather than
    relying on it.

Block id 0 is the SENTINEL: never allocated, the write target of inactive
decode lanes and the padding entry of every table — garbage may be written
there but is never read (validity masks cover it).
"""

from __future__ import annotations

import collections
from typing import Iterable, Sequence

from repro.obs import trace as trace_lib

Key = tuple


class PoolExhausted(RuntimeError):
    """No free or evictable block is left to satisfy an allocation."""


def chain_keys(tokens: Sequence[int], block_size: int) -> list[Key]:
    """Chain hash keys for a block-multiple token stream: ``key_i`` commits
    to every token in blocks ``0..i``, so a chain match is a prefix match."""
    toks = [int(t) for t in tokens]
    if block_size < 1 or len(toks) % block_size:
        raise ValueError(
            f"need a block-multiple stream, got {len(toks)} tokens at "
            f"block_size {block_size}"
        )
    keys: list[Key] = []
    prev: Key = ()
    for i in range(0, len(toks), block_size):
        prev = (prev, tuple(toks[i:i + block_size]))
        keys.append(prev)
    return keys


class BlockPool:
    """Host accounting for a ``num_blocks``-block device pool (id 0 reserved
    as the sentinel)."""

    #: telemetry sink (the owning ServeEngine rebinds its own tracer here so
    #: alloc/evict instants share the decode timeline); stays jax-free
    tracer = trace_lib.NULL

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is the sentinel), got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        # allocate ascending: ids num_blocks-1 .. 1, popped from the end
        self._free: list[int] = list(range(self.num_blocks - 1, 0, -1))
        self._ref: dict[int, int] = {}
        self._by_key: dict[Key, int] = {}
        self._key_of: dict[int, Key] = {}
        self._lru: collections.OrderedDict[int, None] = collections.OrderedDict()
        self._reserved = 0
        self.peak_live = 0
        self.cow_copies = 0

    # -- views ---------------------------------------------------------------
    @property
    def live(self) -> int:
        """Blocks referenced by at least one request."""
        return len(self._ref)

    @property
    def cached(self) -> int:
        """Unreferenced but registered blocks (evictable, still matchable)."""
        return len(self._lru)

    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def reserved(self) -> int:
        return self._reserved

    def available(self) -> int:
        """Blocks an admission may still claim: free + evictable - promised."""
        return len(self._free) + len(self._lru) - self._reserved

    # -- reservations --------------------------------------------------------
    def can_reserve(self, n: int) -> bool:
        return n <= self.available()

    def reserve(self, n: int) -> None:
        if not self.can_reserve(n):
            raise PoolExhausted(
                f"cannot reserve {n} blocks with {self.available()} available"
            )
        self._reserved += n

    def unreserve(self, n: int) -> None:
        if n > self._reserved:
            raise ValueError(f"unreserve({n}) exceeds {self._reserved} outstanding")
        self._reserved -= n

    def feasible(self, matched: Sequence[int], total: int) -> bool:
        """Can a request needing ``total`` blocks, ``matched`` of them adopted
        from the prefix registry, be admitted right now?  Matched CACHED
        blocks count as available until adopted, so they drop out of both
        sides of the inequality."""
        cached = sum(1 for b in matched if b in self._lru)
        return total - len(matched) <= self.available() - cached

    def admit_need(self, keys: Sequence[Key], total: int) -> tuple[list[int], bool]:
        """Admission probe: (matched shared blocks, whether the remainder fits)."""
        matched = self.match(keys)
        return matched, self.feasible(matched, total)

    # -- alloc / refcount ----------------------------------------------------
    def alloc(self, *, reserved: bool = False) -> int:
        """Claim a block (refcount 1).  ``reserved=True`` spends a credit
        promised at admission; otherwise the pool must have headroom beyond
        every outstanding reservation."""
        if reserved:
            if self._reserved <= 0:
                raise ValueError("alloc(reserved=True) with no outstanding reservation")
            self._reserved -= 1
        elif self.available() < 1:
            raise PoolExhausted("pool exhausted (all blocks live or promised)")
        if self._free:
            bid = self._free.pop()
        elif self._lru:
            bid, _ = self._lru.popitem(last=False)  # evict least-recently cached
            del self._by_key[self._key_of.pop(bid)]
            if self.tracer.enabled:
                self.tracer.instant("pool_evict", bid=bid, cached=len(self._lru))
        else:
            raise PoolExhausted("pool exhausted (no free or evictable block)")
        self._ref[bid] = 1
        self.peak_live = max(self.peak_live, len(self._ref))
        if self.tracer.enabled:
            self.tracer.instant("pool_alloc", bid=bid, live=len(self._ref))
        return bid

    def retain(self, bid: int) -> None:
        """Add a reference — reviving the block if it was cached."""
        if bid in self._ref:
            self._ref[bid] += 1
        elif bid in self._lru:
            del self._lru[bid]
            self._ref[bid] = 1
            self.peak_live = max(self.peak_live, len(self._ref))
        else:
            raise ValueError(f"retain of unallocated block {bid}")

    def release(self, bid: int) -> None:
        """Drop a reference.  The last release frees the block — or parks it
        in the evictable cache if it is prefix-registered."""
        r = self._ref.get(bid, 0)
        if r <= 0:
            raise ValueError(f"release of block {bid} would drop its refcount below 0")
        if r > 1:
            self._ref[bid] = r - 1
            return
        del self._ref[bid]
        if bid in self._key_of:
            self._lru[bid] = None  # most-recently cached at the end
        else:
            self._free.append(bid)

    # -- prefix registry -----------------------------------------------------
    def register(self, key: Key, bid: int) -> int:
        """Enter a live block into the prefix registry; first writer wins
        (a duplicate registration keeps the existing block and returns it)."""
        if bid not in self._ref:
            raise ValueError(f"register of non-live block {bid}")
        have = self._by_key.get(key)
        if have is not None:
            return have
        if bid in self._key_of:  # re-keying a registered block is a bug
            raise ValueError(f"block {bid} already registered")
        self._by_key[key] = bid
        self._key_of[bid] = key
        return bid

    def match(self, keys: Iterable[Key]) -> list[int]:
        """Longest registered chain prefix (no refcount change)."""
        out: list[int] = []
        for k in keys:
            bid = self._by_key.get(k)
            if bid is None:
                break
            out.append(bid)
        return out

    # -- copy-on-write -------------------------------------------------------
    def writable(self, bid: int) -> bool:
        """True iff writing ``bid`` in place cannot corrupt a sharer or a
        registered prefix."""
        return self._ref.get(bid) == 1 and bid not in self._key_of

    def cow(self, bid: int) -> int:
        """Copy-on-write: allocate a private replacement for shared/registered
        ``bid``, dropping the caller's reference on it.  The caller copies the
        device rows and swaps its table entry."""
        if self.writable(bid):
            raise ValueError(f"block {bid} is exclusively owned; write in place")
        new = self.alloc()
        self.release(bid)
        self.cow_copies += 1
        return new

    # -- invariants ----------------------------------------------------------
    def check(self) -> None:
        """Conservation + disjointness (asserted by the property tests and
        cheap enough for the engine to call at drain)."""
        free, live, cached = set(self._free), set(self._ref), set(self._lru)
        assert len(self._free) == len(free), "duplicate ids on the free list"
        assert not (free & live) and not (free & cached) and not (live & cached), \
            "a block id appears in two states"
        assert 0 not in free | live | cached, "sentinel block 0 escaped"
        assert len(free) + len(live) + len(cached) == self.num_blocks - 1, \
            "block conservation violated"
        assert all(r > 0 for r in self._ref.values()), "non-positive refcount"
        assert self._reserved >= 0, "negative reservation balance"
        assert set(self._key_of) <= live | cached, "registry points at a freed block"
        assert {self._by_key[k] for k in self._by_key} == set(self._key_of)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"BlockPool(blocks={self.num_blocks}, block={self.block_size}, "
                f"live={self.live}, cached={self.cached}, free={self.free}, "
                f"reserved={self._reserved})")
