"""Fault-tolerant checkpointing (no orbax dependency).

Properties required by the system:
  * ATOMIC: a checkpoint is staged in ``<dir>/.tmp.<step>`` and published with
    a single ``os.rename`` -> a crash mid-save can never corrupt the latest
    restorable state.
  * COMPLETE: callers persist the *entire* adaptive-training state — params,
    optimizer state, diversity accumulators, controller (batch-size bucket,
    LR), data cursor, RNG key — so a restart resumes the exact trajectory.
  * LOGICAL: tensors are stored as host numpy, independent of mesh/topology;
    restore re-shards onto whatever mesh is live (elastic scaling).
  * ASYNC: device->host transfer happens synchronously (cheap), file I/O can
    run on a background thread (``async_save=True``).
  * BOUNDED: ``keep`` most recent steps are retained.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

from repro.utils import pytree as ptu
from repro.utils.logging import get_logger

log = get_logger("ckpt")

_META = "meta.json"
_SHARD_BYTES = 512 * 1024 * 1024  # flush arrays into <=512MB npz volumes


def _to_host(tree: Any) -> list[tuple[str, np.ndarray]]:
    flat = ptu.tree_flatten_with_paths(tree)
    return [(path, np.asarray(jax.device_get(leaf))) for path, leaf in flat]


def save_pytree(directory: str, tree: Any) -> None:
    os.makedirs(directory, exist_ok=True)
    flat = _to_host(tree)
    volumes: list[dict[str, np.ndarray]] = [{}]
    vol_bytes = 0
    index: dict[str, dict] = {}
    for i, (path, arr) in enumerate(flat):
        if vol_bytes > _SHARD_BYTES:
            volumes.append({})
            vol_bytes = 0
        key = f"a{i}"
        volumes[-1][key] = arr
        vol_bytes += arr.nbytes
        index[path] = {
            "volume": len(volumes) - 1,
            "key": key,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    for v, arrs in enumerate(volumes):
        np.savez(os.path.join(directory, f"vol{v}.npz"), **arrs)
    with open(os.path.join(directory, _META), "w") as f:
        json.dump({"index": index, "num_volumes": len(volumes)}, f)


def load_pytree(directory: str, target: Any | None = None) -> Any:
    """Load; if ``target`` is given, leaves are mapped into its structure (by
    flatten order of matching paths) — otherwise a nested dict is returned."""
    with open(os.path.join(directory, _META)) as f:
        meta = json.load(f)
    vols = [
        np.load(os.path.join(directory, f"vol{v}.npz"))
        for v in range(meta["num_volumes"])
    ]
    by_path = {
        path: vols[info["volume"]][info["key"]] for path, info in meta["index"].items()
    }
    if target is None:
        nested: dict = {}
        for path, arr in by_path.items():
            parts = path.split("/")
            node = nested
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = arr
        return nested
    flat_t = ptu.tree_flatten_with_paths(target)
    missing = [p for p, _ in flat_t if p not in by_path]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]} (+{max(len(missing)-5,0)} more)")
    leaves = []
    for path, ref in flat_t:
        arr = by_path[path]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch at {path}: ckpt {arr.shape} vs target {ref.shape}")
        leaves.append(arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr)
    treedef = jax.tree.structure(target)
    return jax.tree.unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3, async_save: bool = False):
        self.root = root
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(root, exist_ok=True)

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:010d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.startswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save / restore --------------------------------------------------------
    def save(self, step: int, state: dict[str, Any], extra: dict | None = None) -> None:
        """``state``: dict of pytrees (tensors). ``extra``: JSON-serialisable
        host state (controller, cursor, python scalars)."""
        self.wait()  # one in-flight save at a time
        host = {k: _to_host_tree(v) for k, v in state.items()}

        def _write():
            tmp = os.path.join(self.root, f".tmp.step_{step:010d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for k, v in host.items():
                save_pytree(os.path.join(tmp, k), v)
            with open(os.path.join(tmp, "extra.json"), "w") as f:
                json.dump({"step": step, **(extra or {})}, f)
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()
            log.info("saved checkpoint step=%d -> %s", step, final)

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def restore(
        self, targets: dict[str, Any], step: int | None = None, plan: Any | None = None
    ) -> tuple[dict[str, Any], dict]:
        """Restore host trees; with ``plan`` (a ``dist.ShardingPlan``), each
        tree is placed onto the plan's inferred shardings via
        ``elastic.reshard.place`` — checkpoints are topology-free, so a state
        saved on one mesh rung restores onto any other."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self._step_dir(step)
        out = {k: load_pytree(os.path.join(d, k), tgt) for k, tgt in targets.items()}
        if plan is not None:
            from repro.elastic.reshard import place  # deferred: ckpt is a leaf layer

            out = {k: place(v, plan) for k, v in out.items()}
        with open(os.path.join(d, "extra.json")) as f:
            extra = json.load(f)
        return out, extra

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)


def _to_host_tree(tree: Any) -> Any:
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
