"""Pallas TPU kernel: row-wise int8 quantisation (gradient compression).

Used by the cross-pod gradient compressor (dist/compression.py): gradients
crossing the slow DCN 'pod' axis are quantised to int8 with one f32 absmax
scale per row-block, with error feedback keeping SGD unbiased over time —
QSGD-style (Alistarh et al., cited by the paper as a diversity-increasing
technique that composes with DiveBatch).

Single fused pass: absmax-reduce + scale + round + cast, one read of the
input — the op is memory-bound, so fusing matters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (block_r, C)
    absmax = jnp.max(jnp.abs(x), axis=1)  # (block_r,)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def quantize_int8(
    x: jax.Array, *, block_rows: int = 256, interpret: bool = True
) -> tuple[jax.Array, jax.Array]:
    """x: (R, C) -> (q int8 (R, C), scales f32 (R,))."""
    assert x.ndim == 2, x.shape
    r, c = x.shape
    pad = (-r) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    rp = x.shape[0]
    grid = (rp // block_rows,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((rp, c), jnp.int8),
            jax.ShapeDtypeStruct((rp,), jnp.float32),
        ),
        interpret=interpret,
    )(x)
    return q[:r], s[:r]


def dequantize_int8(q: jax.Array, scales: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scales[:, None]).astype(dtype)
