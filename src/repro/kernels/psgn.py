"""Pallas TPU kernels: per-sample gradient squared norms for dense layers.

For a dense layer y = x @ W applied over a sequence, the per-sample gradient
is G_b = X_b^T Delta_b (Din, Dout), where X_b is the saved input activation
and Delta_b the upstream output gradient (obtained for free via the probe
trick, DESIGN.md §3). DiveBatch needs ||G_b||_F^2 — never G_b itself.

Two factorisations, both avoiding the (B, Din, Dout) materialisation that
makes BackPACK double peak memory (paper Table 2):

  DIRECT  ||X^T D||_F^2 tile-by-tile: grid (B, Din/bi, Dout/bj, S/bs); an
          (bi, bj) f32 accumulator lives in VMEM scratch across the S-chunk
          axis (innermost, sequential on TPU) and is squared+reduced into the
          output on the last chunk. FLOPs ~ 2*S*Din*Dout per sample.
          MXU-aligned: bi = bj = 128, bs = 512.

  GRAM    sum_{t,t'} (x_t . x_t')(d_t . d_t') tile-by-tile over (S/bi, S/bj)
          pairs; both Gram blocks contract the full feature dim in one MXU
          pass. FLOPs ~ 2*S^2*(Din+Dout) per sample. Wins when
          S << Din*Dout/(Din+Dout).

ops.choose_method picks by FLOP count; ref.py is the pure-jnp oracle.
Kernels are VALIDATED in interpret mode on CPU (tests/test_kernels.py) and
target TPU for execution.

FUSED (psgn_fused) stacks L same-shape dense layers into one launch — grid
(B, L, Din/bi, Dout/bj, S/bs) — and accumulates the CROSS-LAYER sum straight
into a (B, 1) output block that stays resident across all inner grid steps,
so the exact diversity tier issues one kernel for a whole probe tree instead
of L separate launches with an XLA reduction after each.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; interpret mode accepts pltpu.VMEM on CPU too
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


# ---------------------------------------------------------------------------
# DIRECT: grid (B, nI, nJ, nS), VMEM accumulator over the S axis
# ---------------------------------------------------------------------------


def _direct_kernel(x_ref, d_ref, o_ref, acc_ref, *, n_s: int):
    s = pl.program_id(3)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]  # (bs, bi)
    d = d_ref[0]  # (bs, bj)
    acc_ref[...] += jax.lax.dot_general(
        x, d, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(s == n_s - 1)
    def _finish():
        blk = acc_ref[...]
        o_ref[0, 0, 0] = jnp.sum(blk * blk)


def psgn_direct(
    x: jax.Array,  # (B, S, Din)
    delta: jax.Array,  # (B, S, Dout)
    *,
    block_i: int = 128,
    block_j: int = 128,
    block_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """(B,) per-sample ||X_b^T Delta_b||_F^2 (f32)."""
    assert x.ndim == 3 and delta.ndim == 3 and x.shape[:2] == delta.shape[:2]
    b = x.shape[0]
    x = _pad_to(_pad_to(x, 2, block_i), 1, block_s)
    delta = _pad_to(_pad_to(delta, 2, block_j), 1, block_s)
    s, din = x.shape[1], x.shape[2]
    dout = delta.shape[2]
    n_i, n_j, n_s = din // block_i, dout // block_j, s // block_s

    grid = (b, n_i, n_j, n_s)
    out_shape = jax.ShapeDtypeStruct((b, n_i, n_j), jnp.float32)
    scratch = (
        [pltpu.VMEM((block_i, block_j), jnp.float32)]
        if _VMEM is not None
        else [pl.BlockSpec(memory_space=None)]  # pragma: no cover
    )
    partials = pl.pallas_call(
        functools.partial(_direct_kernel, n_s=n_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_i), lambda bb, i, j, ss: (bb, ss, i)),
            pl.BlockSpec((1, block_s, block_j), lambda bb, i, j, ss: (bb, ss, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1), lambda bb, i, j, ss: (bb, i, j)),
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, delta)
    return partials.sum(axis=(1, 2))


# ---------------------------------------------------------------------------
# GRAM: grid (B, nSi, nSj); both Gram blocks contract full feature dims
# ---------------------------------------------------------------------------


def _gram_kernel(xi_ref, xj_ref, di_ref, dj_ref, o_ref):
    xi = xi_ref[0]  # (bi, Din)
    xj = xj_ref[0]  # (bj, Din)
    di = di_ref[0]  # (bi, Dout)
    dj = dj_ref[0]  # (bj, Dout)
    gx = jax.lax.dot_general(
        xi, xj, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    gd = jax.lax.dot_general(
        di, dj, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0, 0, 0] = jnp.sum(gx * gd)


def psgn_gram(
    x: jax.Array,
    delta: jax.Array,
    *,
    block_si: int = 256,
    block_sj: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """(B,) per-sample sum_{t,t'} (x_t.x_t')(d_t.d_t') == ||X^T D||_F^2."""
    assert x.ndim == 3 and delta.ndim == 3 and x.shape[:2] == delta.shape[:2]
    b = x.shape[0]
    x = _pad_to(x, 1, max(block_si, block_sj))
    delta = _pad_to(delta, 1, max(block_si, block_sj))
    s = x.shape[1]
    n_i, n_j = s // block_si, s // block_sj

    grid = (b, n_i, n_j)
    out_shape = jax.ShapeDtypeStruct((b, n_i, n_j), jnp.float32)
    partials = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_si, x.shape[2]), lambda bb, i, j: (bb, i, 0)),
            pl.BlockSpec((1, block_sj, x.shape[2]), lambda bb, i, j: (bb, j, 0)),
            pl.BlockSpec((1, block_si, delta.shape[2]), lambda bb, i, j: (bb, i, 0)),
            pl.BlockSpec((1, block_sj, delta.shape[2]), lambda bb, i, j: (bb, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1), lambda bb, i, j: (bb, i, j)),
        out_shape=out_shape,
        interpret=interpret,
    )(x, x, delta, delta)
    return partials.sum(axis=(1, 2))


# ---------------------------------------------------------------------------
# FUSED: L stacked same-shape layers, one launch, cross-layer sum in-place
# ---------------------------------------------------------------------------


def _fused_kernel(x_ref, d_ref, o_ref, acc_ref, *, n_s: int):
    ll = pl.program_id(1)
    i = pl.program_id(2)
    j = pl.program_id(3)
    s = pl.program_id(4)

    @pl.when(jnp.logical_and(jnp.logical_and(ll == 0, i == 0),
                             jnp.logical_and(j == 0, s == 0)))
    def _init_out():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(s == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0, 0]  # (bs, bi)
    d = d_ref[0, 0]  # (bs, bj)
    acc_ref[...] += jax.lax.dot_general(
        x, d, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(s == n_s - 1)
    def _finish():
        blk = acc_ref[...]
        o_ref[0, 0] += jnp.sum(blk * blk)


def psgn_fused(
    x: jax.Array,  # (L, B, S, Din) — L same-shape dense layers, stacked
    delta: jax.Array,  # (L, B, S, Dout)
    *,
    block_i: int = 128,
    block_j: int = 128,
    block_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """(B,) sum over the L layers of per-sample ||X^T D||_F^2, one launch.

    The (B, 1) output block is revisited by every (l, i, j, s) step for a
    fixed b (the batch axis is outermost), so the cross-layer + cross-tile
    reduction happens in VMEM instead of as L separate XLA reductions.
    """
    assert x.ndim == 4 and delta.ndim == 4 and x.shape[:3] == delta.shape[:3]
    n_l, b = x.shape[0], x.shape[1]
    x = _pad_to(_pad_to(x, 3, block_i), 2, block_s)
    delta = _pad_to(_pad_to(delta, 3, block_j), 2, block_s)
    s, din = x.shape[2], x.shape[3]
    dout = delta.shape[3]
    n_i, n_j, n_s = din // block_i, dout // block_j, s // block_s

    grid = (b, n_l, n_i, n_j, n_s)
    scratch = (
        [pltpu.VMEM((block_i, block_j), jnp.float32)]
        if _VMEM is not None
        else [pl.BlockSpec(memory_space=None)]  # pragma: no cover
    )
    out = pl.pallas_call(
        functools.partial(_fused_kernel, n_s=n_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_s, block_i),
                         lambda bb, ll, i, j, ss: (ll, bb, ss, i)),
            pl.BlockSpec((1, 1, block_s, block_j),
                         lambda bb, ll, i, j, ss: (ll, bb, ss, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda bb, ll, i, j, ss: (bb, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, delta)
    return out[:, 0]
