"""Jit-ready wrappers around the Pallas kernels with cost-model dispatch.

``default_interpret`` is the one platform switch for the whole kernel lane:
compiled Pallas on TPU, interpret mode (pure-jax emulation, still inside
jit) everywhere else — tests exercise the real kernel bodies on CPU.
Callers can force either mode by passing ``interpret=`` explicitly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import psgn as psgn_kernels
from repro.kernels import quant as quant_kernels


def default_interpret() -> bool:
    """True (interpret mode) everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


def choose_method(s: int, d_in: int, d_out: int) -> str:
    """FLOP-count dispatch between the two per-sample-grad-norm kernels:
    direct ~ 2*S*Din*Dout, gram ~ 2*S^2*(Din+Dout)."""
    direct = 2.0 * s * d_in * d_out
    gram = 2.0 * s * s * (d_in + d_out)
    return "direct" if direct <= gram else "gram"


@functools.partial(jax.jit, static_argnames=("method", "interpret"))
def persample_sq_norm(
    x: jax.Array,  # (B, S, Din) or (B, Din)
    delta: jax.Array,  # (B, S, Dout) or (B, Dout)
    method: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """(B,) per-sample squared Frobenius norm of the dense-layer gradient.

    2D inputs (no sequence axis) factorise exactly:
    ||x_b delta_b^T||_F^2 = ||x_b||^2 * ||delta_b||^2 — no kernel needed.
    ``interpret=None`` resolves via ``default_interpret()``.
    """
    if interpret is None:
        interpret = default_interpret()
    if x.ndim == 2:
        xn = jnp.sum(jnp.square(x.astype(jnp.float32)), axis=-1)
        dn = jnp.sum(jnp.square(delta.astype(jnp.float32)), axis=-1)
        return xn * dn
    b, s, d_in = x.shape
    d_out = delta.shape[-1]
    if method == "auto":
        method = choose_method(s, d_in, d_out)
    if method == "direct":
        return psgn_kernels.psgn_direct(
            x, delta,
            block_s=min(512, _round_pow2(s)),
            block_i=min(128, _round_pow2(d_in)),
            block_j=min(128, _round_pow2(d_out)),
            interpret=interpret,
        )
    if method == "gram":
        blk = min(256, _round_pow2(s))
        return psgn_kernels.psgn_gram(x, delta, block_si=blk, block_sj=blk,
                                      interpret=interpret)
    raise ValueError(f"unknown method {method!r}")


def _round_pow2(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def _bias_sq_norm(d: jax.Array) -> jax.Array:
    """(B,) per-sample sq-norm of the BIAS gradient for the same layer: the
    per-sample bias grad is the sequence-sum of the output delta."""
    df = d.astype(jnp.float32)
    if df.ndim == 3:
        df = jnp.sum(df, axis=1)
    return jnp.sum(jnp.square(df), axis=-1)


def persample_sq_norm_tree(
    acts: dict,
    deltas: dict,
    scale: float = 1.0,
    *,
    bias: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Sum per-sample sq-norms over a dict of dense layers (gram-tier total).

    ``deltas`` are probe gradients of a MEAN loss — multiply by batch size
    (``scale``) to undo the 1/B factor.

    Layers whose shapes match and whose cost model picks the direct kernel
    are STACKED and dispatched to ``psgn.psgn_fused`` — one launch with the
    cross-layer sum fused in VMEM — instead of one launch per layer.
    ``bias=True`` adds each layer's bias-gradient sq-norm ``||sum_s d_s||^2``
    (exact for bias-complete dense models; probes see the same delta the
    bias does).
    """
    if interpret is None:
        interpret = default_interpret()
    groups: dict[tuple, list[str]] = {}
    for name, x in acts.items():
        d = deltas[name]
        if x.ndim == 3 and choose_method(x.shape[1], x.shape[2], d.shape[2]) == "direct":
            key = (x.shape, d.shape, x.dtype, d.dtype)
        else:
            key = ("solo", name)
        groups.setdefault(key, []).append(name)

    total = None
    for key, names in groups.items():
        if key[0] != "solo" and len(names) >= 2:
            xs = jnp.stack([acts[n] for n in names])
            ds = jnp.stack([deltas[n] * scale for n in names])
            s, d_in = xs.shape[2], xs.shape[3]
            d_out = ds.shape[3]
            v = psgn_kernels.psgn_fused(
                xs, ds,
                block_s=min(512, _round_pow2(s)),
                block_i=min(128, _round_pow2(d_in)),
                block_j=min(128, _round_pow2(d_out)),
                interpret=interpret,
            )
        else:
            v = None
            for n in names:
                vi = persample_sq_norm(acts[n], deltas[n] * scale,
                                       interpret=interpret)
                v = vi if v is None else v + vi
        if bias:
            for n in names:
                v = v + _bias_sq_norm(deltas[n] * scale)
        total = v if total is None else total + v
    return total


quantize_int8 = quant_kernels.quantize_int8
dequantize_int8 = quant_kernels.dequantize_int8
