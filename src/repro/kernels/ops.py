"""Jit-ready wrappers around the Pallas kernels with cost-model dispatch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import psgn as psgn_kernels
from repro.kernels import quant as quant_kernels


def choose_method(s: int, d_in: int, d_out: int) -> str:
    """FLOP-count dispatch between the two per-sample-grad-norm kernels:
    direct ~ 2*S*Din*Dout, gram ~ 2*S^2*(Din+Dout)."""
    direct = 2.0 * s * d_in * d_out
    gram = 2.0 * s * s * (d_in + d_out)
    return "direct" if direct <= gram else "gram"


@functools.partial(jax.jit, static_argnames=("method", "interpret"))
def persample_sq_norm(
    x: jax.Array,  # (B, S, Din) or (B, Din)
    delta: jax.Array,  # (B, S, Dout) or (B, Dout)
    method: str = "auto",
    interpret: bool = True,
) -> jax.Array:
    """(B,) per-sample squared Frobenius norm of the dense-layer gradient.

    2D inputs (no sequence axis) factorise exactly:
    ||x_b delta_b^T||_F^2 = ||x_b||^2 * ||delta_b||^2 — no kernel needed.
    """
    if x.ndim == 2:
        xn = jnp.sum(jnp.square(x.astype(jnp.float32)), axis=-1)
        dn = jnp.sum(jnp.square(delta.astype(jnp.float32)), axis=-1)
        return xn * dn
    b, s, d_in = x.shape
    d_out = delta.shape[-1]
    if method == "auto":
        method = choose_method(s, d_in, d_out)
    if method == "direct":
        return psgn_kernels.psgn_direct(
            x, delta,
            block_s=min(512, _round_pow2(s)),
            block_i=min(128, _round_pow2(d_in)),
            block_j=min(128, _round_pow2(d_out)),
            interpret=interpret,
        )
    if method == "gram":
        blk = min(256, _round_pow2(s))
        return psgn_kernels.psgn_gram(x, delta, block_si=blk, block_sj=blk,
                                      interpret=interpret)
    raise ValueError(f"unknown method {method!r}")


def _round_pow2(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def persample_sq_norm_tree(acts: dict, deltas: dict, scale: float = 1.0) -> jax.Array:
    """Sum per-sample sq-norms over a dict of dense layers (gram-tier total).

    ``deltas`` are probe gradients of a MEAN loss — multiply by batch size
    (``scale``) to undo the 1/B factor."""
    total = None
    for name, x in acts.items():
        d = deltas[name] * scale
        v = persample_sq_norm(x, d)
        total = v if total is None else total + v
    return total


quantize_int8 = quant_kernels.quantize_int8
dequantize_int8 = quant_kernels.dequantize_int8
