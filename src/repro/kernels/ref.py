"""Pure-jnp oracles for every kernel in this package (tests assert_allclose
kernel outputs against these over shape/dtype sweeps)."""

from __future__ import annotations

import jax.numpy as jnp


def psgn_ref(x: jnp.ndarray, delta: jnp.ndarray) -> jnp.ndarray:
    """(B,) per-sample ||X_b^T Delta_b||_F^2, materialising the per-sample
    gradient (the thing the kernels avoid)."""
    g = jnp.einsum(
        "bsi,bsj->bij", x.astype(jnp.float32), delta.astype(jnp.float32)
    )
    return jnp.sum(g * g, axis=(1, 2))


def psgn_gram_ref(x: jnp.ndarray, delta: jnp.ndarray) -> jnp.ndarray:
    """Same value via the Gram identity (independent derivation)."""
    gx = jnp.einsum("bsi,bti->bst", x.astype(jnp.float32), x.astype(jnp.float32))
    gd = jnp.einsum("bsi,bti->bst", delta.astype(jnp.float32), delta.astype(jnp.float32))
    return jnp.sum(gx * gd, axis=(1, 2))


def quantize_int8_ref(x: jnp.ndarray):
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_ref(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale[:, None]).astype(dtype)


_NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Sk, KV, hd)
    v: jnp.ndarray,
    q_pos: jnp.ndarray,  # (Sq,) absolute query positions
    k_pos: jnp.ndarray,  # (Sk,) absolute key positions
    k_valid: jnp.ndarray,  # (Sk,) bool
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
) -> jnp.ndarray:
    """Dense masked attention at explicit positions — the oracle every
    Pallas attention kernel is validated against (f32 throughout, softcap
    applied before the mask, softmax over the full key axis at once)."""
    hd = q.shape[-1]
    n_rep = q.shape[2] // k.shape[2]
    kr = jnp.repeat(k.astype(jnp.float32), n_rep, axis=2)
    vr = jnp.repeat(v.astype(jnp.float32), n_rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kr)
    logits = logits * (hd ** -0.5)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    rel = q_pos[:, None] - k_pos[None, :]
    ok = jnp.broadcast_to(k_valid[None, :], rel.shape)
    if causal:
        ok = ok & (rel >= 0)
    if window is not None:
        ok = ok & (rel < window)
    logits = jnp.where(ok[None, None], logits, _NEG_INF)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.maximum(jnp.sum(probs, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, vr)


def flash_ref(q, k, v, *, causal=True, window=None, softcap=None):
    """Self-attention special case (positions are just aranges)."""
    return attention_ref(
        q, k, v,
        jnp.arange(q.shape[1]), jnp.arange(k.shape[1]),
        jnp.ones((k.shape[1],), bool),
        causal=causal, window=window, softcap=softcap,
    )


def paged_decode_ref(
    q: jnp.ndarray,        # (B, 1, H, hd)
    pool_k: jnp.ndarray,   # (num_blocks, block, KV, hd)
    pool_v: jnp.ndarray,
    tables: jnp.ndarray,   # (B, n_max) int32
    lengths: jnp.ndarray,  # (B,) valid context per row
    *,
    softcap: float | None = None,
) -> jnp.ndarray:
    """Materialised-gather decode: take() every table entry (sentinel and
    tail included), then mask by per-row length — exactly the XLA lane the
    fused kernel replaces."""
    b, n_max = tables.shape
    blk = pool_k.shape[1]
    gk = jnp.take(pool_k, tables.reshape(-1), axis=0)
    gk = gk.reshape(b, n_max * blk, pool_k.shape[2], pool_k.shape[3])
    gv = jnp.take(pool_v, tables.reshape(-1), axis=0)
    gv = gv.reshape(b, n_max * blk, pool_v.shape[2], pool_v.shape[3])
    outs = []
    for row in range(b):
        pos = jnp.arange(n_max * blk)
        outs.append(attention_ref(
            q[row:row + 1], gk[row:row + 1], gv[row:row + 1],
            jnp.full((1,), lengths[row] - 1), pos, pos < lengths[row],
            causal=False, window=None, softcap=softcap,
        ))
    return jnp.concatenate(outs, axis=0)
