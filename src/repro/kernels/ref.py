"""Pure-jnp oracles for every kernel in this package (tests assert_allclose
kernel outputs against these over shape/dtype sweeps)."""

from __future__ import annotations

import jax.numpy as jnp


def psgn_ref(x: jnp.ndarray, delta: jnp.ndarray) -> jnp.ndarray:
    """(B,) per-sample ||X_b^T Delta_b||_F^2, materialising the per-sample
    gradient (the thing the kernels avoid)."""
    g = jnp.einsum(
        "bsi,bsj->bij", x.astype(jnp.float32), delta.astype(jnp.float32)
    )
    return jnp.sum(g * g, axis=(1, 2))


def psgn_gram_ref(x: jnp.ndarray, delta: jnp.ndarray) -> jnp.ndarray:
    """Same value via the Gram identity (independent derivation)."""
    gx = jnp.einsum("bsi,bti->bst", x.astype(jnp.float32), x.astype(jnp.float32))
    gd = jnp.einsum("bsi,bti->bst", delta.astype(jnp.float32), delta.astype(jnp.float32))
    return jnp.sum(gx * gd, axis=(1, 2))


def quantize_int8_ref(x: jnp.ndarray):
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_ref(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale[:, None]).astype(dtype)
