"""Pallas TPU attention kernels: flash prefill + fused paged decode.

Three kernels behind ``cfg.attn_impl = "pallas"`` (models/transformer.py
dispatches; ``kernels/ops.default_interpret`` decides interpret mode):

  flash_attention   causal + sliding-window + softcap streaming-softmax
                    attention, matching ``models/attention.py::
                    flash_attention`` semantics (same scale, softcap-before-
                    mask order, NEG_INF bias, f32 accumulators).  custom_vjp
                    with the standard flash recompute backward — pass 1
                    re-streams KV blocks for dq, pass 2 re-streams Q blocks
                    for dk/dv — so the TRAIN path can adopt the kernel, not
                    just prefill.

  chunk_attention   the serving generalisation: queries at explicit absolute
                    positions over keys at explicit absolute positions with
                    a per-key validity mask (gathered pool blocks or a
                    windowed ring carry garbage rows that causality alone
                    cannot exclude).  Forward-only — decode never
                    differentiates.

  paged_decode_attention
                    single-token decode against the paged KV pool with the
                    BLOCK-TABLE GATHER FUSED INTO THE KV LOOP: the grid is
                    (B, n_max) and the k/v BlockSpec index_map reads the
                    scalar-prefetched table, so each step streams one POOL
                    block per row instead of materialising the
                    (B, n_max*block, KV, hd) gathered context the XLA path
                    builds with ``jnp.take``.  Per-row lengths mask the
                    sentinel/pool tail and ``pl.when`` skips dead table
                    entries entirely.

All kernels pad ragged shapes to block multiples internally (padding is
masked, outputs sliced); GQA is handled by mapping head h onto KV head
h // n_rep in the index_map.  ``kernels/ref.py`` holds the pure-jnp oracles
the property tests (tests/test_kernels.py) validate against in interpret
mode; TPU is the execution target.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ops import default_interpret

try:  # TPU memory spaces; interpret mode accepts pltpu specs on CPU too
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

NEG_INF = -1e30  # matches models/attention.py


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value)


def _resolve(interpret):
    return default_interpret() if interpret is None else bool(interpret)


def _block_bias(q_pos, k_pos, q_valid, k_valid, causal, window):
    """(bq, bk) additive f32 bias — models/attention.py::_mask_bias plus
    explicit row/key validity (the padding / gathered-garbage mask)."""
    rel = q_pos[:, None] - k_pos[None, :]
    ok = q_valid[:, None] & k_valid[None, :]
    if causal:
        ok = ok & (rel >= 0)
    if window is not None:
        ok = ok & (rel < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32), ok


# ---------------------------------------------------------------------------
# flash forward: grid (B, H, nQ, nK), streaming (m, l, acc) over the KV axis
# ---------------------------------------------------------------------------


def _flash_fwd_kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, kval_ref,
                      o_ref, lse_ref, m_ref, l_ref, acc_ref,
                      *, n_k, scale, causal, window, softcap):
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :]  # (bq, hd)
    k = k_ref[0, :, 0, :]  # (bk, hd)
    v = v_ref[0, :, 0, :]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    q_pos = qp_ref[0]  # (bq,) int32 absolute positions
    k_pos = kp_ref[0]  # (bk,)
    # positions are ABSOLUTE (chunk mode: unrelated to array indices), so
    # padding validity comes only from the sentinels: padded q rows carry a
    # negative position, padded/garbage keys carry k_valid = 0
    bias, _ = _block_bias(
        q_pos, k_pos, q_pos >= 0, kval_ref[0] > 0, causal, window,
    )
    logits = logits + bias
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finish():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l_safe).astype(o_ref.dtype)
        lse_ref[0, 0, :] = (m_ref[...] + jnp.log(l_safe))[:, 0]


def _flash_forward(q, k, v, q_pos, k_pos, k_valid, causal, window, softcap,
                   q_block, kv_block, interpret):
    """Shared streaming forward. Positions/validity are host arrays sized to
    the PADDED seq lens; returns (out (B,Sq,H,hd), lse (B,H,Sqp))."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    n_rep = h // kv
    qp = _pad_to(q, 1, q_block)
    kp = _pad_to(k, 1, kv_block)
    vp = _pad_to(v, 1, kv_block)
    sqp, skp = qp.shape[1], kp.shape[1]
    n_q, n_k = sqp // q_block, skp // kv_block
    q_pos = _pad_to(q_pos.astype(jnp.int32), 0, q_block, value=-(2 ** 30))
    k_pos = _pad_to(k_pos.astype(jnp.int32), 0, kv_block)
    k_valid = _pad_to(k_valid.astype(jnp.int32), 0, kv_block)

    grid = (b, h, n_q, n_k)
    kernel = functools.partial(
        _flash_fwd_kernel, n_k=n_k, scale=hd ** -0.5, causal=causal,
        window=window, softcap=softcap,
    )
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, 1, hd), lambda b_, h_, qi, kj: (b_, qi, h_, 0)),
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda b_, h_, qi, kj: (b_, kj, h_ // n_rep, 0)),
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda b_, h_, qi, kj: (b_, kj, h_ // n_rep, 0)),
            pl.BlockSpec((1, q_block), lambda b_, h_, qi, kj: (0, qi)),
            pl.BlockSpec((1, kv_block), lambda b_, h_, qi, kj: (0, kj)),
            pl.BlockSpec((1, kv_block), lambda b_, h_, qi, kj: (0, kj)),
        ],
        out_specs=[
            pl.BlockSpec((1, q_block, 1, hd), lambda b_, h_, qi, kj: (b_, qi, h_, 0)),
            pl.BlockSpec((1, 1, q_block), lambda b_, h_, qi, kj: (b_, h_, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, sqp, h, hd), q.dtype),
            jax.ShapeDtypeStruct((b, h, sqp), jnp.float32),
        ],
        scratch_shapes=[
            _VMEM((q_block, 1), jnp.float32),
            _VMEM((q_block, 1), jnp.float32),
            _VMEM((q_block, hd), jnp.float32),
        ],
        interpret=_resolve(interpret),
    )(qp, kp, vp, q_pos[None], k_pos[None], k_valid[None])
    return out[:, :sq], lse


# ---------------------------------------------------------------------------
# flash backward: standard recompute — pass 1 (dq), pass 2 (dk, dv)
# ---------------------------------------------------------------------------


def _recompute_dlogits(q, k, v, do, lse, delta, q_pos, k_pos, q_valid, k_valid,
                       scale, causal, window, softcap):
    """(p, dlogits) for one (bq, bk) tile.  Padded rows/keys force p = 0
    explicitly: a padded q row's lse is garbage and exp() would overflow."""
    raw = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    if softcap is not None:
        capped = jnp.tanh(raw / softcap)
        logits = capped * softcap
    else:
        logits = raw
    _, ok = _block_bias(q_pos, k_pos, q_valid, k_valid, causal, window)
    p = jnp.where(ok, jnp.exp(logits - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    dlogits = p * (dp - delta[:, None])
    if softcap is not None:
        dlogits = dlogits * (1.0 - capped * capped)
    return p, dlogits


def _flash_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                     qp_ref, kp_ref, kval_ref, dq_ref, acc_ref,
                     *, n_k, scale, causal, window, softcap):
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos, k_pos = qp_ref[0], kp_ref[0]
    k = k_ref[0, :, 0, :]
    _, dlogits = _recompute_dlogits(
        q_ref[0, :, 0, :], k, v_ref[0, :, 0, :], do_ref[0, :, 0, :],
        lse_ref[0, 0], dl_ref[0, 0], q_pos, k_pos,
        q_pos >= 0, kval_ref[0] > 0,
        scale, causal, window, softcap,
    )
    acc_ref[...] += jax.lax.dot_general(
        dlogits.astype(k.dtype), k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale

    @pl.when(kj == n_k - 1)
    def _finish():
        dq_ref[0, :, 0, :] = acc_ref[...]


def _flash_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                      qp_ref, kp_ref, kval_ref, dk_ref, dv_ref,
                      dk_acc, dv_acc,
                      *, n_q, scale, causal, window, softcap):
    qi = pl.program_id(3)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_pos, k_pos = qp_ref[0], kp_ref[0]
    q = q_ref[0, :, 0, :]
    do = do_ref[0, :, 0, :]
    p, dlogits = _recompute_dlogits(
        q, k_ref[0, :, 0, :], v_ref[0, :, 0, :], do,
        lse_ref[0, 0], dl_ref[0, 0], q_pos, k_pos,
        q_pos >= 0, kval_ref[0] > 0,
        scale, causal, window, softcap,
    )
    dv_acc[...] += jax.lax.dot_general(
        p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    dk_acc[...] += jax.lax.dot_general(
        dlogits.astype(q.dtype), q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale

    @pl.when(qi == n_q - 1)
    def _finish():
        dk_ref[0, :, 0, :] = dk_acc[...]
        dv_ref[0, :, 0, :] = dv_acc[...]


def _flash_backward(q, k, v, out, lse, dout, causal, window, softcap,
                    q_block, kv_block, interpret):
    b, sq, h, hd = q.shape
    sk, kv_heads = k.shape[1], k.shape[2]
    n_rep = h // kv_heads
    scale = hd ** -0.5
    interpret = _resolve(interpret)

    qp = _pad_to(q, 1, q_block)
    dop = _pad_to(dout, 1, q_block)
    kp = _pad_to(k, 1, kv_block)
    vp = _pad_to(v, 1, kv_block)
    sqp, skp = qp.shape[1], kp.shape[1]
    n_q, n_k = sqp // q_block, skp // kv_block
    # delta_i = rowsum(dout_i * out_i): (b, h, sqp)
    delta = _pad_to(
        jnp.einsum("bqhd,bqhd->bhq", dout.astype(jnp.float32),
                   out.astype(jnp.float32)), 2, q_block,
    )
    q_pos = _pad_to(jnp.arange(sq, dtype=jnp.int32), 0, q_block,
                    value=-(2 ** 30))[None]
    k_pos = _pad_to(jnp.arange(sk, dtype=jnp.int32), 0, kv_block)[None]
    k_valid = (k_pos < sk).astype(jnp.int32)

    qspec = pl.BlockSpec((1, q_block, 1, hd), lambda b_, h_, i, j: (b_, i, h_, 0))
    kspec = pl.BlockSpec((1, kv_block, 1, hd),
                         lambda b_, h_, i, j: (b_, i, h_ // n_rep, 0))
    args = (qp, kp, vp, dop, lse, delta, q_pos, k_pos, k_valid)

    # pass 1: dq — grid (B, H, nQ, nK), KV innermost
    dq = pl.pallas_call(
        functools.partial(_flash_dq_kernel, n_k=n_k, scale=scale, causal=causal,
                          window=window, softcap=softcap),
        grid=(b, h, n_q, n_k),
        in_specs=[
            qspec,
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda b_, h_, qi, kj: (b_, kj, h_ // n_rep, 0)),
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda b_, h_, qi, kj: (b_, kj, h_ // n_rep, 0)),
            qspec,
            pl.BlockSpec((1, 1, q_block), lambda b_, h_, qi, kj: (b_, h_, qi)),
            pl.BlockSpec((1, 1, q_block), lambda b_, h_, qi, kj: (b_, h_, qi)),
            pl.BlockSpec((1, q_block), lambda b_, h_, qi, kj: (0, qi)),
            pl.BlockSpec((1, kv_block), lambda b_, h_, qi, kj: (0, kj)),
            pl.BlockSpec((1, kv_block), lambda b_, h_, qi, kj: (0, kj)),
        ],
        out_specs=pl.BlockSpec((1, q_block, 1, hd),
                               lambda b_, h_, qi, kj: (b_, qi, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sqp, h, hd), jnp.float32),
        scratch_shapes=[_VMEM((q_block, hd), jnp.float32)],
        interpret=interpret,
    )(*args)

    # pass 2: dk/dv — grid (B, H, nK, nQ), Q innermost; the repeated-head
    # gradients are folded back onto KV heads outside the kernel (GQA)
    dk_rep, dv_rep = pl.pallas_call(
        functools.partial(_flash_dkv_kernel, n_q=n_q, scale=scale, causal=causal,
                          window=window, softcap=softcap),
        grid=(b, h, n_k, n_q),
        in_specs=[
            pl.BlockSpec((1, q_block, 1, hd), lambda b_, h_, kj, qi: (b_, qi, h_, 0)),
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda b_, h_, kj, qi: (b_, kj, h_ // n_rep, 0)),
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda b_, h_, kj, qi: (b_, kj, h_ // n_rep, 0)),
            pl.BlockSpec((1, q_block, 1, hd), lambda b_, h_, kj, qi: (b_, qi, h_, 0)),
            pl.BlockSpec((1, 1, q_block), lambda b_, h_, kj, qi: (b_, h_, qi)),
            pl.BlockSpec((1, 1, q_block), lambda b_, h_, kj, qi: (b_, h_, qi)),
            pl.BlockSpec((1, q_block), lambda b_, h_, kj, qi: (0, qi)),
            pl.BlockSpec((1, kv_block), lambda b_, h_, kj, qi: (0, kj)),
            pl.BlockSpec((1, kv_block), lambda b_, h_, kj, qi: (0, kj)),
        ],
        out_specs=[
            pl.BlockSpec((1, kv_block, 1, hd), lambda b_, h_, kj, qi: (b_, kj, h_, 0)),
            pl.BlockSpec((1, kv_block, 1, hd), lambda b_, h_, kj, qi: (b_, kj, h_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, skp, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, skp, h, hd), jnp.float32),
        ],
        scratch_shapes=[_VMEM((kv_block, hd), jnp.float32),
                        _VMEM((kv_block, hd), jnp.float32)],
        interpret=interpret,
    )(*args)

    dq = dq[:, :sq].astype(q.dtype)
    dk = dk_rep[:, :sk].reshape(b, sk, kv_heads, n_rep, hd).sum(3).astype(k.dtype)
    dv = dv_rep[:, :sk].reshape(b, sk, kv_heads, n_rep, hd).sum(3).astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, KV, hd)
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Pallas flash attention with the standard recompute backward.

    Semantics match ``models/attention.py::flash_attention`` (the XLA lane):
    scale ``hd**-0.5``, softcap applied BEFORE the mask bias, causal /
    sliding-window masking on absolute positions, f32 running (m, l, acc).
    Ragged Sq/Sk are padded to block multiples internally.
    """
    sq, sk = q.shape[1], k.shape[1]
    out, _ = _flash_forward(
        q, k, v, jnp.arange(sq, dtype=jnp.int32),
        jnp.arange(sk, dtype=jnp.int32), jnp.ones((sk,), jnp.int32),
        causal, window, softcap, q_block, kv_block, interpret,
    )
    return out


def _flash_vjp_fwd(q, k, v, causal, window, softcap, q_block, kv_block, interpret):
    sq, sk = q.shape[1], k.shape[1]
    out, lse = _flash_forward(
        q, k, v, jnp.arange(sq, dtype=jnp.int32),
        jnp.arange(sk, dtype=jnp.int32), jnp.ones((sk,), jnp.int32),
        causal, window, softcap, q_block, kv_block, interpret,
    )
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, window, softcap, q_block, kv_block, interpret, res, dout):
    q, k, v, out, lse = res
    return _flash_backward(q, k, v, out, lse, dout, causal, window, softcap,
                           q_block, kv_block, interpret)


flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def chunk_attention(
    q: jax.Array,  # (B, C, H, hd)
    k: jax.Array,  # (B, Sk, KV, hd)
    v: jax.Array,
    q_pos: jax.Array,  # (C,) absolute positions of the queries
    k_pos: jax.Array,  # (Sk,) absolute positions of the keys
    k_valid: jax.Array,  # (Sk,) bool — False for padding/garbage key rows
    *,
    window: int | None = None,
    softcap: float | None = None,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Pallas ``models/attention.py::chunk_attention``: causal attention at
    explicit positions with a key-validity mask (the paged chunked-prefill
    and windowed-ring layouts).  Forward-only."""
    out, _ = _flash_forward(
        q, k, v, q_pos, k_pos, k_valid.astype(jnp.int32),
        True, window, softcap, q_block, kv_block, interpret,
    )
    return out


# ---------------------------------------------------------------------------
# paged decode: the block-table gather fused into the streaming-softmax loop
# ---------------------------------------------------------------------------


def _paged_decode_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, blk, n_max, softcap):
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]

    # dead table entries (the sentinel/pool tail past this row's length)
    # contribute nothing — skip their FLOPs entirely
    @pl.when(i * blk < length)
    def _block():
        q = q_ref[0, 0]  # (H, hd)
        k = k_ref[0]     # (blk, KV, hd) — the table-gathered pool block
        v = v_ref[0]
        h, hd = q.shape
        kv = k.shape[1]
        n_rep = h // kv
        # GQA without materialising repeated heads: batch the dot over KV
        kt = jnp.transpose(k, (1, 0, 2))  # (KV, blk, hd)
        logits = jax.lax.dot_general(
            q.reshape(kv, n_rep, hd), kt, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        ).reshape(h, blk) * (hd ** -0.5)
        if softcap is not None:
            logits = jnp.tanh(logits / softcap) * softcap
        pos = i * blk + jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1)
        logits = jnp.where(pos < length, logits, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        vt = jnp.transpose(v, (1, 0, 2))  # (KV, blk, hd)
        pv = jax.lax.dot_general(
            p.reshape(kv, n_rep, blk), vt, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * corr + pv.reshape(h, hd)
        m_ref[...] = m_new

    @pl.when(i == n_max - 1)
    def _finish():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def paged_decode_attention(
    q: jax.Array,        # (B, 1, H, hd) — this step's query per slot
    pool_k: jax.Array,   # (num_blocks, block, KV, hd) — the SHARED pool
    pool_v: jax.Array,
    tables: jax.Array,   # (B, n_max) int32 — slot b's logical block i lives
                         # at pool block tables[b, i]; dead entries sentinel 0
    lengths: jax.Array,  # (B,) int32 — valid context length per slot
    *,
    softcap: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused paged decode attention: (B, 1, H, hd).

    The XLA lane materialises ``jnp.take(pool, tables)`` — the full
    (B, n_max*block, KV, hd) gathered context — before attending.  Here the
    gather IS the k/v BlockSpec index_map over the scalar-prefetched table:
    grid step (b, i) streams pool block ``tables[b, i]`` straight from the
    pool, so only live blocks are read per row and the gathered context
    never exists in memory.  Numerics match
    ``models/attention.py::decode_attention`` on the gathered view (same
    scale/softcap/length-mask order, f32 accumulation).
    """
    b, one, h, hd = q.shape
    assert one == 1, q.shape
    nb, blk, kv, _ = pool_k.shape
    n_max = tables.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_max),
        in_specs=[
            pl.BlockSpec((1, 1, h, hd), lambda b_, i, t_, l_: (b_, 0, 0, 0)),
            pl.BlockSpec((1, blk, kv, hd), lambda b_, i, t_, l_: (t_[b_, i], 0, 0, 0)),
            pl.BlockSpec((1, blk, kv, hd), lambda b_, i, t_, l_: (t_[b_, i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, h, hd), lambda b_, i, t_, l_: (b_, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_decode_kernel, blk=blk, n_max=n_max,
                          softcap=softcap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1, h, hd), q.dtype),
        interpret=_resolve(interpret),
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), q, pool_k, pool_v)
