"""Pallas TPU kernels for the paper's compute hot-spots (validated in
interpret mode on CPU; see tests/test_kernels.py):

  psgn.py   per-sample gradient squared norms (direct + gram factorisations)
  quant.py  fused rowwise int8 quantisation for cross-pod grad compression
  ops.py    jit wrappers + cost-model dispatch
  ref.py    pure-jnp oracles
"""

from repro.kernels import ops, psgn, quant, ref

__all__ = ["ops", "psgn", "quant", "ref"]
