"""The Pallas kernel lane: TPU kernels for the repro's compute hot-spots.

Modules
  attention.py  flash prefill (custom_vjp recompute backward), serving
                chunk attention at explicit positions, and the FUSED paged
                decode — the block-table gather runs inside the kernel's
                streaming-softmax KV loop via a scalar-prefetched table, so
                decode reads only the live pool blocks per row instead of
                materialising the gathered context.
  psgn.py       per-sample gradient squared norms for dense layers: direct
                and gram factorisations, plus the fused multi-layer variant
                that stacks same-shape layers into one launch with the
                cross-layer sum accumulated in VMEM.
  quant.py      fused rowwise int8 quantisation for cross-pod grad
                compression.
  ops.py        jit wrappers + dispatch: ``choose_method`` picks the psgn
                factorisation by FLOP count, ``persample_sq_norm_tree``
                groups same-shape layers into the fused kernel, and
                ``default_interpret`` selects compiled Pallas on TPU /
                interpret mode everywhere else (the one platform switch).
  ref.py        pure-jnp oracles — the property tests in
                tests/test_kernels.py validate every kernel against these
                in interpret mode; TPU is the execution target.

Dispatch into the lane
  Attention: ``cfg.attn_impl = "pallas"`` routes models/transformer.py's
  train forward, prefill, chunked paged prefill, and paged decode through
  attention.py (``models/attention.resolve_impl``); "auto" keeps the XLA
  dense/flash fork at ``configs/base.FLASH_THRESHOLD``.
  Diversity: the exact tier's ``psn_impl = "kernel"`` (train/step.py)
  replaces vmap-of-grad per-sample norms with one probe-gradient pass
  through ``ops.persample_sq_norm_tree``; the gram tier always lands here.
"""

from repro.kernels import attention, ops, psgn, quant, ref

__all__ = ["attention", "ops", "psgn", "quant", "ref"]
