from repro.optim.optimizer import (
    AdamWState,
    Optimizer,
    SGDState,
    adamw,
    apply_updates,
    make_optimizer,
    sgd,
)
from repro.optim.schedules import make_schedule

__all__ = [
    "Optimizer",
    "SGDState",
    "AdamWState",
    "sgd",
    "adamw",
    "apply_updates",
    "make_optimizer",
    "make_schedule",
]
