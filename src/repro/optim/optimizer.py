"""Minimal optimizer library (optax is not a dependency).

Interface mirrors the (init, update) functional style:

    opt = sgd(momentum=0.9, weight_decay=5e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, lr)
    params = apply_updates(params, updates)

``lr`` is a *traced argument* of update (not baked into the transform): the
adaptive-batch controller changes LR at epoch boundaries and must not trigger
recompilation.

State dtype is configurable (``state_dtype``) so large models can keep
momenta in bf16 — at 405B params, fp32 momentum alone is 1.6 TB.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple[PyTree, PyTree]]  # (grads, state, params, lr)
    name: str = "optimizer"


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


# ---------------------------------------------------------------------------
# SGD (+ momentum, + weight decay) — the paper's optimizer
# ---------------------------------------------------------------------------


class SGDState(NamedTuple):
    momentum: PyTree  # zeros-like params (empty tuple when momentum == 0)


def sgd(momentum: float = 0.0, weight_decay: float = 0.0, nesterov: bool = False,
        state_dtype=None) -> Optimizer:
    use_momentum = momentum != 0.0

    def init(params: PyTree) -> SGDState:
        if not use_momentum:
            return SGDState(momentum=())
        return SGDState(
            momentum=jax.tree.map(
                lambda p: jnp.zeros(p.shape, state_dtype or p.dtype), params
            )
        )

    def update(grads: PyTree, state: SGDState, params: PyTree, lr) -> tuple[PyTree, SGDState]:
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        if not use_momentum:
            return jax.tree.map(lambda g: -lr * g, grads), state
        new_m = jax.tree.map(
            lambda m, g: momentum * m + g.astype(m.dtype), state.momentum, grads
        )
        if nesterov:
            updates = jax.tree.map(lambda m, g: -lr * (momentum * m + g.astype(m.dtype)), new_m, grads)
        else:
            updates = jax.tree.map(lambda m: -lr * m, new_m)
        return updates, SGDState(momentum=new_m)

    return Optimizer(init=init, update=update, name=f"sgd(m={momentum},wd={weight_decay})")


# ---------------------------------------------------------------------------
# AdamW — for the "DiveBatch composes with Adam-family" extension
# ---------------------------------------------------------------------------


class AdamWState(NamedTuple):
    mu: PyTree
    nu: PyTree
    count: jax.Array


def adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0, state_dtype=None) -> Optimizer:
    def init(params: PyTree) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, state_dtype or p.dtype)
        return AdamWState(
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
            count=jnp.zeros((), jnp.int32),
        )

    def update(grads: PyTree, state: AdamWState, params: PyTree, lr) -> tuple[PyTree, AdamWState]:
        count = state.count + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype), state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)), state.nu, grads
        )
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(m, v, p):
            m_hat = m.astype(jnp.float32) / c1
            v_hat = v.astype(jnp.float32) / c2
            step = m_hat / (jnp.sqrt(v_hat) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(mu=mu, nu=nu, count=count)

    return Optimizer(init=init, update=update, name=f"adamw(wd={weight_decay})")


def make_optimizer(name: str, **kw) -> Optimizer:
    name = name.lower()
    if name == "sgd":
        return sgd(kw.get("momentum", 0.0), kw.get("weight_decay", 0.0),
                   kw.get("nesterov", False), kw.get("state_dtype"))
    if name == "adamw":
        return adamw(kw.get("b1", 0.9), kw.get("b2", 0.999), kw.get("eps", 1e-8),
                     kw.get("weight_decay", 0.0), kw.get("state_dtype"))
    raise ValueError(f"unknown optimizer {name!r}")
