"""Step-wise learning-rate schedules (used inside the compiled train step).

The *epoch*-level coupling between batch size and LR lives in
``core/controller.py``; schedules here are step-granular and jit-traceable
(they take a step counter array and return a scalar multiplier).
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step -> multiplier


def constant() -> Schedule:
    return lambda step: jnp.ones((), jnp.float32)


def warmup_cosine(warmup_steps: int, total_steps: int, final_frac: float = 0.1) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        progress = jnp.clip(
            (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
        return warm * cos

    return fn


def step_decay_steps(decay_factor: float, every_steps: int) -> Schedule:
    def fn(step):
        k = jnp.floor(step.astype(jnp.float32) / every_steps)
        return jnp.power(decay_factor, k)

    return fn


def make_schedule(name: str, **kw) -> Schedule:
    name = name.lower()
    if name == "constant":
        return constant()
    if name == "warmup_cosine":
        return warmup_cosine(kw["warmup_steps"], kw["total_steps"], kw.get("final_frac", 0.1))
    if name == "step_decay":
        return step_decay_steps(kw.get("decay_factor", 0.75), kw["every_steps"])
    raise ValueError(f"unknown schedule {name!r}")
