"""The mesh ladder: nested data-parallel sub-meshes of one physical mesh.

A ``MeshLadder`` is an ordered family of ``ShardingPlan``s ("rungs") built
from one flat device list: rung *i* spans the first ``dp_i * model`` devices
arranged as ``(dp_i, *model_axes)``, with the dp widths a power-of-two chain
``1 -> D`` and the model axes held fixed on every rung.  Nesting matters:
rung *i*'s devices are a prefix of rung *j*'s for i < j, so growing the
footprint never migrates existing shards off their device, only fans them
out — the reshard is a pure widen/narrow.

``plan_for_batch(m)`` implements the elastic policy: the widest rung whose
dp width both divides ``m`` and keeps the per-device microbatch at least
``granule`` samples.  Because the batch policies snap ``m`` onto the
``granule * 2^i`` lattice (``core/batch_policy.bucket``) and the dp widths
are powers of two, the selected rung is a pure function of the bucket — an
adaptive run visits at most ``num_buckets`` (bucket, rung) pairs even though
the worst-case compile bound is ``num_buckets * num_rungs``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterator, Sequence

import numpy as np

from repro.dist.plan import ShardingPlan


@dataclasses.dataclass(frozen=True)
class Rung:
    """One step of the ladder: a dp width and its sharding plan.

    ``pods`` is the number of pods the rung spans (1 for every base
    ``MeshLadder`` rung; ``repro.pod.PodLadder`` builds cross-pod rungs
    whose mesh carries a ``pods > 1`` leading axis).
    """

    index: int
    dp: int
    plan: ShardingPlan
    pods: int = 1

    @property
    def devices(self) -> int:
        return int(self.plan.mesh.devices.size)


class MeshLadder:
    """Ordered ``ShardingPlan`` family over nested sub-meshes.

    Args:
      devices: flat device list (default: ``jax.devices()``). Rung *i* uses a
        prefix of it.
      granule: minimum per-device microbatch a rung may leave (the batch
        policies' lattice granule — pass the same value to both).
      model_axes: ``((name, size), ...)`` non-dp mesh axes held fixed on
        every rung (e.g. ``(("model", 2),)`` for 2-way tensor parallelism).
      dp_axis: name of the data axis on every rung's mesh.
      dp_widths: explicit dp widths (sorted, deduped); default is the full
        power-of-two chain 1..max plus the (possibly non-pow2) maximum.
    """

    def __init__(
        self,
        devices: Sequence[Any] | None = None,
        *,
        granule: int = 1,
        model_axes: Sequence[tuple[str, int]] = (),
        dp_axis: str = "data",
        dp_widths: Sequence[int] | None = None,
    ):
        if devices is None:
            import jax

            devices = jax.devices()
        devices = list(devices)
        self.granule = int(granule)
        if self.granule < 1:
            raise ValueError(f"granule must be >= 1, got {granule}")
        model_axes = tuple((str(n), int(s)) for n, s in model_axes)
        model = math.prod(s for _, s in model_axes) if model_axes else 1
        max_dp = len(devices) // model
        if max_dp < 1:
            raise ValueError(
                f"{len(devices)} devices cannot carry the fixed model axes "
                f"{model_axes} (need >= {model})"
            )
        if dp_widths is None:
            dp_widths = [1 << i for i in range(max_dp.bit_length()) if 1 << i <= max_dp]
            if dp_widths[-1] != max_dp:
                dp_widths.append(max_dp)  # non-pow2 device counts still top out
        widths = sorted(set(int(w) for w in dp_widths))
        if widths[0] < 1 or widths[-1] > max_dp:
            raise ValueError(f"dp widths {widths} out of range [1, {max_dp}]")

        from jax.sharding import Mesh  # deferred: no device state at import

        names = (dp_axis,) + tuple(n for n, _ in model_axes)
        sizes = tuple(s for _, s in model_axes)
        self.rungs: list[Rung] = []
        for i, w in enumerate(widths):
            devs = np.asarray(devices[: w * model], dtype=object).reshape((w,) + sizes)
            mesh = Mesh(devs, names)
            plan = ShardingPlan(
                mesh=mesh,
                dp=(dp_axis,),
                fsdp=(dp_axis,),
                tp=tuple(n for n, _ in model_axes) or None,
                ep=(dp_axis,),
            )
            self.rungs.append(Rung(index=i, dp=w, plan=plan))

    # -- selection -----------------------------------------------------------
    def rung_for_batch(self, m: int) -> Rung:
        """Widest rung whose dp width divides ``m`` and keeps the per-device
        microbatch >= the granule; the narrowest rung when even that is too
        wide (sub-granule batches run dp=1 rather than erroring)."""
        m = int(m)
        best = self.rungs[0]
        for rung in self.rungs:
            if m % rung.dp == 0 and m // rung.dp >= self.granule:
                best = rung
        return best

    def plan_for_batch(self, m: int) -> ShardingPlan:
        return self.rung_for_batch(m).plan

    # -- state hooks ---------------------------------------------------------
    def adapt_state(self, state, src: Rung | None, dst: Rung):
        """Hook for ladder-specific state at a rung transition, called by the
        Trainer AFTER ``elastic.reshard`` moved ``state`` onto ``dst``
        (``src=None`` for the initial placement / a checkpoint restore).  The
        base ladder carries no rung-dependent state: identity.  ``PodLadder``
        overrides this to install / drop / re-zero the compression
        error-feedback residuals (``TrainState.err_state``)."""
        return state

    # -- introspection -------------------------------------------------------
    @property
    def num_rungs(self) -> int:
        return len(self.rungs)

    @property
    def widths(self) -> list[int]:
        return [r.dp for r in self.rungs]

    @property
    def full(self) -> Rung:
        """The widest rung (the fixed-mesh baseline plan)."""
        return self.rungs[-1]

    def __len__(self) -> int:
        return len(self.rungs)

    def __iter__(self) -> Iterator[Rung]:
        return iter(self.rungs)

    def __repr__(self) -> str:
        return f"MeshLadder(dp={self.widths}, granule={self.granule})"
