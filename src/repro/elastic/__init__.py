"""Elastic data-parallel scaling: co-adapt the device footprint with the
DiveBatch batch size.

DiveBatch runs *start small and grow*: an early-epoch batch of 64 on a fixed
16-wide data-parallel mesh leaves per-device microbatches of 4 (or is
outright indivisible), while the late large-batch epochs are exactly where
wide data parallelism pays.  This package makes the batch-size signal drive
the *sharding plan*, not just ``num_micro``:

``ladder``   ``MeshLadder`` — an ordered family of ``ShardingPlan``s over
             nested sub-meshes of ONE physical mesh (dp widths 1 -> D,
             model axes held fixed); ``plan_for_batch(m)`` picks the widest
             rung whose dp width keeps the per-device microbatch >= the
             granule.
``reshard``  ``reshard(state, src_plan, dst_plan)`` — exact, donation-
             friendly ``device_put`` of the full ``TrainState`` onto the
             destination plan's inferred shardings; a strict no-op when the
             rung is unchanged.  ``place(tree, plan)`` is the restore-time
             variant the checkpoint layer reuses, so a checkpoint saved on
             one rung resumes on any other.

The ``StepEngine`` compile cache is keyed by ``(bucket, rung)`` (bounded by
``num_buckets x num_rungs``; far fewer in practice since the rung is a
function of the bucket), and the ``Trainer`` performs the rung transition at
the same epoch boundary that resizes the batch.
"""

from repro.elastic.ladder import MeshLadder, Rung
from repro.elastic.reshard import place, reshard, same_plan

__all__ = ["MeshLadder", "Rung", "place", "reshard", "same_plan"]
