"""Exact state movement between ladder rungs.

``reshard`` moves a full ``TrainState`` (params, optimizer state, diversity
accumulators, compression error-feedback — any pytree) from one rung's plan
onto another's: every leaf is ``device_put`` onto the destination plan's
*inferred* sharding (``dist.sharding.infer_pspecs``, the same suffix rules
the dry-run uses), so optimizer/diversity mirrors land exactly where their
parameters do.  The transfer is value-exact — no arithmetic, no
re-materialisation — and donation-friendly: with ``donate=True`` the source
buffers may be reused for the destination (the steady state during a rung
transition is one state plus the in-flight copies, not two full states).

When source and destination describe the same rung (``same_plan``), the
function is a STRICT no-op: it returns the identical state object and
issues no transfers at all — the Trainer calls it unconditionally at every
epoch boundary.

``place`` is the restore-time variant: it puts a freshly-loaded host
(numpy) tree onto a plan's inferred shardings — or plain single-device jax
arrays when no plan is active.  The checkpoint layer reuses it
(``CheckpointManager.restore(plan=...)``): checkpoints store logical host
tensors, so a state saved on one rung resumes on any other.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.plan import ShardingPlan
from repro.dist.sharding import infer_pspecs, shardings_of

PyTree = Any


def same_mesh(a, b) -> bool:
    """True when two meshes span the same devices under the same axis
    layout (AbstractMeshes compare by shape/names only — they have none)."""
    if a is b:
        return True
    if a is None or b is None:
        return False
    if tuple(a.axis_names) != tuple(b.axis_names):
        return False
    da, db = getattr(a, "devices", None), getattr(b, "devices", None)
    if da is None or db is None:
        return da is None and db is None and dict(a.shape) == dict(b.shape)
    return da.shape == db.shape and all(
        x.id == y.id for x, y in zip(da.flat, db.flat)
    )


def same_plan(a: ShardingPlan | None, b: ShardingPlan | None) -> bool:
    """True when two plans are the same rung: same mesh, same axis roles."""
    if a is b:
        return True
    if a is None or b is None:
        return False
    return (
        a.dp == b.dp
        and a.fsdp == b.fsdp
        and a.tp == b.tp
        and a.ep == b.ep
        and same_mesh(a.mesh, b.mesh)
    )


def state_shardings(tree: PyTree, plan: ShardingPlan) -> PyTree:
    """NamedShardings for ``tree`` on ``plan`` via the suffix inference rules
    (optimizer/diversity accumulators shard exactly like their parameters;
    unmatched leaves — small-model params, scalars — replicate)."""
    return shardings_of(infer_pspecs(tree, plan), plan)


def _device_put(tree: PyTree, shardings, donate: bool) -> PyTree:
    try:
        return jax.device_put(tree, shardings, donate=donate)
    except TypeError:  # jax without the donate kwarg: plain transfer
        return jax.device_put(tree, shardings)


def reshard(
    state: PyTree,
    src_plan: ShardingPlan | None,
    dst_plan: ShardingPlan | None,
    *,
    donate: bool = True,
) -> PyTree:
    """Move ``state`` from ``src_plan``'s rung onto ``dst_plan``'s.

    Strict no-op (the very same object, zero transfers) when the rung is
    unchanged.  ``dst_plan=None`` gathers onto the default device (the
    single-device regime).  Donation invalidates the source buffers on
    backends that support aliasing — callers must hold only the returned
    state, exactly as with engine steps.
    """
    if same_plan(src_plan, dst_plan):
        return state
    if dst_plan is None:
        return _device_put(state, jax.devices()[0], donate)
    return _device_put(state, state_shardings(state, dst_plan), donate)


def place(tree: PyTree, plan: ShardingPlan | None) -> PyTree:
    """Put a host (or device) tree onto ``plan``'s inferred shardings; plain
    single-device jax arrays when ``plan`` is None.  The checkpoint-restore
    path: logical host tensors -> whatever rung is live."""
    if plan is None:
        return jax.tree.map(jnp.asarray, tree)
    return jax.device_put(tree, state_shardings(tree, plan))
