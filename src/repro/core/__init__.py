"""DiveBatch core: gradient-diversity estimation + adaptive batch policies.

This package is the paper's primary contribution:
  diversity.py     Delta_hat estimators (exact / gram / moment) + Oracle
  batch_policy.py  DiveBatch, AdaBatch, Fixed policies + bucketing
  controller.py    epoch controller coupling batch size <-> learning rate
"""

from repro.core import diversity
from repro.core.batch_policy import (
    AdaBatch,
    BatchPolicy,
    DiveBatch,
    FixedBatch,
    bucket,
    make_policy,
)
from repro.core.controller import AdaptiveBatchController, lr_rescale, step_decay
from repro.core.diversity import DiversityState

__all__ = [
    "diversity",
    "DiversityState",
    "BatchPolicy",
    "FixedBatch",
    "AdaBatch",
    "DiveBatch",
    "bucket",
    "make_policy",
    "AdaptiveBatchController",
    "lr_rescale",
    "step_decay",
]
