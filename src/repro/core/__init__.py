"""DiveBatch core: gradient-diversity estimation + adaptive batch policies.

This package is the paper's primary contribution:
  diversity.py     Delta_hat estimators (exact / gram / moment) + Oracle
  batch_policy.py  DiveBatch, OracleDiveBatch, AdaBatch, Fixed + bucketing
  controller.py    DEPRECATED epoch-only controller — a thin shim over a
                   repro.adapt.AdaptationProgram (the single adaptation
                   path; see repro.adapt for the composable API)
"""

from repro.core import diversity
from repro.core.batch_policy import (
    AdaBatch,
    BatchPolicy,
    DiveBatch,
    FixedBatch,
    OracleDiveBatch,
    bucket,
    make_policy,
)
from repro.core.controller import AdaptiveBatchController, lr_rescale, step_decay
from repro.core.diversity import DiversityState

__all__ = [
    "diversity",
    "DiversityState",
    "BatchPolicy",
    "FixedBatch",
    "AdaBatch",
    "DiveBatch",
    "OracleDiveBatch",
    "bucket",
    "make_policy",
    "AdaptiveBatchController",
    "lr_rescale",
    "step_decay",
]
