"""Epoch-boundary batch-size policies.

Policies are HOST-side objects: the global batch size feeds the data pipeline
and selects a compiled train-step bucket, both host decisions. They consume
scalar statistics (already device->host transferred) and return plain ints.

Implemented policies (all from the paper):
  FixedBatch     constant m (the SGD baselines).
  AdaBatch       Devarakonda et al. 2018: multiply m by ``resize_factor``
                 every ``resize_freq`` epochs.
  DiveBatch      m_{k+1} = min(m_max, delta * n * Delta_hat)   [Algorithm 1]
  OracleDiveBatch  same rule, but the caller feeds the *exact* full-dataset
                 diversity (recomputed each epoch) instead of the estimate.

Bucketing: at multi-pod scale an arbitrary integer batch size would (a) not
be divisible by the data-parallel shard count and (b) trigger a fresh XLA
compilation per value. ``bucket()`` snaps m to ``granule * 2^i`` so at most
log2(m_max/granule) compiled variants exist.
"""

from __future__ import annotations

import dataclasses
import math


def bucket(m: int, granule: int, mode: str = "pow2", m_min: int = 1, m_max: int | None = None) -> int:
    """Snap a requested batch size onto the compile-friendly lattice.

    The result is ALWAYS a lattice point (``granule * 2^i`` in pow2 mode, a
    multiple of the granule in "none" mode): an off-lattice ``m_min`` is
    snapped UP to the next lattice point rather than returned verbatim, which
    would silently add a compile bucket beyond the ``num_buckets`` bound.
    When no lattice point exists in ``[m_min, m_max]`` the lattice wins over
    the floor (the largest point <= m_max is returned).
    """
    m = max(int(m), m_min, granule)
    if m_max is not None:
        m = min(m, m_max)
    if mode == "none":
        snapped = max(granule, (m // granule) * granule)
    elif mode == "pow2":
        # nearest power-of-two multiple of the granule (round in log space)
        ratio = max(m / granule, 1.0)
        snapped = granule * (2 ** int(round(math.log2(ratio))))
    else:
        raise ValueError(f"unknown bucket mode {mode!r}")
    floor = max(m_min, granule)
    if snapped < floor:
        if mode == "none":
            snapped = -(-floor // granule) * granule  # ceil to granule multiple
        else:
            while snapped < floor:
                snapped *= 2
    if m_max is not None:
        if mode == "none":
            snapped = min(snapped, (m_max // granule) * granule)
            snapped = max(snapped, granule)
        else:
            while snapped > m_max and snapped > granule:
                snapped //= 2
    return snapped


def num_buckets(m_max: int, granule: int) -> int:
    """Size of the pow2 bucket lattice {granule * 2^i : granule*2^i <= m_max}.

    This is the hard upper bound on distinct compiled step programs any
    adaptive run can trigger (StepEngine caches one program per bucket):
    ``log2(m_max / granule) + 1``.
    """
    return int(math.log2(max(m_max // max(granule, 1), 1))) + 1


@dataclasses.dataclass
class PolicyInfo:
    """Bookkeeping returned by every policy step (logged + checkpointed)."""

    batch_size: int
    raw_batch_size: float
    diversity: float | None = None
    reason: str = ""


class BatchPolicy:
    """Interface: ``on_epoch_end(epoch, diversity) -> PolicyInfo``."""

    def __init__(self, m0: int, m_max: int, granule: int = 1, bucket_mode: str = "pow2"):
        if m0 < 1 or m_max < m0:
            raise ValueError(f"need 1 <= m0 <= m_max, got m0={m0}, m_max={m_max}")
        self.m0 = int(m0)
        self.m_max = int(m_max)
        self.granule = int(granule)
        self.bucket_mode = bucket_mode
        self.m = bucket(m0, granule, bucket_mode, m_max=m_max)

    def on_epoch_end(self, epoch: int, diversity: float | None = None) -> PolicyInfo:
        raise NotImplementedError

    @property
    def max_buckets(self) -> int:
        """Hard upper bound on distinct batch sizes this policy can emit.

        pow2 mode: the lattice size ``log2(m_max/granule) + 1``; "none" mode:
        every multiple of the granule up to m_max. ``bucket()`` outputs are
        always lattice points (an off-lattice ``m_min`` snaps up to the next
        one), so the lattice size IS the bound.
        """
        if self.bucket_mode == "none":
            return max(self.m_max // max(self.granule, 1), 1)
        return num_buckets(self.m_max, self.granule)

    # -- checkpointable state ------------------------------------------------
    def state_dict(self) -> dict:
        return {"m": self.m}

    def load_state_dict(self, state: dict) -> None:
        self.m = int(state["m"])

    @property
    def needs_diversity(self) -> bool:
        return False


class FixedBatch(BatchPolicy):
    def on_epoch_end(self, epoch: int, diversity: float | None = None) -> PolicyInfo:
        return PolicyInfo(self.m, float(self.m), diversity, "fixed")


class AdaBatch(BatchPolicy):
    """Double (by ``resize_factor``) every ``resize_freq`` epochs."""

    def __init__(
        self,
        m0: int,
        m_max: int,
        resize_factor: int = 2,
        resize_freq: int = 20,
        granule: int = 1,
        bucket_mode: str = "pow2",
    ):
        super().__init__(m0, m_max, granule, bucket_mode)
        self.resize_factor = int(resize_factor)
        self.resize_freq = int(resize_freq)

    def on_epoch_end(self, epoch: int, diversity: float | None = None) -> PolicyInfo:
        raw = self.m
        if (epoch + 1) % self.resize_freq == 0:
            raw = self.m * self.resize_factor
        self.m = bucket(raw, self.granule, self.bucket_mode, m_max=self.m_max)
        return PolicyInfo(self.m, float(raw), diversity, "adabatch")


class DiveBatch(BatchPolicy):
    """The paper's Algorithm 1, line 11:  m <- min(m_max, delta * n * Delta).

    ``n`` is the dataset size. ``monotone=True`` optionally forbids shrinking
    (off by default — the paper allows decreases and its nonconvex runs do
    plateau below m_max).
    """

    def __init__(
        self,
        m0: int,
        m_max: int,
        delta: float,
        dataset_size: int,
        granule: int = 1,
        bucket_mode: str = "pow2",
        monotone: bool = False,
        m_min: int | None = None,
    ):
        super().__init__(m0, m_max, granule, bucket_mode)
        self.delta = float(delta)
        self.n = int(dataset_size)
        self.monotone = monotone
        self.m_min = int(m_min) if m_min is not None else 1

    @property
    def needs_diversity(self) -> bool:
        return True

    def on_epoch_end(self, epoch: int, diversity: float | None = None) -> PolicyInfo:
        if diversity is None:
            raise ValueError("DiveBatch.on_epoch_end requires a diversity estimate")
        raw = self.delta * self.n * float(diversity)
        if self.monotone:
            raw = max(raw, self.m)
        m_new = bucket(
            int(max(raw, self.m_min)),
            self.granule,
            self.bucket_mode,
            m_min=self.m_min,
            m_max=self.m_max,
        )
        self.m = m_new
        return PolicyInfo(self.m, raw, float(diversity), self.reason)

    #: provenance tag stamped into every PolicyInfo this rule emits
    reason = "divebatch"


class OracleDiveBatch(DiveBatch):
    """Same resize rule as DiveBatch, but the caller feeds the *exact*
    full-dataset diversity (recomputed at fixed params each epoch — the
    paper's Oracle baseline, ``Trainer(estimator='oracle')``) instead of the
    within-epoch estimate.  Distinguished by ``reason='oracle'`` in the
    PolicyInfo so logs/history tell the two apart."""

    reason = "oracle"


def make_policy(name: str, **kwargs) -> BatchPolicy:
    name = name.lower()
    if name in ("sgd", "fixed"):
        return FixedBatch(kwargs["m0"], kwargs.get("m_max", kwargs["m0"]),
                          kwargs.get("granule", 1), kwargs.get("bucket_mode", "pow2"))
    if name == "adabatch":
        return AdaBatch(
            kwargs["m0"], kwargs["m_max"],
            kwargs.get("resize_factor", 2), kwargs.get("resize_freq", 20),
            kwargs.get("granule", 1), kwargs.get("bucket_mode", "pow2"),
        )
    if name in ("divebatch", "oracle"):
        cls = OracleDiveBatch if name == "oracle" else DiveBatch
        return cls(
            kwargs["m0"], kwargs["m_max"], kwargs["delta"], kwargs["dataset_size"],
            kwargs.get("granule", 1), kwargs.get("bucket_mode", "pow2"),
            kwargs.get("monotone", False), kwargs.get("m_min"),
        )
    raise ValueError(f"unknown policy {name!r}")
