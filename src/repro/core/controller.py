"""Epoch-level adaptive-batch controller — DEPRECATED shim.

``AdaptiveBatchController`` predates the ``repro.adapt`` redesign: it ties a
batch policy to string-typed lr coupling at epoch-only granularity.  It now
survives as a thin compatibility shim over an
``repro.adapt.AdaptationProgram`` (a ``FromBatchPolicy``-wrapped policy plus
a typed ``LrCoupling``): constructing one and calling ``on_epoch_end``
drives exactly the same code path the new API does, and its checkpoints
round-trip both the pre-redesign (v1) and the current (v2) schema.

New code should build an ``AdaptationProgram`` directly — that is the only
way to get step-granular decisions (ticks/events), mid-epoch resize +
reshard, combinators (``Hysteresis``, ``Warmup``, ``Chain``, ...), and the
gradient-noise policy family.  See ``repro.adapt`` and
``examples/quickstart.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.batch_policy import BatchPolicy


def lr_rescale(rule: str, lr: float, m_old: int, m_new: int) -> float:
    if m_old == m_new or rule == "none":
        return lr
    ratio = m_new / m_old
    if rule == "linear":
        return lr * ratio
    if rule == "sqrt":
        return lr * ratio ** 0.5
    raise ValueError(f"unknown lr rescale rule {rule!r}")


@dataclasses.dataclass
class EpochDecision:
    epoch: int
    batch_size: int
    lr: float
    diversity: float | None
    raw_batch_size: float
    rescaled: bool


class AdaptiveBatchController:
    """DEPRECATED: thin shim over ``repro.adapt.AdaptationProgram``.

    The constructor and ``on_epoch_end``/``state_dict``/``load_state_dict``
    surfaces are unchanged from the pre-redesign controller; all state lives
    in ``self.program`` (the ``Trainer`` drives that program directly, so
    controller views stay consistent whichever way the run was built).
    """

    def __init__(
        self,
        policy: BatchPolicy,
        base_lr: float,
        lr_rule: str = "none",
        lr_schedule: Callable[[int, float], float] | None = None,
        estimator: str = "moment",
    ):
        """``lr_schedule(epoch, lr) -> lr`` is the *background* decay applied
        on top of batch-coupled rescaling (e.g. x0.75 every 20 epochs)."""
        # deferred import: repro.adapt reaches back into repro.core
        from repro.adapt import AdaptationProgram, FromBatchPolicy, LrCoupling
        from repro.adapt.policy import PolicyBase

        self.policy = policy
        wrapped = policy if isinstance(policy, PolicyBase) else FromBatchPolicy(policy)
        self.program = AdaptationProgram(
            wrapped,
            base_lr,
            LrCoupling(rule=lr_rule, decay=lr_schedule),
            estimator=estimator,
        )
        self.base_lr = float(base_lr)
        self.lr_rule = lr_rule
        self.lr_schedule = lr_schedule
        self.estimator = estimator

    # -- program views (the legacy attribute surface) -------------------------
    @property
    def lr(self) -> float:
        return self.program.lr

    @lr.setter
    def lr(self, value: float) -> None:
        self.program.lr = float(value)

    @property
    def epoch(self) -> int:
        return self.program.epoch

    @property
    def batch_size(self) -> int:
        return self.program.batch_size

    @property
    def needs_diversity(self) -> bool:
        return self.program.needs_diversity

    @property
    def compile_bound(self) -> int:
        """Max distinct step compilations this run can cost a StepEngine:
        the policy's bucket-lattice size (pow2 default:
        log2(m_max/granule) + 1; see BatchPolicy.max_buckets)."""
        return self.program.compile_bound

    @property
    def history(self) -> list[EpochDecision]:
        return [
            EpochDecision(
                epoch=a.epoch,
                batch_size=a.batch_size,
                lr=a.lr,
                diversity=a.diversity,
                raw_batch_size=(
                    a.raw_batch_size if a.raw_batch_size is not None
                    else float(a.batch_size)
                ),
                rescaled=a.rescaled,
            )
            for a in self.program.history
            if a.boundary == "epoch"
        ]

    def on_epoch_end(self, diversity: float | None = None) -> EpochDecision:
        from repro.adapt import Clock, Signals

        applied = self.program.observe(
            Signals(diversity=diversity, batch_size=self.batch_size),
            Clock(epoch=self.epoch, step=-1, boundary="epoch"),
        )
        return EpochDecision(
            epoch=applied.epoch,
            batch_size=applied.batch_size,
            lr=applied.lr,
            diversity=applied.diversity,
            raw_batch_size=(
                applied.raw_batch_size if applied.raw_batch_size is not None
                else float(applied.batch_size)
            ),
            rescaled=applied.rescaled,
        )

    # -- checkpointable state (v2 written, v1 accepted) -----------------------
    def state_dict(self) -> dict:
        return self.program.state_dict()

    def load_state_dict(self, state: dict) -> None:
        self.program.load_state_dict(state)


def step_decay(factor: float = 0.75, every: int = 20) -> Callable[[int, float], float]:
    """The paper's synthetic-experiment schedule: lr *= factor every N epochs."""

    def schedule(epoch: int, lr: float) -> float:
        if (epoch + 1) % every == 0:
            return lr * factor
        return lr

    return schedule
