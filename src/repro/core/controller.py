"""Epoch-level adaptive-batch controller.

Ties together: a batch policy (DiveBatch / AdaBatch / Fixed), a diversity
estimator tier, the learning-rate coupling (Goyal et al. linear scaling /
sqrt / none), and the background LR schedule (the paper uses step decay
x0.75 every 20 epochs on synthetic; the CIFAR recipes use their own decay).

The controller is a host-side object; everything it returns feeds either the
data pipeline (batch size) or the next compiled-step bucket (lr is a traced
scalar argument so LR changes never recompile).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.batch_policy import BatchPolicy, PolicyInfo


def lr_rescale(rule: str, lr: float, m_old: int, m_new: int) -> float:
    if m_old == m_new or rule == "none":
        return lr
    ratio = m_new / m_old
    if rule == "linear":
        return lr * ratio
    if rule == "sqrt":
        return lr * ratio ** 0.5
    raise ValueError(f"unknown lr rescale rule {rule!r}")


@dataclasses.dataclass
class EpochDecision:
    epoch: int
    batch_size: int
    lr: float
    diversity: float | None
    raw_batch_size: float
    rescaled: bool


class AdaptiveBatchController:
    def __init__(
        self,
        policy: BatchPolicy,
        base_lr: float,
        lr_rule: str = "none",
        lr_schedule: Callable[[int, float], float] | None = None,
        estimator: str = "moment",
    ):
        """``lr_schedule(epoch, lr) -> lr`` is the *background* decay applied
        on top of batch-coupled rescaling (e.g. x0.75 every 20 epochs)."""
        self.policy = policy
        self.lr = float(base_lr)
        self.base_lr = float(base_lr)
        self.lr_rule = lr_rule
        self.lr_schedule = lr_schedule
        self.estimator = estimator
        self.epoch = 0
        self.history: list[EpochDecision] = []

    @property
    def batch_size(self) -> int:
        return self.policy.m

    @property
    def needs_diversity(self) -> bool:
        return self.policy.needs_diversity

    @property
    def compile_bound(self) -> int:
        """Max distinct step compilations this run can cost a StepEngine:
        the policy's bucket-lattice size (pow2 default:
        log2(m_max/granule) + 1; see BatchPolicy.max_buckets)."""
        return self.policy.max_buckets

    def on_epoch_end(self, diversity: float | None = None) -> EpochDecision:
        m_old = self.policy.m
        info: PolicyInfo = self.policy.on_epoch_end(self.epoch, diversity)
        m_new = info.batch_size
        self.lr = lr_rescale(self.lr_rule, self.lr, m_old, m_new)
        if self.lr_schedule is not None:
            self.lr = self.lr_schedule(self.epoch, self.lr)
        decision = EpochDecision(
            epoch=self.epoch,
            batch_size=m_new,
            lr=self.lr,
            diversity=info.diversity,
            raw_batch_size=info.raw_batch_size,
            rescaled=m_old != m_new,
        )
        self.history.append(decision)
        self.epoch += 1
        return decision

    # -- checkpointable state -------------------------------------------------
    def state_dict(self) -> dict:
        return {
            "policy": self.policy.state_dict(),
            "lr": self.lr,
            "epoch": self.epoch,
            "history": [dataclasses.asdict(d) for d in self.history],
        }

    def load_state_dict(self, state: dict) -> None:
        self.policy.load_state_dict(state["policy"])
        self.lr = float(state["lr"])
        self.epoch = int(state["epoch"])
        self.history = [EpochDecision(**d) for d in state.get("history", [])]


def step_decay(factor: float = 0.75, every: int = 20) -> Callable[[int, float], float]:
    """The paper's synthetic-experiment schedule: lr *= factor every N epochs."""

    def schedule(epoch: int, lr: float) -> float:
        if (epoch + 1) % every == 0:
            return lr * factor
        return lr

    return schedule
