"""Gradient-diversity estimation (the paper's core quantity).

Gradient diversity (Yin et al. 2018, Definition 1):

    Delta_S(theta) = sum_i ||g_i||^2 / || sum_i g_i ||^2

DiveBatch (Algorithm 1) accumulates, across all microbatches of an epoch,
  * the running sum of gradients                      -> ``grad_sum`` (pytree)
  * the running sum of per-sample grad sq-norms       -> ``sq_norm_sum``
and at the epoch boundary sets  m_{k+1} = min(m_max, delta * n * Delta_hat).

Three estimator tiers provide the numerator at different scales:

  exact   vmap(grad) per sample. Reference semantics; O(B) memory blowup.
  gram    probe trick + per-sample-gradient-norm identity on dense layers
          (see kernels/psgn.py); exact for matmul parameters, which dominate.
  moment  recovers sum_i ||g_i||^2 unbiasedly from *microbatch-sum* gradient
          norms using E||sum_{i<=m} g_i||^2 = m E||g||^2 + m(m-1) ||mu||^2.
          Zero extra backward work -> the tier used at 7B..1T scale.

All accumulation state is a pytree (``DiversityState``) so it shards, jits,
checkpoints, and donates like any other training state.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import pytree as ptu

PyTree = Any

EPS = 1e-20


class DiversityState(NamedTuple):
    """Within-epoch accumulators. Reset at every epoch boundary.

    grad_sum      running sum over all per-sample gradients seen this epoch.
                  (Each microbatch contributes ``microbatch_size * mean_grad``.)
    sq_norm_sum   exact/gram: running sum_i ||g_i||^2.
                  moment:     running sum_j ||microbatch_sum_grad_j||^2.
    mb_count      number of microbatches accumulated (moment estimator).
    sample_count  number of samples accumulated.
    """

    grad_sum: PyTree
    sq_norm_sum: jax.Array
    mb_count: jax.Array
    sample_count: jax.Array


def init_state(params: PyTree, accum_dtype=jnp.float32) -> DiversityState:
    return DiversityState(
        grad_sum=ptu.tree_zeros_like(params, dtype=accum_dtype),
        sq_norm_sum=jnp.zeros((), jnp.float32),
        mb_count=jnp.zeros((), jnp.float32),
        sample_count=jnp.zeros((), jnp.float32),
    )


def reset_state(state: DiversityState) -> DiversityState:
    return DiversityState(
        grad_sum=ptu.tree_zeros_like(state.grad_sum),
        sq_norm_sum=jnp.zeros((), jnp.float32),
        mb_count=jnp.zeros((), jnp.float32),
        sample_count=jnp.zeros((), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Per-microbatch accumulation (jit-side, called inside train_step)
# ---------------------------------------------------------------------------


def accumulate(
    state: DiversityState,
    mean_grad: PyTree,
    microbatch_size: jax.Array | int,
    persample_sq_norm_sum: jax.Array | None = None,
) -> DiversityState:
    """Fold one microbatch's gradient statistics into the state.

    mean_grad              the (possibly all-reduced) mean gradient of the
                           microbatch — the same tensor the optimizer consumes,
                           so this costs one extra axpy over the param tree.
    microbatch_size        number of samples in the microbatch (global).
    persample_sq_norm_sum  sum_i ||g_i||^2 over the microbatch, if an exact or
                           gram estimator computed it. If None, the moment
                           estimator's statistic ||m * mean_grad||^2 is used.
    """
    m = jnp.asarray(microbatch_size, jnp.float32)
    grad_sum = jax.tree.map(
        lambda acc, g: acc + m.astype(acc.dtype) * g.astype(acc.dtype),
        state.grad_sum,
        mean_grad,
    )
    if persample_sq_norm_sum is None:
        contrib = (m * m) * ptu.tree_sq_norm(mean_grad)  # ||sum over microbatch||^2
    else:
        contrib = jnp.asarray(persample_sq_norm_sum, jnp.float32)
    return DiversityState(
        grad_sum=grad_sum,
        sq_norm_sum=state.sq_norm_sum + contrib,
        mb_count=state.mb_count + 1.0,
        sample_count=state.sample_count + m,
    )


# ---------------------------------------------------------------------------
# Epoch-boundary estimates (jit-friendly scalar math)
# ---------------------------------------------------------------------------


def diversity_exact(state: DiversityState) -> jax.Array:
    """Delta_hat for the exact/gram tiers: sq_norm_sum / ||grad_sum||^2."""
    denom = ptu.tree_sq_norm(state.grad_sum)
    return state.sq_norm_sum / jnp.maximum(denom, EPS)


def diversity_moment(state: DiversityState) -> jax.Array:
    """Delta_hat from microbatch-sum norms (no per-sample work).

    With J microbatches of (average) size m, n = J*m samples:
        Q := sum_j ||S_j||^2,  E[Q] = J*m*E2 + J*m*(m-1)*M
        R := ||sum_i g_i||^2,  E[R] = n*E2 + n*(n-1)*M
    where E2 = E||g||^2 and M = ||mu||^2. Solving:
        M  = (R - Q) / (n*(n - m))        (clamped at >= 0)
        E2 = Q/n - (m - 1)*M              (clamped at >= eps)
    and Delta_hat = n*E2 / R.
    """
    n = jnp.maximum(state.sample_count, 1.0)
    J = jnp.maximum(state.mb_count, 1.0)
    m = n / J
    Q = state.sq_norm_sum
    R = ptu.tree_sq_norm(state.grad_sum)
    denom = jnp.maximum(n * (n - m), EPS)
    M = jnp.maximum((R - Q) / denom, 0.0)
    E2 = jnp.maximum(Q / n - (m - 1.0) * M, EPS)
    # Single-microbatch epoch degenerates (n == m): fall back to treating the
    # microbatch statistic as exact — Delta_hat = Q/R then equals 1 scaled.
    delta = jnp.where(n - m < 0.5, Q / jnp.maximum(R, EPS), n * E2 / jnp.maximum(R, EPS))
    return delta


def estimate(state: DiversityState, estimator: str) -> jax.Array:
    if estimator in ("exact", "gram"):
        return diversity_exact(state)
    if estimator == "moment":
        return diversity_moment(state)
    raise ValueError(f"unknown estimator {estimator!r}")


# ---------------------------------------------------------------------------
# Per-sample gradient helpers (exact tier + Oracle)
# ---------------------------------------------------------------------------


def persample_grads(
    loss_fn: Callable[[PyTree, Any], jax.Array], params: PyTree, batch: Any
) -> PyTree:
    """vmap(grad): per-sample gradients. loss_fn(params, example) -> scalar."""
    return jax.vmap(jax.grad(loss_fn), in_axes=(None, 0))(params, batch)


def persample_sq_norms(
    loss_fn: Callable[[PyTree, Any], jax.Array], params: PyTree, batch: Any
) -> jax.Array:
    """(B,) array of per-sample gradient squared norms (exact tier)."""
    grads = persample_grads(loss_fn, params, batch)
    leaves = jax.tree.leaves(
        jax.tree.map(
            lambda g: jnp.sum(
                jnp.square(g.astype(jnp.float32)).reshape(g.shape[0], -1), axis=-1
            ),
            grads,
        )
    )
    return functools.reduce(jnp.add, leaves)


def dataset_diversity(
    loss_fn: Callable[[PyTree, Any], jax.Array],
    params: PyTree,
    batches,
) -> jax.Array:
    """ORACLE: exact Delta_S(theta) over an iterable of batches (one pass).

    ``batches`` yields pytrees whose leaves have a leading sample axis. All
    gradients are evaluated at the *same* fixed params (unlike DiveBatch's
    within-epoch accumulation) — this is the paper's Oracle baseline.
    """
    sq_fn = jax.jit(lambda p, b: persample_sq_norms(loss_fn, p, b))

    def sum_fn(p, b):
        bsz = jax.tree.leaves(b)[0].shape[0]
        return ptu.tree_scale(
            jax.grad(lambda pp: jnp.mean(jax.vmap(lambda e: loss_fn(pp, e))(b)))(p), bsz
        )

    sum_fn = jax.jit(sum_fn)

    total_sq = jnp.zeros((), jnp.float32)
    grad_sum = None
    for batch in batches:
        total_sq = total_sq + jnp.sum(sq_fn(params, batch))
        gs = sum_fn(params, batch)
        grad_sum = gs if grad_sum is None else ptu.tree_add(grad_sum, gs)
    if grad_sum is None:
        raise ValueError("dataset_diversity: empty dataset")
    return total_sq / jnp.maximum(ptu.tree_sq_norm(grad_sum), EPS)
