"""Pytree helpers shared by the optimizer, checkpointing, and diversity code.

All functions are pure and jit-safe unless noted.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_zeros_like(tree: PyTree, dtype=None) -> PyTree:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, tree)


def tree_axpy(a, x: PyTree, y: PyTree) -> PyTree:
    """a * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    """Sum of elementwise products across the whole tree (float32 accum)."""
    leaves = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_sq_norm(tree: PyTree) -> jax.Array:
    """Squared L2 norm of the concatenated tree (float32 accum)."""
    leaves = jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), tree)
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_count(tree: PyTree) -> int:
    """Total number of elements (static)."""
    return int(sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree)))


def tree_bytes(tree: PyTree) -> int:
    return int(
        sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize for x in jax.tree.leaves(tree))
    )


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: PyTree) -> PyTree:
    """Map with a '/'-joined string path, convenient for sharding rules."""

    def _fn(path, leaf):
        return fn(_path_str(path), leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_flatten_with_paths(tree: PyTree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(path), leaf) for path, leaf in flat]


def tree_allclose(a: PyTree, b: PyTree, rtol=1e-5, atol=1e-6) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol) for x, y in zip(la, lb))
