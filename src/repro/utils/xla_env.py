"""XLA environment setup for CPU multi-device harnesses.

One canonical implementation of the "force N host devices" dance used by the
elastic benchmarks and the supervisor CLI (tests/conftest.py keeps its own
pre-import copy because it must run before anything under ``repro`` loads).
"""

from __future__ import annotations

import os

_COUNT_FLAG = "--xla_force_host_platform_device_count"


def force_host_device_count(n: int, platform: str = "cpu") -> None:
    """Point XLA at ``n`` host devices.

    Must run before the first jax BACKEND INIT (the first device use) in the
    process — merely having imported jax is fine. Strips any pre-existing
    count flag so this one wins regardless of XLA's duplicate-flag
    precedence; a no-op on an already-initialized backend.
    """
    os.environ.setdefault("JAX_PLATFORMS", platform)
    rest = " ".join(
        f for f in os.environ.get("XLA_FLAGS", "").split()
        if not f.startswith(_COUNT_FLAG)
    )
    os.environ["XLA_FLAGS"] = (rest + f" {_COUNT_FLAG}={int(n)}").strip()
