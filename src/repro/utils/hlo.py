"""Post-SPMD HLO analysis: trip-count-aware FLOPs / HBM-bytes / collective
accounting + the three-term roofline model.

Why not ``compiled.cost_analysis()``: XLA's cost analysis counts each while-
loop body ONCE, but our programs put everything in loops (layer scan, micro-
batch scan, flash-attention KV scan) — so its numbers are off by the product
of trip counts (~100x). This module parses the optimized HLO text, builds the
computation call graph (while bodies with parsed trip counts, fusions,
calls), and propagates execution multipliers:

  flops       2*M*N*K for every dot (+ conv), anywhere in the graph
  hbm bytes   operand+result bytes of every top-level instruction per
              computation (fusion interiors excluded — they live in
              registers/VMEM), times execution count. A no-reuse roofline
              upper bound on HBM traffic.
  collectives operand bytes and ring-model link time per kind, times
              execution count.

Hardware model (TPU v5e, task spec): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from functools import lru_cache

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0, "s4": 1, "u4": 1,
    "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-_]+)\s+\(.*->.*\{$")
_CALL_TARGET_RE = re.compile(r"(?:body|condition|calls|to_apply)=%?([\w.\-_]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_DIMS_RE = re.compile(r"_contracting_dims=\{([\d,]*)\}")


def _parse_shapes(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dtype, shape))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, shape in _parse_shapes(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    line: str

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(self.type_str)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    is_entry: bool = False


def _split_rhs(rhs: str) -> tuple[str, str, str]:
    """'(f32[2],f32[]) tuple(%a, %b), meta' -> (type, opcode, rest)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = rhs[: i + 1]
                    rest = rhs[i + 1 :].strip()
                    break
        else:
            return rhs, "", ""
    else:
        sp = rhs.find(" ")
        type_str, rest = rhs[:sp], rhs[sp + 1 :].strip()
    m = re.match(r"([\w\-]+)\(", rest)
    opcode = m.group(1) if m else rest.split("(")[0].strip()
    return type_str, opcode, rest


def _operand_names(rest: str, opcode: str) -> list[str]:
    paren = rest.find("(")
    if paren == -1:
        return []
    depth = 0
    end = paren
    for i in range(paren, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = rest[paren + 1 : end]
    return re.findall(r"%([\w.\-_]+)", inner)


class HloProgram:
    def __init__(self, text: str):
        self.computations: dict[str, Computation] = {}
        self.entry: str | None = None
        self.sizes: dict[str, int] = {}  # global instr name -> result bytes
        self.shapes: dict[str, list] = {}
        self._parse(text)

    def _parse(self, text: str):
        current: Computation | None = None
        for raw in text.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            if not stripped or stripped.startswith("//") or stripped.startswith("HloModule"):
                continue
            if stripped.endswith("{") and (m := _COMP_HEADER_RE.match(stripped)):
                # computation header: `%name (params) -> type {` or `ENTRY ...`
                name = m.group(2)
                current = Computation(name, [], is_entry=bool(m.group(1)))
                self.computations[name] = current
                if m.group(1):
                    self.entry = name
                continue
            if stripped == "}":
                current = None
                continue
            if current is None or "=" not in stripped:
                continue
            lhs, _, rhs = stripped.partition(" = ")
            lhs = lhs.strip()
            if lhs.startswith("ROOT "):
                lhs = lhs[5:].strip()
            if not lhs.startswith("%") and not re.match(r"^[\w.\-_]+$", lhs):
                continue
            name = lhs.lstrip("%")
            type_str, opcode, rest = _split_rhs(rhs)
            if not opcode:
                continue
            instr = Instr(
                name=name, type_str=type_str, opcode=opcode,
                operands=_operand_names(rest, opcode), line=stripped,
            )
            current.instrs.append(instr)
            self.sizes[name] = _shape_bytes(type_str)
            self.shapes[name] = _parse_shapes(type_str)

    # ------------------------------------------------------------------
    def trip_count(self, cond_name: str) -> int:
        comp = self.computations.get(cond_name)
        if not comp:
            return 1
        best = 1
        for ins in comp.instrs:
            for c in _CONST_INT_RE.findall(ins.line):
                best = max(best, int(c))
        return best

    def _dot_flops(self, ins: Instr) -> float:
        # 2 * prod(result) * prod(contracting dims of lhs)
        result_elems = 0
        for _, shape in _parse_shapes(ins.type_str):
            n = 1
            for d in shape:
                n *= d
            result_elems += n
        contract = 1
        m = _DIMS_RE.search(ins.line)  # lhs_contracting_dims
        if m and ins.operands:
            lhs_shapes = self.shapes.get(ins.operands[0], [])
            if lhs_shapes:
                _, lhs_shape = lhs_shapes[0]
                idxs = [int(i) for i in m.group(1).split(",") if i]
                for i in idxs:
                    if i < len(lhs_shape):
                        contract *= lhs_shape[i]
        return 2.0 * result_elems * contract

    def _conv_flops(self, ins: Instr) -> float:
        result_elems = 0
        for _, shape in _parse_shapes(ins.type_str):
            n = 1
            for d in shape:
                n *= d
            result_elems += n
        # kernel = second operand; flops = 2 * out_elems * (kernel elems / out_channels)
        if len(ins.operands) >= 2:
            kshapes = self.shapes.get(ins.operands[1], [])
            if kshapes:
                _, kshape = kshapes[0]
                kelems = 1
                for d in kshape:
                    kelems *= d
                out_ch = kshape[-1] if kshape else 1
                return 2.0 * result_elems * max(kelems // max(out_ch, 1), 1)
        return 2.0 * result_elems

    # ------------------------------------------------------------------
    def analyze(self) -> dict:
        flops_memo: dict[str, float] = {}
        coll_accum: dict[str, dict] = {}
        bytes_total = [0.0]
        convert_bytes = [0.0]  # pure dtype-convert traffic (CPU-backend bf16
        # emulation artifact: TPU MXU consumes bf16 natively, so converts of
        # weights/activations around matmuls would not exist there)

        _SKIP_BYTES = {
            "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
            "after-all", "partition-id", "replica-id", "iota",
        }

        def _is_pure_convert(ins: Instr) -> bool:
            if ins.opcode == "convert":
                return True
            return ins.opcode == "fusion" and "wrapped_convert" in ins.line

        def _io_bytes(ins: Instr) -> float:
            """HBM traffic of one instruction. Slice-wise updates/reads of big
            buffers (scan gradient accumulators, stacked layer params, KV
            caches) move only the slice — XLA aliases the buffer in place —
            so counting full operand+result (naive model) inflates traffic
            ~60x on stacked-parameter gradient accumulation:
              * dynamic-update-slice (incl. fusions): 2x the update operand;
              * any operand >= 32x the result (a slice-read of a loop-carried
                buffer, e.g. one layer out of a (126, ...) stack): 2x result.
            Genuine big reductions (loss/norm sums) are < 0.1% of traffic and
            absorb the same cap harmlessly."""
            opers = [self.sizes.get(o, 0) for o in ins.operands]
            res = ins.result_bytes
            if "dynamic-update-slice" in ins.line.split(" = ")[0] or \
               ins.opcode == "dynamic-update-slice":
                big = max([res] + opers)
                small = [b for b in opers if 1024 <= b < big]
                slice_b = min(small) if small else max(
                    [b for b in opers if b < big] + [0])
                return 2.0 * slice_b + sum(b for b in opers if b < 1024)
            io = float(res)
            for b in opers:
                if res > 0 and b >= 32 * res:
                    io += min(2.0 * res, b)
                else:
                    io += b
            return io

        def comp_cost(cname: str, mult: float, top_level: bool) -> float:
            """Returns flops of computation; accumulates bytes+collectives
            scaled by mult. ``top_level`` False => fusion interior (no HBM)."""
            comp = self.computations.get(cname)
            if comp is None:
                return 0.0
            flops = 0.0
            for ins in comp.instrs:
                op = ins.opcode
                base = op[:-6] if op.endswith("-start") else op
                if op.endswith("-done"):
                    continue
                # flops
                if base == "dot":
                    flops += self._dot_flops(ins)
                elif base == "convolution":
                    flops += self._conv_flops(ins)
                elif base == "fusion":
                    m = _CALL_TARGET_RE.search(ins.line)
                    if m:
                        flops += comp_cost(m.group(1), mult, top_level=False)
                elif base == "while":
                    body = cond = None
                    for key, target in re.findall(r"(body|condition)=%?([\w.\-_]+)", ins.line):
                        if key == "body":
                            body = target
                        else:
                            cond = target
                    trips = self.trip_count(cond) if cond else 1
                    if body:
                        # return value is per-execution of THIS computation, so
                        # the body contributes trips * its per-execution flops
                        flops += trips * comp_cost(body, mult * trips, top_level=top_level)
                elif base in ("call", "async-start"):
                    m = _CALL_TARGET_RE.search(ins.line)
                    if m:
                        flops += comp_cost(m.group(1), mult, top_level=top_level)
                elif base == "conditional":
                    m = _BRANCHES_RE.search(ins.line)
                    if m:
                        branches = re.findall(r"%?([\w.\-_]+)", m.group(1))
                        if branches:
                            flops += max(
                                comp_cost(b, mult, top_level=top_level) for b in branches
                            )
                # collectives
                if base in COLLECTIVE_KINDS:
                    op_bytes = sum(self.sizes.get(o, 0) for o in ins.operands)
                    gsize = 2
                    gm = _GROUPS_BRACE_RE.search(ins.line)
                    if gm:
                        gsize = len(gm.group(1).split(","))
                    else:
                        gm = _GROUPS_IOTA_RE.search(ins.line)
                        if gm:
                            gsize = int(gm.group(2))
                    d = coll_accum.setdefault(
                        base, {"count": 0.0, "operand_bytes": 0.0, "time_s": 0.0}
                    )
                    d["count"] += mult
                    d["operand_bytes"] += mult * op_bytes
                    d["time_s"] += mult * _ring_time(base, op_bytes, self.sizes.get(ins.name, 0), gsize)
                # bytes (HBM traffic model): top-level ops only
                if top_level and base not in _SKIP_BYTES and base != "while":
                    io = _io_bytes(ins)
                    bytes_total[0] += mult * io
                    if _is_pure_convert(ins):
                        convert_bytes[0] += mult * io
            return flops

        total_flops = comp_cost(self.entry, 1.0, top_level=True) if self.entry else 0.0
        total_coll_bytes = sum(d["operand_bytes"] for d in coll_accum.values())
        total_coll_time = sum(d["time_s"] for d in coll_accum.values())
        return {
            "flops": total_flops,
            "hbm_bytes": bytes_total[0],
            # traffic excluding pure dtype converts (CPU bf16-emulation noise)
            "hbm_bytes_adjusted": bytes_total[0] - convert_bytes[0],
            "convert_bytes": convert_bytes[0],
            "collectives": {
                "by_kind": coll_accum,
                "total_operand_bytes": total_coll_bytes,
                "total_time_s": total_coll_time,
            },
        }

    def f32_upcast_live_bytes(self) -> int:
        """Live-buffer estimate of the CPU backend's hoisted f32 copies of
        bf16 tensors (entry + loop-body computations). memory_analysis temp
        bytes minus this approximates the TPU-resident footprint."""
        total = 0
        for comp in self.computations.values():
            if not (comp.is_entry or "region" in comp.name):
                continue
            for ins in comp.instrs:
                if ins.type_str.startswith("f32") and (
                    ins.opcode == "convert"
                    or (ins.opcode == "fusion" and "wrapped_convert" in ins.line)
                ):
                    total += ins.result_bytes
        return total


def _ring_time(kind: str, operand_bytes: int, result_bytes: int, n: int,
               link_bw: float = ICI_BW) -> float:
    n = max(n, 2)
    ring = (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * ring * operand_bytes / link_bw
    if kind == "all-gather":
        return ring * max(result_bytes, operand_bytes) / link_bw
    if kind == "reduce-scatter":
        return ring * operand_bytes / link_bw
    if kind == "all-to-all":
        return ring * operand_bytes / link_bw
    return operand_bytes / link_bw  # collective-permute


def analyze_hlo(text: str) -> dict:
    return HloProgram(text).analyze()


def roofline_terms(flops: float, hbm_bytes: float, collective_time_s: float) -> dict:
    """Three roofline terms in seconds, PER DEVICE (inputs are per-device)."""
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_time_s,
    }
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant
    terms["step_time_lower_bound_s"] = max(compute_s, memory_s, collective_time_s)
    return terms


def model_flops(n_params_active: float, tokens: float, kind: str = "train") -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference) — global, all chips."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens
