"""Minimal structured logger used across the framework.

We avoid the stdlib logging global config (frameworks should not mutate the
root logger of the host application) and keep a tiny wrapper that callers can
silence or redirect.
"""

from __future__ import annotations

import logging
import sys

_FORMAT = "%(asctime)s %(name)s %(levelname)s %(message)s"
_configured: set[str] = set()


def get_logger(name: str, level: int = logging.INFO) -> logging.Logger:
    logger = logging.getLogger(f"repro.{name}")
    if name not in _configured:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter(_FORMAT, datefmt="%H:%M:%S"))
        logger.addHandler(handler)
        logger.setLevel(level)
        logger.propagate = False
        _configured.add(name)
    return logger
