"""AdaptationProgram — the runtime driver of an adaptation policy.

One program = one policy (possibly a combinator stack) + one
:class:`LrCoupling` + the live scalar state (lr, epoch counter, decision
history).  The ``Trainer`` calls :meth:`observe` at every boundary — epoch
ends, every-``tick_every``-steps ticks, injected events — and then reads
``batch_size`` / ``lr`` / ``estimator`` back; the legacy
``AdaptiveBatchController`` is a thin deprecated shim over exactly this
object, so both construction styles drive the identical code path.

Checkpoint schema: ``state_dict`` emits version 2 ``{"version": 2, ...}``;
``load_state_dict`` also accepts the pre-redesign (v1) controller dict
``{"policy": {...}, "lr": ..., "epoch": ..., "history": [...]}`` so
checkpoints written before the redesign restore unchanged.
"""

from __future__ import annotations

import dataclasses

from repro.adapt.combinators import LrCoupling
from repro.adapt.signals import Clock, Signals
from repro.obs import runlog as runlog_lib
from repro.obs import trace as trace_lib

#: checkpoint schema version written by state_dict
SCHEMA_VERSION = 2


@dataclasses.dataclass
class Applied:
    """One decision as actually applied (the program's history record)."""

    epoch: int
    step: int
    boundary: str
    batch_size: int
    lr: float
    diversity: float | None = None
    raw_batch_size: float | None = None
    reason: str = ""
    rescaled: bool = False
    estimator: str | None = None
    rung: int | None = None


class AdaptationProgram:
    """Drive an :class:`AdaptationPolicy` against the training clock.

    tick_every   > 0 asks the Trainer to open a "tick" boundary every that
                 many optimizer steps (0 = epoch boundaries only).
    estimator    the current diversity-estimator tier; a Decision carrying
                 ``estimator=...`` retargets it (the Trainer rebuilds its
                 compiled step accordingly).
    """

    def __init__(
        self,
        policy,
        base_lr: float,
        coupling: LrCoupling | None = None,
        *,
        estimator: str = "moment",
        tick_every: int = 0,
    ):
        self.policy = policy
        self.coupling = coupling if coupling is not None else LrCoupling()
        self.lr = float(base_lr)
        self.base_lr = float(base_lr)
        self.estimator = estimator
        self.tick_every = int(tick_every)
        self.epoch = 0
        self.history: list[Applied] = []
        # telemetry sinks (repro.obs); null defaults are strict no-ops
        self.tracer = trace_lib.NULL
        self.runlog = runlog_lib.NULL

    def bind_obs(self, *, tracer=None, runlog=None) -> None:
        """Attach telemetry sinks; ``None`` leaves a sink unchanged."""
        if tracer is not None:
            self.tracer = tracer
        if runlog is not None:
            self.runlog = runlog

    # -- views ---------------------------------------------------------------
    @property
    def batch_size(self) -> int:
        return self.policy.batch_size

    @property
    def needs_diversity(self) -> bool:
        return self.policy.needs_diversity

    @property
    def compile_bound(self) -> int:
        """Max distinct step compilations this program can cost a StepEngine
        (the policy's bucket-lattice size; see BatchPolicy.max_buckets)."""
        return getattr(self.policy, "max_buckets", 1)

    # -- the boundary --------------------------------------------------------
    def observe(self, signals: Signals, clock: Clock) -> Applied | None:
        """Feed one boundary observation through the policy.

        Returns the applied record when the policy decided something OR the
        boundary is an epoch end (epoch boundaries always advance the epoch
        counter, apply the background lr decay, and append to history — the
        legacy controller contract); silent ticks return None.

        Every Applied record is also emitted to the bound run log as a
        ``decision`` event — the run-log decision stream mirrors
        ``self.history`` exactly, which is what lets ``launch/monitor.py``
        reconstruct the batch-size/lr schedule from the file alone.
        """
        with self.tracer.span("observe", boundary=clock.boundary,
                              epoch=clock.epoch, step=clock.step):
            applied = self._observe(signals, clock)
        if applied is not None and self.runlog.enabled:
            self.runlog.emit("decision", **dataclasses.asdict(applied))
        return applied

    def _observe(self, signals: Signals, clock: Clock) -> Applied | None:
        m_old = self.batch_size
        d = self.policy.observe(signals, clock)
        if d is not None:
            m_new = d.batch_size if d.batch_size is not None else m_old
            if d.lr is not None:
                self.lr = float(d.lr)
            else:
                self.lr = self.coupling.rescale(self.lr, m_old, m_new)
            if d.estimator is not None:
                self.estimator = d.estimator
        if clock.boundary == "epoch":
            self.lr = self.coupling.background(clock.epoch, self.lr)
            self.epoch = clock.epoch + 1
        if d is None and clock.boundary != "epoch":
            return None
        applied = Applied(
            epoch=clock.epoch,
            step=clock.step,
            boundary=clock.boundary,
            batch_size=self.batch_size,
            lr=self.lr,
            diversity=d.diversity if d is not None else signals.diversity,
            raw_batch_size=d.raw_batch_size if d is not None else None,
            reason=d.reason if d is not None else "",
            rescaled=self.batch_size != m_old,
            estimator=d.estimator if d is not None else None,
            rung=d.rung if d is not None else None,
        )
        self.history.append(applied)
        return applied

    # -- checkpointable state (schema v2; v1 accepted) -----------------------
    def state_dict(self) -> dict:
        return {
            "version": SCHEMA_VERSION,
            "policy": self.policy.state_dict(),
            "lr": self.lr,
            "base_lr": self.base_lr,
            "epoch": self.epoch,
            "estimator": self.estimator,
            "tick_every": self.tick_every,
            "history": [dataclasses.asdict(a) for a in self.history],
        }

    def load_state_dict(self, state: dict) -> None:
        version = int(state.get("version", 1))
        self.policy.load_state_dict(state["policy"])
        self.lr = float(state["lr"])
        self.epoch = int(state["epoch"])
        if version >= 2:
            self.base_lr = float(state.get("base_lr", self.base_lr))
            self.estimator = state.get("estimator", self.estimator)
            self.tick_every = int(state.get("tick_every", self.tick_every))
            self.history = [Applied(**a) for a in state.get("history", [])]
        else:
            # v1: the pre-redesign AdaptiveBatchController layout — history
            # entries are EpochDecision dicts (epoch-boundary only, no clock)
            self.history = [
                Applied(
                    epoch=int(h["epoch"]),
                    step=-1,
                    boundary="epoch",
                    batch_size=int(h["batch_size"]),
                    lr=float(h["lr"]),
                    diversity=h.get("diversity"),
                    raw_batch_size=h.get("raw_batch_size"),
                    rescaled=bool(h.get("rescaled", False)),
                )
                for h in state.get("history", [])
            ]
