"""Training signals for adaptation policies.

``Signals`` is the record every :class:`~repro.adapt.policy.AdaptationPolicy`
observes; ``Clock`` says *when* it is observing (epoch end, every-k-steps
tick, or an external event such as a supervisor Watchdog flag).

The device-side inputs all come from the ``DiversityState`` accumulators the
``StepEngine`` already populates in-jit on every step (``grad_sum``,
``sq_norm_sum``, ``mb_count``, ``sample_count``): the diversity estimate,
the gradient-noise-scale proxy, and the sample count are computed in ONE
cached jit that returns a stacked scalar vector, so a boundary costs at most
one extra device->host transfer on top of the per-step loss (the epoch
boundary's reset of the accumulators rides in the same program).

Gradient-noise scale (McCandlish et al. 2018, "An Empirical Model of
Large-Batch Training"): ``B_noise = tr(Sigma) / ||mu||^2`` where ``Sigma``
is the per-sample gradient covariance and ``mu`` the true gradient.  The
same unbiased small-batch/big-batch moment inversion that powers the
``moment`` diversity tier recovers both quantities from the accumulators —
``E||g||^2`` (small-batch norms) and ``||grad_sum||^2`` (the big-batch
norm) — with zero additional per-step work.  This is the signal the
Sievert-2021 / AdAdaGrad-style :class:`~repro.adapt.policy.GradNoisePolicy`
family adapts on, at sub-epoch granularity.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import pytree as ptu

EPS = 1e-20

#: boundary kinds a Clock can carry
BOUNDARIES = ("epoch", "tick", "event")


@dataclasses.dataclass(frozen=True)
class Clock:
    """When an observation happens.

    epoch     the epoch the boundary belongs to (the one just finishing for
              ``boundary='epoch'``; the running one for ticks/events).
    step      the global optimizer-step count at the boundary (host-side
              counter; no device sync).
    boundary  'epoch' | 'tick' | 'event'.
    """

    epoch: int
    step: int
    boundary: str = "epoch"

    def __post_init__(self):
        if self.boundary not in BOUNDARIES:
            raise ValueError(
                f"unknown boundary {self.boundary!r}; expected one of {BOUNDARIES}"
            )


@dataclasses.dataclass(frozen=True)
class Signals:
    """What a policy observes at a boundary.  ``None`` = not measured.

    diversity   Delta_hat over the accumulation window (DiveBatch's signal).
    gns         gradient-noise-scale proxy tr(Sigma)/||mu||^2 over the same
                window (GradNoisePolicy's signal).
    loss        most recent per-step mean loss (already host-side).
    throughput  steps/sec over the trailing ThroughputWindow (host-side,
                free; falls back to the global dispatch average before the
                first window fills).
    batch_size  the live global batch size.
    samples     samples accumulated since the last reset (device counter,
                rides in the same transfer as diversity/gns).
    event       name of the external event for ``boundary='event'``.
    diversity_bound  Yin et al.'s batch-size cap ``n * Delta_hat`` over the
                same window (Theorem 3 of "Gradient Diversity: a Key
                Ingredient for Scalable Distributed Learning": speedup is
                provable only up to a batch of n*diversity).  Decoded off
                the same accumulators and stacked into the SAME transfer as
                diversity/gns — no extra device->host read.  The
                ``BoundedRung`` combinator clamps decisions under it.
    """

    diversity: float | None = None
    gns: float | None = None
    loss: float | None = None
    throughput: float | None = None
    batch_size: int = 0
    samples: float = 0.0
    event: str | None = None
    diversity_bound: float | None = None


class ThroughputWindow:
    """Sliding-window rate estimator: events/second over a trailing window.

    ``Signals.throughput`` used to carry the engine's *global* dispatch
    average, which dilutes a straggler or a hot streak over the whole run;
    a policy (or the supervisor Watchdog) reacting to throughput needs the
    recent rate.  ``add(n)`` records ``n`` events now; ``rate()`` is events
    per second over the last ``window_s`` seconds — or over the elapsed time
    so far when the window is not yet full, so early reads are unbiased
    rather than deflated.  ``repro.serve`` reuses the same estimator for
    ``ServeStats.tokens_per_sec`` (events = emitted tokens).

    The clock is injectable (``clock=`` or per-call ``now=``) so the window
    math is unit-testable without sleeping.
    """

    def __init__(self, window_s: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        self.window_s = float(window_s)
        self._clock = clock
        self._samples: collections.deque[tuple[float, float]] = collections.deque()
        self._start: float | None = None

    def _evict(self, now: float) -> None:
        # strict <: the trailing window is the CLOSED interval
        # [now - window_s, now] — its length is exactly the window_s the
        # denominator charges, so a sample exactly window_s old still
        # counts (the old <= dropped it while still dividing by the full
        # window, deflating the rate at the boundary)
        edge = now - self.window_s
        while self._samples and self._samples[0][0] < edge:
            self._samples.popleft()

    def add(self, n: float = 1.0, now: float | None = None) -> None:
        """Record ``n`` events at ``now`` (defaults to the injected clock)."""
        now = self._clock() if now is None else float(now)
        if self._start is None:
            self._start = now
        self._samples.append((now, float(n)))
        self._evict(now)

    def rate(self, now: float | None = None) -> float | None:
        """Events/second over the trailing window; None before any event.

        The denominator is ``min(window_s, now - first_event_time)`` — a
        window that has only been filling for 2 of its 10 seconds divides by
        2, not 10.  A burst whose events all landed at a single instant has
        no measurable span: the rate charges the full window instead — the
        conservative lower bound — so recorded events always yield a finite,
        non-None rate (the old code returned None as if nothing happened).
        """
        if self._start is None:
            return None
        now = self._clock() if now is None else float(now)
        self._evict(now)
        count = sum(n for _, n in self._samples)
        span = min(self.window_s, now - self._start)
        if span <= 0.0:
            return count / self.window_s
        return count / span


def gns_from_accumulators(div_state: Any, estimator: str = "moment") -> jax.Array:
    """tr(Sigma)/||mu||^2 from the DiversityState accumulators (jit-safe).

    Uses the same moment inversion as ``diversity.diversity_moment``: with
    per-window statistics ``Q`` (sum of small-batch squared norms, batch size
    ``m`` = 1 for the exact/gram tiers, the microbatch size for moment) and
    ``R = ||grad_sum||^2``,

        M  = (R - Q) / (n (n - m))      ~ ||mu||^2        (clamped >= 0)
        E2 = Q/n - (m - 1) M            ~ E||g||^2        (clamped >= eps)
        tr(Sigma) = E2 - M

    Degenerate windows (single small batch, or empty accumulators) return 0.
    """
    n = jnp.maximum(div_state.sample_count, 1.0)
    if estimator in ("exact", "gram"):
        m = jnp.float32(1.0)
    else:
        m = n / jnp.maximum(div_state.mb_count, 1.0)
    Q = div_state.sq_norm_sum
    R = ptu.tree_sq_norm(div_state.grad_sum)
    M = jnp.maximum((R - Q) / jnp.maximum(n * (n - m), EPS), 0.0)
    E2 = jnp.maximum(Q / n - (m - 1.0) * M, EPS)
    tr_sigma = jnp.maximum(E2 - M, 0.0)
    gns = tr_sigma / jnp.maximum(M, EPS)
    degenerate = jnp.logical_or(n - m < 0.5, R < EPS)
    return jnp.where(degenerate, 0.0, gns)


@functools.lru_cache(maxsize=None)
def _read_jit(estimator: str, reset: bool):
    # deferred import: repro.core's __init__ pulls the controller shim, which
    # reaches back into repro.adapt — module-level would be a cycle
    from repro.core import diversity

    def read(div_state):
        est = diversity.estimate(div_state, estimator)
        scalars = jnp.stack(
            [
                est,
                gns_from_accumulators(div_state, estimator),
                div_state.sample_count,
                # Yin et al.'s batch cap n * Delta_hat, off the same decode
                div_state.sample_count * est,
            ]
        )
        if not reset:
            # tick reads leave the accumulators untouched — returning them
            # through the jit would copy the param-sized grad_sum tree
            return scalars
        return scalars, diversity.reset_state(div_state)

    return jax.jit(read)


def read_signals(
    state: Any,
    estimator: str = "moment",
    *,
    reset: bool,
    batch_size: int = 0,
    loss: float | None = None,
    throughput: float | None = None,
    event: str | None = None,
) -> tuple[Signals, Any]:
    """Read boundary signals off a ``TrainState``'s diversity accumulators.

    Returns ``(signals, state)``; with ``reset=True`` the returned state has
    freshly-zeroed accumulators (the epoch-boundary semantics), with
    ``reset=False`` the state is unchanged (mid-epoch ticks observe the
    running window).  Exactly ONE device->host transfer regardless of how
    many scalars are read (they come back stacked).
    """
    if reset:
        scalars, div_state = _read_jit(estimator, True)(state.div_state)
        state = state._replace(div_state=div_state)
    else:
        scalars = _read_jit(estimator, False)(state.div_state)
    vals = np.asarray(scalars)  # the single host transfer
    sig = Signals(
        diversity=float(vals[0]),
        gns=float(vals[1]),
        samples=float(vals[2]),
        loss=loss,
        throughput=throughput,
        batch_size=int(batch_size),
        event=event,
        diversity_bound=float(vals[3]),
    )
    return sig, state
