"""repro.adapt — composable, signal-driven training adaptation.

The single adaptation path for the repo: a policy observes
:class:`Signals` (diversity estimate, gradient-noise scale, loss,
throughput, events) at :class:`Clock` boundaries (epoch ends,
every-k-steps ticks, injected events) and emits typed :class:`Decision`
records unifying batch size, learning rate, estimator tier, and the
elastic-ladder rung.  ``AdaptationProgram`` drives a policy against the
clock; combinators (``Clamped`` / ``Warmup`` / ``Hysteresis`` / ``Chain`` /
``Switch``) compose policies; :class:`LrCoupling` types the batch->lr
coupling.  The legacy ``core.AdaptiveBatchController`` survives as a thin
deprecated shim over an ``AdaptationProgram``.
"""

from repro.adapt.combinators import (
    BoundedRung,
    Chain,
    Clamped,
    Hysteresis,
    LrCoupling,
    Switch,
    Warmup,
)
from repro.adapt.policy import (
    AdaBatchPolicy,
    AdaptationPolicy,
    Decision,
    DiveBatchPolicy,
    FixedPolicy,
    FromBatchPolicy,
    GradNoisePolicy,
    PolicyBase,
)
from repro.adapt.program import SCHEMA_VERSION, AdaptationProgram, Applied
from repro.adapt.signals import (
    Clock,
    Signals,
    ThroughputWindow,
    gns_from_accumulators,
    read_signals,
)

__all__ = [
    "Clock",
    "Signals",
    "ThroughputWindow",
    "read_signals",
    "gns_from_accumulators",
    "Decision",
    "AdaptationPolicy",
    "PolicyBase",
    "FromBatchPolicy",
    "FixedPolicy",
    "AdaBatchPolicy",
    "DiveBatchPolicy",
    "GradNoisePolicy",
    "LrCoupling",
    "Clamped",
    "BoundedRung",
    "Warmup",
    "Hysteresis",
    "Chain",
    "Switch",
    "AdaptationProgram",
    "Applied",
    "SCHEMA_VERSION",
]
