"""Policy combinators + the typed learning-rate coupling.

Combinators wrap an inner :class:`AdaptationPolicy` and transform its
decisions; they satisfy the same protocol, so they nest freely:

    Hysteresis(Clamped(DiveBatchPolicy(...), m_min=32), band=0.1)

``LrCoupling`` is the typed replacement for the old string-valued
``lr_rule``/``lr_schedule`` pair on ``AdaptiveBatchController``: one record
carrying the batch->lr scaling rule (Goyal et al. linear / sqrt / none) and
the background decay schedule, consumed by ``AdaptationProgram``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

from repro.adapt.policy import Decision, PolicyBase
from repro.adapt.signals import Clock, Signals
from repro.core.controller import lr_rescale, step_decay  # canonical defs

__all__ = [
    "LrCoupling",
    "Clamped",
    "BoundedRung",
    "Warmup",
    "Hysteresis",
    "Chain",
    "Switch",
    "lr_rescale",
    "step_decay",
]


@dataclasses.dataclass(frozen=True)
class LrCoupling:
    """How the learning rate follows the batch size.

    rule    'linear' (Goyal et al. scaling), 'sqrt', or 'none'.
    decay   optional background schedule ``(epoch, lr) -> lr`` applied at
            every epoch boundary on top of the coupling (e.g.
            ``step_decay(0.75, 20)``, the paper's synthetic setting).
    """

    rule: str = "none"
    decay: Callable[[int, float], float] | None = None

    def __post_init__(self):
        if self.rule not in ("none", "linear", "sqrt"):
            raise ValueError(f"unknown lr coupling rule {self.rule!r}")

    @classmethod
    def linear(cls, decay=None) -> "LrCoupling":
        return cls("linear", decay)

    @classmethod
    def sqrt(cls, decay=None) -> "LrCoupling":
        return cls("sqrt", decay)

    @classmethod
    def none(cls, decay=None) -> "LrCoupling":
        return cls("none", decay)

    def rescale(self, lr: float, m_old: int, m_new: int) -> float:
        return lr_rescale(self.rule, lr, m_old, m_new)

    def background(self, epoch: int, lr: float) -> float:
        return self.decay(epoch, lr) if self.decay is not None else lr


class _Wrapper(PolicyBase):
    """Delegating base for single-inner combinators."""

    def __init__(self, inner):
        self.inner = inner

    def fires(self, clock: Clock) -> bool:
        return self.inner.fires(clock)

    def observe(self, signals: Signals, clock: Clock) -> Decision | None:
        return self.inner.observe(signals, clock)

    @property
    def batch_size(self) -> int:
        return self.inner.batch_size

    def set_batch_size(self, m: int) -> None:
        self.inner.set_batch_size(m)

    @property
    def needs_diversity(self) -> bool:
        return self.inner.needs_diversity

    @property
    def max_buckets(self) -> int:
        return self.inner.max_buckets

    def state_dict(self) -> dict:
        return {"inner": self.inner.state_dict()}

    def load_state_dict(self, state: dict) -> None:
        self.inner.load_state_dict(state["inner"])


class Clamped(_Wrapper):
    """Clamp decided batch sizes into ``[m_min, m_max]``.

    With lattice-point bounds (the normal case) the output stays on the
    lattice: clamp only ever substitutes a bound for the decided value.  The
    clamped value is written back into the inner policy so its internal
    state agrees with what actually runs.
    """

    def __init__(self, inner, m_min: int | None = None, m_max: int | None = None):
        super().__init__(inner)
        self.m_min = m_min
        self.m_max = m_max

    def observe(self, signals: Signals, clock: Clock) -> Decision | None:
        d = self.inner.observe(signals, clock)
        if d is None or d.batch_size is None:
            return d
        m = d.batch_size
        if self.m_min is not None:
            m = max(m, self.m_min)
        if self.m_max is not None:
            m = min(m, self.m_max)
        if m != d.batch_size:
            self.inner.set_batch_size(m)
            d = dataclasses.replace(d, batch_size=m, reason=d.reason + "+clamp")
        return d


class BoundedRung(_Wrapper):
    """Clamp decisions under the gradient-diversity batch bound.

    Yin et al. ("Gradient Diversity: a Key Ingredient for Scalable
    Distributed Learning") prove mini-batch SGD matches serial SGD's
    convergence only while the batch stays below ``n * Delta_S`` — gradient
    diversity IS the theory of how wide a data-parallel rung may grow.
    ``Signals.diversity_bound`` carries the windowed estimate of that cap
    (``samples * Delta_hat``, decoded off the same stacked-scalar read as
    ``gns``); this combinator enforces it on every inner ``Decision``:

      * ``batch_size`` is clamped onto the largest lattice point
        ``granule * 2^k <= margin * bound`` (floored at ``granule`` —
        training must proceed even under a collapsed estimate);
      * an explicit ``rung`` whose dp width exceeds the cap is substituted
        with the widest ladder rung that fits (when ``ladder`` is given).

    A missing / non-finite / non-positive bound passes decisions through
    untouched (e.g. the very first boundary, before any accumulation).
    """

    def __init__(self, inner, *, granule: int = 1, margin: float = 1.0,
                 ladder=None):
        super().__init__(inner)
        if granule < 1:
            raise ValueError(f"granule must be >= 1, got {granule}")
        if margin <= 0:
            raise ValueError(f"margin must be > 0, got {margin}")
        self.granule = int(granule)
        self.margin = float(margin)
        self.ladder = ladder

    def _cap(self, signals: Signals) -> float | None:
        b = signals.diversity_bound
        if b is None or not math.isfinite(b) or b <= 0:
            return None
        return self.margin * b

    def observe(self, signals: Signals, clock: Clock) -> Decision | None:
        d = self.inner.observe(signals, clock)
        if d is None:
            return d
        cap = self._cap(signals)
        if cap is None:
            return d
        bounded = False
        if d.batch_size is not None and d.batch_size > cap:
            m = self.granule
            while m * 2 <= cap:
                m *= 2
            self.inner.set_batch_size(m)
            d = dataclasses.replace(d, batch_size=m,
                                    reason=d.reason + "+bound")
            bounded = True
        if (d.rung is not None and self.ladder is not None
                and self.ladder.rungs[d.rung].dp > cap):
            best = self.ladder.rungs[0]
            for r in self.ladder.rungs:
                if r.dp <= cap:
                    best = r
            d = dataclasses.replace(
                d, rung=best.index,
                reason=d.reason if bounded else d.reason + "+bound")
        return d


class Warmup(_Wrapper):
    """Suppress adaptation until ``epochs`` epochs / ``steps`` steps have
    passed (the inner policy is not even consulted, so its schedule starts
    fresh at release)."""

    def __init__(self, inner, *, epochs: int = 0, steps: int = 0):
        super().__init__(inner)
        self.epochs = int(epochs)
        self.steps = int(steps)

    def _active(self, clock: Clock) -> bool:
        return clock.epoch >= self.epochs and clock.step >= self.steps

    def fires(self, clock: Clock) -> bool:
        return self._active(clock) and self.inner.fires(clock)

    def observe(self, signals: Signals, clock: Clock) -> Decision | None:
        if not self._active(clock):
            return None
        return self.inner.observe(signals, clock)


class Hysteresis(_Wrapper):
    """Schmitt trigger on the bucket lattice: a resize is accepted only when
    the RAW target clears the rounding threshold adjacent to the held bucket
    by a relative ``band``; otherwise the held size is kept.

    On the pow2 lattice the round-to-nearest boundary above a held size
    ``A`` sits at ``A*sqrt(2)`` (and below at ``A/sqrt(2)``), so the
    acceptance rule is

        move up   iff  raw > A*sqrt(2)*(1+band)
        move down iff  raw < A/sqrt(2)/(1+band)

    This makes the schedule rung-invariant under dp-reduction-order jitter
    (the ROADMAP's observed schedule fork): two consecutive raw estimates
    whose ratio lies within ``[1/(1+band), 1+band]`` can NEVER produce an
    A -> B -> A flap — after accepting a move on ``r1``, the opposite
    threshold is strictly out of reach of any ``r2`` within the band of
    ``r1`` (strict inequalities; see tests/test_adapt.py property test).
    """

    def __init__(self, inner, band: float = 0.1):
        super().__init__(inner)
        if band < 0:
            raise ValueError(f"band must be >= 0, got {band}")
        self.band = float(band)
        self._held: int | None = None

    def observe(self, signals: Signals, clock: Clock) -> Decision | None:
        d = self.inner.observe(signals, clock)
        if d is None or d.batch_size is None:
            return d
        if self._held is None or d.batch_size == self._held:
            self._held = d.batch_size
            return d
        held = self._held
        raw = d.raw_batch_size if d.raw_batch_size is not None else float(d.batch_size)
        up = held * math.sqrt(2.0) * (1.0 + self.band)
        down = held / math.sqrt(2.0) / (1.0 + self.band)
        accept = raw > up if d.batch_size > held else raw < down
        if accept:
            self._held = d.batch_size
            return d
        self.inner.set_batch_size(held)
        return dataclasses.replace(d, batch_size=held, reason=d.reason + "+hold")

    @property
    def batch_size(self) -> int:
        return self._held if self._held is not None else self.inner.batch_size

    def set_batch_size(self, m: int) -> None:
        # external write-back (Switch handover, Chain merge) re-anchors the
        # band: holding the old value would desync batch_size from the run
        self.inner.set_batch_size(m)
        self._held = int(m)

    def state_dict(self) -> dict:
        return {"inner": self.inner.state_dict(), "held": self._held}

    def load_state_dict(self, state: dict) -> None:
        self.inner.load_state_dict(state["inner"])
        h = state.get("held")
        self._held = int(h) if h is not None else None


class Chain(PolicyBase):
    """Observe several policies at one boundary and merge their decisions
    field-wise (FIRST non-None value per field wins — list policies in
    priority order).  The first policy is the primary batch authority:
    ``batch_size`` reads from it, and an accepted merge writes the final
    batch back into every member so their states stay coherent."""

    def __init__(self, *policies):
        if not policies:
            raise ValueError("Chain needs at least one policy")
        self.policies = list(policies)

    def fires(self, clock: Clock) -> bool:
        return any(p.fires(clock) for p in self.policies)

    def observe(self, signals: Signals, clock: Clock) -> Decision | None:
        decisions = [d for p in self.policies if (d := p.observe(signals, clock))]
        if not decisions:
            return None
        merged: dict = {}
        for d in decisions:
            for f in dataclasses.fields(Decision):
                v = getattr(d, f.name)
                if f.name == "reason":
                    continue
                if merged.get(f.name) is None and v is not None:
                    merged[f.name] = v
        merged["reason"] = "+".join(d.reason for d in decisions if d.reason)
        out = Decision(**merged)
        if out.batch_size is not None:
            for p in self.policies:
                p.set_batch_size(out.batch_size)
        return out

    @property
    def batch_size(self) -> int:
        return self.policies[0].batch_size

    def set_batch_size(self, m: int) -> None:
        for p in self.policies:
            p.set_batch_size(m)

    @property
    def needs_diversity(self) -> bool:
        return any(p.needs_diversity for p in self.policies)

    @property
    def max_buckets(self) -> int:
        return max(getattr(p, "max_buckets", 1) for p in self.policies)

    def state_dict(self) -> dict:
        return {"policies": [p.state_dict() for p in self.policies]}

    def load_state_dict(self, state: dict) -> None:
        for p, s in zip(self.policies, state["policies"]):
            p.load_state_dict(s)


class Switch(PolicyBase):
    """Route each observation to one of several policies.

    ``selector(clock) -> index``.  The convenience constructor
    ``Switch.at_epochs([e1, e2, ...], [p0, p1, p2, ...])`` runs ``p0``
    before epoch ``e1``, ``p1`` before ``e2``, and so on.  The newly-active
    policy inherits the previous one's live batch size, so a handover never
    teleports the schedule.
    """

    def __init__(self, selector: Callable[[Clock], int], policies: Sequence):
        if not policies:
            raise ValueError("Switch needs at least one policy")
        self.selector = selector
        self.policies = list(policies)
        self._active = 0

    @classmethod
    def at_epochs(cls, boundaries: Sequence[int], policies: Sequence) -> "Switch":
        bounds = list(boundaries)
        if len(policies) != len(bounds) + 1:
            raise ValueError(
                f"need len(policies) == len(boundaries)+1, got "
                f"{len(policies)} policies for {len(bounds)} boundaries"
            )

        def selector(clock: Clock) -> int:
            return sum(clock.epoch >= b for b in bounds)

        return cls(selector, policies)

    def _select(self, clock: Clock):
        idx = max(0, min(int(self.selector(clock)), len(self.policies) - 1))
        if idx != self._active:
            self.policies[idx].set_batch_size(self.policies[self._active].batch_size)
            self._active = idx
        return self.policies[idx]

    def fires(self, clock: Clock) -> bool:
        return self._select(clock).fires(clock)

    def observe(self, signals: Signals, clock: Clock) -> Decision | None:
        return self._select(clock).observe(signals, clock)

    @property
    def batch_size(self) -> int:
        return self.policies[self._active].batch_size

    def set_batch_size(self, m: int) -> None:
        self.policies[self._active].set_batch_size(m)

    @property
    def needs_diversity(self) -> bool:
        return any(p.needs_diversity for p in self.policies)

    @property
    def max_buckets(self) -> int:
        return max(getattr(p, "max_buckets", 1) for p in self.policies)

    def state_dict(self) -> dict:
        return {"policies": [p.state_dict() for p in self.policies],
                "active": self._active}

    def load_state_dict(self, state: dict) -> None:
        for p, s in zip(self.policies, state["policies"]):
            p.load_state_dict(s)
        self._active = int(state.get("active", 0))
