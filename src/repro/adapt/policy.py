"""The adaptation protocol: ``observe(signals, clock) -> Decision | None``.

A policy is a host-side object that watches training :class:`Signals` at
boundaries (:class:`Clock`) and emits typed :class:`Decision` records.  This
replaces the epoch-only ``BatchPolicy.on_epoch_end(epoch, diversity)``
funnel: the same protocol expresses epoch-end DiveBatch, every-k-steps
gradient-noise adaptation (Sievert 2021; Lau et al. 2024, AdAdaGrad), and
event-driven resizes from a supervisor Watchdog.

Implementations here:
  FixedPolicy       constant m (the SGD baselines).
  AdaBatchPolicy    multiply m every ``resize_freq`` epochs.
  DiveBatchPolicy   m <- min(m_max, delta * n * Delta_hat)  [Algorithm 1],
                    optionally at tick/event boundaries with the running
                    estimate; ``oracle=True`` selects the OracleDiveBatch
                    rule (the caller feeds exact full-dataset diversity).
  GradNoisePolicy   m tracks the measured gradient-noise scale
                    (``alpha * B_noise``), EMA-smoothed — the
                    Sievert/AdAdaGrad family the epoch-only API could not
                    express.
  FromBatchPolicy   adapter lifting any legacy ``core.BatchPolicy`` into the
                    protocol (the ``AdaptiveBatchController`` shim uses it).

Composition (clamping, warmup, hysteresis, chaining, lr coupling) lives in
``combinators.py``; the run-time driver is ``program.AdaptationProgram``.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

from repro.adapt.signals import Clock, Signals
from repro.core import batch_policy as bp


@dataclasses.dataclass(frozen=True)
class Decision:
    """One typed adaptation decision.  ``None`` fields = leave unchanged.

    batch_size      new global batch size (already on the bucket lattice).
    lr              explicit learning rate; when None the program derives it
                    from the batch change via its ``LrCoupling``.
    estimator       diversity-estimator tier to switch to (exact|gram|moment).
    rung            explicit elastic-ladder rung index (overrides the
                    batch-derived rung; e.g. a straggler event narrowing the
                    footprint).
    reason          provenance string ("divebatch", "gradnoise", ...).
    raw_batch_size  the pre-bucketing target (hysteresis bands compare it
                    against lattice thresholds).
    diversity       the estimate the decision was based on (bookkeeping).
    """

    batch_size: int | None = None
    lr: float | None = None
    estimator: str | None = None
    rung: int | None = None
    reason: str = ""
    raw_batch_size: float | None = None
    diversity: float | None = None


@runtime_checkable
class AdaptationPolicy(Protocol):
    """Structural protocol every policy and combinator satisfies."""

    def observe(self, signals: Signals, clock: Clock) -> Decision | None: ...

    def fires(self, clock: Clock) -> bool: ...

    @property
    def batch_size(self) -> int: ...

    def set_batch_size(self, m: int) -> None: ...

    def state_dict(self) -> dict: ...

    def load_state_dict(self, state: dict) -> None: ...


class PolicyBase:
    """Shared boundary gating: fire on epochs always, on ticks/events by
    flag.  Subclasses implement ``_decide`` and own their batch state."""

    def __init__(self, *, on_epoch: bool = True, on_tick: bool = False,
                 on_event: bool = False):
        self.on_epoch = on_epoch
        self.on_tick = on_tick
        self.on_event = on_event

    def fires(self, clock: Clock) -> bool:
        return {
            "epoch": self.on_epoch,
            "tick": self.on_tick,
            "event": self.on_event,
        }[clock.boundary]

    def observe(self, signals: Signals, clock: Clock) -> Decision | None:
        if not self.fires(clock):
            return None
        return self._decide(signals, clock)

    def _decide(self, signals: Signals, clock: Clock) -> Decision | None:
        raise NotImplementedError

    @property
    def needs_diversity(self) -> bool:
        return False

    def state_dict(self) -> dict:
        return {}

    def load_state_dict(self, state: dict) -> None:
        pass


class FromBatchPolicy(PolicyBase):
    """Lift a legacy ``core.batch_policy.BatchPolicy`` into the protocol.

    The inner policy's epoch rule runs at whatever boundaries the flags
    enable (its ``on_epoch_end(epoch, diversity)`` math is boundary-agnostic
    for Fixed/DiveBatch; epoch-counting policies like AdaBatch should keep
    the epoch-only default).  ``state_dict`` passes straight through, so a
    pre-redesign ``{"m": ...}`` checkpoint loads unchanged.
    """

    def __init__(self, inner: bp.BatchPolicy, *, on_epoch: bool = True,
                 on_tick: bool = False, on_event: bool = False):
        super().__init__(on_epoch=on_epoch, on_tick=on_tick, on_event=on_event)
        self.inner = inner

    def _decide(self, signals: Signals, clock: Clock) -> Decision | None:
        info = self.inner.on_epoch_end(clock.epoch, signals.diversity)
        return Decision(
            batch_size=info.batch_size,
            raw_batch_size=info.raw_batch_size,
            diversity=info.diversity,
            reason=info.reason,
        )

    @property
    def batch_size(self) -> int:
        return self.inner.m

    def set_batch_size(self, m: int) -> None:
        self.inner.m = int(m)

    @property
    def needs_diversity(self) -> bool:
        return self.inner.needs_diversity

    @property
    def max_buckets(self) -> int:
        return self.inner.max_buckets

    def state_dict(self) -> dict:
        return self.inner.state_dict()

    def load_state_dict(self, state: dict) -> None:
        self.inner.load_state_dict(state)


class FixedPolicy(FromBatchPolicy):
    def __init__(self, m0: int, m_max: int | None = None, granule: int = 1,
                 bucket_mode: str = "pow2"):
        super().__init__(bp.FixedBatch(m0, max(m_max or m0, m0), granule, bucket_mode))


class AdaBatchPolicy(FromBatchPolicy):
    """Epoch-counting: fires only at epoch boundaries by construction."""

    def __init__(self, m0: int, m_max: int, resize_factor: int = 2,
                 resize_freq: int = 20, granule: int = 1,
                 bucket_mode: str = "pow2"):
        super().__init__(
            bp.AdaBatch(m0, m_max, resize_factor, resize_freq, granule, bucket_mode)
        )


class DiveBatchPolicy(FromBatchPolicy):
    """Algorithm 1, protocol form.  ``on_tick``/``on_event`` let the (memory-
    less) rule also fire mid-epoch on the running diversity estimate.

    ``dataset_size=None`` scales by the samples actually accumulated in the
    observation window (``signals.samples``) instead of a fixed n — the
    streaming/LM regime where an "epoch" is a step interval.
    """

    def __init__(self, m0: int, m_max: int, delta: float,
                 dataset_size: int | None = None, granule: int = 1,
                 bucket_mode: str = "pow2", monotone: bool = False,
                 m_min: int | None = None, *, oracle: bool = False,
                 on_tick: bool = False, on_event: bool = True):
        cls = bp.OracleDiveBatch if oracle else bp.DiveBatch
        inner = cls(m0, m_max, delta, dataset_size or 1, granule, bucket_mode,
                    monotone, m_min)
        super().__init__(inner, on_tick=on_tick, on_event=on_event)
        self._window_sized = dataset_size is None

    def _decide(self, signals: Signals, clock: Clock) -> Decision | None:
        if self._window_sized:
            self.inner.n = max(int(signals.samples), 1)
        return super()._decide(signals, clock)


class GradNoisePolicy(PolicyBase):
    """Track the critical batch size: m <- alpha * B_noise (EMA-smoothed).

    The gradient-noise scale ``B_noise = tr(Sigma)/||mu||^2`` estimates the
    batch size at which data parallelism stops paying (McCandlish et al.
    2018); Sievert (2021) and AdAdaGrad (Lau et al. 2024) adapt the batch on
    exactly this family of variance signals, at sub-epoch granularity —
    hence ``on_tick=True`` by default.  The raw signal is noisy, so an EMA
    with weight ``ema`` on the PREVIOUS smoothed value stabilises it; the
    output lands on the same bucket lattice as every other policy.
    """

    def __init__(self, m0: int, m_max: int, granule: int = 1,
                 bucket_mode: str = "pow2", *, alpha: float = 1.0,
                 ema: float = 0.5, m_min: int | None = None,
                 on_tick: bool = True, on_event: bool = True):
        super().__init__(on_tick=on_tick, on_event=on_event)
        if m0 < 1 or m_max < m0:
            raise ValueError(f"need 1 <= m0 <= m_max, got m0={m0}, m_max={m_max}")
        self.m_max = int(m_max)
        self.granule = int(granule)
        self.bucket_mode = bucket_mode
        self.alpha = float(alpha)
        self.ema = float(ema)
        self.m_min = int(m_min) if m_min is not None else 1
        self.m = bp.bucket(m0, granule, bucket_mode, m_max=m_max)
        self._gns: float | None = None

    def _decide(self, signals: Signals, clock: Clock) -> Decision | None:
        if signals.gns is None:
            return None
        g = float(signals.gns)
        self._gns = g if self._gns is None else self.ema * self._gns + (1 - self.ema) * g
        raw = self.alpha * self._gns
        self.m = bp.bucket(
            int(max(raw, self.m_min)), self.granule, self.bucket_mode,
            m_min=self.m_min, m_max=self.m_max,
        )
        return Decision(batch_size=self.m, raw_batch_size=raw,
                        diversity=signals.diversity, reason="gradnoise")

    @property
    def batch_size(self) -> int:
        return self.m

    def set_batch_size(self, m: int) -> None:
        self.m = int(m)

    @property
    def needs_diversity(self) -> bool:
        # the GNS proxy reads the same DiversityState accumulators
        return True

    @property
    def max_buckets(self) -> int:
        if self.bucket_mode == "none":
            return max(self.m_max // max(self.granule, 1), 1)
        return bp.num_buckets(self.m_max, self.granule)

    def state_dict(self) -> dict:
        return {"m": self.m, "gns": self._gns}

    def load_state_dict(self, state: dict) -> None:
        self.m = int(state["m"])
        g = state.get("gns")
        self._gns = float(g) if g is not None else None
