"""Error-feedback gradient compression for cross-pod (DCN) reductions.

Between pods the all-reduce crosses data-center networking, ~20x slower per
byte than ICI — compressing the pod-level gradient exchange int8 cuts that
traffic 4x vs f32 at a quantization error that error feedback (Seide et al.
2014; Karimireddy et al. 2019 "EF signSGD") keeps from accumulating: the
residual of each round is carried into the next round's quantizer input, so
the TRANSMITTED signal integrates to the true signal over time.

``compress_leaf``         one leaf: absmax-scaled int8 quantize of
                          (grad + carried error), returning the dequantized
                          transmit value and the new error residual.
``compressed_pod_mean``   runs INSIDE ``shard_map``: quantizes local leaves,
                          all-gathers the int8 payload + f32 scale over the
                          pod axis (the compressed wire format), and returns
                          the dequantized mean plus the new error state.
``make_compressed_pod_mean``  wraps the above in ``shard_map`` over a mesh
                          axis for callers that hold unsharded trees.

Production caller: ``repro.pod.step.make_pod_train_step`` — the train step
``PodLadder`` compiles on every cross-pod (``pods > 1``) elastic rung calls
``compressed_pod_mean`` inside its shard_map for the DCN gradient exchange,
with the error-feedback residuals threaded through ``TrainState.err_state``
(installed / re-zeroed per rung by ``PodLadder.adapt_state``).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

try:  # moved out of experimental in newer jax
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover
    from jax import shard_map
from jax.sharding import PartitionSpec as P

PyTree = Any

_QMAX = 127.0


def init_error_state(grads: PyTree) -> PyTree:
    """Zero f32 residuals, one per gradient leaf (local-shard shapes)."""
    return jax.tree.map(lambda g: jnp.zeros(jnp.shape(g), jnp.float32), grads)


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor absmax int8: returns (q int8, scale f32 scalar)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / _QMAX
    q = jnp.clip(jnp.round(x / scale), -_QMAX, _QMAX).astype(jnp.int8)
    return q, scale


def compress_leaf(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Quantize one gradient leaf with error feedback.

    Returns ``(dequantized, new_err)``: ``dequantized`` is what the wire
    carries (reconstructed to g's dtype), ``new_err`` the f32 residual to
    feed back next round.  Works on any shape including scalars.
    """
    x = g.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = _quantize(x)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), x - deq


def compressed_pod_mean(
    grads: PyTree, err: PyTree, axis_name: str
) -> tuple[PyTree, PyTree]:
    """Compressed mean over a shard_map axis (call inside ``shard_map``).

    Each shard quantizes its local leaves (folding in the carried error),
    all-gathers the int8 tensors and their scalar scales over ``axis_name``
    — the only cross-pod bytes are the compressed payload — and dequantizes
    and averages locally.  Returns ``(mean_tree, new_err_tree)``; the error
    state stays shard-local.
    """
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(err)
    assert len(leaves) == len(err_leaves), "grads/err tree mismatch"

    means, new_errs = [], []
    for g, e in zip(leaves, err_leaves):
        x = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, scale = _quantize(x)
        new_errs.append(x - q.astype(jnp.float32) * scale)
        q_all = jax.lax.all_gather(q, axis_name)  # (pods, ...)
        s_all = jax.lax.all_gather(scale, axis_name)  # (pods,)
        deq = q_all.astype(jnp.float32) * s_all.reshape((-1,) + (1,) * jnp.ndim(g))
        means.append(jnp.mean(deq, axis=0).astype(g.dtype))
    return treedef.unflatten(means), treedef.unflatten(new_errs)


def make_compressed_pod_mean(mesh, axis_name: str):
    """A jittable ``(grads, err) -> (mean, new_err)`` over stacked trees.

    Both ``grads`` and ``err`` carry a leading pod axis (length = the mesh
    axis size) and are sharded over ``axis_name``; build ``err`` as
    ``init_error_state`` of the stacked gradients.  The mean comes back
    replicated; the residuals stay PER-POD (sharded over ``axis_name``) —
    each pod's next round folds in its own residual, which is what makes
    the error-feedback accumulation argument hold.
    """

    def fn(grads: PyTree, err: PyTree):
        red, new_err = compressed_pod_mean(
            jax.tree.map(lambda g: g[0], grads),
            jax.tree.map(lambda e: e[0], err),
            axis_name,
        )
        return red, jax.tree.map(lambda e: e[None], new_err)

    return shard_map(
        fn,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name)),
        out_specs=(P(), P(axis_name)),
        check_rep=False,
    )
