"""Sharding plans: which mesh axes carry which kind of parallelism.

A ``ShardingPlan`` names the mesh axes for the four parallelism kinds the
codebase uses:

  dp    data parallelism — the batch axis of inputs/activations.
  fsdp  parameter/optimizer-state sharding (ZeRO-style); usually the same
        axes as ``dp``, extended with 'pod' for models that do not fit HBM.
  tp    tensor parallelism — the hidden/vocab axis of matmul weights.
  ep    expert parallelism — the expert axis of MoE weights/buffers.

Model code never builds shardings directly.  The launch layer activates a
plan (plus a table of named activation PartitionSpecs) with ``use_plan``;
inside that context :func:`constrain` attaches ``with_sharding_constraint``
to the named activations.  Outside any plan — CPU smoke tests, benchmarks,
single-host runs — ``constrain`` is an EXACT no-op (returns its argument
unchanged, inserts nothing into the jaxpr), which is what lets the same
model code run everywhere.

The active plan lives in a ``contextvars.ContextVar`` so nesting and
re-entrancy behave like lexical scoping, including across exceptions.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math
from typing import Any, Iterator, Mapping

import jax
from jax.sharding import AbstractMesh, NamedSharding, PartitionSpec as P

PyTree = Any

AxisNames = Any  # str | tuple[str, ...]


def abstract_mesh(axis_sizes: tuple[int, ...], axis_names: tuple[str, ...]):
    """Version-portable ``AbstractMesh`` constructor.

    jax <= 0.4.x takes a tuple of ``(name, size)`` pairs; newer releases take
    ``(axis_sizes, axis_names)``.  Tests and the dry-run build fake
    production-shape meshes through this so divisibility rules can be checked
    without 512 devices.
    """
    try:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    except TypeError:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Mesh + axis assignment for dp/fsdp/tp/ep parallelism."""

    mesh: Any  # Mesh or AbstractMesh
    dp: tuple[str, ...] = ("data",)
    fsdp: tuple[str, ...] = ("data",)
    tp: AxisNames = "model"
    ep: tuple[str, ...] = ("data",)

    def axis_size(self, axes: AxisNames) -> int:
        """Total number of shards over ``axes`` (a name or tuple of names)."""
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return math.prod(self.mesh.shape[a] for a in axes)

    @property
    def dp_size(self) -> int:
        return self.axis_size(self.dp)

    @property
    def fsdp_size(self) -> int:
        return self.axis_size(self.fsdp)

    @property
    def tp_size(self) -> int:
        return self.axis_size(self.tp)

    @property
    def ep_size(self) -> int:
        return self.axis_size(self.ep)


# ---------------------------------------------------------------------------
# Active-plan context
# ---------------------------------------------------------------------------

_ACTIVE: contextvars.ContextVar[tuple[ShardingPlan, Mapping[str, P]] | None] = (
    contextvars.ContextVar("repro_dist_active_plan", default=None)
)


def current_plan() -> ShardingPlan | None:
    """The innermost active plan, or None outside every ``use_plan``."""
    active = _ACTIVE.get()
    return active[0] if active is not None else None


def current_act_specs() -> Mapping[str, P]:
    """The activation-spec table of the innermost active plan ({} if none)."""
    active = _ACTIVE.get()
    return active[1] if active is not None else {}


@contextlib.contextmanager
def use_plan(plan: ShardingPlan,
             act_specs: Mapping[str, P] | None = None) -> Iterator[ShardingPlan]:
    """Activate ``plan`` (with named activation specs) for the dynamic extent
    of the block.  Nests: the previous plan is restored on exit, also on
    exceptions."""
    token = _ACTIVE.set((plan, dict(act_specs or {})))
    try:
        yield plan
    finally:
        _ACTIVE.reset(token)


def _divisible_spec(shape: tuple[int, ...], spec: P, plan: ShardingPlan) -> P | None:
    """Drop spec entries whose axis product does not divide the dim.

    ``with_sharding_constraint`` rejects uneven shardings; activation names
    are shared across shapes (e.g. 'attn_q' applies to both the q-block and
    kv-block layouts), so per-dim divisibility is resolved at constrain time.
    Returns None when the spec has nothing to say about this shape.
    """
    entries = tuple(spec)
    if len(entries) != len(shape):
        return None
    fitted = []
    for dim, entry in zip(shape, entries):
        if entry is None or dim % plan.axis_size(entry) != 0:
            fitted.append(None)
        else:
            fitted.append(entry)
    if all(e is None for e in fitted):
        return None
    return P(*fitted)


def constrain(x: jax.Array, name: str) -> jax.Array:
    """Attach the activation sharding registered under ``name``, if any.

    Exact identity (the very same object, nothing added to the trace) when
    no plan is active, the name is not in the plan's spec table, or the spec
    cannot legally apply to ``x``'s shape.
    """
    active = _ACTIVE.get()
    if active is None:
        return x
    plan, specs = active
    spec = specs.get(name)
    if spec is None:
        return x
    fitted = _divisible_spec(tuple(x.shape), spec, plan)
    if fitted is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(plan.mesh, fitted))
