"""Divisibility-aware PartitionSpec inference for parameter/state/batch/cache
trees.

Rules are SUFFIX rules over '/'-joined tree paths, so the same table covers
``params/...``, ``opt_state/momentum/...`` and ``div_state/grad_sum/...``
leaves — optimizer and diversity accumulators shard exactly like the
parameters they mirror.  Stacked block parameters carry a leading repeat
axis (always replicated); rules therefore address TRAILING dims.

Every axis assignment goes through :func:`_fit_axes`, which returns the
largest-product subset of the candidate mesh axes that divides the dim —
an indivisible dim degrades to replication instead of erroring, and a
multi-axis group like ``("pod", "data")`` factorises (a dim divisible by
the 'data' size but not by pod*data still gets the 16-way shard).

Layout summary (all subject to divisibility):

  column-parallel kernels  (.., d_in, d_out)   d_in -> fsdp, d_out -> tp
  row-parallel kernels     (.., d_in, d_out)   d_in -> tp,   d_out -> fsdp
  lm_head kernel           (d, V)              V -> tp, d replicated
  embedding                (V, d)              V -> fsdp, d -> tp
  MoE expert weights       (.., E, d, ff)      E -> ep, contraction dim
                                               replicated, other -> tp
  Mamba channel params     (.., d_inner, ..)   d_inner -> tp
  norms / biases / scalars                     replicated

Batch leaves shard their leading dim over dp.  KV-cache leaves shard batch
over dp (falling back to the SEQUENCE dim for batch-1 long-context decode)
and kv-heads over tp (falling back to head_dim when kv_heads < tp size).
"""

from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.plan import AxisNames, ShardingPlan
from repro.utils import pytree as ptu

PyTree = Any


def _fit_axes(dim: int, axes: AxisNames, plan: ShardingPlan):
    """Largest-product subset of ``axes`` whose shard count divides ``dim``.

    Returns a PartitionSpec entry: a single axis name, a tuple of names
    (order preserved), or None when nothing divides.  Ties prefer the
    earliest subset, so a single exact axis beats an equal-product pair.
    """
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(a for a in axes if a is not None)
    best: tuple[str, ...] = ()
    best_prod = 1
    n = len(axes)
    for mask in range(1, 1 << n):
        subset = tuple(axes[i] for i in range(n) if (mask >> i) & 1)
        prod = math.prod(plan.mesh.shape[a] for a in subset)
        if prod > best_prod and dim > 0 and dim % prod == 0:
            best, best_prod = subset, prod
    if not best:
        return None
    return best[0] if len(best) == 1 else best


# ---------------------------------------------------------------------------
# Parameter / optimizer-state rules
# ---------------------------------------------------------------------------

# Kernels whose OUTPUT dim carries tp (input dim carries fsdp).
_COLUMN_PARALLEL = (
    "attn/q/kernel",
    "attn/k/kernel",
    "attn/v/kernel",
    "ffn/w_gate/kernel",
    "ffn/w_up/kernel",
    "ffn/w_in/kernel",
    "mamba/in_proj/kernel",
    "mamba/dt_proj/kernel",
    "frontend/kernel",
)

# Kernels whose INPUT dim carries tp (output dim carries fsdp).
_ROW_PARALLEL = (
    "attn/o/kernel",
    "ffn/w_out/kernel",
    "mamba/out_proj/kernel",
)

# Mamba per-channel params: the trailing-dims position of d_inner.
_MAMBA_CHANNEL = {
    "mamba/A_log": -2,       # (d_inner, d_state)
    "mamba/x_proj/kernel": -2,  # (d_inner, dt_rank + 2*d_state)
    "mamba/D": -1,           # (d_inner,)
    "mamba/conv_kernel": -1,  # (K, d_inner)
    "mamba/conv_bias": -1,   # (d_inner,)
}

# MoE expert tensors are raw (E, d_in, d_out) arrays (no '/kernel' level):
# expert axis -> ep, contraction dim replicated, the other matmul dim -> tp.
_MOE_EXPERT = {
    "ffn/w_gate": (-3, -1),  # (E, d, ff): shard ff
    "ffn/w_up": (-3, -1),
    "ffn/w_out": (-3, -2),   # (E, ff, d): shard ff
}


def _param_entries(path: str, shape: tuple[int, ...],
                   plan: ShardingPlan) -> list:
    nd = len(shape)
    ent: list = [None] * nd

    def fit(i: int, axes: AxisNames) -> None:
        if -nd <= i < nd:
            ent[i] = _fit_axes(shape[i], axes, plan)

    for suffix, (ep_i, tp_i) in _MOE_EXPERT.items():
        if path.endswith(suffix):
            fit(ep_i, plan.ep)
            fit(tp_i, plan.tp)
            return ent
    for suffix, tp_i in _MAMBA_CHANNEL.items():
        if path.endswith(suffix):
            fit(tp_i, plan.tp)
            return ent
    if path.endswith("lm_head/kernel"):
        fit(-1, plan.tp)
        return ent
    if path.endswith("embed/embedding"):
        fit(-2, plan.fsdp)
        fit(-1, plan.tp)
        return ent
    if any(path.endswith(s) for s in _COLUMN_PARALLEL) and nd >= 2:
        fit(-2, plan.fsdp)
        fit(-1, plan.tp)
        return ent
    if any(path.endswith(s) for s in _ROW_PARALLEL) and nd >= 2:
        fit(-2, plan.tp)
        fit(-1, plan.fsdp)
        return ent
    # norms, biases, router, scalar counters: replicated
    return ent


def infer_pspecs(tree: PyTree, plan: ShardingPlan) -> PyTree:
    """PartitionSpec tree for a parameter or whole-train-state tree.

    Leaves are anything with ``.shape`` (arrays or ShapeDtypeStructs); the
    result has one ``PartitionSpec`` per leaf.
    """

    def rule(path: str, leaf) -> P:
        return P(*_param_entries(path, tuple(leaf.shape), plan))

    return ptu.tree_map_with_path(rule, tree)


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------


def batch_pspecs(specs: PyTree, plan: ShardingPlan) -> PyTree:
    """Input batches shard their leading (sample) dim over the dp axes."""

    def rule(path: str, leaf) -> P:
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        ent: list = [None] * len(shape)
        ent[0] = _fit_axes(shape[0], plan.dp, plan)
        return P(*ent)

    return ptu.tree_map_with_path(rule, specs)


def cache_pspecs(cache: PyTree, plan: ShardingPlan) -> PyTree:
    """KV/SSM decode-cache sharding.

    KV leaves are (..., B, S, KV, hd): batch -> dp, but a batch-1
    long-context cache falls back to sharding the sequence dim over dp
    (the cache IS the footprint there); kv_heads -> tp, falling back to
    head_dim when the head count is smaller than the tp degree.
    Mamba state leaves shard batch -> dp and d_inner -> tp.

    The serve block pool (``models/transformer.init_pages``) rides the same
    k/v rule: a pool leaf is (repeats, num_blocks, block, kv, hd), so the
    trailing-4 convention lands the BLOCK axis on dp (the pool's capacity
    dim, pow2-sized by the engine so it always fits the mesh) and kv heads
    on tp — block-table gathers/scatters then address dp-local shards.
    """

    def rule(path: str, leaf) -> P:
        shape = tuple(leaf.shape)
        nd = len(shape)
        if nd == 0:
            return P()
        ent: list = [None] * nd
        last = path.rsplit("/", 1)[-1]
        if last in ("k", "v") and nd >= 4:
            b_i, s_i, kv_i, hd_i = nd - 4, nd - 3, nd - 2, nd - 1
            dp = _fit_axes(shape[b_i], plan.dp, plan)
            if dp is not None:
                ent[b_i] = dp
            else:
                ent[s_i] = _fit_axes(shape[s_i], plan.dp, plan)
            tp = _fit_axes(shape[kv_i], plan.tp, plan)
            if tp is not None:
                ent[kv_i] = tp
            else:
                ent[hd_i] = _fit_axes(shape[hd_i], plan.tp, plan)
        elif last == "h" and nd >= 3:  # (..., B, d_inner, d_state)
            ent[nd - 3] = _fit_axes(shape[nd - 3], plan.dp, plan)
            ent[nd - 2] = _fit_axes(shape[nd - 2], plan.tp, plan)
        elif last == "conv" and nd >= 3:  # (..., B, K-1, d_inner)
            ent[nd - 3] = _fit_axes(shape[nd - 3], plan.dp, plan)
            ent[nd - 1] = _fit_axes(shape[nd - 1], plan.tp, plan)
        return P(*ent)

    return ptu.tree_map_with_path(rule, cache)


def shardings_of(pspecs: PyTree, plan: ShardingPlan) -> PyTree:
    """NamedShardings on the plan's mesh for a PartitionSpec tree."""
    return jax.tree.map(
        lambda spec: NamedSharding(plan.mesh, spec),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
