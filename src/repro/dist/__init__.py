"""Distribution layer: sharding plans, PartitionSpec inference, compression.

``plan``         ShardingPlan (mesh + dp/fsdp/tp/ep axis assignment), the
                 ``use_plan`` context and the ``constrain`` activation hook
                 that models call without knowing whether a plan is active.
``sharding``     divisibility-aware PartitionSpec inference for parameter /
                 optimizer-state / batch / KV-cache trees.
``compression``  error-feedback int8 gradient compression and the compressed
                 cross-pod mean used on DCN-connected meshes.
"""

from repro.dist import compression, plan, sharding
from repro.dist.plan import (
    ShardingPlan,
    abstract_mesh,
    constrain,
    current_plan,
    use_plan,
)
from repro.dist.sharding import (
    batch_pspecs,
    cache_pspecs,
    infer_pspecs,
    shardings_of,
)

__all__ = [
    "ShardingPlan",
    "abstract_mesh",
    "constrain",
    "current_plan",
    "use_plan",
    "infer_pspecs",
    "batch_pspecs",
    "cache_pspecs",
    "shardings_of",
    "plan",
    "sharding",
    "compression",
]
