"""DiveBatch-JAX: gradient-diversity-aware adaptive batch sizing
(Chen, Wang & Sundaram 2025) as a multi-pod JAX training/inference framework.

Subpackages:
  core     the paper's contribution: diversity estimators + batch policies
  adapt    signal-driven adaptation: policies/combinators/program, the
           single path for batch/lr/estimator/rung decisions (epoch ends,
           every-k-steps ticks, injected events)
  models   transformer zoo (dense/GQA, MoE, Mamba, hybrid, encoder), resnet
  optim    SGD+momentum / AdamW / schedules
  data     synthetic datasets + resumable sharded loaders
  dist     sharding plans/rules, gradient compression
  elastic  mesh ladder + exact resharding: device footprint tracks batch size
  train    production train step + host training loop
  serve    batched prefill/decode engine
  ckpt     atomic sharded checkpoints
  kernels  Pallas TPU kernels (per-sample grad norms, int8 quant)
  configs  the 10 assigned architectures
  launch   mesh, multi-pod dry-run, CLIs, fault-tolerance supervisor
"""

__version__ = "1.0.0"
