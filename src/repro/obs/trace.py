"""Span tracer: the one timeline every subsystem emits into.

``Tracer.span("compile", bucket=4, rung=1)`` is a context manager recording
one Chrome/Perfetto *complete* event (``ph="X"``) per exit — host wall-time
spans for the decisions the stack makes at runtime (compiles, dispatches,
reshards, prefill chunks, decode steps, adaptation boundaries).  The export
(:meth:`Tracer.save`) is the trace-event JSON Perfetto / ``chrome://tracing``
load directly: ``{"traceEvents": [...], "displayTimeUnit": "ms"}``.

Design constraints, in order:

  * **A disabled tracer is a strict no-op.**  ``NULL`` (the module-level
    :class:`NullTracer`) returns one shared, stateless span object and never
    touches its arguments — no allocation, no clock read, no host transfer.
    Hot loops additionally guard on ``tracer.enabled`` so the disabled path
    costs one attribute load and a branch per step (the overhead guard in
    ``tests/test_obs.py`` pins both properties).
  * **Thread-safe.**  Spans carry ``threading.get_ident()`` as their ``tid``
    and the event list is appended under a lock — the prefetch producer
    thread and the main loop interleave on one timeline.
  * **Device alignment (optional).**  ``Tracer(jax_annotate=True)`` bridges
    every span into ``jax.profiler.TraceAnnotation`` — and spans carrying a
    ``step_num`` arg into ``jax.profiler.StepTraceAnnotation`` — so a device
    profile collected with ``jax.profiler.trace`` lines up step-for-step
    with the host spans.  The import is lazy: this module stays jax-free so
    jax-free hosts (``serve/blocks.py``) can emit into it.

``SCHEMA_VERSION`` is pinned by the trace schema test; it rides in the
export's ``otherData`` next to ``wall_origin`` (the wall-clock time of the
tracer's ts=0), which lets ``launch/monitor.py`` merge run-log events onto
the same timeline.
"""

from __future__ import annotations

import json
import os
import threading
import time

#: version of the exported trace layout (pinned in tests/test_obs.py)
SCHEMA_VERSION = 1


def jsonable(o):
    """JSON default= hook: numpy scalars -> python, everything else -> str."""
    item = getattr(o, "item", None)
    if callable(item):
        try:
            return item()
        except Exception:
            pass
    return str(o)


class _NullSpan:
    """The shared do-nothing span (one instance per process)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every call is a strict no-op (see module docstring)."""

    __slots__ = ()
    enabled = False

    def span(self, name, **args):
        return _NULL_SPAN

    def instant(self, name, **args):
        return None

    def to_json(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms",
                "otherData": {"schema": SCHEMA_VERSION}}

    def save(self, path) -> None:
        return None


#: the process-wide disabled tracer — the default everywhere
NULL = NullTracer()


class _Span:
    """One live span: records a ``ph="X"`` complete event on exit."""

    __slots__ = ("_tracer", "_name", "_args", "_t0", "_ann")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self._name = name
        self._args = args
        self._ann = None

    def __enter__(self):
        tr = self._tracer
        if tr._annotate:
            from jax import profiler  # lazy: keep the module jax-free

            step = self._args.get("step_num")
            self._ann = (
                profiler.StepTraceAnnotation(self._name, step_num=int(step))
                if step is not None
                else profiler.TraceAnnotation(self._name)
            )
            self._ann.__enter__()
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        if self._ann is not None:
            self._ann.__exit__(*exc)
        self._tracer._complete(self._name, self._args, self._t0, t1)
        return False


class Tracer:
    """In-memory span/instant recorder with Chrome trace-event export."""

    enabled = True

    def __init__(self, *, jax_annotate: bool = False):
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._origin_ns = time.perf_counter_ns()
        #: wall-clock time of ts=0 (lets the monitor align run-log events)
        self.wall_origin = time.time()
        self._pid = os.getpid()
        self._annotate = bool(jax_annotate)
        self._named_threads: set[int] = set()

    # -- recording -----------------------------------------------------------
    def _ts(self, t_ns: int) -> float:
        """Microseconds since tracer start (the trace-event time unit)."""
        return (t_ns - self._origin_ns) / 1_000.0

    def _name_thread(self, tid: int) -> None:
        if tid in self._named_threads:
            return
        self._named_threads.add(tid)
        self._events.append({
            "ph": "M", "name": "thread_name", "ts": 0.0,
            "pid": self._pid, "tid": tid,
            "args": {"name": threading.current_thread().name},
        })

    def _complete(self, name: str, args: dict, t0: int, t1: int) -> None:
        tid = threading.get_ident()
        ev = {
            "ph": "X", "name": name, "ts": self._ts(t0),
            "dur": max((t1 - t0) / 1_000.0, 0.001),
            "pid": self._pid, "tid": tid, "args": args,
        }
        with self._lock:
            self._name_thread(tid)
            self._events.append(ev)

    def span(self, name: str, **args) -> _Span:
        """Context manager recording one complete event when it exits."""
        return _Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        """Point-in-time event (``ph="i"``, thread-scoped)."""
        tid = threading.get_ident()
        ev = {
            "ph": "i", "name": name, "ts": self._ts(time.perf_counter_ns()),
            "s": "t", "pid": self._pid, "tid": tid, "args": args,
        }
        with self._lock:
            self._name_thread(tid)
            self._events.append(ev)

    # -- export --------------------------------------------------------------
    @property
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def to_json(self) -> dict:
        return {
            "traceEvents": self.events,
            "displayTimeUnit": "ms",
            "otherData": {
                "schema": SCHEMA_VERSION,
                "wall_origin": self.wall_origin,
                "pid": self._pid,
            },
        }

    def save(self, path: str) -> str:
        """Write the Perfetto-loadable ``trace.json``; returns the path.

        If ``path`` is a directory the file is ``<path>/trace.json``."""
        if os.path.isdir(path) or path.endswith(os.sep):
            path = os.path.join(path, "trace.json")
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, default=jsonable)
        return path
