"""Schema-versioned JSONL run log: the durable record of one run.

A :class:`RunLog` is an append-only JSONL file (conventionally
``runs/<name>/runlog.jsonl``) of *typed events*: every record carries the
schema version ``v``, a ``kind`` from :data:`EVENTS`, a wall-clock ``t``,
and the kind's required fields (validated at emit time, so a malformed
event fails at the write site, not in the reader).  The log captures what
the in-memory stats cannot — the *sequence* of runtime decisions:

  * per-boundary scalars — loss, diversity, GNS, batch size, lr, rung,
    throughput (``epoch`` / ``decision`` events);
  * every adapt ``Applied`` decision, reshard, compile, checkpoint,
    injected event, and supervisor restart, each as its own kind — a
    cross-rung failure/restart is reconstructable from this one file.

``launch/monitor.py`` is the reader: it tails a run log, prints per-epoch /
per-window summary tables, and rebuilds the full batch-size/rung/lr
schedule from the decision stream.  :data:`NULL` is the disabled sink
(``emit`` is a no-op); hot paths guard on ``runlog.enabled`` exactly like
the tracer.
"""

from __future__ import annotations

import json
import math
import os
import threading
import time

from repro.obs.trace import jsonable

#: run-log record layout version (pinned by tests/test_obs.py; the reader
#: rejects records from a NEWER schema instead of misparsing them)
SCHEMA_VERSION = 1

#: typed event kinds -> required fields (extra fields always allowed)
EVENTS = {
    # run lifecycle
    "run_start": ("run",),
    "restart": ("restarts", "epoch"),
    "checkpoint": ("epoch", "step"),
    "inject": ("name",),
    # training boundaries
    "epoch": ("epoch", "steps", "batch_size", "lr", "loss"),
    "decision": ("epoch", "step", "boundary", "batch_size", "lr"),
    # engine events (scope: "train" | "serve")
    "compile": ("scope", "what", "seconds"),
    "reshard": ("scope", "src", "dst"),
    # pod supervision (repro.pod): a host loss degrades the ladder in place
    "pod_lost": ("pod", "epoch"),
    "demote": ("src", "dst", "pods"),
    # serving
    "serve_admit": ("rid", "prompt_len", "budget"),
    "serve_retire": ("rid", "pos"),
    "serve_window": ("step", "tokens", "tokens_per_sec", "live"),
    # a ServePolicy decision the engine actually applied (serve/policy.py):
    # reordered admission, a slot-budget cap, or a shrink-patience change
    "serve_policy": ("step", "reason"),
}


def _clean(v):
    """JSON-safe scalar: non-finite floats become null (json.dumps would
    otherwise emit bare NaN, which strict readers reject)."""
    if isinstance(v, float) and not math.isfinite(v):
        return None
    return v


class NullRunLog:
    """Disabled run log: ``emit`` is a strict no-op."""

    __slots__ = ()
    enabled = False

    def emit(self, kind: str, /, **fields) -> None:
        return None

    def close(self) -> None:
        return None


#: the process-wide disabled run log — the default everywhere
NULL = NullRunLog()


class RunLog:
    """Append-only JSONL event writer (line-buffered, thread-safe)."""

    enabled = True

    def __init__(self, path: str, *, meta: dict | None = None):
        if os.path.isdir(path) or path.endswith(os.sep):
            path = os.path.join(path, "runlog.jsonl")
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        self.path = path
        self._f = open(path, "w", buffering=1)
        self._lock = threading.Lock()
        self.emit("run_start", run=dict(meta or {}))

    def emit(self, kind: str, /, **fields) -> None:
        """Write one typed event (validates kind + required fields).  The
        event kind is positional-only so fields named ``kind`` etc. stay
        usable — but the record envelope keys themselves are reserved."""
        spec = EVENTS.get(kind)
        if spec is None:
            raise ValueError(
                f"unknown run-log event kind {kind!r}; known: {sorted(EVENTS)}"
            )
        missing = [f for f in spec if f not in fields]
        if missing:
            raise ValueError(f"event {kind!r} missing required fields {missing}")
        clash = {"v", "kind", "t"} & fields.keys()
        if clash:
            raise ValueError(f"field names {sorted(clash)} are reserved "
                             f"(record envelope keys)")
        rec = {"v": SCHEMA_VERSION, "kind": kind, "t": time.time()}
        rec.update((k, _clean(v)) for k, v in fields.items())
        line = json.dumps(rec, default=jsonable)
        with self._lock:
            if self._f.closed:
                return
            self._f.write(line + "\n")

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def read_runlog(path: str) -> list[dict]:
    """Parse a run log back into its event records.

    Accepts a ``runs/<name>`` directory or the JSONL path itself.  Raises on
    records written by a NEWER schema version; blank lines are skipped (a
    torn final line from a crashed writer raises — the log is evidence)."""
    if os.path.isdir(path):
        path = os.path.join(path, "runlog.jsonl")
    events: list[dict] = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            v = int(rec.get("v", 0))
            if v > SCHEMA_VERSION:
                raise ValueError(
                    f"{path}:{i + 1}: run-log schema v{v} is newer than this "
                    f"reader (v{SCHEMA_VERSION})"
                )
            events.append(rec)
    return events
