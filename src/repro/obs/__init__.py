"""repro.obs: the one telemetry path every subsystem emits into.

Three surfaces, one discipline:

  * :mod:`repro.obs.trace` — thread-safe span tracer with Chrome/Perfetto
    trace-event export (host spans; optional jax profiler bridge).
  * :mod:`repro.obs.metrics` — process-wide registry of counters / gauges /
    histograms; ``EngineStats`` / ``ServeStats`` are emitting views over it.
  * :mod:`repro.obs.runlog` — schema-versioned JSONL run log of typed
    events (epoch boundaries, adapt decisions, compiles, reshards,
    checkpoints, restarts) under ``runs/<name>/``.

Everything defaults to the disabled null objects (``trace.NULL``,
``runlog.NULL``) — a strict no-op — so instrumented hot paths cost one
attribute load and a branch when telemetry is off.
"""

import os

from repro.obs import metrics, runlog, trace
from repro.obs.metrics import REGISTRY, Counter, Gauge, Histogram, Registry, StatsView
from repro.obs.runlog import NullRunLog, RunLog, read_runlog
from repro.obs.trace import NullTracer, Tracer

def from_cli(trace_dir: str | None, runlog_path: str | None, *,
             meta: dict | None = None):
    """Build ``(tracer, runlog)`` from the launch CLIs' ``--trace DIR`` /
    ``--runlog [PATH]`` flag values.

    ``trace_dir`` enables tracing (the dir is created so a later
    ``tracer.save(trace_dir)`` lands at ``DIR/trace.json``); ``runlog_path``
    enables the run log — the empty string (bare ``--runlog``) means
    ``<trace_dir>/runlog.jsonl``.  Disabled sinks come back as ``None`` so
    callers can skip save/close; pass them straight to Trainer/ServeEngine,
    whose ``None`` default is the null sink."""
    tracer = None
    if trace_dir is not None:
        os.makedirs(trace_dir, exist_ok=True)
        tracer = Tracer()
    rl = None
    if runlog_path is not None:
        path = runlog_path or trace_dir
        if not path:
            raise ValueError("--runlog without a path requires --trace DIR")
        rl = RunLog(path, meta=meta)
    return tracer, rl


__all__ = [
    "from_cli",
    "trace",
    "metrics",
    "runlog",
    "Tracer",
    "NullTracer",
    "Registry",
    "REGISTRY",
    "Counter",
    "Gauge",
    "Histogram",
    "StatsView",
    "RunLog",
    "NullRunLog",
    "read_runlog",
]
