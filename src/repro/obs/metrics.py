"""Process-wide metrics registry: counters, gauges, histograms.

One :class:`Registry` (the module-level ``REGISTRY``) holds every metric the
process emits, addressed by dotted name.  Stats records that used to be
parallel bookkeeping — ``train.engine.EngineStats``, ``serve.engine
.ServeStats`` — are now *emitting views* over this registry via
:class:`StatsView`: their scalar fields live in registry metrics (each
instance under a unique ``<prefix>.<n>`` namespace), the legacy attribute
surface (``stats.compiles += 1``, ``stats.as_dict()``) is preserved
verbatim, and ``REGISTRY.snapshot()`` sees every engine in the process at
once.  The equivalence test in ``tests/test_obs.py`` pins each legacy field
against its registry entry so no bench/test consumer changes.

Counters carry monotonically-accumulated values (ints by convention),
gauges carry last-written values, histograms carry count/total/min/max plus
the last value.  Writes are GIL-atomic single-attribute stores; the registry
itself locks only metric creation.
"""

from __future__ import annotations

import itertools
import threading


class Counter:
    """Accumulated value: ``inc(n)`` adds, ``set(v)`` overwrites (the
    ``stats.field += 1`` surface reads then sets)."""

    __slots__ = ("name", "_v")

    def __init__(self, name: str):
        self.name = name
        self._v = 0

    def inc(self, n=1) -> None:
        self._v += n

    def set(self, v) -> None:
        self._v = v

    @property
    def value(self):
        return self._v


class Gauge:
    """Last-written value (floats or config-style ints)."""

    __slots__ = ("name", "_v")

    def __init__(self, name: str):
        self.name = name
        self._v = 0

    def set(self, v) -> None:
        self._v = v

    @property
    def value(self):
        return self._v


class Histogram:
    """Streaming summary: count / total / min / max / last."""

    __slots__ = ("name", "count", "total", "vmin", "vmax", "last")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.vmin = None
        self.vmax = None
        self.last = None

    def observe(self, v) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.vmin = v if self.vmin is None else min(self.vmin, v)
        self.vmax = v if self.vmax is None else max(self.vmax, v)
        self.last = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> dict:
        return {"count": self.count, "total": self.total, "mean": self.mean,
                "min": self.vmin, "max": self.vmax, "last": self.last}


class Registry:
    """Name -> metric map; get-or-create, type-checked."""

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()
        self._seq = itertools.count()

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name)
            elif type(m) is not cls:
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}"
                )
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def get(self, name: str):
        return self._metrics.get(name)

    def unique_namespace(self, prefix: str) -> str:
        """A fresh per-instance namespace like ``train.engine.3`` — each
        StatsView claims one so engines in the same process never collide."""
        return f"{prefix}.{next(self._seq)}"

    def snapshot(self) -> dict:
        """Flat ``{name: value}`` view (histograms expand to summaries)."""
        with self._lock:
            items = list(self._metrics.items())
        return {
            name: m.summary() if isinstance(m, Histogram) else m.value
            for name, m in items
        }

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


#: the process-wide registry every subsystem emits into
REGISTRY = Registry()


class StatsView:
    """Back a stats object's scalar fields by registry metrics.

    Subclasses declare ``_COUNTERS`` (accumulated ints) and ``_GAUGES``
    (last-written scalars); ``_init_metrics`` registers each under the
    instance namespace.  Attribute reads/writes on those names route to the
    registry — every other attribute (bools, lists) behaves normally, so the
    legacy dataclass surface (``+=``, ``.append``, ``as_dict``) is
    unchanged.
    """

    _COUNTERS: tuple[str, ...] = ()
    _GAUGES: tuple[str, ...] = ()

    def _init_metrics(self, prefix: str, registry: Registry | None = None) -> None:
        reg = registry if registry is not None else REGISTRY
        ns = reg.unique_namespace(prefix)
        fields = {}
        for f in self._COUNTERS:
            fields[f] = reg.counter(f"{ns}.{f}")
        for f in self._GAUGES:
            fields[f] = reg.gauge(f"{ns}.{f}")
        self.registry = reg
        self.namespace = ns
        # set last: __setattr__ routes through _metrics once it exists
        self._metrics = fields

    def __getattr__(self, name):
        m = object.__getattribute__(self, "__dict__").get("_metrics")
        if m is not None and name in m:
            return m[name].value
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {name!r}"
        )

    def __setattr__(self, name, value):
        m = self.__dict__.get("_metrics")
        if m is not None and name in m:
            m[name].set(value)
        else:
            object.__setattr__(self, name, value)

    def metric_dict(self) -> dict:
        """The registry-backed scalar fields, by field name."""
        return {f: self._metrics[f].value for f in (*self._COUNTERS, *self._GAUGES)}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        body = ", ".join(f"{k}={v}" for k, v in self.metric_dict().items())
        return f"{type(self).__name__}({body})"
