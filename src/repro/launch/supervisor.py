"""Fault-tolerance supervisor: runs training with failure injection and
checkpoint/restart, verifying trajectory continuity.

At cluster scale this process would watch worker heartbeats and relaunch the
SPMD job from the latest checkpoint on any failure; here it exercises exactly
that logic in-process (the restart path is identical: fresh Trainer +
``resume()``), plus a step-time watchdog for straggler detection.

  python -m repro.launch.supervisor --epochs 12 --fail-at 4 --fail-at 8

With ``--elastic`` the job runs on a ``repro.elastic`` MeshLadder: a failure
injected after the batch has grown restarts onto a DIFFERENT (wider) rung —
the checkpoint is topology-free and the resumed Trainer re-derives its rung
from the restored batch size.

  python -m repro.launch.supervisor --epochs 6 --fail-at 3 --elastic

With ``--pods N`` the job runs on a ``repro.pod.PodLadder`` (cross-pod rungs
move compressed gradients) and ``--lose-pod EPOCH[:POD]`` injects a HOST
loss: instead of crash + checkpoint restore, the supervisor marks the pod
unhealthy and DEMOTES — the surviving state reshards onto the widest
all-healthy rung and training carries straight on (typed ``pod_lost`` /
``demote`` run-log events record it).

  python -m repro.launch.supervisor --epochs 6 --pods 2 --lose-pod 3
"""

from __future__ import annotations

import argparse
import contextlib
import time

import numpy as np

from repro.utils.logging import get_logger

# NOTE: nothing at module level may *initialize* the jax backend: main()
# forces the CPU host-device count via XLA_FLAGS, which must be set before
# the first device use in the process (repro.ckpt is imported lazily in
# run_supervised for the same reason).

log = get_logger("supervisor")


class InjectedFailure(RuntimeError):
    pass


class Watchdog:
    """Step-time z-score straggler detector.

    ``on_flag(step, z)`` (optional) fires on every flag — the supervisor
    wires it to ``Trainer.inject_event('straggler')``, which the
    ``repro.adapt`` program observes as an ``event`` boundary BETWEEN steps:
    an event-responsive policy can resize the batch / evacuate to a
    narrower elastic rung mid-epoch instead of waiting for the epoch end.
    """

    def __init__(self, window: int = 20, z_thresh: float = 4.0, on_flag=None):
        self.times: list[float] = []
        self.window = window
        self.z_thresh = z_thresh
        self.flagged: list[tuple[int, float]] = []
        self.on_flag = on_flag

    def observe(self, step: int, dt: float):
        self.times.append(dt)
        hist = self.times[-self.window :]
        prev = hist[:-1]
        # Degenerate windows: a z-score needs at least 2 prior observations
        # for a spread.  Keep the historical warm-up (first check at the 5th
        # observation) where the window allows it, but small windows
        # (window < 5) now fire too instead of never.
        if len(prev) < max(2, min(4, self.window - 1)):
            return
        mu, sd = float(np.mean(prev)), float(np.std(prev))
        if sd <= 0.0:
            # Constant history: any deviation is infinitely many sigmas out.
            # Floor the spread relative to the mean so equal step times give
            # z = 0 and a genuine spike still flags, while epsilon-level
            # jitter (the old +1e-9 epsilon made ANY 4ns deviation a
            # "straggler") does not.
            sd = max(abs(mu), 1e-9) * 1e-3
        z = (dt - mu) / sd
        if z > self.z_thresh:
            self.flagged.append((step, z))
            log.warning("straggler: step %d took %.3fs (z=%.1f)", step, dt, z)
            if self.on_flag is not None:
                self.on_flag(step, z)


def _normalize_losses(lose_pod) -> list[tuple[int, int | None]]:
    """``lose_pod`` items are epochs or ``(epoch, pod)`` pairs; None pod
    means "the last pod" (resolved against the live topology)."""
    out: list[tuple[int, int | None]] = []
    for item in lose_pod or []:
        if isinstance(item, (tuple, list)):
            e, p = item
            out.append((int(e), int(p)))
        else:
            out.append((int(item), None))
    return out


def run_supervised(make_trainer, total_epochs: int, fail_at: list[int],
                   ckpt_dir: str, max_restarts: int = 10,
                   tracer=None, runlog=None, lose_pod=None) -> list:
    """``make_trainer(ckpt_manager)`` builds a fresh Trainer bound to the
    checkpoint directory. Failures are injected at the given epochs; each
    crash is answered with a rebuild + resume. Returns the final history.

    ``lose_pod`` injects HOST losses (epochs, or ``(epoch, pod)`` pairs) on
    a ``repro.pod.PodLadder`` trainer: instead of the crash/restart path,
    the pod is marked unhealthy and the trainer DEMOTES — the surviving
    state is resharded onto the widest all-healthy rung with no checkpoint
    restore (``pod_lost`` + ``demote`` run-log events mark it).  Losses
    survive process restarts: a rebuilt ladder is re-marked before resume.

    ``tracer``/``runlog`` (repro.obs) are rebound onto every rebuilt Trainer
    and each (re)start is emitted as a typed ``restart`` event — one trace
    and one run log span the whole supervised run, so a cross-rung restart
    is reconstructable from the single file (restarts=0 marks the initial
    start)."""
    from repro.ckpt import CheckpointManager

    restarts = 0
    pending_failures = set(fail_at)
    pending_losses = _normalize_losses(lose_pod)
    lost_pods: set[int] = set()
    while True:
        mgr = CheckpointManager(ckpt_dir, keep=3)
        trainer = make_trainer(mgr)
        if tracer is not None or runlog is not None:
            trainer.bind_obs(tracer=tracer, runlog=runlog)
        health = getattr(getattr(trainer, "elastic", None), "health", None)
        if (pending_losses or lost_pods) and health is None:
            raise ValueError(
                "lose_pod injection needs a trainer on a repro.pod.PodLadder "
                "(it has no pod health registry to mark)"
            )
        # a rebuilt trainer has a fresh ladder: re-mark earlier losses BEFORE
        # resume() so the restored rung is already health-filtered
        for p in lost_pods:
            health.mark_lost(p)
        trainer.resume()
        if health is not None and lost_pods:
            # no-checkpoint start (resume was a no-op) may still sit on an
            # unhealthy initial rung; demote() no-ops when already healthy
            trainer.demote(note="pods lost before restart")
        rung = getattr(trainer, "rung", None)
        if runlog is not None and runlog.enabled:
            runlog.emit("restart", restarts=restarts,
                        epoch=trainer.cursor.epoch,
                        batch_size=trainer.adapt.batch_size,
                        rung=rung.index if rung is not None else None)
        if rung is not None:
            # elastic restart: the checkpoint's batch size picked the rung,
            # which after a mid-run failure is NOT the ladder's first one
            log.info("elastic: %s on rung %d (dp=%d)",
                     "restarted" if restarts else "starting",
                     rung.index, rung.dp)
        # straggler flags feed the trainer's adapt program as mid-epoch events
        watchdog = Watchdog(
            on_flag=lambda step, z: trainer.inject_event("straggler")
        )
        try:
            while trainer.cursor.epoch < total_epochs:
                t0 = time.time()
                ep = trainer.cursor.epoch
                if ep in pending_failures:
                    pending_failures.discard(ep)
                    raise InjectedFailure(f"injected at epoch {ep}")
                for e, p in [lp for lp in pending_losses if lp[0] == ep]:
                    pending_losses.remove((e, p))
                    pod = p if p is not None else health.num_pods - 1
                    cur = trainer.rung
                    src_rung = cur.index if cur is not None else None
                    health.mark_lost(pod)
                    lost_pods.add(pod)
                    if runlog is not None and runlog.enabled:
                        runlog.emit("pod_lost", pod=pod, epoch=ep,
                                    rung=src_rung)
                    ctx = (tracer.span("demote", scope="train", pod=pod,
                                       epoch=ep)
                           if tracer is not None else contextlib.nullcontext())
                    with ctx:
                        src_i, dst_i = trainer.demote(note=f"pod {pod} lost")
                    if runlog is not None and runlog.enabled:
                        runlog.emit("demote", src=src_i, dst=dst_i,
                                    pods=trainer.rung.pods,
                                    dp=trainer.rung.dp, epoch=ep)
                    log.warning(
                        "pod %d lost at epoch %d: DEGRADED rung %s -> %s "
                        "(dp=%d), no restart", pod, ep, src_i, dst_i,
                        trainer.rung.dp)
                trainer.run_epoch()
                trainer.save()
                watchdog.observe(trainer.cursor.epoch, time.time() - t0)
            return trainer.history
        except InjectedFailure as e:
            restarts += 1
            log.warning("FAILURE: %s — restarting (%d/%d)", e, restarts, max_restarts)
            if restarts > max_restarts:
                raise


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=12)
    ap.add_argument("--fail-at", type=int, action="append", default=[])
    ap.add_argument("--ckpt-dir", default="runs/supervised")
    ap.add_argument("--method", default="divebatch")
    ap.add_argument("--elastic", action="store_true",
                    help="run on a repro.elastic MeshLadder: a mid-run "
                         "failure after the batch has grown restarts onto a "
                         "DIFFERENT (wider) rung than the run started on")
    ap.add_argument("--pods", type=int, default=0,
                    help="run on a repro.pod.PodLadder spanning N virtual "
                         "pods (cross-pod rungs move compressed gradients); "
                         "implies 8 CPU host devices unless --devices")
    ap.add_argument("--lose-pod", action="append", default=[],
                    metavar="EPOCH[:POD]",
                    help="inject a HOST loss at EPOCH (of pod POD, default "
                         "the last pod): the supervisor DEGRADES onto the "
                         "widest all-healthy rung instead of restarting; "
                         "repeatable")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N CPU host devices (before first jax use; "
                         "--elastic/--pods default to 8 so the ladder has "
                         "rungs)")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="record a Chrome/Perfetto trace (repro.obs) spanning "
                         "every restart; writes DIR/trace.json at exit")
    ap.add_argument("--runlog", default=None, nargs="?", const="",
                    metavar="PATH",
                    help="write the schema-versioned JSONL run log, restarts "
                         "included; bare --runlog means <--trace "
                         "DIR>/runlog.jsonl")
    args = ap.parse_args()

    ndev = args.devices or (8 if (args.elastic or args.pods) else 0)
    if ndev:
        # effective until the first backend init (first device use), which in
        # this process is the trainer build below
        from repro.utils.xla_env import force_host_device_count

        force_host_device_count(ndev)

    import jax

    from repro.adapt import (
        AdaBatchPolicy,
        AdaptationProgram,
        DiveBatchPolicy,
        FixedPolicy,
    )
    from repro.data import sigmoid_synthetic
    from repro.elastic import MeshLadder
    from repro.models import small
    from repro.optim import sgd
    from repro.train.loop import ModelFns, Trainer

    train, val, _ = sigmoid_synthetic(n=4000, d=64, seed=0)

    def make_policy_obj():
        # DiveBatch with on_event=True: a Watchdog straggler flag re-fires
        # the (memoryless) rule between steps on the running estimate —
        # the event wiring is live, not just plumbed
        if args.method == "divebatch":
            return DiveBatchPolicy(64, 1024, delta=0.1, dataset_size=len(train),
                                   granule=16, on_event=True)
        if args.method == "adabatch":
            return AdaBatchPolicy(64, 1024, granule=16)
        return FixedPolicy(64, 1024, granule=16)

    def make_ladder():
        if args.pods:
            from repro.pod import PodLadder

            return PodLadder(pods=args.pods, granule=16)
        return MeshLadder(granule=16) if args.elastic else None

    def make_trainer(mgr):
        fns = ModelFns(
            batch_loss=small.logreg_batch_loss,
            example_loss=small.logreg_loss,
            metrics=lambda p, b: {"acc": small.logreg_accuracy(p, b)},
        )
        program = AdaptationProgram(make_policy_obj(), base_lr=1.0,
                                    estimator="exact")
        return Trainer(
            fns, small.logreg_init(jax.random.key(0), 64), sgd(momentum=0.9),
            program, train, val, estimator="exact", ckpt=mgr,
            elastic=make_ladder(),
        )

    from repro.obs import from_cli as obs_from_cli

    lose_pod: list = []
    for spec in args.lose_pod:
        e, _, p = str(spec).partition(":")
        lose_pod.append((int(e), int(p)) if p else int(e))

    tracer, runlog = obs_from_cli(
        args.trace, args.runlog,
        meta={"cmd": "supervisor", "method": args.method,
              "elastic": bool(args.elastic), "fail_at": args.fail_at,
              "pods": args.pods, "lose_pod": args.lose_pod},
    )
    history = run_supervised(make_trainer, args.epochs, args.fail_at,
                             args.ckpt_dir, tracer=tracer, runlog=runlog,
                             lose_pod=lose_pod)
    if tracer is not None:
        print(f"trace: {tracer.save(args.trace)}")
    if runlog is not None:
        runlog.close()
        print(f"runlog: {runlog.path}")
    print(f"completed {len(history)} epochs across restarts; "
          f"final val acc {history[-1].val_metrics.get('acc'):.4f}")


if __name__ == "__main__":
    main()
