"""Training CLI: paper reproductions and LM training with adaptive batching.

Examples:
  python -m repro.launch.train --task synthetic-convex --method divebatch
  python -m repro.launch.train --task imagelike --method adabatch --epochs 30
  python -m repro.launch.train --task lm --arch qwen2-7b --reduced \
      --method divebatch --steps 50
"""

from __future__ import annotations

import argparse
import contextlib
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AdaptiveBatchController, make_policy, step_decay
from repro.data import imagelike_classification, sigmoid_synthetic
from repro.dist.plan import ShardingPlan, use_plan
from repro.elastic import MeshLadder
from repro.optim import sgd
from repro.train.loop import ModelFns, Trainer
from repro.ckpt import CheckpointManager


def build_task(task: str, seed: int):
    from repro.models import resnet, small

    if task == "synthetic-convex":
        train, val, _ = sigmoid_synthetic(n=20_000, d=512, seed=seed)
        params = small.logreg_init(jax.random.key(seed), 512)
        fns = ModelFns(
            batch_loss=small.logreg_batch_loss,
            example_loss=small.logreg_loss,
            metrics=lambda p, b: {"acc": small.logreg_accuracy(p, b)},
        )
        return fns, params, train, val
    if task == "synthetic-nonconvex":
        train, val, _ = sigmoid_synthetic(n=20_000, d=512, seed=seed)
        params = small.mlp_init(jax.random.key(seed), 512)
        fns = ModelFns(
            batch_loss=small.mlp_batch_loss,
            example_loss=small.mlp_loss,
            metrics=lambda p, b: {"acc": small.mlp_accuracy(p, b)},
            probe_loss=small.mlp_batch_loss_with_probes,
            probe_specs=small.mlp_probe_specs,
        )
        return fns, params, train, val
    if task == "imagelike":
        train, val = imagelike_classification(n=6_000, hw=16, num_classes=10, seed=seed)
        params = resnet.resnet_init(jax.random.key(seed), depth=8, width=8)
        fns = ModelFns(
            batch_loss=resnet.resnet_batch_loss,
            example_loss=resnet.resnet_loss,
            metrics=lambda p, b: {"acc": resnet.resnet_accuracy(p, b)},
        )
        return fns, params, train, val
    raise ValueError(f"unknown task {task!r}")


def make_controller(args, dataset_size: int) -> AdaptiveBatchController:
    policy = make_policy(
        args.method,
        m0=args.batch_size,
        m_max=args.max_batch_size,
        delta=args.delta,
        dataset_size=dataset_size,
        granule=args.granule,
        resize_freq=args.resize_freq,
    )
    return AdaptiveBatchController(
        policy,
        base_lr=args.lr,
        lr_rule=args.lr_rule,
        lr_schedule=step_decay(args.lr_decay, args.lr_decay_every) if args.lr_decay < 1 else None,
        estimator=args.estimator,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="synthetic-convex")
    ap.add_argument("--method", default="divebatch",
                    choices=["sgd", "adabatch", "divebatch", "oracle"])
    ap.add_argument("--estimator", default="exact",
                    choices=["exact", "gram", "moment", "oracle"])
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=128)
    ap.add_argument("--max-batch-size", type=int, default=2048)
    ap.add_argument("--delta", type=float, default=0.1)
    ap.add_argument("--granule", type=int, default=16)
    ap.add_argument("--resize-freq", type=int, default=20)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--lr-rule", default="none", choices=["none", "linear", "sqrt"])
    ap.add_argument("--lr-decay", type=float, default=0.75)
    ap.add_argument("--lr-decay-every", type=int, default=20)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--weight-decay", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dp", type=int, default=0,
                    help="data-parallel shards; >0 activates a dist plan over "
                         "that many local devices (same engine code path as "
                         "the multi-pod dry-run)")
    ap.add_argument("--elastic", action="store_true",
                    help="co-adapt the device footprint with the batch size: "
                         "a repro.elastic MeshLadder over --dp (default: all) "
                         "local devices, rung transitions at the epoch "
                         "boundaries that resize the batch")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable TrainState buffer donation (debugging)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out", default=None,
                    help="write run JSON here: {'history': [epoch records], "
                         "'engine': EngineStats}")
    args = ap.parse_args()

    if args.method == "oracle":
        args.estimator = "oracle"

    # The CPU-test and multi-pod paths are the same engine: with --dp the
    # whole run executes under a ShardingPlan (batches dp-sharded, GSPMD
    # propagates into the donated step); without one, constrain() is a no-op
    # and the identical code runs single-device. --elastic replaces the fixed
    # plan with a MeshLadder: the batch-size signal drives the sharding plan,
    # not just the step bucket.
    plan_ctx = contextlib.nullcontext()
    ladder = None
    if args.elastic:
        ndev = args.dp or len(jax.devices())
        if ndev > len(jax.devices()):
            raise SystemExit(
                f"--dp {ndev} exceeds the {len(jax.devices())} available "
                f"devices (the fixed --dp path would fail the same way)"
            )
        ladder = MeshLadder(jax.devices()[:ndev], granule=args.granule)
    elif args.dp:
        mesh = jax.make_mesh((args.dp,), ("data",))
        plan_ctx = use_plan(ShardingPlan(mesh=mesh))

    with plan_ctx:
        fns, params, train, val = build_task(args.task, args.seed)
        controller = make_controller(args, len(train))
        trainer = Trainer(
            fns, params, sgd(momentum=args.momentum, weight_decay=args.weight_decay),
            controller, train, val,
            estimator=args.estimator if args.method in ("divebatch", "oracle") else "none",
            seed=args.seed,
            ckpt=CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None,
            ckpt_every=args.ckpt_every,
            donate=not args.no_donate,
            elastic=ladder,
        )
        if args.resume and trainer.ckpt:
            trainer.resume()
        remaining = args.epochs - trainer.cursor.epoch
        history = trainer.run(max(remaining, 0))
    stats = trainer.engine.stats
    if args.out:
        import dataclasses

        with open(args.out, "w") as f:
            json.dump(
                {"history": [dataclasses.asdict(r) for r in history],
                 "engine": stats.as_dict()},
                f, indent=1,
            )
    final = history[-1] if history else None
    if final:
        print(f"final: epoch={final.epoch} val_loss={final.val_loss:.4f} "
              f"metrics={final.val_metrics} batch={final.batch_size}")
    print(f"engine: compiles={stats.compiles} (bound {controller.compile_bound}) "
          f"hits={stats.bucket_hits} buckets={stats.buckets} "
          f"dispatch-steps/s={stats.dispatch_steps_per_sec:.1f} donated={stats.donate}")
    if ladder is not None:
        print(f"elastic: ladder dp={ladder.widths} reshards={stats.reshards} "
              f"rungs-per-compile={stats.rungs}")


if __name__ == "__main__":
    main()
