"""Training CLI: paper reproductions and LM training with adaptive batching.

Adaptation is built on ``repro.adapt``: ``--method`` picks the policy
(divebatch / adabatch / sgd / oracle / gns — the gradient-noise-scale
family), ``--tick-every N`` enables mid-epoch decisions every N steps (with
``--elastic`` a mid-epoch resize also reshards the rung between steps), and
``--hysteresis B`` wraps the policy in a tolerance band around the pow2
bucket thresholds.

Examples:
  python -m repro.launch.train --task synthetic-convex --method divebatch
  python -m repro.launch.train --task imagelike --method adabatch --epochs 30
  python -m repro.launch.train --task synthetic-convex --method gns \
      --tick-every 8 --elastic
"""

from __future__ import annotations

import argparse
import contextlib
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.adapt import (
    AdaBatchPolicy,
    AdaptationProgram,
    DiveBatchPolicy,
    FixedPolicy,
    GradNoisePolicy,
    Hysteresis,
    LrCoupling,
)
from repro.core import step_decay
from repro.data import imagelike_classification, sigmoid_synthetic
from repro.dist.plan import ShardingPlan, use_plan
from repro.elastic import MeshLadder
from repro.obs import from_cli as obs_from_cli
from repro.optim import sgd
from repro.train.loop import ModelFns, Trainer
from repro.ckpt import CheckpointManager


def build_task(task: str, seed: int):
    from repro.models import resnet, small

    if task == "synthetic-convex":
        train, val, _ = sigmoid_synthetic(n=20_000, d=512, seed=seed)
        params = small.logreg_init(jax.random.key(seed), 512)
        fns = ModelFns(
            batch_loss=small.logreg_batch_loss,
            example_loss=small.logreg_loss,
            metrics=lambda p, b: {"acc": small.logreg_accuracy(p, b)},
        )
        return fns, params, train, val
    if task == "synthetic-nonconvex":
        train, val, _ = sigmoid_synthetic(n=20_000, d=512, seed=seed)
        params = small.mlp_init(jax.random.key(seed), 512)
        fns = ModelFns(
            batch_loss=small.mlp_batch_loss,
            example_loss=small.mlp_loss,
            metrics=lambda p, b: {"acc": small.mlp_accuracy(p, b)},
            probe_loss=small.mlp_batch_loss_with_probes,
            probe_specs=small.mlp_probe_specs,
        )
        return fns, params, train, val
    if task == "imagelike":
        train, val = imagelike_classification(n=6_000, hw=16, num_classes=10, seed=seed)
        params = resnet.resnet_init(jax.random.key(seed), depth=8, width=8)
        fns = ModelFns(
            batch_loss=resnet.resnet_batch_loss,
            example_loss=resnet.resnet_loss,
            metrics=lambda p, b: {"acc": resnet.resnet_accuracy(p, b)},
        )
        return fns, params, train, val
    raise ValueError(f"unknown task {task!r}")


def make_program(args, dataset_size: int) -> AdaptationProgram:
    """Build the repro.adapt program for the CLI flags (the single
    adaptation path — the legacy AdaptiveBatchController is a shim over
    exactly this object)."""
    common = dict(m0=args.batch_size, m_max=args.max_batch_size,
                  granule=args.granule)
    tick = args.tick_every > 0
    if args.method in ("sgd", "fixed"):
        policy = FixedPolicy(**common)
    elif args.method == "adabatch":
        policy = AdaBatchPolicy(resize_freq=args.resize_freq, **common)
    elif args.method in ("divebatch", "oracle"):
        policy = DiveBatchPolicy(
            delta=args.delta, dataset_size=dataset_size,
            oracle=args.method == "oracle", on_tick=tick, **common,
        )
    elif args.method == "gns":
        policy = GradNoisePolicy(alpha=args.gns_alpha, on_tick=tick, **common)
    else:
        raise ValueError(f"unknown method {args.method!r}")
    if args.hysteresis > 0:
        policy = Hysteresis(policy, band=args.hysteresis)
    decay = step_decay(args.lr_decay, args.lr_decay_every) if args.lr_decay < 1 else None
    return AdaptationProgram(
        policy,
        base_lr=args.lr,
        coupling=LrCoupling(rule=args.lr_rule, decay=decay),
        estimator=args.estimator,
        tick_every=args.tick_every,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="synthetic-convex")
    ap.add_argument("--method", default="divebatch",
                    choices=["sgd", "adabatch", "divebatch", "oracle", "gns"])
    ap.add_argument("--estimator", default="exact",
                    choices=["exact", "gram", "moment", "oracle"])
    ap.add_argument("--tick-every", type=int, default=0,
                    help="mid-epoch adaptation: observe the running signals "
                         "every N optimizer steps (0 = epoch boundaries "
                         "only); a mid-epoch decision resizes the batch and "
                         "reshards the elastic rung between steps")
    ap.add_argument("--gns-alpha", type=float, default=1.0,
                    help="--method gns: target batch = alpha * measured "
                         "gradient-noise scale")
    ap.add_argument("--hysteresis", type=float, default=0.0,
                    help="tolerance band around pow2 bucket thresholds "
                         "(e.g. 0.1): resizes within the band hold the "
                         "current size, making the schedule rung-invariant "
                         "under dp-reduction-order jitter")
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=128)
    ap.add_argument("--max-batch-size", type=int, default=2048)
    ap.add_argument("--delta", type=float, default=0.1)
    ap.add_argument("--granule", type=int, default=16)
    ap.add_argument("--resize-freq", type=int, default=20)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--lr-rule", default="none", choices=["none", "linear", "sqrt"])
    ap.add_argument("--lr-decay", type=float, default=0.75)
    ap.add_argument("--lr-decay-every", type=int, default=20)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--weight-decay", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dp", type=int, default=0,
                    help="data-parallel shards; >0 activates a dist plan over "
                         "that many local devices (same engine code path as "
                         "the multi-pod dry-run)")
    ap.add_argument("--elastic", action="store_true",
                    help="co-adapt the device footprint with the batch size: "
                         "a repro.elastic MeshLadder over --dp (default: all) "
                         "local devices, rung transitions at the epoch "
                         "boundaries that resize the batch")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable TrainState buffer donation (debugging)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out", default=None,
                    help="write run JSON here: {'history': [epoch records], "
                         "'engine': EngineStats}")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="record a Chrome/Perfetto trace (repro.obs) and "
                         "write DIR/trace.json at exit")
    ap.add_argument("--runlog", default=None, nargs="?", const="",
                    metavar="PATH",
                    help="write the schema-versioned JSONL run log "
                         "(repro.obs.runlog; read it with launch/monitor.py); "
                         "bare --runlog means <--trace DIR>/runlog.jsonl")
    args = ap.parse_args()

    if args.method == "oracle":
        args.estimator = "oracle"

    # The CPU-test and multi-pod paths are the same engine: with --dp the
    # whole run executes under a ShardingPlan (batches dp-sharded, GSPMD
    # propagates into the donated step); without one, constrain() is a no-op
    # and the identical code runs single-device. --elastic replaces the fixed
    # plan with a MeshLadder: the batch-size signal drives the sharding plan,
    # not just the step bucket.
    plan_ctx = contextlib.nullcontext()
    ladder = None
    if args.elastic:
        ndev = args.dp or len(jax.devices())
        if ndev > len(jax.devices()):
            raise SystemExit(
                f"--dp {ndev} exceeds the {len(jax.devices())} available "
                f"devices (the fixed --dp path would fail the same way)"
            )
        ladder = MeshLadder(jax.devices()[:ndev], granule=args.granule)
    elif args.dp:
        mesh = jax.make_mesh((args.dp,), ("data",))
        plan_ctx = use_plan(ShardingPlan(mesh=mesh))

    tracer, runlog = obs_from_cli(
        args.trace, args.runlog,
        meta={"cmd": "train", "task": args.task, "method": args.method,
              "estimator": args.estimator, "seed": args.seed,
              "elastic": bool(args.elastic)},
    )
    with plan_ctx:
        fns, params, train, val = build_task(args.task, args.seed)
        program = make_program(args, len(train))
        trainer = Trainer(
            fns, params, sgd(momentum=args.momentum, weight_decay=args.weight_decay),
            program, train, val,
            estimator=args.estimator if args.method in ("divebatch", "oracle", "gns") else "none",
            seed=args.seed,
            ckpt=CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None,
            ckpt_every=args.ckpt_every,
            donate=not args.no_donate,
            elastic=ladder,
            tracer=tracer,
            runlog=runlog,
        )
        if args.resume and trainer.ckpt:
            trainer.resume()
        remaining = args.epochs - trainer.cursor.epoch
        history = trainer.run(max(remaining, 0))
    if tracer is not None:
        print(f"trace: {tracer.save(args.trace)}")
    if runlog is not None:
        runlog.close()
        print(f"runlog: {runlog.path}")
    stats = trainer.engine.stats
    if args.out:
        import dataclasses

        with open(args.out, "w") as f:
            json.dump(
                {"history": [dataclasses.asdict(r) for r in history],
                 "engine": stats.as_dict()},
                f, indent=1,
            )
    final = history[-1] if history else None
    if final:
        print(f"final: epoch={final.epoch} val_loss={final.val_loss:.4f} "
              f"metrics={final.val_metrics} batch={final.batch_size}")
    print(f"engine: compiles={stats.compiles} (bound {program.compile_bound}) "
          f"hits={stats.bucket_hits} buckets={stats.buckets} "
          f"dispatch-steps/s={stats.dispatch_steps_per_sec:.1f} donated={stats.donate}")
    mid = [a for a in program.history if a.boundary != "epoch"]
    if mid:
        print(f"adapt: {len(mid)} mid-epoch decisions "
              f"({sum(a.rescaled for a in mid)} resized) via "
              f"{sorted(set(a.boundary for a in mid))}")
    if ladder is not None:
        print(f"elastic: ladder dp={ladder.widths} reshards={stats.reshards} "
              f"rungs-per-compile={stats.rungs}")


if __name__ == "__main__":
    main()
