"""Run-log monitor: summary tables, schedule reconstruction, trace merge.

The reader side of ``repro.obs``: point it at a ``runs/<name>`` directory (or
the ``runlog.jsonl`` itself) and it prints what the run did — a per-epoch
table for training logs, a per-window table for serving logs, and the typed
lifecycle events (compiles, reshards, checkpoints, restarts, injected
events) in between.  ``schedule()`` rebuilds the full batch-size/rung/lr
schedule from the ``decision`` event stream (which mirrors
``AdaptationProgram.history`` record-for-record); ``merge_traces()`` folds
the run-log events onto the tracer's timeline via the trace's
``wall_origin`` and emits one merged Perfetto-loadable ``trace.json``.

  python -m repro.launch.monitor runs/smoke-train
  python -m repro.launch.monitor runs/smoke-train --follow
  python -m repro.launch.monitor runs/smoke-train --trace merged.json
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import time

from repro.obs.runlog import read_runlog
from repro.obs.trace import jsonable


def load(path: str) -> list[dict]:
    """All events of a run log (directory or JSONL path)."""
    return read_runlog(path)


def schedule(events: list[dict]) -> list[dict]:
    """The batch-size/rung/lr schedule, one row per adapt decision.

    Rows mirror ``AdaptationProgram.history`` (epoch/step/boundary/
    batch_size/lr come straight off each ``decision`` event); the live rung
    is tracked across ``reshard``/``restart`` events so every row also says
    where the decision executed."""
    rung = None
    out: list[dict] = []
    for ev in events:
        kind = ev.get("kind")
        if kind == "reshard" and ev.get("scope") == "train":
            rung = ev.get("dst")
        elif kind == "demote":
            rung = ev.get("dst")
        elif kind == "restart" and ev.get("rung") is not None:
            rung = ev.get("rung")
        elif kind == "decision":
            out.append({
                "t": ev.get("t"),
                "epoch": ev["epoch"],
                "step": ev["step"],
                "boundary": ev["boundary"],
                "batch_size": ev["batch_size"],
                "lr": ev["lr"],
                "rung": rung,
            })
    return out


def _fmt(v, nd: int = 4) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.{nd}g}"
    return str(v)


def _table(rows: list[dict], cols: list[str]) -> str:
    cells = [[_fmt(r.get(c)) for c in cols] for r in rows]
    widths = [max(len(c), *(len(row[i]) for row in cells)) if cells else len(c)
              for i, c in enumerate(cols)]
    lines = ["  ".join(c.rjust(w) for c, w in zip(cols, widths))]
    for row in cells:
        lines.append("  ".join(c.rjust(w) for c, w in zip(row, widths)))
    return "\n".join(lines)


def epoch_table(events: list[dict]) -> str:
    rows = [e for e in events if e.get("kind") == "epoch"]
    return _table(rows, ["epoch", "steps", "batch_size", "lr", "loss",
                         "val_loss", "diversity", "gns", "rung", "wall_s"])


def serve_table(events: list[dict]) -> str:
    rows = [e for e in events if e.get("kind") == "serve_window"]
    return _table(rows, ["step", "tokens", "tokens_per_sec", "live",
                         "live_blocks", "bucket", "rung"])


def lifecycle(events: list[dict]) -> str:
    """One line per non-boundary typed event, in log order."""
    lines = []
    for ev in events:
        kind = ev.get("kind")
        if kind == "compile":
            lines.append(f"compile   [{ev.get('scope')}] {ev.get('what')} "
                         f"({_fmt(ev.get('seconds'))}s)")
        elif kind == "reshard":
            lines.append(f"reshard   [{ev.get('scope')}] rung "
                         f"{_fmt(ev.get('src'))} -> {ev.get('dst')} "
                         f"(dp {_fmt(ev.get('dp'))})")
        elif kind == "checkpoint":
            lines.append(f"checkpoint epoch={ev.get('epoch')} step={ev.get('step')}")
        elif kind == "restart":
            what = "start" if not ev.get("restarts") else f"restart #{ev['restarts']}"
            lines.append(f"restart   {what} at epoch={ev.get('epoch')} "
                         f"batch={_fmt(ev.get('batch_size'))} "
                         f"rung={_fmt(ev.get('rung'))}")
        elif kind == "inject":
            lines.append(f"inject    {ev.get('name')!r} at "
                         f"epoch={ev.get('epoch')} step={ev.get('step')}")
        elif kind == "serve_policy":
            lines.append(f"policy    {ev.get('reason')!r} at step="
                         f"{ev.get('step')} reordered={_fmt(ev.get('reordered'))} "
                         f"budget={_fmt(ev.get('slot_budget'))} "
                         f"patience={_fmt(ev.get('shrink_patience'))} "
                         f"queue={_fmt(ev.get('queue_depth'))}")
        elif kind == "pod_lost":
            lines.append(f"pod_lost  pod={ev.get('pod')} at "
                         f"epoch={ev.get('epoch')} rung={_fmt(ev.get('rung'))}")
        elif kind == "demote":
            lines.append(f"demote    rung {_fmt(ev.get('src'))} -> "
                         f"{ev.get('dst')} (pods {_fmt(ev.get('pods'))}, "
                         f"dp {_fmt(ev.get('dp'))})")
    return "\n".join(lines)


def summary(events: list[dict]) -> str:
    """The full human-readable report for one run log."""
    parts = []
    start = next((e for e in events if e.get("kind") == "run_start"), None)
    if start is not None:
        parts.append(f"run: {json.dumps(start.get('run', {}), default=jsonable)}")
    life = lifecycle(events)
    if life:
        parts.append(life)
    if any(e.get("kind") == "epoch" for e in events):
        parts.append("epochs:")
        parts.append(epoch_table(events))
    if any(e.get("kind") == "serve_window" for e in events):
        parts.append("serve windows:")
        parts.append(serve_table(events))
    sched = schedule(events)
    if sched:
        parts.append(f"schedule ({len(sched)} decisions):")
        parts.append(_table(sched, ["epoch", "step", "boundary",
                                    "batch_size", "lr", "rung"]))
    return "\n".join(parts)


def merge_traces(run_dir: str, out: str) -> str:
    """Merge every ``trace*.json`` under ``run_dir`` plus the run log into
    one Perfetto-loadable trace; run-log events become instants on their own
    thread lane, aligned via the first trace's ``wall_origin``."""
    traces = sorted(glob.glob(os.path.join(run_dir, "trace*.json")))
    merged: list[dict] = []
    origin = None
    pid = 0
    for p in traces:
        with open(p) as f:
            doc = json.load(f)
        other = doc.get("otherData", {})
        if origin is None and other.get("wall_origin") is not None:
            origin = float(other["wall_origin"])
            pid = int(other.get("pid", 0))
        merged.extend(doc.get("traceEvents", []))
    log_path = os.path.join(run_dir, "runlog.jsonl")
    if os.path.exists(log_path) and origin is not None:
        merged.append({"ph": "M", "name": "thread_name", "ts": 0.0,
                       "pid": pid, "tid": -1, "args": {"name": "runlog"}})
        for ev in read_runlog(log_path):
            t = ev.get("t")
            if t is None:
                continue
            args = {k: v for k, v in ev.items() if k not in ("v", "t")}
            merged.append({
                "ph": "i", "name": ev.get("kind", "event"), "s": "t",
                "ts": (float(t) - origin) * 1e6, "pid": pid, "tid": -1,
                "args": args,
            })
    with open(out, "w") as f:
        json.dump({"traceEvents": merged, "displayTimeUnit": "ms"}, f,
                  default=jsonable)
    return out


def _drain(f, buf: str) -> tuple[list[str], str]:
    """Read every COMPLETE line currently available on ``f``.

    A live writer's trailing record may be torn (flushed mid-line, or read
    mid-write): partial text is carried in ``buf`` and re-joined with the
    rest of the line once the writer completes it — a follower never emits
    (or json-parses) a half record, and never loses one either.  Returns
    ``(complete_lines, carry_buffer)``; pure, so the torn-tail behaviour is
    unit-testable without a live tail loop (tests/test_obs.py).
    """
    lines: list[str] = []
    while True:
        chunk = f.readline()
        if not chunk:
            return lines, buf
        buf += chunk
        if buf.endswith("\n"):
            if buf.strip():
                lines.append(buf.strip())
            buf = ""


def _follow(path: str) -> None:
    """Tail the run log, printing each typed event as it lands (torn/partial
    trailing lines are held back until the writer completes them)."""
    if os.path.isdir(path):
        path = os.path.join(path, "runlog.jsonl")
    while not os.path.exists(path):
        time.sleep(0.2)
    buf = ""
    with open(path) as f:
        while True:
            lines, buf = _drain(f, buf)
            for line in lines:
                print(line)
            if not lines:
                time.sleep(0.5)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("run", help="runs/<name> directory or runlog.jsonl path")
    ap.add_argument("--follow", action="store_true",
                    help="tail the log instead of printing the summary")
    ap.add_argument("--trace", default=None, metavar="OUT",
                    help="also write the merged trace.json (trace*.json + "
                         "run-log instants) to OUT")
    args = ap.parse_args(argv)
    if args.follow:
        try:
            _follow(args.run)
        except KeyboardInterrupt:
            return
        return
    print(summary(load(args.run)))
    if args.trace:
        run_dir = args.run if os.path.isdir(args.run) else os.path.dirname(args.run)
        print(f"merged trace: {merge_traces(run_dir, args.trace)}")


if __name__ == "__main__":
    main()
