"""Production mesh + sharding-plan construction.

``make_production_mesh`` is a FUNCTION (never module-level state) so that
importing this module touches no jax device state — required because the
dry-run process forces 512 host devices via XLA_FLAGS *before* first jax use,
while tests/benches must keep seeing 1 device.
"""

from __future__ import annotations

import jax

from repro.dist.plan import ShardingPlan

GIB = 1 << 30


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_plan(mesh, *, param_bytes: int | None = None,
              fsdp_pod_threshold: int = 2 * GIB) -> ShardingPlan:
    """Build the sharding plan for a mesh.

    On the multi-pod mesh the 'pod' axis always carries data parallelism; it
    is ALSO added to the FSDP/EP axes when the model would otherwise exceed
    ``fsdp_pod_threshold`` parameter bytes per chip (ZeRO across pods trades
    DCN all-gathers for fitting 405B/1T-scale states in 16 GB HBM).
    """
    axes = mesh.axis_names
    if "pod" in axes:
        dp = ("pod", "data")
        fsdp: tuple[str, ...] = ("data",)
        ep: tuple[str, ...] = ("data",)
        if param_bytes is not None:
            chips = mesh.devices.size
            per_chip = param_bytes / (mesh.shape["data"] * mesh.shape["model"])
            if per_chip > fsdp_pod_threshold:
                fsdp = ("pod", "data")
                ep = ("pod", "data")
        return ShardingPlan(mesh=mesh, dp=dp, fsdp=fsdp, tp="model", ep=ep)
    return ShardingPlan(mesh=mesh, dp=("data",), fsdp=("data",), tp="model", ep=("data",))
