import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory/cost/collective analyses.

MUST be run as its own process (``python -m repro.launch.dryrun``): the
XLA_FLAGS line above executes before any jax import, forcing 512 placeholder
CPU devices so ``jax.make_mesh`` can build the (2,16,16) production mesh.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out runs/dryrun]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (  # noqa: E402
    ARCH_NAMES,
    SHAPES,
    cell_supported,
    get_config,
    input_specs,
)
from repro.dist import sharding as shd  # noqa: E402
from repro.dist.plan import use_plan  # noqa: E402
from repro.launch.mesh import make_plan, make_production_mesh  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.optim import sgd  # noqa: E402
from repro.train.engine import StepEngine  # noqa: E402
from repro.train.state import init_state  # noqa: E402
from repro.utils import hlo as hlo_lib  # noqa: E402
from repro.utils import pytree as ptu  # noqa: E402
from repro.utils.logging import get_logger  # noqa: E402

log = get_logger("dryrun")

GIB = 1 << 30

# Per-cell tuning knobs discovered during the perf iteration (EXPERIMENTS.md
# §Perf). Keys: (arch, shape) -> dict of overrides.
CELL_TUNING: dict[tuple[str, str], dict] = {
    # §Perf B3 (EXPERIMENTS.md): FSDP weight re-gathers scale with the
    # accumulation length; 4 microbatches cut the collective term
    # 372s -> 245s (-34%) for +6 GiB of activation footprint.
    ("llama3-405b", "train_4k"): {"num_micro": 4},
    # §Perf D1: larger SSM chunks -> fewer chunk-scan boundaries (stacked ys
    # writes): memory term 73s -> 52s (-29%) for +0.9 GiB.
    ("falcon-mamba-7b", "train_4k"): {"config": {"ssm_chunk": 1024}},
}


def dryrun_config(arch: str):
    """The production-run variant of an arch config (bf16, scan, remat)."""
    cfg = get_config(arch)
    overrides = dict(
        scan_layers=True,
        remat=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        # q-block axis must be divisible by the 16-way model axis for the
        # Ulysses-style attention sharding (see act_specs_for)
        flash_q_block=256,
        flash_kv_block=1024,
    )
    return cfg.replace(**overrides)


def _micro_plan(cfg, shape, plan) -> tuple[int, int]:
    """(num_micro, micro_global_batch) for a train cell: pick ~1 sequence per
    dp shard for giant models, more for small ones."""
    dp = plan.dp_size
    b = shape.global_batch
    # 1 sequence/shard for giant dense models, big-E MoE, and hybrids (whose
    # mamba chunk scans carry (B, L, d_inner, d_state) working sets)
    heavy = (
        cfg.d_model >= 6144
        or cfg.num_experts >= 64
        or ("mamba" in cfg.pattern and cfg.num_experts > 0)
    )
    seqs_per_shard = 1 if heavy else 4
    micro = min(b, dp * seqs_per_shard)
    while b % micro != 0:
        micro //= 2
    micro = max(micro, 1)
    return b // micro, micro


def build_train(cfg, shape, plan, tuning):
    opt_dtype = jnp.bfloat16 if cfg.d_model >= 6144 or cfg.num_experts >= 64 else jnp.float32
    div_dtype = opt_dtype
    optimizer = sgd(momentum=0.9, state_dtype=opt_dtype)
    num_micro, micro = _micro_plan(cfg, shape, plan)
    num_micro = tuning.get("num_micro", num_micro)
    moe_groups = plan.dp_size if cfg.num_experts else 1

    params_specs = tf.param_specs(cfg)
    state_specs = jax.eval_shape(lambda p: init_state(p, optimizer, div_dtype), params_specs)
    state_ps = shd.infer_pspecs(state_specs, plan)
    state_sh = shd.shardings_of(state_ps, plan)

    batch_specs = input_specs(cfg, shape)["batch"]
    batch_ps = shd.batch_pspecs(batch_specs, plan)
    batch_sh = shd.shardings_of(batch_ps, plan)

    lr_spec = jax.ShapeDtypeStruct((), jnp.float32)

    # Same engine as Trainer/launch.train: one donated, bucketed step program
    # per num_micro; the dry-run AOT-lowers the jitted fn for one bucket.
    engine = StepEngine.for_lm(
        cfg, optimizer, dp_size=plan.dp_size, moe_groups=moe_groups,
        diversity_on=True, grad_accum_dtype=opt_dtype,
        in_shardings=(state_sh, batch_sh, NamedSharding(plan.mesh, P())),
        out_shardings=(state_sh, None),
    )
    jitted = engine.jitted(num_micro)
    args = (state_specs, batch_specs, lr_spec)
    info = {"num_micro": num_micro, "micro_global": micro,
            "opt_dtype": str(opt_dtype.__name__ if hasattr(opt_dtype, '__name__') else opt_dtype)}
    return jitted, args, info


def build_prefill(cfg, shape, plan, tuning):
    specs = input_specs(cfg, shape)["batch"]
    batch_ps = shd.batch_pspecs(specs, plan)
    batch_sh = shd.shardings_of(batch_ps, plan)
    params_specs = tf.param_specs(cfg)
    params_sh = shd.shardings_of(shd.infer_pspecs(params_specs, plan), plan)

    # MoE prefill must route tokens in groups: a single group over 1M tokens
    # builds an (E, T*k*cf/E, d) dispatch buffer plus a (T*k, E) routing
    # cumsum (measured 81-128 GiB/dev on kimi prefill_32k; ~12 GiB grouped).
    tokens = shape.global_batch * shape.seq_len
    groups = 1
    if cfg.num_experts:
        groups = max(plan.dp_size, tokens // 8192)
        while tokens % groups != 0 or groups % plan.dp_size != 0:
            groups -= 1
        groups = max(groups, plan.dp_size)

    def fn(params, batch):
        return tf.prefill_step(cfg, params, batch, moe_groups=groups)

    # explicit output shardings: without them GSPMD may replicate the
    # (batch, seq, kv, hd) caches over the data axes (measured 13.9 GiB/dev
    # on gemma2 prefill_32k vs 0.8 GiB sharded)
    out_specs = jax.eval_shape(fn, params_specs, specs)
    logits_sh = NamedSharding(plan.mesh, P(*( [tuple(plan.dp)] + [None] * (len(out_specs[0].shape) - 1))))
    cache_sh = shd.shardings_of(shd.cache_pspecs(out_specs[1], plan), plan)
    jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh),
                     out_shardings=(logits_sh, cache_sh))
    return jitted, (params_specs, specs), {}


def build_decode(cfg, shape, plan, tuning):
    specs = input_specs(cfg, shape)
    tok_specs, cache_specs = specs["tokens"], specs["cache"]
    params_specs = tf.param_specs(cfg)
    params_sh = shd.shardings_of(shd.infer_pspecs(params_specs, plan), plan)
    cache_sh = shd.shardings_of(shd.cache_pspecs(cache_specs, plan), plan)
    b = tok_specs.shape[0]
    from repro.dist.sharding import _fit_axes  # divisibility-aware batch axis
    dp = _fit_axes(b, plan.dp, plan)
    tok_sh = NamedSharding(plan.mesh, P(dp, *([None] * (len(tok_specs.shape) - 1))))

    def fn(params, cache, tokens):
        return tf.decode_step(cfg, params, cache, tokens)

    jitted = jax.jit(
        fn, in_shardings=(params_sh, cache_sh, tok_sh),
        out_shardings=(None, cache_sh), donate_argnums=(1,),
    )
    return jitted, (params_specs, cache_specs, tok_specs), {}


def act_specs_for(cfg, plan, kind: str):
    """Activation sharding constraints installed during lowering.

    The residual carry of the layer scan is what remat saves per layer, so
    keeping it sharded over BOTH the dp axes (batch dim) and the tp axis
    (d_model dim) divides saved-activation HBM by dp*tp.

    Attention runs context-parallel (Ulysses-style): the q-block axis takes
    the tp axis and K/V are replicated within the layer — this sidesteps the
    head-count/16 divisibility problem (qwen2: 28 heads, internvl2: 14)."""
    dp = tuple(plan.dp)
    ep = tuple(plan.ep)
    moe = {
        # dispatch buffers (G,E,C,d): group-major before the EP boundary,
        # expert-major inside (forces the canonical all-to-all). d stays
        # unsharded: it is the contraction dim of the expert GEMMs — sharding
        # it over tp would turn every GEMM into partial-sum all-reduces.
        "moe_dispatch": P(None, ep, None, None),
        "moe_combine": P(dp, None, None, None),
    }
    if kind == "train":
        return {
            "residual": P(dp, None, plan.tp),
            "attn_q": P(dp, plan.tp, None, None, None),
            "attn_kv": P(dp, None, None, None),
            **moe,
        }
    if kind == "prefill":
        return {
            "attn_q": P(dp, plan.tp, None, None, None),
            "attn_kv": P(dp, None, None, None),
            **moe,
        }
    return moe


def active_params(cfg, specs) -> float:
    """Parameter count weighted by activation fraction (MoE experts count
    top_k/E) — the N in MODEL_FLOPS = 6*N*D."""
    total = 0.0
    for path, leaf in ptu.tree_flatten_with_paths(specs):
        import numpy as np

        n = float(np.prod(leaf.shape))
        if cfg.num_experts and (
            path.endswith("ffn/w_gate") or path.endswith("ffn/w_up")
            or path.endswith("ffn/w_out")
        ):
            n *= cfg.top_k / cfg.num_experts
        if path.endswith("embed/embedding"):
            continue  # lookup, not matmul
        total += n
    return total


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str | None,
             tuning_override: dict | None = None) -> dict:
    shape = SHAPES[shape_name]
    cfg = dryrun_config(arch)
    ok, why = cell_supported(arch, shape_name, cfg.causal)
    record: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "kind": shape.kind,
    }
    if not ok:
        record.update(status="skipped", reason=why)
        _save(record, out_dir)
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    params_specs = tf.param_specs(cfg)
    param_bytes = ptu.tree_bytes(params_specs)
    plan = make_plan(mesh, param_bytes=param_bytes)
    tuning = dict(CELL_TUNING.get((arch, shape_name), {}))
    if tuning_override:
        tuning.update(tuning_override)
    if "config" in tuning:
        cfg = cfg.replace(**tuning["config"])

    builders = {"train": build_train, "prefill": build_prefill, "decode": build_decode}
    t0 = time.time()
    try:
        with use_plan(plan, act_specs_for(cfg, plan, shape.kind)):
            jitted, args, info = builders[shape.kind](cfg, shape, plan, tuning)
            with mesh:
                lowered = jitted.lower(*args)
                compiled = lowered.compile()
        mem = compiled.memory_analysis()
        raw_cost = compiled.cost_analysis()
        if isinstance(raw_cost, (list, tuple)):  # jax<=0.4.x returns [dict]
            raw_cost = raw_cost[0] if raw_cost else {}
        hlo_text = compiled.as_text()
        prog = hlo_lib.HloProgram(hlo_text)
        analysis = prog.analyze()  # trip-count-aware, per-device
        upcast_live = prog.f32_upcast_live_bytes()
        chips = mesh.devices.size
        # memory term uses convert-adjusted traffic: the CPU backend emulates
        # bf16 matmuls via hoisted f32 copies that would not exist on TPU.
        terms = hlo_lib.roofline_terms(
            analysis["flops"], analysis["hbm_bytes_adjusted"],
            analysis["collectives"]["total_time_s"],
        )
        terms["memory_unadjusted_s"] = analysis["hbm_bytes"] / hlo_lib.HBM_BW
        # useful-compute ratio: MODEL_FLOPS vs compiled (per-device * chips)
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        mf = hlo_lib.model_flops(
            active_params(cfg, params_specs), tokens,
            "train" if shape.kind == "train" else "infer",
        )
        hlo_global_flops = analysis["flops"] * chips
        record.update(
            status="ok",
            compile_s=round(time.time() - t0, 1),
            chips=chips,
            param_bytes=param_bytes,
            plan={"dp": plan.dp, "fsdp": plan.fsdp, "tp": plan.tp, "ep": plan.ep},
            tuning=info,
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            },
            cost={
                "hlo_flops_per_device": analysis["flops"],
                "hlo_hbm_bytes_per_device": analysis["hbm_bytes"],
                "hlo_hbm_bytes_adjusted": analysis["hbm_bytes_adjusted"],
                "convert_bytes": analysis["convert_bytes"],
                "raw_cost_analysis_flops": float(raw_cost.get("flops", 0.0)) if raw_cost else 0.0,
                "model_flops_global": mf,
                "useful_flops_ratio": (mf / hlo_global_flops) if hlo_global_flops else 0.0,
            },
            collectives=analysis["collectives"],
            roofline=terms,
        )
        # per-device HBM occupancy (arguments are sharded; sizes reported by
        # memory_analysis are already per-device on SPMD executables).
        # adjusted = minus the CPU backend's hoisted f32 copies of bf16 data.
        arg_b = record["memory"]["argument_bytes"]
        tmp_b = record["memory"]["temp_bytes"]
        record["memory"]["f32_upcast_live_bytes"] = upcast_live
        record["memory"]["hbm_per_device_gib"] = round((arg_b + tmp_b) / GIB, 3)
        record["memory"]["hbm_per_device_adjusted_gib"] = round(
            (arg_b + max(tmp_b - upcast_live, 0)) / GIB, 3
        )
        log.info(
            "%s x %s [%s]: compile %.1fs, %.2f GiB/dev (adj %.2f), dominant=%s",
            arch, shape_name, record["mesh"], record["compile_s"],
            record["memory"]["hbm_per_device_gib"],
            record["memory"]["hbm_per_device_adjusted_gib"], terms["dominant"],
        )
    except Exception as e:  # noqa: BLE001 — a failed cell is a recorded bug
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
        log.error("%s x %s FAILED: %s", arch, shape_name, record["error"])
    _save(record, out_dir)
    return record


def _save(record: dict, out_dir: str | None):
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{record['arch']}__{record['shape']}__{record['mesh']}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--num-micro", type=int, default=None)
    args = ap.parse_args()

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    tuning = {"num_micro": args.num_micro} if args.num_micro else None
    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))

    failures = 0
    for multi in meshes:
        for arch, shape in cells:
            rec = run_cell(arch, shape, multi, args.out, tuning)
            if rec["status"] == "ok":
                print(f"OK   {arch} x {shape} [{rec['mesh']}] "
                      f"{rec['memory']['hbm_per_device_gib']} GiB/dev "
                      f"dominant={rec['roofline']['dominant']}")
                print("  memory:", rec["memory"])
                print("  cost:", rec["cost"])
            elif rec["status"] == "skipped":
                print(f"SKIP {arch} x {shape} [{rec['mesh']}]: {rec['reason']}")
            else:
                failures += 1
                print(f"FAIL {arch} x {shape} [{rec['mesh']}]: {rec['error']}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
