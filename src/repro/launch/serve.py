"""Serving CLI: elastic continuous-batching decode over the model zoo.

The serving counterpart of ``launch/train.py``: ``--elastic`` wires a
``repro.elastic.MeshLadder`` into the ``ServeEngine`` so the live decode
batch drives the device footprint (rung transitions reshard the params and
the KV cache between steps); ``--dp N`` instead pins a fixed N-wide
data-parallel plan for the whole run (today's behaviour, the baseline
``benchmarks/bench_serve.py`` measures against).

``--policy fifo|priority|fair`` selects the ``serve.policy.ServePolicy``
driving admission order / slot budget at every boundary (fifo is the
default and reproduces the pre-hook engine; priority/fair read the
``tenant``/``priority`` metadata ``--tenants`` stamps onto the synthetic
requests).

Examples:
  python -m repro.launch.serve --arch yi-6b --requests 16
  python -m repro.launch.serve --elastic --requests 32 --ramp 8
  python -m repro.launch.serve --policy fair --tenants 2 --ramp 2
  python -m repro.launch.serve --dp 8 --sampler categorical --out serve.json
"""

from __future__ import annotations

import argparse
import contextlib
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.dist.plan import ShardingPlan, use_plan
from repro.elastic import MeshLadder
from repro.models import transformer as tf
from repro.obs import from_cli as obs_from_cli
from repro.serve import POLICIES, Request, ServeEngine


def build_requests(cfg, n: int, *, max_new: int, seed: int,
                   tenants: int = 0) -> list[Request]:
    """Synthetic request set; with ``tenants > 0`` request *i* belongs to
    tenant ``t<i % tenants>`` with priority ``i % tenants`` (so priority
    and fair-share policies have classes to act on)."""
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt=rng.integers(
                1, cfg.vocab_size, size=int(rng.integers(4, 24))
            ).astype(np.int32),
            max_new_tokens=int(rng.integers(max(max_new // 2, 1), max_new + 1)),
            tenant=f"t{i % tenants}" if tenants else None,
            priority=i % tenants if tenants else 0,
        )
        for i in range(n)
    ]


def serve_trace(engine: ServeEngine, requests: list[Request], ramp: int) -> list:
    """Drive an arrival trace: one request every ``ramp`` engine steps
    (``ramp=0`` submits everything up front), then drain."""
    rids = []
    if ramp <= 0:
        rids = [engine.submit(r) for r in requests]
        engine.drain()
    else:
        pending = list(requests)
        while pending or engine.busy:
            if pending:
                rids.append(engine.submit(pending.pop(0)))
                for _ in range(ramp):
                    if not engine.step():
                        break
            else:
                engine.step()
    return [engine.result(rid) for rid in rids]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b",
                    help="configs registry arch (served reduced + shrunk "
                         "unless --full-size)")
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ramp", type=int, default=0,
                    help="submit one request every N engine steps (0 = all "
                         "up front) — a ramping trace is where the elastic "
                         "ladder pays")
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--prompt-granule", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=0,
                    help="KV pool block size in tokens (0 = prompt granule)")
    ap.add_argument("--pool-blocks", type=int, default=0,
                    help="KV pool capacity in blocks (0 = worst-case for "
                         "max_slots, pow2)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="prefill chunk length in tokens (0 = whole prompt "
                         "per boundary); chunks interleave with decode")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable chain-hash prompt prefix sharing")
    ap.add_argument("--policy", default="fifo", choices=list(POLICIES),
                    help="serve-side admission policy (serve/policy.py); "
                         "fifo reproduces the pre-hook engine token-for-token")
    ap.add_argument("--tenants", type=int, default=0,
                    help="stamp round-robin tenant/priority metadata onto the "
                         "synthetic requests (gives --policy priority|fair "
                         "classes to act on)")
    ap.add_argument("--sampler", default="greedy", choices=["greedy", "categorical"])
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dp", type=int, default=0,
                    help="pin a fixed dp-wide plan (the non-elastic baseline)")
    ap.add_argument("--elastic", action="store_true",
                    help="MeshLadder over --dp (default: all) local devices; "
                         "the live slot count picks the rung")
    ap.add_argument("--out", default=None, help="write {results, stats} JSON")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="record a Chrome/Perfetto trace (repro.obs) and "
                         "write DIR/trace.json at exit")
    ap.add_argument("--runlog", default=None, nargs="?", const="",
                    metavar="PATH",
                    help="write the schema-versioned JSONL run log "
                         "(repro.obs.runlog; read it with launch/monitor.py); "
                         "bare --runlog means <--trace DIR>/runlog.jsonl")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full_size)
    if not args.full_size:
        cfg = cfg.replace(num_layers=min(cfg.num_layers, 4), d_model=128,
                          num_heads=4, num_kv_heads=2)
    params = tf.init_params(cfg, jax.random.key(args.seed))

    plan_ctx = contextlib.nullcontext()
    ladder = None
    if args.elastic:
        ndev = args.dp or len(jax.devices())
        if ndev > len(jax.devices()):
            raise SystemExit(
                f"--dp {ndev} exceeds the {len(jax.devices())} available devices"
            )
        ladder = MeshLadder(jax.devices()[:ndev], granule=1)
    elif args.dp:
        mesh = jax.make_mesh((args.dp,), ("data",))
        plan_ctx = use_plan(ShardingPlan(mesh=mesh, tp=None))

    tracer, runlog = obs_from_cli(
        args.trace, args.runlog,
        meta={"cmd": "serve", "arch": args.arch, "requests": args.requests,
              "seed": args.seed, "elastic": bool(args.elastic),
              "policy": args.policy},
    )
    with plan_ctx:
        engine = ServeEngine(
            cfg, params, max_slots=args.max_slots, max_seq=args.max_seq,
            sampler=args.sampler, temperature=args.temperature,
            seed=args.seed, prompt_granule=args.prompt_granule,
            elastic=ladder,
            block_size=args.block_size or None,
            pool_blocks=args.pool_blocks or None,
            prefill_chunk=args.prefill_chunk,
            prefix_sharing=not args.no_prefix_sharing,
            policy=args.policy,
            tracer=tracer,
            runlog=runlog,
        )
        requests = build_requests(cfg, args.requests,
                                  max_new=args.max_new, seed=args.seed,
                                  tenants=args.tenants)
        results = serve_trace(engine, requests, args.ramp)
    if tracer is not None:
        print(f"trace: {tracer.save(args.trace)}")
    if runlog is not None:
        runlog.close()
        print(f"runlog: {runlog.path}")

    stats = engine.stats
    total = sum(r.steps for r in results)
    print(f"policy: {args.policy}"
          + (f" ({args.tenants} tenants)" if args.tenants else ""))
    print(f"served {len(results)} requests, {total} tokens "
          f"({stats.tokens_per_sec:.1f} tok/s windowed, "
          f"{stats.steps} decode steps, {stats.slot_steps} decoded lanes)")
    print(f"engine: compiles={stats.compiles} (buckets={stats.buckets} "
          f"rungs={stats.rungs}) prefill={stats.prefill_compiles} "
          f"aux={stats.aux_compiles} hits={stats.bucket_hits}")
    print(f"pool: {stats.peak_blocks}/{stats.pool_blocks} blocks peak "
          f"(block={stats.block_size}) chunks={stats.prefill_chunks} "
          f"shared_hits={stats.shared_prefill_hits} "
          f"shared_blocks={stats.shared_blocks} cow={stats.cow_copies}")
    if ladder is not None:
        print(f"elastic: ladder dp={ladder.widths} reshards={stats.reshards} "
              f"resizes={stats.resizes}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(
                {"results": [{"steps": r.steps, "tokens": r.tokens.tolist()}
                             for r in results],
                 "stats": stats.as_dict()},
                f, indent=1,
            )


if __name__ == "__main__":
    main()
