"""Gram-tier diversity estimation for transformers: probe-instrumented
forward + per-sample gradient norms via the Pallas psgn kernels.

Adds a zero 'probe' on the output of every DENSE layer of an (eager-mode)
transformer; ``grad`` w.r.t. the probes equals the upstream activation
gradients, and together with the saved inputs the per-sample gradient
squared norm of each dense kernel is

    ||G_b||_F^2 = ||X_b^T Delta_b||_F^2      (kernels/psgn.py, no
                                              materialisation of G_b)

Coverage: attention q/k/v/o + dense FFN kernels (the matmul parameters that
dominate the parameter count). Embeddings, norms, MoE expert tensors and
SSM scan parameters are excluded — ``coverage(cfg)`` reports the covered
fraction so callers can decide (the moment tier has full coverage and is
the default at scale; this tier exists for medium-scale models where exact
per-sample statistics are wanted without vmap's memory blowup).

Eager mode only (``cfg.scan_layers=False``): probes are per-layer pytree
leaves, which a scanned stack cannot address individually.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops as kernel_ops
from repro.models.layers import apply_rope, dense, embed
from repro.models import attention as attn_lib
from repro.models import ssm as ssm_lib
from repro.models import transformer as tf
from repro.models.moe import moe_apply


def _dense_probe_names(cfg: ModelConfig) -> list[tuple[str, int]]:
    """[(probe name, output width)] for every covered dense layer."""
    hd = cfg.resolved_head_dim
    out = []
    for r in range(cfg.repeats):
        for p in range(cfg.period):
            kind = cfg.pattern[p]
            base = f"l{r}p{p}"
            if kind in ("attn", "attn_local"):
                out += [
                    (f"{base}.q", cfg.num_heads * hd),
                    (f"{base}.k", cfg.num_kv_heads * hd),
                    (f"{base}.v", cfg.num_kv_heads * hd),
                    (f"{base}.o", cfg.d_model),
                ]
            if cfg.d_ff > 0 and cfg.ffn_kind(p) == "dense":
                if cfg.ffn_glu:
                    out += [(f"{base}.gate", cfg.d_ff), (f"{base}.up", cfg.d_ff)]
                else:
                    out += [(f"{base}.in", cfg.d_ff)]
                out += [(f"{base}.down", cfg.d_model)]
    return out


def probe_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        name: jnp.zeros((batch, seq, width), dt)
        for name, width in _dense_probe_names(cfg)
    }


def coverage(cfg: ModelConfig) -> float:
    """Fraction of parameters whose per-sample grad norm the gram tier covers."""
    hd = cfg.resolved_head_dim
    per_layer_attn = cfg.d_model * hd * (cfg.num_heads + 2 * cfg.num_kv_heads) \
        + cfg.num_heads * hd * cfg.d_model
    covered = 0
    for p in range(cfg.period):
        if cfg.pattern[p] in ("attn", "attn_local"):
            covered += per_layer_attn
        if cfg.d_ff > 0 and cfg.ffn_kind(p) == "dense":
            mult = 3 if cfg.ffn_glu else 2
            covered += mult * cfg.d_model * cfg.d_ff
    covered *= cfg.repeats
    from repro.utils import pytree as ptu

    total = ptu.tree_count(tf.param_specs(cfg))
    return covered / total


def loss_with_probes(cfg: ModelConfig, params, probes: dict, batch: dict,
                     moe_groups: int = 1):
    """(loss, saved dense-layer inputs). Same math as tf.loss_fn (verified in
    tests to the last ulp when probes are zero)."""
    assert not cfg.scan_layers, "gram probes require eager (non-scanned) mode"
    acts: dict = {}

    def pdense(p, x, name):
        if name in probes:
            acts[name] = x
            return dense(p, x, probe=probes[name])
        return dense(p, x)

    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.input_mode == "tokens":
        x = embed(params["embed"], batch["tokens"]).astype(cdt)
    else:
        x = dense(params["frontend"], batch["embeddings"].astype(cdt))
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    hd = cfg.resolved_head_dim
    aux = jnp.zeros((), jnp.float32)

    for r in range(cfg.repeats):
        for p in range(cfg.period):
            blk = jax.tree.map(lambda leaf: leaf[r], params[f"pos{p}"])
            kind = cfg.pattern[p]
            base = f"l{r}p{p}"
            h = tf._norm(cfg, blk["norm"], x)
            if kind == "mamba":
                h = ssm_lib.mamba_apply(
                    blk["mamba"], h, d_state=cfg.ssm_state, dt_rank=cfg.dt_rank,
                    chunk=cfg.ssm_chunk,
                )
            else:
                ap = blk["attn"]
                q = pdense(ap["q"], h, f"{base}.q").reshape(b, s, cfg.num_heads, hd)
                k = pdense(ap["k"], h, f"{base}.k").reshape(b, s, cfg.num_kv_heads, hd)
                v = pdense(ap["v"], h, f"{base}.v").reshape(b, s, cfg.num_kv_heads, hd)
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
                window = cfg.window if kind == "attn_local" else None
                o = attn_lib.attention(q, k, v, causal=cfg.causal, window=window,
                                       softcap=cfg.attn_softcap)
                h = pdense(ap["o"], o.reshape(b, s, cfg.num_heads * hd), f"{base}.o")
            x = x + h
            if "ffn" in blk:
                h = tf._norm(cfg, blk["ffn_norm"], x)
                if cfg.ffn_kind(p) == "moe":
                    h, a = moe_apply(blk["ffn"], h, top_k=cfg.top_k,
                                     capacity_factor=cfg.capacity_factor,
                                     groups=moe_groups, act=cfg.ffn_act)
                    aux = aux + a
                else:
                    from repro.models.layers import ACTIVATIONS

                    act_fn = ACTIVATIONS[cfg.ffn_act]
                    if cfg.ffn_glu:
                        hh = act_fn(pdense(blk["ffn"]["w_gate"], h, f"{base}.gate"))
                        hh = hh * pdense(blk["ffn"]["w_up"], h, f"{base}.up")
                    else:
                        hh = act_fn(pdense(blk["ffn"]["w_in"], h, f"{base}.in"))
                    h = pdense(blk["ffn"]["w_out"], hh, f"{base}.down")
                x = x + h

    x = tf._norm(cfg, params["final_norm"], x)
    loss = tf.xent_chunked(x, params["lm_head"]["kernel"], batch["targets"],
                           cfg.xent_chunk, cfg.final_softcap)
    if cfg.num_experts:
        loss = loss + 0.01 * aux
    return loss, acts


def persample_sq_norms_gram(cfg: ModelConfig, params, batch: dict,
                            moe_groups: int = 1) -> jax.Array:
    """(B,) per-sample gradient sq-norms over the covered dense kernels.

    The sample unit is a SEQUENCE; per-sample loss = that sequence's mean
    token CE (matching vmap-of-per-sequence-loss semantics). loss is the
    batch mean, so probe grads are scaled by B."""
    tokens = batch["tokens"] if "tokens" in batch else batch["embeddings"]
    b, s = tokens.shape[0], tokens.shape[1]
    probes = probe_specs(cfg, b, s)
    (loss, acts), pgrads = jax.value_and_grad(
        lambda pr: loss_with_probes(cfg, params, pr, batch, moe_groups),
        has_aux=True,
    )(probes)
    total = None
    for name, x in acts.items():
        delta = pgrads[name] * np.float32(b)
        v = kernel_ops.persample_sq_norm(x, delta)
        total = v if total is None else total + v
    return total
