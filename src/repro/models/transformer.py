"""The composable decoder/encoder stack covering all 10 assigned archs.

A model is (pattern x repeats) blocks; each pattern position has a mixer
('attn' | 'attn_local' | 'mamba') and an FFN kind ('dense' | 'moe' | 'none').
Parameters for each pattern position are STACKED over the repeat axis R and
the stack runs as one ``lax.scan`` (+ optional ``jax.checkpoint``) — compile
time and HLO size are O(period), not O(num_layers), which is what makes the
126-layer 405B dry-run compile quickly.

Cross-entropy is CHUNKED over tokens (never materialises the (B,S,V) logits —
at vocab 256k that tensor alone would be ~0.5 TB for the train_4k cell).

Entry points:
  init_params(cfg, key)          parameter pytree (stacked blocks)
  loss_fn(cfg, params, batch)    scalar mean CE loss (+ MoE aux)
  prefill_step(cfg, params, batch)  -> (last_logits, cache)
  decode_step(cfg, params, cache, tokens) -> (logits, cache)
  init_cache(cfg, batch, seq_len)   cache pytree (or ShapeDtypeStructs via
                                    jax.eval_shape for the dry-run)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.plan import constrain
from repro.kernels import attention as kernels_attn
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    ACTIVATIONS,
    apply_rope,
    dense,
    dense_init,
    embed,
    embed_init,
    layer_norm,
    norm_init,
    rms_norm,
)

PyTree = Any


def _norm(cfg: ModelConfig, params, x):
    return rms_norm(params, x) if cfg.norm_type == "rms" else layer_norm(params, x)


def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_attn(cfg: ModelConfig, key) -> dict:
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = _pdtype(cfg)
    return {
        "q": dense_init(k1, cfg.d_model, cfg.num_heads * hd, dt, use_bias=cfg.qkv_bias),
        "k": dense_init(k2, cfg.d_model, cfg.num_kv_heads * hd, dt, use_bias=cfg.qkv_bias),
        "v": dense_init(k3, cfg.d_model, cfg.num_kv_heads * hd, dt, use_bias=cfg.qkv_bias),
        "o": dense_init(k4, cfg.num_heads * hd, cfg.d_model, dt),
    }


def _init_ffn(cfg: ModelConfig, key, kind: str) -> dict:
    dt = _pdtype(cfg)
    if kind == "moe":
        return moe_lib.moe_init(key, cfg.d_model, cfg.d_ff, cfg.num_experts, dt)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.ffn_glu:
        return {
            "w_gate": dense_init(k1, cfg.d_model, cfg.d_ff, dt),
            "w_up": dense_init(k2, cfg.d_model, cfg.d_ff, dt),
            "w_out": dense_init(k3, cfg.d_ff, cfg.d_model, dt),
        }
    return {
        "w_in": dense_init(k1, cfg.d_model, cfg.d_ff, dt),
        "w_out": dense_init(k3, cfg.d_ff, cfg.d_model, dt),
    }


def _init_block(cfg: ModelConfig, key, pos: int) -> dict:
    kind = cfg.pattern[pos]
    ffn_kind = cfg.ffn_kind(pos) if cfg.d_ff > 0 else "none"
    k_mix, k_ffn = jax.random.split(key)
    dt = _pdtype(cfg)
    block: dict = {"norm": norm_init(cfg.d_model, dt)}
    if kind in ("attn", "attn_local"):
        block["attn"] = _init_attn(cfg, k_mix)
    elif kind == "mamba":
        block["mamba"] = ssm_lib.mamba_init(
            k_mix, cfg.d_model, cfg.ssm_state, cfg.ssm_expand, cfg.ssm_conv,
            cfg.ssm_dt_rank, dt,
        )
    else:
        raise ValueError(f"unknown mixer kind {kind!r}")
    if ffn_kind != "none":
        block["ffn_norm"] = norm_init(cfg.d_model, dt)
        block["ffn"] = _init_ffn(cfg, k_ffn, ffn_kind)
    return block


def init_params(cfg: ModelConfig, key) -> PyTree:
    keys = jax.random.split(key, cfg.period + 3)
    dt = _pdtype(cfg)
    params: dict = {}
    if cfg.input_mode == "tokens":
        params["embed"] = embed_init(keys[-1], cfg.vocab_size, cfg.d_model, dt)
    else:
        params["frontend"] = dense_init(keys[-1], cfg.d_model, cfg.d_model, dt)
    for p in range(cfg.period):
        stack_keys = jax.random.split(keys[p], cfg.repeats)
        params[f"pos{p}"] = jax.vmap(lambda k: _init_block(cfg, k, p))(stack_keys)
    params["final_norm"] = norm_init(cfg.d_model, dt)
    params["lm_head"] = dense_init(keys[-2], cfg.d_model, cfg.vocab_size, dt)
    return params


def _blocks(params: PyTree, cfg: ModelConfig) -> dict:
    return {f"pos{p}": params[f"pos{p}"] for p in range(cfg.period)}


# ---------------------------------------------------------------------------
# Block apply (single layer, full sequence)
# ---------------------------------------------------------------------------


def _attn_sublayer(cfg: ModelConfig, p: dict, x: jax.Array, kind: str,
                   positions: jax.Array) -> jax.Array:
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(p["q"], x).reshape(b, s, cfg.num_heads, hd)
    k = dense(p["k"], x).reshape(b, s, cfg.num_kv_heads, hd)
    v = dense(p["v"], x).reshape(b, s, cfg.num_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    window = cfg.window if kind == "attn_local" else None
    impl = attn_lib.resolve_impl(cfg, s)
    if impl == "pallas":
        out = kernels_attn.flash_attention(
            q, k, v, cfg.causal, window, cfg.attn_softcap,
            min(cfg.flash_q_block, s), min(cfg.flash_kv_block, s),
        )
    elif impl == "flash":
        out = attn_lib.flash_attention(
            q, k, v, cfg.causal, window, cfg.attn_softcap,
            min(cfg.flash_q_block, s), min(cfg.flash_kv_block, s),
        )
    else:
        out = attn_lib.attention(
            q, k, v, causal=cfg.causal, window=window, softcap=cfg.attn_softcap
        )
    return dense(p["o"], out.reshape(b, s, cfg.num_heads * hd))


def _ffn_sublayer(cfg: ModelConfig, p: dict, x: jax.Array, kind: str,
                  moe_groups: int) -> tuple[jax.Array, jax.Array]:
    act = ACTIVATIONS[cfg.ffn_act]
    if kind == "moe":
        from repro.dist.plan import current_plan

        plan = current_plan()
        if cfg.moe_impl == "ep" and plan is not None:
            from repro.models.moe_ep import moe_apply_ep

            return moe_apply_ep(
                p, x, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                act=cfg.ffn_act, mesh=plan.mesh, dp_axes=plan.dp,
                ep_axes=plan.ep, tp_axis=plan.tp,
            )
        return moe_lib.moe_apply(
            p, x, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            groups=moe_groups, act=cfg.ffn_act,
        )
    if cfg.ffn_glu:
        h = act(dense(p["w_gate"], x)) * dense(p["w_up"], x)
    else:
        h = act(dense(p["w_in"], x))
    return dense(p["w_out"], h), jnp.zeros((), jnp.float32)


def _block_apply(cfg: ModelConfig, pos: int, p: dict, x: jax.Array,
                 positions: jax.Array, moe_groups: int) -> tuple[jax.Array, jax.Array]:
    kind = cfg.pattern[pos]
    h = _norm(cfg, p["norm"], x)
    if kind == "mamba":
        h = ssm_lib.mamba_apply(
            p["mamba"], h, d_state=cfg.ssm_state, dt_rank=cfg.dt_rank, chunk=cfg.ssm_chunk
        )
    else:
        h = _attn_sublayer(cfg, p["attn"], h, kind, positions)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        h = _norm(cfg, p["ffn_norm"], x)
        h, aux = _ffn_sublayer(cfg, p["ffn"], x=h, kind=cfg.ffn_kind(pos), moe_groups=moe_groups)
        x = x + h
    return x, aux


# ---------------------------------------------------------------------------
# Stack
# ---------------------------------------------------------------------------


def _run_stack(cfg: ModelConfig, params: PyTree, x: jax.Array,
               positions: jax.Array, moe_groups: int) -> tuple[jax.Array, jax.Array]:
    blocks = _blocks(params, cfg)

    # For multi-position patterns (gemma2 period 2, jamba period 8) remat each
    # BLOCK, not just the scan body: otherwise the backward of one scan step
    # holds `period` layers of intermediates live at once (measured 47 GiB on
    # jamba train_4k vs ~12 GiB with per-block remat).
    def apply_block(p, layer_p, h):
        if cfg.remat and cfg.period > 1:
            return jax.checkpoint(
                lambda lp, hh: _block_apply(cfg, p, lp, hh, positions, moe_groups),
                prevent_cse=False,
            )(layer_p, h)
        return _block_apply(cfg, p, layer_p, h, positions, moe_groups)

    def body(carry, layer):
        h, aux = carry
        h = constrain(h, "residual")
        for p in range(cfg.period):
            h, a = apply_block(p, layer[f"pos{p}"], h)
            aux = aux + a
        return (h, aux), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    else:
        aux = jnp.zeros((), jnp.float32)
        for r in range(cfg.repeats):
            layer = jax.tree.map(lambda leaf: leaf[r], blocks)
            (x, aux), _ = body((x, aux), layer)
    return x, aux


def _embed_input(cfg: ModelConfig, params: PyTree, batch: dict) -> jax.Array:
    cdt = _cdtype(cfg)
    if cfg.input_mode == "tokens":
        return embed(params["embed"], batch["tokens"]).astype(cdt)
    return dense(params["frontend"], batch["embeddings"].astype(cdt))


# ---------------------------------------------------------------------------
# Loss (chunked cross-entropy)
# ---------------------------------------------------------------------------


def _chunk_ce(xc: jax.Array, kernel: jax.Array, tc: jax.Array, softcap):
    """One chunk's CE pieces. xc: (B,C,d); tc: (B,C). Returns (loss_sum, aux
    for backward): logits are formed in f32 and immediately reduced."""
    logits = (xc @ kernel.astype(xc.dtype)).astype(jnp.float32)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
    return jnp.sum(lse - tgt)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def xent_chunked(x: jax.Array, kernel: jax.Array, targets: jax.Array,
                 chunk: int = 512, softcap: float | None = None) -> jax.Array:
    """Mean token CE, chunked over the SEQUENCE axis so the (B,S,V) logits
    are never materialised (vocab 256k at train_4k would be ~0.5 TB).

    custom_vjp: the naive scan-under-grad would store every chunk's f32
    logits for the backward pass (measured 4e13 HBM bytes/step on qwen2);
    here the backward RECOMPUTES each chunk's logits and accumulates dW on
    the fly — residuals are just (x, kernel, targets).
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def body(acc, i):
        xc = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
        tc = jax.lax.dynamic_slice_in_dim(targets, i * chunk, chunk, axis=1)
        return acc + _chunk_ce(xc, kernel, tc, softcap), None

    loss_sum, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), jnp.arange(nc))
    return loss_sum / (b * s)


def _xent_fwd(x, kernel, targets, chunk, softcap):
    return xent_chunked(x, kernel, targets, chunk, softcap), (x, kernel, targets)


def _xent_bwd(chunk, softcap, res, g):
    x, kernel, targets = res
    b, s, d = x.shape
    nc = s // min(chunk, s)
    chunk = min(chunk, s)
    v = kernel.shape[1]
    scale = g / (b * s)

    def body(dw_acc, i):
        xc = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
        tc = jax.lax.dynamic_slice_in_dim(targets, i * chunk, chunk, axis=1)
        logits = (xc @ kernel.astype(xc.dtype)).astype(jnp.float32)
        if softcap is not None:
            capped = jnp.tanh(logits / softcap)
            probs = jax.nn.softmax(capped * softcap, axis=-1)
        else:
            probs = jax.nn.softmax(logits, axis=-1)
        dlogits = probs - jax.nn.one_hot(tc, v, dtype=jnp.float32)
        if softcap is not None:
            dlogits = dlogits * (1.0 - capped * capped)
        dlogits = (dlogits * scale).astype(x.dtype)
        dxc = dlogits @ kernel.astype(x.dtype).T
        dw_acc = dw_acc + jnp.einsum(
            "bcd,bcv->dv", xc.astype(jnp.float32), dlogits.astype(jnp.float32)
        )
        return dw_acc, dxc

    dw0 = jnp.zeros(kernel.shape, jnp.float32)
    dw, dx_chunks = jax.lax.scan(body, dw0, jnp.arange(nc))
    dx = jnp.moveaxis(dx_chunks, 0, 1).reshape(b, s, d)
    return dx, dw.astype(kernel.dtype), None


xent_chunked.defvjp(_xent_fwd, _xent_bwd)


def loss_fn(cfg: ModelConfig, params: PyTree, batch: dict,
            moe_groups: int = 1) -> tuple[jax.Array, dict]:
    x = _embed_input(cfg, params, batch)
    positions = jnp.arange(x.shape[1])[None, :]
    x, aux = _run_stack(cfg, params, x, positions, moe_groups)
    x = _norm(cfg, params["final_norm"], x)
    loss = xent_chunked(
        x, params["lm_head"]["kernel"], batch["targets"], cfg.xent_chunk, cfg.final_softcap
    )
    metrics = {"ce_loss": loss, "moe_aux": aux}
    if cfg.num_experts:
        loss = loss + 0.01 * aux
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving: prefill + decode with per-pattern-position caches
# ---------------------------------------------------------------------------


def _cache_len_for(cfg: ModelConfig, pos: int, seq_len: int) -> int:
    if cfg.pattern[pos] == "attn_local" and cfg.window is not None:
        return min(cfg.window, seq_len)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *, skip: tuple = ()) -> PyTree:
    """Decode cache sized for a context of ``seq_len`` tokens.

    ``skip`` drops pattern positions from the tree — the paged serve path
    keeps full-attention KV in the block pool (``init_pages``) and only the
    O(1)-per-slot state (windowed rings, SSM state, lengths) stays dense."""
    cdt = _cdtype(cfg)
    hd = cfg.resolved_head_dim
    cache: dict = {"len": jnp.zeros((), jnp.int32)}
    for p in range(cfg.period):
        if p in skip:
            continue
        kind = cfg.pattern[p]
        if kind == "mamba":
            d_inner = cfg.ssm_expand * cfg.d_model
            cache[f"pos{p}"] = {
                "h": jnp.zeros((cfg.repeats, batch, d_inner, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((cfg.repeats, batch, cfg.ssm_conv - 1, d_inner), cdt),
            }
        else:
            s_c = _cache_len_for(cfg, p, seq_len)
            cache[f"pos{p}"] = {
                "k": jnp.zeros((cfg.repeats, batch, s_c, cfg.num_kv_heads, hd), cdt),
                "v": jnp.zeros((cfg.repeats, batch, s_c, cfg.num_kv_heads, hd), cdt),
            }
    return cache


def paged_positions(cfg: ModelConfig) -> tuple[int, ...]:
    """Pattern positions whose KV lives in the block pool when serving paged:
    the FULL-attention positions, whose per-slot cost would otherwise be
    O(max_seq).  Windowed rings and SSM state are already O(1) per slot and
    stay in the dense per-slot cache."""
    return tuple(p for p in range(cfg.period) if cfg.pattern[p] == "attn")


def init_pages(cfg: ModelConfig, num_blocks: int, block_size: int) -> PyTree:
    """The paged KV pool: per full-attention pattern position, a flat pool of
    ``num_blocks`` blocks of ``block_size`` token rows, stacked over repeats
    (same scan layout as the dense cache).  Block 0 is the sentinel — never
    allocated, the write target of inactive lanes (see serve/blocks.py)."""
    cdt = _cdtype(cfg)
    hd = cfg.resolved_head_dim
    return {
        f"pos{p}": {
            "k": jnp.zeros(
                (cfg.repeats, num_blocks, block_size, cfg.num_kv_heads, hd), cdt
            ),
            "v": jnp.zeros(
                (cfg.repeats, num_blocks, block_size, cfg.num_kv_heads, hd), cdt
            ),
        }
        for p in paged_positions(cfg)
    }


def decode_step(cfg: ModelConfig, params: PyTree, cache: PyTree,
                tokens_or_embs: jax.Array,
                moe_groups: int = 1, *,
                pages: PyTree | None = None,
                tables: jax.Array | None = None):
    """One token for every sequence in the batch. tokens: (B,1) int or
    (B,1,d) embeddings. Returns (logits (B,1,V), updated cache) — plus the
    updated pages when running paged.

    ``cache["len"]`` is either a scalar (every sequence at the same position
    — the classic lockstep-batch regime) or a ``(B,)`` vector of PER-SLOT
    positions (the ``repro.serve`` continuous-batching regime, where slots
    are admitted/retired independently and each row lives on its own
    timeline: RoPE, the ring-buffer write slot, and the validity mask are
    all per-row).

    Paged regime (``pages``/``tables`` given): full-attention positions read
    and write the block pool instead of a dense per-slot cache.  ``tables``
    is ``(B, n_max)`` int32 — slot b's logical block i lives at pool block
    ``tables[b, i]`` — so each row writes its current token at
    ``tables[b, pos // block]`` offset ``pos % block`` and reads its whole
    context through a table gather.  Inactive lanes point at sentinel block
    0 (written garbage, masked by the validity count on read)."""
    cdt = _cdtype(cfg)
    if cfg.input_mode == "tokens":
        x = embed(params["embed"], tokens_or_embs).astype(cdt)
    else:
        x = dense(params["frontend"], tokens_or_embs.astype(cdt))
    b = x.shape[0]
    pos_now = cache["len"]  # () int32, or (B,) int32 per-slot
    per_slot = jnp.ndim(pos_now) == 1
    hd = cfg.resolved_head_dim
    pages_in = pages if pages is not None else {}

    def layer_body(x, scanned):
        layer, lcache, lpages = scanned
        new_cache, new_pages = {}, {}
        for p in range(cfg.period):
            kind = cfg.pattern[p]
            blk = layer[f"pos{p}"]
            h = _norm(cfg, blk["norm"], x)
            if kind == "mamba":
                h, new_state = ssm_lib.mamba_decode_step(
                    blk["mamba"], lcache[f"pos{p}"], h,
                    d_state=cfg.ssm_state, dt_rank=cfg.dt_rank,
                )
                new_cache[f"pos{p}"] = new_state
            elif f"pos{p}" in pages_in:
                ap = blk["attn"]
                q = dense(ap["q"], h).reshape(b, 1, cfg.num_heads, hd)
                k = dense(ap["k"], h).reshape(b, 1, cfg.num_kv_heads, hd)
                v = dense(ap["v"], h).reshape(b, 1, cfg.num_kv_heads, hd)
                posv = (jnp.reshape(pos_now, (b,)) if per_slot
                        else jnp.full((b,), pos_now, jnp.int32))
                q = apply_rope(q, posv[:, None], cfg.rope_theta)
                k = apply_rope(k, posv[:, None], cfg.rope_theta)
                pk, pv = lpages[f"pos{p}"]["k"], lpages[f"pos{p}"]["v"]
                blk_sz = pk.shape[1]
                rows = jnp.arange(b)
                wb = tables[rows, posv // blk_sz]  # (B,) pool block per row
                off = jnp.mod(posv, blk_sz)
                pk = pk.at[wb, off].set(k[:, 0])
                pv = pv.at[wb, off].set(v[:, 0])
                # write-then-read: this token is visible to its own query
                if cfg.attn_impl == "pallas":
                    # fused lane: the table gather happens inside the kernel's
                    # KV loop — the (B, n_max*block, KV, hd) gathered context
                    # below never materialises
                    h = kernels_attn.paged_decode_attention(
                        q, pk, pv, tables, posv + 1, softcap=cfg.attn_softcap,
                    )
                else:
                    gk = jnp.take(pk, tables, axis=0).reshape(b, -1, cfg.num_kv_heads, hd)
                    gv = jnp.take(pv, tables, axis=0).reshape(b, -1, cfg.num_kv_heads, hd)
                    h = attn_lib.decode_attention(
                        q, gk, gv, posv + 1, softcap=cfg.attn_softcap, window=None,
                    )
                h = dense(ap["o"], h.reshape(b, 1, cfg.num_heads * hd))
                new_pages[f"pos{p}"] = {"k": pk, "v": pv}
            else:
                ap = blk["attn"]
                q = dense(ap["q"], h).reshape(b, 1, cfg.num_heads, hd)
                k = dense(ap["k"], h).reshape(b, 1, cfg.num_kv_heads, hd)
                v = dense(ap["v"], h).reshape(b, 1, cfg.num_kv_heads, hd)
                if per_slot:
                    posb = jnp.reshape(pos_now, (b, 1))
                else:
                    posb = jnp.full((b, 1), pos_now, jnp.int32)
                q = apply_rope(q, posb, cfg.rope_theta)
                k = apply_rope(k, posb, cfg.rope_theta)
                s_c = lcache[f"pos{p}"]["k"].shape[1]
                slot = jnp.mod(pos_now, s_c)  # ring buffer for windowed layers
                if per_slot:
                    # each row writes at its own ring slot: a batched scatter
                    # touches B cache rows, not the whole (B, S, KV, hd) cache
                    rows = jnp.arange(b)
                    kc = lcache[f"pos{p}"]["k"].at[rows, slot].set(k[:, 0])
                    vc = lcache[f"pos{p}"]["v"].at[rows, slot].set(v[:, 0])
                else:
                    kc = jax.lax.dynamic_update_slice_in_dim(lcache[f"pos{p}"]["k"], k, slot, axis=1)
                    vc = jax.lax.dynamic_update_slice_in_dim(lcache[f"pos{p}"]["v"], v, slot, axis=1)
                n_valid = jnp.minimum(pos_now + 1, s_c)
                # Ring buffer: windowed layers size their cache to the window,
                # so every retained slot is attendable — mask only on validity.
                h = attn_lib.decode_attention(
                    q, kc, vc, n_valid, softcap=cfg.attn_softcap, window=None,
                )
                h = dense(ap["o"], h.reshape(b, 1, cfg.num_heads * hd))
                new_cache[f"pos{p}"] = {"k": kc, "v": vc}
            x = x + h
            if "ffn" in blk:
                h = _norm(cfg, blk["ffn_norm"], x)
                h, _ = _ffn_sublayer(cfg, blk["ffn"], x=h, kind=cfg.ffn_kind(p), moe_groups=moe_groups)
                x = x + h
        return x, (new_cache, new_pages)

    blocks = _blocks(params, cfg)
    layer_caches = {k: v for k, v in cache.items() if k != "len"}
    x, (new_caches, new_pages) = jax.lax.scan(
        layer_body, x, (blocks, layer_caches, pages_in)
    )
    x = _norm(cfg, params["final_norm"], x)
    logits = (x @ params["lm_head"]["kernel"].astype(x.dtype)).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    new_caches["len"] = cache["len"] + 1
    if pages is None:
        return logits, new_caches
    return logits, new_caches, new_pages


def prefill_step(cfg: ModelConfig, params: PyTree, batch: dict,
                 moe_groups: int = 1) -> tuple[jax.Array, PyTree]:
    """Encode a prompt; returns (last-position logits, populated cache).

    Encoder-only configs (causal=False) return full logits and no cache."""
    cdt = _cdtype(cfg)
    x = _embed_input(cfg, params, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    hd = cfg.resolved_head_dim

    if not cfg.causal:  # encoder: plain forward
        h, _ = _run_stack(cfg, params, x, positions, moe_groups=moe_groups)
        h = _norm(cfg, params["final_norm"], h)
        logits = (h @ params["lm_head"]["kernel"].astype(h.dtype)).astype(jnp.float32)
        return logits, {}

    def layer_body(carry, layer):
        x = carry
        new_cache = {}
        for p in range(cfg.period):
            kind = cfg.pattern[p]
            blk = layer[f"pos{p}"]
            h = _norm(cfg, blk["norm"], x)
            if kind == "mamba":
                # run the chunked scan and keep the final state for decode
                h_out, state = ssm_lib.mamba_apply(
                    blk["mamba"], h, d_state=cfg.ssm_state, dt_rank=cfg.dt_rank,
                    chunk=cfg.ssm_chunk, return_state=True,
                )
                new_cache[f"pos{p}"] = {
                    "h": state["h"],
                    "conv": state["conv"].astype(cdt),
                }
                h = h_out
            else:
                ap = blk["attn"]
                q = dense(ap["q"], h).reshape(b, s, cfg.num_heads, hd)
                k = dense(ap["k"], h).reshape(b, s, cfg.num_kv_heads, hd)
                v = dense(ap["v"], h).reshape(b, s, cfg.num_kv_heads, hd)
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
                window = cfg.window if kind == "attn_local" else None
                # honor cfg.attn_impl exactly like _attn_sublayer: "auto"
                # picks by length, a pinned impl is obeyed
                impl = attn_lib.resolve_impl(cfg, s)
                if impl == "pallas":
                    h = kernels_attn.flash_attention(
                        q, k, v, True, window, cfg.attn_softcap,
                        min(cfg.flash_q_block, s), min(cfg.flash_kv_block, s),
                    )
                elif impl == "flash":
                    h = attn_lib.flash_attention(
                        q, k, v, True, window, cfg.attn_softcap,
                        min(cfg.flash_q_block, s), min(cfg.flash_kv_block, s),
                    )
                else:
                    h = attn_lib.attention(q, k, v, causal=True, window=window,
                                           softcap=cfg.attn_softcap)
                h = dense(ap["o"], h.reshape(b, s, cfg.num_heads * hd))
                s_c = _cache_len_for(cfg, p, s)
                new_cache[f"pos{p}"] = {
                    "k": k[:, -s_c:].astype(cdt),
                    "v": v[:, -s_c:].astype(cdt),
                }
            x = x + h
            if "ffn" in blk:
                h = _norm(cfg, blk["ffn_norm"], x)
                h, _ = _ffn_sublayer(cfg, blk["ffn"], x=h, kind=cfg.ffn_kind(p), moe_groups=moe_groups)
                x = x + h
        return x, new_cache

    body = layer_body
    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, caches = jax.lax.scan(body, x, _blocks(params, cfg))
    x = _norm(cfg, params["final_norm"], x)
    last = x[:, -1:, :]
    logits = (last @ params["lm_head"]["kernel"].astype(last.dtype)).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    caches["len"] = jnp.full((), s, jnp.int32)
    return logits, caches


def prefill_chunk(cfg: ModelConfig, params: PyTree, row: PyTree, pages: PyTree,
                  batch: dict, offset: jax.Array, prior_tab: jax.Array,
                  write_tab: jax.Array, moe_groups: int = 1):
    """One chunk of a paged, resumable prefill for a SINGLE request.

    The prompt is fed in block-aligned chunks so a long prompt never stalls
    the running decode batch: the engine interleaves one chunk per request
    per boundary.  Each chunk attends to (a) the prior context gathered from
    the request's already-written pool blocks (full-attention positions) or
    its windowed ring / SSM state (carried in ``row``), and (b) its own keys
    — causally, at absolute positions ``offset + arange(C)``.

    Args:
      row: per-request carry — ``{"len": (1,)}`` plus windowed-ring and SSM
        entries (``init_cache(cfg, 1, max_seq, skip=paged_positions(cfg))``
        shapes); full-attention positions have NO row entry, their KV goes
        straight to ``pages`` at ``write_tab``.
      pages: the block pool (``init_pages`` layout).
      batch: ``{"tokens": (1, C)}`` — C a multiple of the block size.
      offset: () int32, this chunk's first absolute position (block-aligned).
      prior_tab: (nbp,) int32 prior prompt blocks in logical order, padded
        with sentinel 0 up to a pow2 length (so the compile key is
        ``(C, nbp, rung)``, not per-offset); entries past ``offset`` tokens
        are masked on read.
      write_tab: (C // block,) int32 destination blocks for this chunk.

    Returns (last-position logits (1,1,V), new row, new pages).
    """
    cdt = _cdtype(cfg)
    x = _embed_input(cfg, params, batch)
    _, c, _ = x.shape
    hd = cfg.resolved_head_dim
    positions = offset + jnp.arange(c)[None, :]  # (1, C)
    q_pos = offset + jnp.arange(c)
    paged = set(paged_positions(cfg))

    def _chunk_attn(q, k, v, k_pos, k_valid, window):
        # both prior-context layouts funnel through here; the pallas lane
        # runs the tiled kernel (pads ragged K internally), everything else
        # keeps the XLA chunk_attention
        if cfg.attn_impl == "pallas":
            return kernels_attn.chunk_attention(
                q, k, v, q_pos, k_pos, k_valid, window=window,
                softcap=cfg.attn_softcap,
                q_block=min(cfg.flash_q_block, c),
                kv_block=min(cfg.flash_kv_block, k.shape[1]),
            )
        return attn_lib.chunk_attention(
            q, k, v, q_pos, k_pos, k_valid, window=window,
            softcap=cfg.attn_softcap,
        )

    def layer_body(x, scanned):
        layer, lrow, lpages = scanned
        new_row, new_pages = {}, {}
        for p in range(cfg.period):
            kind = cfg.pattern[p]
            blk = layer[f"pos{p}"]
            h = _norm(cfg, blk["norm"], x)
            if kind == "mamba":
                # the internal chunked scan needs an even split; fall back to
                # one chunk when the prefill chunk doesn't divide
                sc = min(cfg.ssm_chunk, c)
                if c % sc:
                    sc = c
                h_out, state = ssm_lib.mamba_apply(
                    blk["mamba"], h, d_state=cfg.ssm_state, dt_rank=cfg.dt_rank,
                    chunk=sc, return_state=True, state=lrow[f"pos{p}"],
                )
                new_row[f"pos{p}"] = {
                    "h": state["h"],
                    "conv": state["conv"].astype(cdt),
                }
                h = h_out
            elif p in paged:  # full attention: prior context from the pool
                ap = blk["attn"]
                q = dense(ap["q"], h).reshape(1, c, cfg.num_heads, hd)
                k = dense(ap["k"], h).reshape(1, c, cfg.num_kv_heads, hd)
                v = dense(ap["v"], h).reshape(1, c, cfg.num_kv_heads, hd)
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
                pk, pv = lpages[f"pos{p}"]["k"], lpages[f"pos{p}"]["v"]
                blk_sz = pk.shape[1]
                np_prior = prior_tab.shape[0]
                prior = np_prior * blk_sz
                gk = jnp.take(pk, prior_tab, axis=0).reshape(1, prior, cfg.num_kv_heads, hd)
                gv = jnp.take(pv, prior_tab, axis=0).reshape(1, prior, cfg.num_kv_heads, hd)
                k_pos = jnp.concatenate([jnp.arange(prior), q_pos])
                k_valid = jnp.concatenate(
                    [jnp.arange(prior) < offset, jnp.ones((c,), bool)]
                )
                h = _chunk_attn(
                    q, jnp.concatenate([gk, k], axis=1),
                    jnp.concatenate([gv, v], axis=1),
                    k_pos, k_valid, window=None,
                )
                h = dense(ap["o"], h.reshape(1, c, cfg.num_heads * hd))
                pk = pk.at[write_tab].set(k[0].reshape(-1, blk_sz, cfg.num_kv_heads, hd))
                pv = pv.at[write_tab].set(v[0].reshape(-1, blk_sz, cfg.num_kv_heads, hd))
                new_pages[f"pos{p}"] = {"k": pk, "v": pv}
            elif kind == "attn_local":  # prior context from the windowed ring
                ap = blk["attn"]
                q = dense(ap["q"], h).reshape(1, c, cfg.num_heads, hd)
                k = dense(ap["k"], h).reshape(1, c, cfg.num_kv_heads, hd)
                v = dense(ap["v"], h).reshape(1, c, cfg.num_kv_heads, hd)
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
                ring_k, ring_v = lrow[f"pos{p}"]["k"], lrow[f"pos{p}"]["v"]
                s_c = ring_k.shape[1]
                prior_pos = offset - s_c + jnp.arange(s_c)  # chronological
                idx = jnp.mod(prior_pos, s_c)
                gk = jnp.take(ring_k, idx, axis=1)
                gv = jnp.take(ring_v, idx, axis=1)
                k_pos = jnp.concatenate([prior_pos, q_pos])
                k_valid = jnp.concatenate([prior_pos >= 0, jnp.ones((c,), bool)])
                h = _chunk_attn(
                    q, jnp.concatenate([gk, k], axis=1),
                    jnp.concatenate([gv, v], axis=1),
                    k_pos, k_valid, window=cfg.window,
                )
                h = dense(ap["o"], h.reshape(1, c, cfg.num_heads * hd))
                w = min(c, s_c)  # the chunk tail that survives into the ring
                widx = jnp.mod(offset + c - w + jnp.arange(w), s_c)
                ring_k = ring_k.at[:, widx].set(k[:, c - w:])
                ring_v = ring_v.at[:, widx].set(v[:, c - w:])
                new_row[f"pos{p}"] = {"k": ring_k, "v": ring_v}
            else:
                raise ValueError(
                    f"pattern position {p} ({kind!r}) has no paged-prefill path"
                )
            x = x + h
            if "ffn" in blk:
                h = _norm(cfg, blk["ffn_norm"], x)
                h, _ = _ffn_sublayer(cfg, blk["ffn"], x=h, kind=cfg.ffn_kind(p), moe_groups=moe_groups)
                x = x + h
        return x, (new_row, new_pages)

    blocks = _blocks(params, cfg)
    row_layers = {k: v for k, v in row.items() if k != "len"}
    x, (new_row, new_pages) = jax.lax.scan(
        layer_body, x, (blocks, row_layers, pages)
    )
    x = _norm(cfg, params["final_norm"], x)
    last = x[:, -1:, :]
    logits = (last @ params["lm_head"]["kernel"].astype(last.dtype)).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    new_row["len"] = row["len"] + c
    return logits, new_row, new_pages


# ---------------------------------------------------------------------------
# Shape stand-ins (dry-run)
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> PyTree:
    """ShapeDtypeStruct tree of the parameters — no allocation."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> PyTree:
    return jax.eval_shape(lambda: init_cache(cfg, batch, seq_len))
