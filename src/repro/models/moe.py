"""Mixture-of-Experts FFN: top-k routing with capacity-based grouped GEMM.

Formulation chosen for GSPMD/multi-pod friendliness (DESIGN.md §5):
  * tokens are processed in ``groups`` (set to the data-parallel shard count)
    so routing (top-k, cumsum positions, scatter) is group-local — GSPMD
    partitions the group axis with zero communication;
  * per group, assignments are scattered into an (E, C, d) expert buffer; the
    expert GEMMs run as one grouped einsum over the expert axis. With expert
    weights sharded E->data and buffers G->data, GSPMD lowers the group<->
    expert transposition into the canonical MoE all-to-all;
  * capacity C = ceil(T_g * top_k * capacity_factor / E); overflow tokens are
    dropped (weight 0), Switch-style.

``moe_reference`` computes the same function densely (all experts for all
tokens) and is the correctness oracle in tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.plan import constrain
from repro.models.layers import ACTIVATIONS, dense_init


def moe_init(key, d_model: int, d_ff: int, num_experts: int, dtype=jnp.float32) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale_in = 1.0 / jnp.sqrt(d_model)
    scale_out = 1.0 / jnp.sqrt(d_ff)
    return {
        "router": dense_init(k1, d_model, num_experts, jnp.float32),
        "w_gate": (jax.random.normal(k2, (num_experts, d_model, d_ff)) * scale_in).astype(dtype),
        "w_up": (jax.random.normal(k3, (num_experts, d_model, d_ff)) * scale_in).astype(dtype),
        "w_out": (jax.random.normal(k4, (num_experts, d_ff, d_model)) * scale_out).astype(dtype),
    }


def _route(router_kernel: jax.Array, x: jax.Array, top_k: int):
    """x: (T, d) -> (weights (T,k), experts (T,k)); weights renormalised."""
    logits = (x.astype(jnp.float32) @ router_kernel.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, experts


def _capacity(tokens_per_group: int, top_k: int, num_experts: int, factor: float) -> int:
    c = int(tokens_per_group * top_k * factor / num_experts) + 1
    return max(c, 4)


def moe_apply(
    params: dict,
    x: jax.Array,  # (B, S, d) or (T, d)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    groups: int = 1,
    act: str = "silu",
) -> tuple[jax.Array, jax.Array]:
    """Returns (output matching x's shape, auxiliary load-balance loss)."""
    orig_shape = x.shape
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    T = xt.shape[0]
    assert T % groups == 0, (T, groups)
    tg = T // groups
    E = params["w_gate"].shape[0]
    C = _capacity(tg, top_k, E, capacity_factor)
    act_fn = ACTIVATIONS[act]

    xg = xt.reshape(groups, tg, d)

    def per_group(xg_i):  # (tg, d)
        weights, experts = _route(params["router"]["kernel"], xg_i, top_k)  # (tg,k)
        # position of each assignment within its expert (Switch cumsum trick)
        oh = jax.nn.one_hot(experts.reshape(-1), E, dtype=jnp.int32)  # (tg*k, E)
        pos = (jnp.cumsum(oh, axis=0) - 1) * oh  # 0-based positions, only on hits
        pos_in_expert = pos.sum(axis=-1)  # (tg*k,)
        e_flat = experts.reshape(-1)
        w_flat = weights.reshape(-1)
        keep = pos_in_expert < C
        slot = jnp.where(keep, pos_in_expert, C - 1)
        token_idx = jnp.repeat(jnp.arange(tg), top_k)
        x_assign = xg_i[token_idx] * keep[:, None].astype(xg_i.dtype)
        buf = jnp.zeros((E, C, d), xg_i.dtype).at[e_flat, slot].add(x_assign)
        # load-balance aux (Switch eq. 4): E * sum_e f_e * p_e
        me = jax.nn.one_hot(experts[:, 0], E, dtype=jnp.float32).mean(0)
        pe = jax.nn.softmax(
            (xg_i.astype(jnp.float32) @ params["router"]["kernel"].astype(jnp.float32)),
            axis=-1,
        ).mean(0)
        aux = E * jnp.sum(me * pe)
        return buf, (e_flat, slot, w_flat, keep, token_idx), aux

    bufs, combine_info, aux = jax.vmap(per_group)(xg)  # bufs: (G, E, C, d)

    # EP boundary: reshard dispatch buffers group-major -> expert-major (the
    # canonical MoE all-to-all; without the constraint GSPMD was measured to
    # all-reduce the full (G,E,C,d) buffer instead), run the grouped GEMMs
    # expert-local, and reshard back for the combine.
    bufs = constrain(bufs, "moe_dispatch")
    h = act_fn(jnp.einsum("gecd,edf->gecf", bufs, params["w_gate"].astype(bufs.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", bufs, params["w_up"].astype(bufs.dtype))
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["w_out"].astype(h.dtype))
    out_buf = constrain(out_buf, "moe_combine")

    def per_group_combine(out_buf_i, info):
        e_flat, slot, w_flat, keep, token_idx = info
        y_assign = out_buf_i[e_flat, slot]  # (tg*k, d)
        y_assign = y_assign * (w_flat * keep).astype(y_assign.dtype)[:, None]
        return jnp.zeros((tg, d), y_assign.dtype).at[token_idx].add(y_assign)

    yg = jax.vmap(per_group_combine)(out_buf, combine_info)  # (G, tg, d)
    return yg.reshape(orig_shape), aux.mean()


def moe_reference(params: dict, x: jax.Array, *, top_k: int, act: str = "silu") -> jax.Array:
    """Dense oracle: every expert computed for every token, then top-k mixed.
    No capacity limit — equals moe_apply exactly only when nothing is dropped
    (use capacity_factor high enough in tests)."""
    orig_shape = x.shape
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    weights, experts = _route(params["router"]["kernel"], xt, top_k)
    act_fn = ACTIVATIONS[act]
    h = act_fn(jnp.einsum("td,edf->tef", xt, params["w_gate"].astype(xt.dtype)))
    h = h * jnp.einsum("td,edf->tef", xt, params["w_up"].astype(xt.dtype))
    y_all = jnp.einsum("tef,efd->ted", h, params["w_out"].astype(h.dtype))  # (T,E,d)
    sel = jnp.take_along_axis(y_all, experts[:, :, None], axis=1)  # (T,k,d)
    y = (sel * weights[:, :, None].astype(sel.dtype)).sum(axis=1)
    return y.reshape(orig_shape)
