"""ResNet-20-family CNN for the CIFAR reproduction (He et al. 2016).

GroupNorm replaces BatchNorm so per-sample gradients are well defined
(DESIGN.md §3/§9). Widths/stage layout follow the CIFAR ResNet-20 recipe:
3 stages x n basic blocks, widths (16, 32, 64), n = (depth-2)/6 = 3.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import group_norm, norm_init


def _conv_init(key, k: int, c_in: int, c_out: int, dtype=jnp.float32) -> jax.Array:
    fan_in = k * k * c_in
    return (jax.random.normal(key, (k, k, c_in, c_out)) * jnp.sqrt(2.0 / fan_in)).astype(dtype)


def _conv(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _gn_groups(c: int) -> int:
    for g in (8, 4, 2, 1):
        if c % g == 0:
            return g
    return 1


def resnet_init(key, depth: int = 20, num_classes: int = 10, width: int = 16,
                dtype=jnp.float32) -> dict:
    assert (depth - 2) % 6 == 0, "depth must be 6n+2"
    n = (depth - 2) // 6
    widths = [width, 2 * width, 4 * width]
    keys = iter(jax.random.split(key, 4 + 6 * 3 * n + 3))
    params: dict = {
        "stem": {"conv": _conv_init(next(keys), 3, 3, width, dtype),
                 "norm": norm_init(width, dtype, with_bias=True)},
        "stages": [],
    }
    c_in = width
    for s, c_out in enumerate(widths):
        blocks = []
        for b in range(n):
            stride = 2 if (s > 0 and b == 0) else 1
            blk = {
                "conv1": _conv_init(next(keys), 3, c_in, c_out, dtype),
                "norm1": norm_init(c_out, dtype, with_bias=True),
                "conv2": _conv_init(next(keys), 3, c_out, c_out, dtype),
                "norm2": norm_init(c_out, dtype, with_bias=True),
            }
            if stride != 1 or c_in != c_out:
                blk["proj"] = _conv_init(next(keys), 1, c_in, c_out, dtype)
            blocks.append(blk)
            c_in = c_out
        params["stages"].append(blocks)
    params["head"] = {
        "kernel": (jax.random.normal(next(keys), (c_in, num_classes)) / jnp.sqrt(c_in)).astype(dtype),
        "bias": jnp.zeros((num_classes,), dtype),
    }
    return params


def resnet_forward(params: dict, x: jax.Array) -> jax.Array:
    """x: (B, H, W, 3) -> logits (B, num_classes)."""
    h = _conv(x, params["stem"]["conv"])
    h = jax.nn.relu(group_norm(params["stem"]["norm"], h, _gn_groups(h.shape[-1])))
    for stage in params["stages"]:
        for blk in stage:
            stride = 2 if "proj" in blk and blk["conv1"].shape[2] != blk["conv1"].shape[3] else 1
            # stride derivation: downsampling blocks are exactly those with a
            # channel-increasing projection
            y = _conv(h, blk["conv1"], stride)
            y = jax.nn.relu(group_norm(blk["norm1"], y, _gn_groups(y.shape[-1])))
            y = _conv(y, blk["conv2"])
            y = group_norm(blk["norm2"], y, _gn_groups(y.shape[-1]))
            sc = _conv(h, blk["proj"], stride) if "proj" in blk else h
            h = jax.nn.relu(y + sc)
    pooled = h.mean(axis=(1, 2))
    return pooled @ params["head"]["kernel"].astype(pooled.dtype) + params["head"]["bias"].astype(pooled.dtype)


def resnet_loss(params: dict, example: dict) -> jax.Array:
    """Per-sample (or batch-mean) softmax CE. example['x']: (..., H, W, 3)."""
    x = example["x"]
    squeeze = x.ndim == 3
    if squeeze:
        x = x[None]
    logits = resnet_forward(params, x).astype(jnp.float32)
    y = jnp.atleast_1d(example["y"])
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - tgt)
    return loss


def resnet_batch_loss(params: dict, batch: dict) -> jax.Array:
    return resnet_loss(params, batch)


def resnet_accuracy(params: dict, batch: dict) -> jax.Array:
    logits = resnet_forward(params, batch["x"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
