from repro.models import attention, layers, moe, resnet, small, ssm, transformer

__all__ = ["attention", "layers", "moe", "resnet", "small", "ssm", "transformer"]
