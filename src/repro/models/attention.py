"""Attention: GQA with RoPE, optional QKV bias, sliding window, logit softcap.

Two memory regimes:
  * ``attention()``        — materialises (B,H,Sq,Sk) scores. Used for short
                             sequences (train_4k smoke) and as the oracle.
  * ``flash_attention()``  — chunked streaming-softmax over KV blocks
                             (lax.scan), O(Sq*block) live memory. Used for
                             long prefill where (S,S) scores would not fit.
  * ``decode_attention()`` — single-query attention against a KV cache.

All functions take q:(B,Sq,H,hd), k/v:(B,Sk,KV,hd) with H % KV == 0 (GQA) and
return (B,Sq,H,hd).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs import base as base_configs
from repro.dist.plan import constrain

NEG_INF = -1e30


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)).reshape(
        b, s, kv * n_rep, hd
    )


def _mask_bias(
    q_pos: jax.Array,  # (Sq,)
    k_pos: jax.Array,  # (Sk,)
    causal: bool,
    window: int | None,
) -> jax.Array:
    """(Sq, Sk) additive bias: 0 where attendable, NEG_INF where masked."""
    rel = q_pos[:, None] - k_pos[None, :]  # >0 means key in the past
    ok = jnp.ones(rel.shape, bool)
    if causal:
        ok = ok & (rel >= 0)
    if window is not None:
        ok = ok & (rel < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    k = _repeat_kv(k, h // kv)
    v = _repeat_kv(v, h // kv)
    scale = hd ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    bias = _mask_bias(
        jnp.arange(sq) + q_offset, jnp.arange(k.shape[1]), causal, window
    )
    logits = logits + bias[None, None]
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _flash_forward(q, k, v, causal, window, softcap, q_block, kv_block):
    """Returns (out (b,sq,h,hd), lse (b,nq,h,q_block)) — the flash forward.

    Outer vmap over query blocks (sharded over the TP axis, Ulysses-style,
    via the 'attn_q' constraint), inner lax.scan over KV blocks carrying
    (running_max, denominator, numerator)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    assert sq % q_block == 0 and sk % kv_block == 0, (sq, sk, q_block, kv_block)
    n_rep = h // k.shape[2]
    scale = hd ** -0.5
    nq, nk = sq // q_block, sk // kv_block
    qb = q.reshape(b, nq, q_block, h, hd)
    qb = constrain(qb, "attn_q")
    k = constrain(k, "attn_kv")
    v = constrain(v, "attn_kv")

    def per_qblock(qi, q_blk):  # (b, q_block, h, hd)
        q_pos = qi * q_block + jnp.arange(q_block)

        def kv_step(carry, kj):
            m_prev, l_prev, acc = carry
            k_blk = _repeat_kv(jax.lax.dynamic_slice_in_dim(k, kj * kv_block, kv_block, 1), n_rep)
            v_blk = _repeat_kv(jax.lax.dynamic_slice_in_dim(v, kj * kv_block, kv_block, 1), n_rep)
            logits = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk).astype(jnp.float32) * scale
            if softcap is not None:
                logits = jnp.tanh(logits / softcap) * softcap
            bias = _mask_bias(q_pos, kj * kv_block + jnp.arange(kv_block), causal, window)
            logits = logits + bias[None, None]
            m_new = jnp.maximum(m_prev, logits.max(axis=-1))
            corr = jnp.exp(m_prev - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l_prev * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, h, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        acc0 = jnp.zeros((b, h, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, acc0), jnp.arange(nk))
        l_safe = jnp.maximum(l, 1e-30)
        out = (acc / l_safe[..., None]).transpose(0, 2, 1, 3).astype(q.dtype)
        lse = m + jnp.log(l_safe)  # (b, h, q_block)
        return out, lse

    out, lse = jax.vmap(per_qblock, in_axes=(0, 1), out_axes=(1, 1))(jnp.arange(nq), qb)
    out = constrain(out, "attn_q")
    return out.reshape(b, sq, h, hd), lse  # lse: (b, nq, h, q_block)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_block: int = 512,
    kv_block: int = 1024,
) -> jax.Array:
    """Streaming-softmax attention, O(q_block * kv_block) live scores.

    custom_vjp: scan-under-grad would stack every KV step's probability block
    for the backward (O(S^2) HBM traffic and the single largest byte source
    in the measured HLO). The backward here is the standard flash recompute:
    residuals are (q, k, v, out, lse); pass 1 re-streams KV blocks to get dq
    (sharded over q blocks), pass 2 re-streams Q blocks to get dk, dv
    (sharded over kv blocks)."""
    return _flash_forward(q, k, v, causal, window, softcap, q_block, kv_block)[0]


def _flash_fwd(q, k, v, causal, window, softcap, q_block, kv_block):
    out, lse = _flash_forward(q, k, v, causal, window, softcap, q_block, kv_block)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, softcap, q_block, kv_block, res, dout):
    q, k, v, out, lse = res
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kv_heads = k.shape[2]
    n_rep = h // kv_heads
    scale = hd ** -0.5
    nq, nk = sq // q_block, sk // kv_block

    qb = constrain(q.reshape(b, nq, q_block, h, hd), "attn_q")
    dob = constrain(dout.reshape(b, nq, q_block, h, hd), "attn_q")
    ob = constrain(out.reshape(b, nq, q_block, h, hd), "attn_q")
    k = constrain(k, "attn_kv")
    v = constrain(v, "attn_kv")
    # delta_i = rowsum(dout_i * out_i): (b, nq, h, q_block)
    delta = jnp.einsum("bnqhd,bnqhd->bnhq", dob.astype(jnp.float32), ob.astype(jnp.float32))

    def _block_dlogits(q_blk, k_blk, lse_blk, delta_blk, do_blk, v_blk, q_pos, k_pos):
        """Recompute p and dlogits for one (q_block, kv_block) tile.
        Shapes: q_blk (b,qc,h,hd), k_blk/v_blk (b,kc,h,hd) [already repeated],
        lse_blk/delta_blk (b,h,qc), do_blk (b,qc,h,hd)."""
        raw = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk).astype(jnp.float32) * scale
        if softcap is not None:
            capped = jnp.tanh(raw / softcap)
            logits = capped * softcap
        else:
            logits = raw
        bias = _mask_bias(q_pos, k_pos, causal, window)
        logits = logits + bias[None, None]
        p = jnp.exp(logits - lse_blk[..., None])  # (b,h,qc,kc)
        dp = jnp.einsum("bqhd,bkhd->bhqk", do_blk, v_blk).astype(jnp.float32)
        dlogits = p * (dp - delta_blk[..., None])
        if softcap is not None:
            dlogits = dlogits * (1.0 - capped * capped)
        return p, dlogits

    # ---- pass 1: dq, sharded over q blocks -------------------------------
    def dq_qblock(qi, q_blk, lse_blk, delta_blk, do_blk):
        q_pos = qi * q_block + jnp.arange(q_block)

        def kv_step(dq_acc, kj):
            k_blk = _repeat_kv(jax.lax.dynamic_slice_in_dim(k, kj * kv_block, kv_block, 1), n_rep)
            v_blk = _repeat_kv(jax.lax.dynamic_slice_in_dim(v, kj * kv_block, kv_block, 1), n_rep)
            _, dlogits = _block_dlogits(
                q_blk, k_blk, lse_blk, delta_blk, do_blk, v_blk,
                q_pos, kj * kv_block + jnp.arange(kv_block),
            )
            dq_acc = dq_acc + jnp.einsum(
                "bhqk,bkhd->bqhd", dlogits.astype(k_blk.dtype), k_blk
            ).astype(jnp.float32) * scale
            return dq_acc, None

        dq0 = jnp.zeros((b, q_block, h, hd), jnp.float32)
        dq, _ = jax.lax.scan(kv_step, dq0, jnp.arange(nk))
        return dq

    dq = jax.vmap(dq_qblock, in_axes=(0, 1, 1, 1, 1), out_axes=1)(
        jnp.arange(nq), qb, lse, delta, dob
    )
    dq = constrain(dq, "attn_q").reshape(b, sq, h, hd).astype(q.dtype)

    # ---- pass 2: dk/dv, sharded over kv blocks ----------------------------
    kb = constrain(k.reshape(b, nk, kv_block, kv_heads, hd), "attn_q")
    vb = constrain(v.reshape(b, nk, kv_block, kv_heads, hd), "attn_q")

    def dkv_kvblock(kj, k_blk_s, v_blk_s):
        k_blk = _repeat_kv(k_blk_s, n_rep)
        v_blk = _repeat_kv(v_blk_s, n_rep)
        k_pos = kj * kv_block + jnp.arange(kv_block)

        def q_step(carry, qi):
            dk_acc, dv_acc = carry
            q_blk = jax.lax.dynamic_slice_in_dim(qb, qi, 1, 1)[:, 0]
            do_blk = jax.lax.dynamic_slice_in_dim(dob, qi, 1, 1)[:, 0]
            lse_blk = jax.lax.dynamic_slice_in_dim(lse, qi, 1, 1)[:, 0]
            delta_blk = jax.lax.dynamic_slice_in_dim(delta, qi, 1, 1)[:, 0]
            p, dlogits = _block_dlogits(
                q_blk, k_blk, lse_blk, delta_blk, do_blk, v_blk,
                qi * q_block + jnp.arange(q_block), k_pos,
            )
            dv_acc = dv_acc + jnp.einsum(
                "bhqk,bqhd->bkhd", p.astype(do_blk.dtype), do_blk
            ).astype(jnp.float32)
            dk_acc = dk_acc + jnp.einsum(
                "bhqk,bqhd->bkhd", dlogits.astype(q_blk.dtype), q_blk
            ).astype(jnp.float32) * scale
            return (dk_acc, dv_acc), None

        z = jnp.zeros((b, kv_block, h, hd), jnp.float32)
        (dk_full, dv_full), _ = jax.lax.scan(q_step, (z, z), jnp.arange(nq))
        # GQA: fold the repeated-head axis back onto kv heads
        dk_s = dk_full.reshape(b, kv_block, kv_heads, n_rep, hd).sum(3)
        dv_s = dv_full.reshape(b, kv_block, kv_heads, n_rep, hd).sum(3)
        return dk_s, dv_s

    dk, dv = jax.vmap(dkv_kvblock, in_axes=(0, 1, 1), out_axes=1)(
        jnp.arange(nk), kb, vb
    )
    dk = constrain(dk, "attn_q").reshape(b, sk, kv_heads, hd).astype(k.dtype)
    dv = constrain(dv, "attn_q").reshape(b, sk, kv_heads, hd).astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def chunk_attention(
    q: jax.Array,  # (B, C, H, hd) — one prefill chunk of queries
    k: jax.Array,  # (B, Sk, KV, hd) — prior context ++ this chunk's keys
    v: jax.Array,
    q_pos: jax.Array,  # (C,) absolute positions of the queries
    k_pos: jax.Array,  # (Sk,) absolute positions of the keys
    k_valid: jax.Array,  # (Sk,) bool — False for padding/garbage key rows
    *,
    window: int | None = None,
    softcap: float | None = None,
) -> jax.Array:
    """Chunked-prefill attention: queries at explicit absolute positions over
    keys at explicit absolute positions with a validity mask.

    This is ``attention()`` generalised to non-contiguous key layouts (prior
    context gathered from pool blocks or a windowed ring, then this chunk's
    own keys) — same op order as the dense oracle, so a one-chunk prefill at
    offset 0 with all-valid keys is bit-identical to ``attention()``.
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    k = _repeat_kv(k, h // kv)
    v = _repeat_kv(v, h // kv)
    scale = hd ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    bias = _mask_bias(q_pos, k_pos, True, window)
    bias = jnp.where(k_valid[None, :], bias, NEG_INF)
    logits = logits + bias[None, None]
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def decode_attention(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, S, KV, hd)
    v_cache: jax.Array,
    cache_len: jax.Array,  # scalar or (B,) — number of valid cache entries
    *,
    softcap: float | None = None,
    window: int | None = None,
) -> jax.Array:
    b, _, h, hd = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    k = _repeat_kv(k_cache, h // kv)
    v = _repeat_kv(v_cache, h // kv)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * hd ** -0.5
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.reshape(cache_len, (-1, 1))  # (B, S)
    if window is not None:
        valid = valid & (pos[None, :] >= jnp.reshape(cache_len, (-1, 1)) - window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def choose_attention(sq: int, sk: int, flash_threshold: int | None = None):
    """Pick the dense or flash implementation by sequence length (the one
    flip point lives in configs/base.py::FLASH_THRESHOLD)."""
    if flash_threshold is None:
        flash_threshold = base_configs.FLASH_THRESHOLD
    if max(sq, sk) > flash_threshold:
        return flash_attention
    return functools.partial(attention)


def resolve_impl(cfg, s: int) -> str:
    """Resolve cfg.attn_impl for a length-``s`` self-attention call site.

    'auto' flips from dense to flash at cfg.flash_threshold (one constant,
    configs/base.py) provided the length tiles evenly; explicit 'dense' /
    'flash' / 'pallas' pass through.  'pallas' routes to the kernels in
    kernels/attention.py, which pad ragged lengths internally (no
    divisibility requirement) and run in interpret mode off-TPU.
    """
    impl = cfg.attn_impl
    if impl == "auto":
        impl = (
            "flash"
            if s > cfg.flash_threshold and s % cfg.flash_q_block == 0
            else "dense"
        )
    return impl
