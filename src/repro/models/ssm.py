"""Mamba-1 (selective SSM) block, TPU-adapted.

The recurrence  h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t  is linear in h,
so prefill/training uses a CHUNKED associative scan: sequence chunks of
``chunk`` steps run a parallel ``associative_scan`` (O(log chunk) depth, MXU/
VPU friendly) while an outer ``lax.scan`` threads the boundary state — live
memory is O(B * chunk * d_inner * d_state) instead of O(B * L * ...).

TP: the SSM is diagonal over channels, so sharding d_inner over the 'model'
axis parallelises the whole block with zero collective traffic except the
in/out projections (DESIGN.md §5 'SP/TP for SSM').

Decode keeps O(1) state per layer: (h, conv_buffer) — this is why the SSM and
hybrid archs run the long_500k cell that full-attention archs skip.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, silu


def mamba_init(
    key,
    d_model: int,
    d_state: int = 16,
    expand: int = 2,
    conv_dim: int = 4,
    dt_rank: int = 0,
    dtype=jnp.float32,
) -> dict:
    d_inner = expand * d_model
    dt_rank = dt_rank or max(1, math.ceil(d_model / 16))
    keys = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :], (d_inner, 1))
    return {
        "in_proj": dense_init(keys[0], d_model, 2 * d_inner, dtype),
        "conv_kernel": (jax.random.normal(keys[1], (conv_dim, d_inner)) / conv_dim).astype(dtype),
        "conv_bias": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(keys[2], d_inner, dt_rank + 2 * d_state, dtype),
        "dt_proj": dense_init(keys[3], dt_rank, d_inner, dtype, use_bias=True),
        "A_log": jnp.log(a),  # fp32: A = -exp(A_log)
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(keys[4], d_inner, d_model, dtype),
    }


def _causal_conv(x: jax.Array, kernel: jax.Array, bias: jax.Array,
                 init_state: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv over (B, L, C). kernel: (K, C)."""
    k = kernel.shape[0]
    if init_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = init_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, L+K-1, C)
    out = sum(
        xp[:, i : i + x.shape[1], :] * kernel[i][None, None, :].astype(x.dtype)
        for i in range(k)
    )
    return out + bias.astype(x.dtype)


def _ssm_params(params: dict, x: jax.Array, dt_rank: int, d_state: int):
    """x: (..., d_inner) -> (dt (...,d_inner), B (...,d_state), C (...,d_state))."""
    proj = x @ params["x_proj"]["kernel"].astype(x.dtype)
    dt_in, b, c = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = dt_in @ params["dt_proj"]["kernel"].astype(x.dtype) + params["dt_proj"]["bias"].astype(x.dtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    return dt, b.astype(jnp.float32), c.astype(jnp.float32)


def _scan_chunk(a: jax.Array, bx: jax.Array, h0: jax.Array):
    """Associative scan of h_t = a_t * h_{t-1} + bx_t within a chunk.

    a, bx: (B, L, d_inner, d_state); h0: (B, d_inner, d_state).
    Returns (h_all (B,L,di,ds), h_last)."""

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    # fold h0 into the first step
    bx = bx.at[:, 0].add(a[:, 0] * h0)
    a_c, b_c = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return b_c, b_c[:, -1]


def mamba_apply(
    params: dict,
    x: jax.Array,  # (B, L, d_model)
    *,
    d_state: int,
    dt_rank: int = 0,
    chunk: int = 256,
    return_state: bool = False,
    state: dict | None = None,
):
    """Apply the block over ``x``; ``state`` (as returned with
    ``return_state=True`` or from ``mamba_decode_init``) resumes a sequence
    mid-stream, so chunked prefill can feed block-sized pieces and get the
    same result as one full-length call."""
    b, l, d_model = x.shape
    dt_rank = dt_rank or max(1, math.ceil(d_model / 16))
    d_inner = params["A_log"].shape[0]
    xz = x @ params["in_proj"]["kernel"].astype(x.dtype)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi_preconv = xi
    xi = silu(_causal_conv(xi, params["conv_kernel"], params["conv_bias"],
                           init_state=None if state is None else state["conv"]))

    dt, bmat, cmat = _ssm_params(params, xi, dt_rank, d_state)
    a = -jnp.exp(params["A_log"])  # (d_inner, d_state), fp32
    # discretise: a_bar = exp(dt*A), bx = dt * B * x
    chunk = min(chunk, l)
    assert l % chunk == 0, (l, chunk)
    n_chunks = l // chunk

    def chunk_step(h, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * chunk, chunk, axis=1)
        dt_c, b_c, c_c, x_c = sl(dt), sl(bmat), sl(cmat), sl(xi)
        a_bar = jnp.exp(dt_c[..., None] * a[None, None])  # (B,chunk,di,ds)
        bx = (dt_c * x_c.astype(jnp.float32))[..., None] * b_c[..., None, :]
        h_all, h_last = _scan_chunk(a_bar, bx, h)
        y_c = jnp.einsum("blds,bls->bld", h_all, c_c)
        # state h stays f32 across chunks; the STACKED per-chunk outputs are
        # cast to the compute dtype (the f32 (n_chunks,B,chunk,d_inner) stack
        # was the dominant live buffer in the jamba train cell)
        return h_last, y_c.astype(x.dtype)

    if state is None:
        h0 = jnp.zeros((b, d_inner, d_state), jnp.float32)
    else:
        h0 = state["h"].astype(jnp.float32)
    h_final, ys = jax.lax.scan(chunk_step, h0, jnp.arange(n_chunks))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, l, d_inner).astype(jnp.float32)
    y = y + params["D"][None, None] * xi.astype(jnp.float32)
    y = (y.astype(x.dtype)) * silu(z)
    out = y @ params["out_proj"]["kernel"].astype(x.dtype)
    if return_state:
        k = params["conv_kernel"].shape[0]
        if state is None:
            conv_tail = xi_preconv[:, -(k - 1):, :]
        else:
            # short chunks (l < K-1) still need K-1 rows of history
            conv_tail = jnp.concatenate(
                [state["conv"].astype(xi_preconv.dtype), xi_preconv], axis=1
            )[:, -(k - 1):, :]
        return out, {"h": h_final, "conv": conv_tail}
    return out


def mamba_decode_init(batch: int, d_model: int, d_state: int, expand: int, conv_dim: int,
                      dtype=jnp.float32) -> dict:
    d_inner = expand * d_model
    return {
        "h": jnp.zeros((batch, d_inner, d_state), jnp.float32),
        "conv": jnp.zeros((batch, conv_dim - 1, d_inner), dtype),
    }


def mamba_decode_step(
    params: dict,
    state: dict,
    x: jax.Array,  # (B, 1, d_model)
    *,
    d_state: int,
    dt_rank: int = 0,
) -> tuple[jax.Array, dict]:
    b, _, d_model = x.shape
    dt_rank = dt_rank or max(1, math.ceil(d_model / 16))
    xz = x @ params["in_proj"]["kernel"].astype(x.dtype)
    xi, z = jnp.split(xz, 2, axis=-1)  # (B,1,d_inner)
    conv_in = jnp.concatenate([state["conv"].astype(xi.dtype), xi], axis=1)  # (B,K,di)
    kernel = params["conv_kernel"]
    k = kernel.shape[0]
    xi = silu((conv_in * kernel.astype(xi.dtype)[None]).sum(axis=1, keepdims=True)
              + params["conv_bias"].astype(xi.dtype))
    new_conv = conv_in[:, 1:, :]

    dt, bmat, cmat = _ssm_params(params, xi, dt_rank, d_state)  # (B,1,·)
    a = -jnp.exp(params["A_log"])
    a_bar = jnp.exp(dt[0 if False else ...][..., None] * a[None, None])[:, 0]  # (B,di,ds)
    bx = ((dt * xi.astype(jnp.float32))[..., None] * bmat[..., None, :])[:, 0]
    h = a_bar * state["h"] + bx
    y = jnp.einsum("bds,bs->bd", h, cmat[:, 0])[:, None, :]  # (B,1,di)
    y = y + params["D"][None, None] * xi.astype(jnp.float32)
    y = y.astype(x.dtype) * silu(z)
    out = y @ params["out_proj"]["kernel"].astype(x.dtype)
    return out, {"h": h, "conv": new_conv}
