"""Expert-parallel MoE with EXPLICIT all-to-all (shard_map path).

§Perf Cell C4 (EXPERIMENTS.md): under pjit/GSPMD the index-based combine of
the capacity-dispatch MoE lowers to an all-gather of the full (E, C, d)
expert buffer — ~n_ep× the bytes an all-to-all needs. This module is the
production EP formulation: routing is shard-local, tokens travel to their
expert's shard and back via two `lax.all_to_all`s, expert GEMMs run on
resident weights, and the w_out contraction reduces over the tp axis with an
explicit psum.

Protocol per shard (T = local tokens, A = n_ep destination shards):
  1. route top-k; destination shard = expert // E_local
  2. scatter assignments into per-destination send buffers
     (A, CAP, d), CAP = ceil(T*k*cf/A); overflow drops (Switch-style)
  3. all_to_all  ->  (A, CAP, d) received tokens + their local-expert ids
  4. local capacity-dispatch to (E_local, C2, d); grouped GEMM
     (gate/up tp-sharded on ff; out reduces ff with psum over tp)
  5. gather results per received slot; all_to_all back; weighted combine.

Numerics match `moe.moe_reference` exactly when nothing is dropped
(tests/test_moe_ep.py sweeps this on an 8-device mesh).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.layers import ACTIVATIONS


def _positions_within(dest: jax.Array, num_dest: int) -> jax.Array:
    """0-based arrival order of each assignment at its destination bucket."""
    oh = jax.nn.one_hot(dest, num_dest, dtype=jnp.int32)  # (N, A)
    return jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1, dest[:, None], axis=1)[:, 0]


def _moe_ep_shard(
    x: jax.Array,  # (B_loc, S, d)
    router: jax.Array,  # (d, E)
    w_gate: jax.Array,  # (E_loc, d, ff_loc)
    w_up: jax.Array,
    w_out: jax.Array,  # (E_loc, ff_loc, d)
    *,
    top_k: int,
    capacity_factor: float,
    act: str,
    ep_axes,
    tp_axis: str,
    n_ep: int,
    e_total: int,
):
    act_fn = ACTIVATIONS[act]
    b, s, d = x.shape
    t = b * s
    e_loc = e_total // n_ep
    xt = x.reshape(t, d)

    # 1. route (fp32 router math, exactly as the GSPMD path)
    logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, top_k)  # (t, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    n = t * top_k
    e_flat = experts.reshape(n)
    w_flat = weights.reshape(n)
    token_idx = jnp.repeat(jnp.arange(t), top_k)
    dest = e_flat // e_loc  # destination ep shard
    e_local_id = e_flat % e_loc

    cap = max(int(t * top_k * capacity_factor / n_ep) + 1, 4)
    pos = _positions_within(dest, n_ep)
    keep = pos < cap
    # out-of-range rows drop (mode='drop'): dropped assignments never land
    row = jnp.where(keep, dest, n_ep)
    col = jnp.where(keep, pos, 0)

    send_x = jnp.zeros((n_ep, cap, d), x.dtype).at[row, col].add(
        xt[token_idx], mode="drop")
    send_e = jnp.full((n_ep, cap), -1, jnp.int32).at[row, col].set(
        e_local_id, mode="drop")

    # 2. exchange: slot [a] <- what shard a sent to me
    recv_x = jax.lax.all_to_all(send_x, ep_axes, 0, 0, tiled=True).reshape(
        n_ep * cap, d)
    recv_e = jax.lax.all_to_all(send_e[..., None], ep_axes, 0, 0,
                                tiled=True).reshape(n_ep * cap)

    # 3. local capacity dispatch to (E_loc, C2, d)
    t2 = n_ep * cap
    c2 = max(int(2.0 * t2 / e_loc) + 1, 4)
    valid = recv_e >= 0
    e_safe = jnp.where(valid, recv_e, 0)
    pos2 = _positions_within(jnp.where(valid, recv_e, e_loc), e_loc + 1)
    keep2 = valid & (pos2 < c2)
    row2 = jnp.where(keep2, e_safe, e_loc)
    col2 = jnp.where(keep2, pos2, 0)
    buf = jnp.zeros((e_loc, c2, d), x.dtype).at[row2, col2].add(
        recv_x, mode="drop")

    # 4. grouped GEMM on resident experts; ff is tp-sharded, so the w_out
    # contraction is a partial sum -> explicit psum over the tp axis
    h = act_fn(jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
    y_buf = jnp.einsum("ecf,efd->ecd", h, w_out.astype(h.dtype))

    # 5. read back per received slot, THEN reduce the tp partial sums (the
    # gathered (t2, d) rows are ~3x smaller than the padded (E_loc, C2, d)
    # buffer), return exchange, weighted combine
    y_recv = y_buf[row2, col2] * keep2[:, None].astype(y_buf.dtype)
    y_recv = jax.lax.psum(y_recv, tp_axis)
    back = jax.lax.all_to_all(y_recv.reshape(n_ep, cap, d), ep_axes, 0, 0,
                              tiled=True)
    y_assign = back[row, col] * (w_flat * keep).astype(back.dtype)[:, None]
    y = jnp.zeros((t, d), x.dtype).at[token_idx].add(y_assign.astype(x.dtype))

    # aux load-balance loss (same definition as the GSPMD path), psum-averaged
    me = jax.nn.one_hot(experts[:, 0], e_total, dtype=jnp.float32).mean(0)
    pe = probs.mean(0)
    aux = e_total * jnp.sum(me * pe)
    aux = jax.lax.pmean(aux, ep_axes)
    return y.reshape(b, s, d), aux


def moe_apply_ep(
    params: dict,
    x: jax.Array,  # (B, S, d) global
    *,
    top_k: int,
    capacity_factor: float,
    act: str,
    mesh: Mesh,
    dp_axes: Sequence[str],
    ep_axes: Sequence[str],
    tp_axis: str,
) -> tuple[jax.Array, jax.Array]:
    """shard_map wrapper. Expert weights must be sharded E over ep_axes and
    ff over tp_axis (the standard rule table does this)."""
    e_total = params["w_gate"].shape[0]
    ep_axes = tuple(ep_axes)
    n_ep = 1
    for a in ep_axes:
        n_ep *= mesh.shape[a]
    dp = tuple(dp_axes)

    fn = functools.partial(
        _moe_ep_shard,
        top_k=top_k, capacity_factor=capacity_factor, act=act,
        ep_axes=ep_axes, tp_axis=tp_axis, n_ep=n_ep, e_total=e_total,
    )
    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(
            P(dp, None, None),       # x: batch over dp, d replicated
            P(None, None),           # router replicated
            P(ep_axes, None, tp_axis),  # w_gate (E, d, ff)
            P(ep_axes, None, tp_axis),  # w_up
            P(ep_axes, tp_axis, None),  # w_out (E, ff, d)
        ),
        out_specs=(P(dp, None, None), P()),
        check_rep=False,
    )
    return mapped(x, params["router"]["kernel"], params["w_gate"],
                  params["w_up"], params["w_out"])
