"""The paper's experimental models: logistic regression and a 2-layer MLP
(Section 5.1), with gram-estimator probe support.

``loss_fn(params, example)`` signatures are per-sample (scalar loss) so the
exact estimator can ``vmap(grad)`` them directly; ``batch_loss`` is the mean
over a batch (what the optimizer differentiates).

Probe support: ``batch_loss_with_probes(params, probes, batch)`` adds zero
probes on every dense output and returns the saved input activations, so a
single backward pass yields (X, Delta) per dense layer for kernels/psgn.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init


def _bce_with_logits(logit: jax.Array, y: jax.Array) -> jax.Array:
    # numerically stable binary cross entropy
    return jnp.maximum(logit, 0.0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))


# ---------------------------------------------------------------------------
# Logistic regression (the convex case)
# ---------------------------------------------------------------------------


def logreg_init(key, d: int, dtype=jnp.float32) -> dict:
    return {"linear": dense_init(key, d, 1, dtype, use_bias=True)}


def logreg_loss(params: dict, example: dict) -> jax.Array:
    logit = dense(params["linear"], example["x"])[..., 0]
    return jnp.mean(_bce_with_logits(logit.astype(jnp.float32), example["y"].astype(jnp.float32)))


def logreg_batch_loss(params: dict, batch: dict) -> jax.Array:
    return logreg_loss(params, batch)


def logreg_accuracy(params: dict, batch: dict) -> jax.Array:
    logit = dense(params["linear"], batch["x"])[..., 0]
    return jnp.mean(((logit > 0).astype(jnp.int32) == batch["y"]).astype(jnp.float32))


# ---------------------------------------------------------------------------
# 2-layer MLP (the nonconvex case) — parameter count ~= logreg's d+1
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, hidden: int | None = None, dtype=jnp.float32) -> dict:
    # paper: "2-layer MLPs with the same number of parameters" as logreg.
    # (d+1) params total -> hidden h solves h(d+2)+1 ~ d+1; we default to the
    # conventional reading (same order of magnitude) with hidden = d // 8.
    hidden = hidden or max(4, d // 8)
    k1, k2 = jax.random.split(key)
    return {
        "fc1": dense_init(k1, d, hidden, dtype, use_bias=True),
        "fc2": dense_init(k2, hidden, 1, dtype, use_bias=True),
    }


def mlp_forward(params: dict, x: jax.Array, probes: dict | None = None,
                acts: dict | None = None) -> jax.Array:
    p1 = probes.get("fc1") if probes else None
    p2 = probes.get("fc2") if probes else None
    if acts is not None:
        acts["fc1"] = x
    h = jax.nn.relu(dense(params["fc1"], x, probe=p1))
    if acts is not None:
        acts["fc2"] = h
    return dense(params["fc2"], h, probe=p2)[..., 0]


def mlp_loss(params: dict, example: dict) -> jax.Array:
    logit = mlp_forward(params, example["x"])
    return jnp.mean(_bce_with_logits(logit.astype(jnp.float32), example["y"].astype(jnp.float32)))


def mlp_batch_loss(params: dict, batch: dict) -> jax.Array:
    return mlp_loss(params, batch)


def mlp_batch_loss_with_probes(params: dict, probes: dict, batch: dict):
    """Returns (loss, acts). grad w.r.t. probes = upstream activation grads,
    scaled by 1/B because the loss is a mean (callers rescale)."""
    acts: dict = {}
    logit = mlp_forward(params, batch["x"], probes=probes, acts=acts)
    loss = jnp.mean(_bce_with_logits(logit.astype(jnp.float32), batch["y"].astype(jnp.float32)))
    return loss, acts


def mlp_probe_specs(params: dict, batch_size: int) -> dict:
    hidden = params["fc1"]["kernel"].shape[1]
    return {
        "fc1": jnp.zeros((batch_size, hidden), jnp.float32),
        "fc2": jnp.zeros((batch_size, 1), jnp.float32),
    }


def mlp_accuracy(params: dict, batch: dict) -> jax.Array:
    logit = mlp_forward(params, batch["x"])
    return jnp.mean(((logit > 0).astype(jnp.int32) == batch["y"]).astype(jnp.float32))
