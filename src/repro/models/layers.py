"""Primitive layers: inits, norms, dense (+probe hook), rotary embeddings.

Everything is a pure function over explicit parameter pytrees (dicts). The
``probe`` argument on :func:`dense` is the gram-estimator hook: a zero array
of the output's shape added to the output — ``grad`` w.r.t. it equals the
upstream activation gradient, which together with the saved input activation
yields per-sample gradient norms without a second backward pass
(see kernels/psgn.py and DESIGN.md §3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None,
               use_bias: bool = False) -> dict:
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"kernel": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if use_bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"embedding": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def norm_init(d: int, dtype=jnp.float32, with_bias: bool = False) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if with_bias:
        p["bias"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# Applies
# ---------------------------------------------------------------------------


def dense(params: dict, x: jax.Array, probe: jax.Array | None = None) -> jax.Array:
    y = x @ params["kernel"].astype(x.dtype)
    if "bias" in params:
        y = y + params["bias"].astype(x.dtype)
    if probe is not None:
        y = y + probe.astype(x.dtype)
    return y


def embed(params: dict, ids: jax.Array) -> jax.Array:
    return params["embedding"][ids]


def rms_norm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32)
    if "bias" in params:
        y = y + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


def layer_norm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32)
    if "bias" in params:
        y = y + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


def group_norm(params: dict, x: jax.Array, groups: int, eps: float = 1e-5) -> jax.Array:
    """NHWC group norm — per-sample (no cross-batch stats), so per-sample
    gradients are well defined (DESIGN.md §3: replaces BatchNorm)."""
    n, h, w, c = x.shape
    dtype = x.dtype
    xg = x.astype(jnp.float32).reshape(n, h, w, groups, c // groups)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    y = xg.reshape(n, h, w, c) * params["scale"].astype(jnp.float32)
    if "bias" in params:
        y = y + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10_000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """x: (..., S, H, head_dim); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x)


ACTIVATIONS = {"silu": silu, "gelu": gelu, "relu": jax.nn.relu}
