"""gemma2-27b [dense] — local+global alternating attention with logit
softcaps. 46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
head_dim=128 (model spec; 32*128 != d_model by design — q/kv project to
4096). Sliding window 4096 on local layers. [arXiv:2408.00118; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36_864,
    vocab_size=256_000,
    pattern=("attn_local", "attn"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    ffn_act="gelu",
    source="arXiv:2408.00118; hf",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=251, window=8, param_dtype="float32",
        compute_dtype="float32", xent_chunk=64, remat=False,
    )
