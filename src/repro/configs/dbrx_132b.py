"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
16 experts top-4 (fine-grained). [hf:databricks/dbrx-base]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10_752,
    vocab_size=100_352,
    ffn_pattern=("moe",),
    num_experts=16,
    top_k=4,
    capacity_factor=1.25,
    rope_theta=500_000.0,
    source="hf:databricks/dbrx-base",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=96,
        vocab_size=251, num_experts=4, top_k=2, capacity_factor=4.0,
        param_dtype="float32", compute_dtype="float32", xent_chunk=64, remat=False,
    )
