"""falcon-mamba-7b [ssm] — attention-free Mamba-1. 64L d_model=4096 d_ff=0
vocab=65024, ssm_state=16. [arXiv:2410.05355]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65_024,
    pattern=("mamba",),
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    source="arXiv:2410.05355",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, vocab_size=251, param_dtype="float32",
        compute_dtype="float32", xent_chunk=64, ssm_chunk=16, remat=False,
    )
