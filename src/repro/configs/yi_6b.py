"""yi-6b [dense] — llama-arch GQA. 32L d_model=4096 32H (kv=4) d_ff=11008
vocab=64000. [arXiv:2403.04652; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11_008,
    vocab_size=64_000,
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652; hf",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=251, param_dtype="float32", compute_dtype="float32",
        xent_chunk=64, remat=False,
    )
