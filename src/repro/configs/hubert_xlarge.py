"""hubert-xlarge [audio] — encoder-only transformer (w2v2 arch). 48L
d_model=1280 16H d_ff=5120 vocab=504 (masked-unit prediction targets).
Frame frontend is a STUB (precomputed frame embeddings). No decode shapes.
[arXiv:2106.07447]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    input_mode="embeddings",
    norm_type="layer",
    ffn_glu=False,
    ffn_act="gelu",
    source="arXiv:2106.07447",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=63, param_dtype="float32", compute_dtype="float32",
        xent_chunk=64, remat=False,
    )
