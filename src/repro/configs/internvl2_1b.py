"""internvl2-1b [vlm] — InternViT frontend (STUB) + InternLM2-1B backbone.

Backbone per assignment: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655. [arXiv:2404.16821; hf]. The vision frontend supplies
precomputed patch embeddings (input_mode='embeddings') per the task spec.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151_655,
    input_mode="embeddings",
    rope_theta=1_000_000.0,
    source="arXiv:2404.16821; hf",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=251, param_dtype="float32", compute_dtype="float32",
        xent_chunk=64, remat=False,
    )
