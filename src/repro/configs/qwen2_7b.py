"""qwen2-7b [dense] — GQA with QKV bias. 28L d_model=3584 28H (kv=4)
d_ff=18944 vocab=152064. [arXiv:2407.10671; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18_944,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="arXiv:2407.10671; hf",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=251, param_dtype="float32", compute_dtype="float32",
        xent_chunk=64, remat=False,
    )
