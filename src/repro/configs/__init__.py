"""Config registry: the 10 assigned archs + shape table + input specs."""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ENCODER_ONLY_ARCHS,
    FULL_ATTENTION_ARCHS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_supported,
)

_MODULES = {
    "internvl2-1b": "internvl2_1b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "dbrx-132b": "dbrx_132b",
    "yi-6b": "yi_6b",
    "gemma2-27b": "gemma2_27b",
    "qwen2-7b": "qwen2_7b",
    "llama3-405b": "llama3_405b",
    "hubert-xlarge": "hubert_xlarge",
    "jamba-v0.1-52b": "jamba_v01_52b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.reduced() if reduced else mod.CONFIG


def input_specs(cfg: ModelConfig, shape: ShapeConfig, batch_override: int | None = None,
                seq_override: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for the model inputs of a cell.

    train/prefill: token (or stub-embedding) batch [+ targets for train].
    decode: one new token per sequence + the KV/SSM cache sized to seq_len.
    """
    from repro.models import transformer as tf  # local import to avoid cycles

    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    f = jnp.dtype(cfg.compute_dtype)
    if cfg.input_mode == "tokens":
        inputs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    else:
        inputs = {"embeddings": jax.ShapeDtypeStruct((b, s, cfg.d_model), f)}
    if shape.kind == "train":
        inputs["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return {"batch": inputs}
    if shape.kind == "prefill":
        return {"batch": inputs}
    # decode: single token + cache
    if cfg.input_mode == "tokens":
        tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct((b, 1, cfg.d_model), f)
    cache = tf.cache_specs(cfg, b, s)
    return {"tokens": tok, "cache": cache}


__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "ARCH_NAMES",
    "FULL_ATTENTION_ARCHS",
    "ENCODER_ONLY_ARCHS",
    "get_config",
    "cell_supported",
    "input_specs",
]
