"""Architecture + run configuration dataclasses and the shape table.

Every assigned architecture is a ``ModelConfig`` (exact numbers from the
public sources quoted in the task table) plus a ``reduced()`` variant used by
CPU smoke tests. ``SHAPES`` is the assigned input-shape set shared by all
LM-family archs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

# The ONE attn_impl="auto" flip point: sequences at or below this run dense
# attention, longer ones run the tiled lane (flash, or the Pallas kernels
# when attn_impl="pallas").  models/attention.py::resolve_impl and
# choose_attention both read it — do not fork it inline again.
FLASH_THRESHOLD = 1024


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads

    # layer pattern (repeating period; num_layers % len(pattern) == 0)
    pattern: tuple[str, ...] = ("attn",)  # 'attn' | 'attn_local' | 'mamba'
    ffn_pattern: tuple[str, ...] = ("dense",)  # 'dense' | 'moe'

    # attention details
    qkv_bias: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    window: int | None = None  # sliding window for 'attn_local'
    rope_theta: float = 10_000.0
    causal: bool = True  # False => encoder-only (no decode shapes)

    # moe
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "gspmd"  # 'gspmd' | 'ep' (shard_map explicit all-to-all)

    # ssm
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0  # 0 = ceil(d_model / 16)
    ssm_chunk: int = 256

    # io
    input_mode: str = "tokens"  # 'tokens' | 'embeddings' (audio/vlm stub frontend)
    norm_type: str = "rms"  # 'rms' | 'layer'
    ffn_act: str = "silu"  # activation inside (GLU-style) FFN
    ffn_glu: bool = True  # gated FFN (SwiGLU); False => plain 2-layer MLP

    # numerics / structure
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: bool = True
    xent_chunk: int = 512
    attn_impl: str = "auto"  # 'auto' | 'dense' | 'flash' | 'pallas'
    flash_threshold: int = FLASH_THRESHOLD  # auto: dense iff s <= threshold
    flash_q_block: int = 512
    flash_kv_block: int = 1024
    moe_groups: int = 0  # 0 => data shard count at call time

    # metadata
    source: str = ""

    def __post_init__(self):
        if self.num_layers % len(self.pattern) != 0:
            raise ValueError(f"{self.name}: num_layers % pattern period != 0")
        if len(self.ffn_pattern) not in (1, len(self.pattern)):
            # allow ffn_pattern either scalar-like or same period
            if len(self.pattern) % len(self.ffn_pattern) != 0:
                raise ValueError(f"{self.name}: ffn_pattern period mismatch")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads if self.num_heads else 0)

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def repeats(self) -> int:
        return self.num_layers // self.period

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(1, math.ceil(self.d_model / 16))

    def ffn_kind(self, pos: int) -> str:
        return self.ffn_pattern[pos % len(self.ffn_pattern)]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# Archs whose attention is purely quadratic skip long_500k; encoder-only archs
# skip decode shapes entirely (see DESIGN.md §6).
FULL_ATTENTION_ARCHS = {
    "yi-6b", "qwen2-7b", "llama3-405b", "dbrx-132b", "kimi-k2-1t-a32b", "internvl2-1b",
}
ENCODER_ONLY_ARCHS = {"hubert-xlarge"}


def cell_supported(arch_name: str, shape_name: str, causal: bool) -> tuple[bool, str]:
    shape = SHAPES[shape_name]
    if arch_name in ENCODER_ONLY_ARCHS and shape.kind == "decode":
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k" and arch_name in FULL_ATTENTION_ARCHS:
        return False, "pure full-attention arch: 500k KV cache needs sub-quadratic attention"
    return True, ""
