"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave with MoE every
other layer. 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
16 experts top-2. Period-8 pattern with attention at offset 4 (hf config).
[arXiv:2403.19887; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=65_536,
    pattern=(
        "mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba",
    ),
    ffn_pattern=(
        "dense", "moe", "dense", "moe", "dense", "moe", "dense", "moe",
    ),
    num_experts=16,
    top_k=2,
    capacity_factor=1.25,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    source="arXiv:2403.19887; hf",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, d_ff=96,
        vocab_size=251, num_experts=4, top_k=2, capacity_factor=4.0,
        param_dtype="float32", compute_dtype="float32", xent_chunk=64,
        ssm_chunk=16, remat=False,
    )
