"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE, 61L d_model=7168
64H (GQA kv=8, head_dim 112) d_ff=2048/expert, vocab=163840, 384 experts
top-8. [arXiv:2501.kimi2 per assignment]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=2048,
    vocab_size=163_840,
    ffn_pattern=("moe",),
    num_experts=384,
    top_k=8,
    capacity_factor=1.25,
    source="arXiv:2501.kimi2 (assignment table)",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=251, num_experts=8, top_k=2, capacity_factor=4.0,
        param_dtype="float32", compute_dtype="float32", xent_chunk=64, remat=False,
    )
