"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256. [arXiv:2407.21783]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16_384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53_248,
    vocab_size=128_256,
    rope_theta=500_000.0,
    source="arXiv:2407.21783",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=251, param_dtype="float32", compute_dtype="float32",
        xent_chunk=64, remat=False,
    )
