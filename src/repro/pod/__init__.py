"""repro.pod — host-spanning elastic rungs (multi-pod data parallelism).

A *pod* is one host's worth of devices (fast ICI inside, slow DCN between).
``PodTopology`` partitions the flat device list into pods — on the 8-device
CPU harness this emulates N hosts in-process, so every cross-pod code path
runs under the normal test suite.  ``PodLadder`` extends ``elastic.MeshLadder``
with cross-pod rungs whose meshes carry a ``pods > 1`` leading axis: on those
rungs the gradient mean crosses the pod axis through the error-feedback int8
compressor (``dist.compression``) — int8 payload + f32 scale on the wire —
with the residuals threaded through ``TrainState.err_state`` and re-zeroed
at every rung transition.  ``PodHealth`` tracks which pods are alive;
``launch/supervisor.py`` answers a pod loss by DEGRADING the ladder onto the
widest all-healthy rung (``Trainer.demote``) instead of restarting from a
checkpoint.
"""

from repro.pod.health import PodHealth
from repro.pod.ladder import PodLadder
from repro.pod.step import make_pod_train_step
from repro.pod.topology import PodTopology

__all__ = ["PodTopology", "PodHealth", "PodLadder", "make_pod_train_step"]
