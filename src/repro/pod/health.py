"""Pod health registry: which pods are alive, and how wide a rung may span.

Cross-pod rungs span a *prefix* of the pod list (pods ``0..p-1`` — the same
prefix-nesting the device ladder uses), so rung usability is exactly
``prefix_healthy(p)``.  The supervisor marks a pod lost on a host failure;
``PodLadder.rung_for_batch`` then filters the ladder to all-healthy rungs
and ``Trainer.demote`` reshards the surviving state down — no restart.
"""

from __future__ import annotations


class PodHealth:
    def __init__(self, num_pods: int):
        num_pods = int(num_pods)
        if num_pods < 1:
            raise ValueError(f"num_pods must be >= 1, got {num_pods}")
        self.num_pods = num_pods
        self._healthy = [True] * num_pods

    def _check(self, pod: int) -> int:
        pod = int(pod)
        if not 0 <= pod < self.num_pods:
            raise ValueError(f"pod {pod} out of range [0, {self.num_pods})")
        return pod

    def mark_lost(self, pod: int) -> None:
        self._healthy[self._check(pod)] = False

    def mark_healthy(self, pod: int) -> None:
        self._healthy[self._check(pod)] = True

    def is_healthy(self, pod: int) -> bool:
        return self._healthy[self._check(pod)]

    def prefix_healthy(self, k: int) -> bool:
        """True when pods ``0..k-1`` are ALL healthy (a k-pod rung is usable)."""
        k = int(k)
        if not 1 <= k <= self.num_pods:
            return False
        return all(self._healthy[:k])

    @property
    def healthy_prefix(self) -> int:
        """Length of the leading all-healthy run (0 when pod 0 is lost)."""
        n = 0
        for ok in self._healthy:
            if not ok:
                break
            n += 1
        return n

    @property
    def lost(self) -> list[int]:
        return [i for i, ok in enumerate(self._healthy) if not ok]

    def __repr__(self) -> str:
        bits = "".join("H" if ok else "L" for ok in self._healthy)
        return f"PodHealth({bits})"
