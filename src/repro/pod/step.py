"""The cross-pod train step: shard_map over a (pods, data) mesh with the
gradient mean routed through the error-feedback int8 compressor.

Structure per step (one shard_map program over the rung's full mesh):

  1. local value_and_grad on each device's batch shard;
  2. ``pmean`` over the within-pod ``data`` axis — the fast ICI reduction,
     exact f32;
  3. the pod-level means cross the ``pod`` axis through
     ``dist.compression.compressed_pod_mean`` — int8 payload + f32 scale on
     the wire (the only DCN bytes), residuals carried shard-local in
     ``TrainState.err_state``;
  4. replicated optimizer update (cross-pod plans keep ``fsdp=()`` so params
     are replicated — the update is computed identically everywhere).

Diversity accumulates inside the same program, exactly like the plain step:
the ``moment`` tier treats each POD's uncompressed mean as one microbatch
(``mb_count += pods``, so the decode's small-batch size is the per-pod
batch); the ``exact`` tier psums the per-sample squared norms over both
axes.  The ``gram`` tier's probe kernels are not wired across pods yet.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

try:  # moved out of experimental in newer jax
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover
    from jax import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import diversity
from repro.dist.compression import compressed_pod_mean
from repro.optim import Optimizer, apply_updates
from repro.train.state import TrainState
from repro.utils import pytree as ptu

PyTree = Any


def make_pod_train_step(
    rung,
    optimizer: Optimizer,
    *,
    loss_fn: Callable,
    example_loss: Callable | None = None,
    diversity_on: bool = True,
    estimator: str = "moment",
    compress: bool = True,
    pod_axis: str = "pod",
    data_axis: str = "data",
) -> Callable[[TrainState, dict, jax.Array], tuple[TrainState, dict]]:
    """Returns ``train_step(state, batch, lr) -> (state, metrics)`` for a
    cross-pod ``Rung`` (its mesh must carry ``(pod_axis, data_axis)``).

    ``loss_fn(params, batch) -> scalar`` is the mean loss over a batch
    shard; ``example_loss`` is required for the exact tier.  With
    ``compress=True`` (the production setting) ``state.err_state`` must hold
    the stacked per-pod residual tree (``PodLadder.adapt_state`` installs
    it); ``compress=False`` runs the same program with an exact f32 pmean
    across pods — the baseline the compression golden test compares against.
    """
    mesh = rung.plan.mesh
    pods = int(mesh.shape[pod_axis])
    dpp = int(mesh.shape[data_axis])
    if pods < 2:
        raise ValueError(f"cross-pod step needs a pods>=2 mesh axis, got {pods}")
    if estimator == "gram":
        raise NotImplementedError(
            "the gram tier's probe kernels are not wired across pods; use "
            "'moment' (production) or 'exact' (reference) on cross-pod rungs"
        )
    if estimator not in ("exact", "moment"):
        raise ValueError(f"unknown cross-pod estimator {estimator!r}")
    if estimator == "exact" and example_loss is None:
        raise ValueError("estimator='exact' needs example_loss")

    def body(state: TrainState, batch: dict, lr: jax.Array):
        params = state.params  # replicated: cross-pod plans keep fsdp=()
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        # within-pod reduction (ICI): exact f32 mean over the pod's shards
        grads = jax.lax.pmean(grads, data_axis)
        local_b = jax.tree.leaves(batch)[0].shape[0]
        global_b = local_b * pods * dpp

        if compress:
            if state.err_state is None:
                raise ValueError(
                    "compress=True needs TrainState.err_state (the stacked "
                    "per-pod residuals PodLadder.adapt_state installs)"
                )
            err = jax.tree.map(lambda e: e[0], state.err_state)
            mean, new_err = compressed_pod_mean(grads, err, pod_axis)
            new_err = jax.tree.map(lambda e: e[None], new_err)
        else:
            mean = jax.lax.pmean(grads, pod_axis)
            new_err = state.err_state

        div_state = state.div_state
        if diversity_on:
            b = jnp.float32(global_b)
            if estimator == "exact":
                sq = jax.lax.psum(
                    jnp.sum(diversity.persample_sq_norms(example_loss, params, batch)),
                    (pod_axis, data_axis),
                )
                mb = jnp.float32(1.0)  # decode expects m=1 small batches
            else:
                # one "microbatch" per pod: the UNCOMPRESSED pod mean is the
                # small-batch statistic, so quantization noise never enters Q
                m_pod = jnp.float32(global_b // pods)
                sq = jax.lax.psum((m_pod * m_pod) * ptu.tree_sq_norm(grads), pod_axis)
                mb = jnp.float32(pods)
            div_state = diversity.DiversityState(
                grad_sum=jax.tree.map(
                    lambda acc, g: acc + b.astype(acc.dtype) * g.astype(acc.dtype),
                    div_state.grad_sum,
                    mean,
                ),
                sq_norm_sum=div_state.sq_norm_sum + sq,
                mb_count=div_state.mb_count + mb,
                sample_count=div_state.sample_count + b,
            )

        updates, opt_state = optimizer.update(mean, state.opt_state, params, lr)
        params = apply_updates(params, updates)
        new_state = TrainState(
            params=params,
            opt_state=opt_state,
            div_state=div_state,
            step=state.step + 1,
            err_state=new_err,
        )
        metrics = {
            "loss": jax.lax.pmean(loss, (pod_axis, data_axis)),
            "grad_norm_sq": ptu.tree_sq_norm(mean),
        }
        return new_state, metrics

    # Specs are pytree prefixes: one P per TrainState field covers its whole
    # subtree.  Everything is replicated except the batch (sharded over both
    # axes) and the error residuals (stacked (pods, ...) leaves, one shard
    # per pod).
    state_spec = TrainState(
        params=P(), opt_state=P(), div_state=P(), step=P(),
        err_state=P(pod_axis),
    )
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(state_spec, P((pod_axis, data_axis)), P()),
        out_specs=(state_spec, P()),
        check_rep=False,
    )
