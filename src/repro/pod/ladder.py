"""PodLadder: a MeshLadder whose top rungs span multiple pods.

The within-pod rungs are the ordinary ``MeshLadder`` over pod 0's devices
(dp widths 1..devices_per_pod).  Above them sit *cross-pod* rungs — one per
power-of-two pod count (plus a non-pow2 maximum) — whose meshes carry a
``(pod, data)`` axis pair over a prefix of the pod list.  Prefix nesting is
preserved end to end: every rung's devices are a prefix of the next rung's,
so the elastic widen/narrow stays a pure fan-out.

Cross-pod plans set ``fsdp=()`` (params replicated): the sharding-inference
rules then place parameters and their optimizer/diversity mirrors identically
on every device, which is what lets the cross-pod step compute the update
replicated from one compressed gradient mean instead of ZeRO-gathering over
the slow pod axis.  The compression error-feedback residuals ride in
``TrainState.err_state``; ``adapt_state`` installs / drops / re-zeros them at
every rung transition (a residual is meaningless on a different pod layout).
"""

from __future__ import annotations

from typing import Any, Sequence

import numpy as np

from repro.dist.plan import ShardingPlan
from repro.elastic.ladder import MeshLadder, Rung
from repro.pod.health import PodHealth
from repro.pod.topology import PodTopology


class PodLadder(MeshLadder):
    """Elastic ladder spanning ``pods`` virtual pods.

    Args:
      pods: number of pods to partition ``devices`` into (>= 2).
      devices: flat device list (default ``jax.devices()``).
      granule: minimum per-device microbatch, as in ``MeshLadder``.
      dp_axis / pod_axis: mesh axis names.
      compress: route cross-pod gradient means through the error-feedback
        int8 compressor (``dist.compression``); False runs the same rungs
        with an exact f32 cross-pod pmean (the golden-test baseline).
    """

    def __init__(
        self,
        pods: int = 2,
        devices: Sequence[Any] | None = None,
        *,
        granule: int = 1,
        dp_axis: str = "data",
        pod_axis: str = "pod",
        compress: bool = True,
    ):
        pods = int(pods)
        if pods < 2:
            raise ValueError(f"PodLadder needs pods >= 2, got {pods}")
        topo = PodTopology(pods, devices)
        # within-pod rungs: the ordinary ladder over pod 0's devices
        super().__init__(topo.pods[0], granule=granule, dp_axis=dp_axis)
        self.topology = topo
        self.health = PodHealth(pods)
        self.pod_axis = pod_axis
        self.dp_axis = dp_axis
        self.compress = bool(compress)

        from jax.sharding import Mesh  # deferred: no device state at import

        dpp = topo.devices_per_pod
        pod_counts = [1 << i for i in range(1, pods.bit_length()) if (1 << i) <= pods]
        if not pod_counts or pod_counts[-1] != pods:
            pod_counts.append(pods)  # non-pow2 pod counts still top out
        for p in pod_counts:
            devs = np.asarray(topo.devices[: p * dpp], dtype=object).reshape(p, dpp)
            mesh = Mesh(devs, (pod_axis, dp_axis))
            # fsdp=() => params replicated (see module docstring); the batch
            # shards its leading dim over pod x data.
            plan = ShardingPlan(
                mesh=mesh,
                dp=(pod_axis, dp_axis),
                fsdp=(),
                tp=None,
                ep=(dp_axis,),
            )
            self.rungs.append(
                Rung(index=len(self.rungs), dp=p * dpp, plan=plan, pods=p)
            )

    # -- selection -----------------------------------------------------------
    def rung_for_batch(self, m: int) -> Rung:
        """Widest ALL-HEALTHY rung for ``m`` (same divisibility/granule rule
        as the base ladder, filtered through ``health.prefix_healthy``); the
        narrowest healthy rung when nothing fits.  Raises when pod 0 is lost
        — no rung excludes pod 0, so the job cannot degrade further."""
        m = int(m)
        best = None
        for rung in self.rungs:
            if not self.health.prefix_healthy(rung.pods):
                continue
            if best is None:
                best = rung
            if m % rung.dp == 0 and m // rung.dp >= self.granule:
                best = rung
        if best is None:
            raise RuntimeError(
                "no healthy rung left (pod 0 is lost); a degrade-don't-restart "
                "supervisor cannot survive losing the primary pod"
            )
        return best

    # -- state hooks ---------------------------------------------------------
    def adapt_state(self, state, src: Rung | None, dst: Rung):
        """Thread the compression residuals across a rung transition.

        Within-pod rungs carry no residuals (``err_state=None``); a cross-pod
        rung gets freshly-zeroed stacked ``(pods, *param_shape)`` f32 leaves
        sharded one-per-pod.  Residuals survive only a transition that keeps
        the pod layout (src.pods == dst.pods); any other move re-zeros them —
        a residual is a per-pod quantizer carry, meaningless elsewhere.
        """
        if dst.pods <= 1:
            if state.err_state is None:
                return state
            return state._replace(err_state=None)
        if not self.compress:
            # uncompressed cross-pod rungs run a plain pmean: no residuals
            if state.err_state is None:
                return state
            return state._replace(err_state=None)
        if (
            src is not None
            and src.pods == dst.pods
            and state.err_state is not None
        ):
            return state

        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        zeros = jax.tree.map(
            lambda p: jnp.zeros((dst.pods,) + tuple(jnp.shape(p)), jnp.float32),
            state.params,
        )
        sharding = NamedSharding(dst.plan.mesh, P(self.pod_axis))
        zeros = jax.device_put(
            zeros, jax.tree.map(lambda _: sharding, zeros)
        )
        return state._replace(err_state=zeros)

    # -- engine --------------------------------------------------------------
    def engine_for(
        self,
        fns,
        optimizer,
        *,
        estimator: str = "moment",
        diversity_on: bool = True,
        donate: bool = True,
        psn_chunk: int | None = None,
    ):
        """A rung-aware ``StepEngine``: within-pod rungs compile the plain
        ``make_train_step`` program, cross-pod rungs the shard_map'd
        compressed step (``pod/step.py``).  The Trainer picks this up by
        duck-typing instead of ``StepEngine.for_model_fns``."""
        from repro.pod import step as pod_step
        from repro.train import step as step_lib
        from repro.train.engine import StepEngine, eval_fn_for

        injit = ("exact", "gram", "moment")

        def build(key: int, tier: str | None = None, rung: int | None = None):
            est = tier if tier is not None else estimator
            track = diversity_on and est in injit
            r = self.rungs[rung] if rung is not None else None
            if r is not None and r.pods > 1:
                return pod_step.make_pod_train_step(
                    r,
                    optimizer,
                    loss_fn=fns.batch_loss,
                    example_loss=fns.example_loss,
                    diversity_on=track,
                    estimator=est if track else "moment",
                    compress=self.compress,
                    pod_axis=self.pod_axis,
                    data_axis=self.dp_axis,
                )
            return step_lib.make_train_step(
                None,
                optimizer,
                num_micro=1,
                diversity_on=track,
                loss_fn=fns.batch_loss,
                estimator=est if track else "moment",
                example_loss=fns.example_loss,
                probe_loss=fns.probe_loss,
                probe_specs=fns.probe_specs,
                psn_chunk=psn_chunk,
            )

        eng = StepEngine(build, donate=donate, eval_fn=eval_fn_for(fns))
        if diversity_on and estimator in injit:
            eng.tier = estimator
        return eng

    # -- introspection -------------------------------------------------------
    def __repr__(self) -> str:
        return (
            f"PodLadder(pods={self.topology.num_pods}, dp={self.widths}, "
            f"granule={self.granule}, compress={self.compress})"
        )
