"""Pod topology: hosts x devices-per-host over one flat device list.

Production multi-host jax gives each process its own slice of
``jax.devices()``; here the same structure is *emulated in-process* by
partitioning the single-process device list into equal contiguous pods, so
the cross-pod mesh axis, the compressed DCN gradient exchange, and the
supervisor's degrade path all exercise on the 8-CPU-device test harness.
"""

from __future__ import annotations

from typing import Any, Sequence


class PodTopology:
    """Equal partition of a flat device list into ``num_pods`` virtual pods.

    ``pods[i]`` is pod *i*'s device list (contiguous, in order), so pod 0's
    devices are always a prefix of the flat list — the same prefix-nesting
    invariant ``MeshLadder`` relies on for widen/narrow reshards.
    """

    def __init__(self, num_pods: int, devices: Sequence[Any] | None = None):
        if devices is None:
            import jax

            devices = jax.devices()
        devices = list(devices)
        num_pods = int(num_pods)
        if num_pods < 1:
            raise ValueError(f"num_pods must be >= 1, got {num_pods}")
        if len(devices) % num_pods != 0:
            raise ValueError(
                f"{len(devices)} devices do not partition into {num_pods} "
                f"equal pods"
            )
        self.num_pods = num_pods
        self.devices = devices
        self.devices_per_pod = len(devices) // num_pods
        self.pods: list[list[Any]] = [
            devices[i * self.devices_per_pod : (i + 1) * self.devices_per_pod]
            for i in range(num_pods)
        ]

    def pod_of(self, device: Any) -> int:
        """Which pod a device belongs to (by identity)."""
        for i, pod in enumerate(self.pods):
            if any(d is device for d in pod):
                return i
        raise ValueError(f"device {device!r} is not in this topology")

    def __len__(self) -> int:
        return self.num_pods

    def __repr__(self) -> str:
        return (
            f"PodTopology(num_pods={self.num_pods}, "
            f"devices_per_pod={self.devices_per_pod})"
        )
