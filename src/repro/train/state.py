"""Training state: parameters + optimizer + DiveBatch diversity accumulators."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import diversity
from repro.optim import Optimizer

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt_state: PyTree
    div_state: diversity.DiversityState
    step: jax.Array
    # Cross-pod compression error-feedback residuals (repro.pod): a stacked
    # ``(pods, *param_shape)`` f32 tree on cross-pod rungs, None everywhere
    # else. Transient wire state — installed/zeroed by PodLadder.adapt_state
    # at rung transitions and deliberately NOT checkpointed.
    err_state: PyTree = None


def init_state(params: PyTree, optimizer: Optimizer, div_dtype=jnp.float32) -> TrainState:
    # Donation-ready: leaves must be jax Arrays up front — numpy leaves would
    # be re-uploaded on every step and can never alias donated output buffers.
    params = jax.tree.map(jnp.asarray, params)
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        div_state=diversity.init_state(params, accum_dtype=div_dtype),
        step=jnp.zeros((), jnp.int32),
    )


def state_specs(cfg, optimizer: Optimizer, div_dtype=jnp.float32) -> TrainState:
    """ShapeDtypeStruct version (no allocation) for the dry-run."""
    from repro.models import transformer as tf

    params = tf.param_specs(cfg)
    return jax.eval_shape(
        lambda p: init_state(p, optimizer, div_dtype), params
    )
