from repro.train.engine import EngineStats, ModelFns, StepEngine
from repro.train.state import TrainState, init_state, state_specs
from repro.train.step import epoch_end_host, make_train_step

__all__ = [
    "TrainState",
    "init_state",
    "state_specs",
    "make_train_step",
    "epoch_end_host",
    "StepEngine",
    "EngineStats",
    "ModelFns",
]
