"""The production LM train step: microbatch gradient accumulation + DiveBatch
diversity accumulation, as one jitted program.

Batch-size adaptivity at scale = adapting ``num_micro`` (the accumulation
length): the microbatch shape is fixed per mesh, the global batch is
``num_micro * micro_batch``, and the compile cache is keyed by the power-of-2
``num_micro`` bucket (core/batch_policy.bucket).

The microbatch re-layout ``(B, ...) -> (G, M, ...)`` is sharding-preserving:
it splits the dp-sharded batch dim as (dp, G, M/dp), transposes, and merges
(dp, M/dp) back into the microbatch dim — every microbatch stays evenly
spread over all dp shards with zero communication.

Diversity accumulation uses the moment estimator (DESIGN.md §3): per
microbatch it costs one tree-axpy into the (ZeRO-sharded) grad_sum
accumulator plus one squared-norm reduction of the mean gradient the
optimizer already has — no per-sample work.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import diversity
from repro.models import transformer as tf
from repro.optim import Optimizer, apply_updates
from repro.train.state import TrainState
from repro.utils import pytree as ptu

PyTree = Any


def _to_micro(x: jax.Array, num_micro: int, dp_size: int) -> jax.Array:
    b = x.shape[0]
    assert b % num_micro == 0, (b, num_micro)
    m = b // num_micro
    if dp_size > 1 and m % dp_size == 0 and b % (dp_size * num_micro) == 0:
        x = x.reshape(dp_size, num_micro, m // dp_size, *x.shape[1:])
        x = jnp.moveaxis(x, 0, 1)
        return x.reshape(num_micro, m, *x.shape[3:])
    return x.reshape(num_micro, m, *x.shape[1:])


def make_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    num_micro: int,
    *,
    dp_size: int = 1,
    moe_groups: int = 1,
    diversity_on: bool = True,
    grad_accum_dtype=jnp.float32,
    loss_fn: Callable | None = None,
) -> Callable[[TrainState, dict, jax.Array], tuple[TrainState, dict]]:
    """Returns train_step(state, batch, lr) -> (state, metrics)."""
    base_loss = loss_fn or (lambda p, b: tf.loss_fn(cfg, p, b, moe_groups=moe_groups))

    def train_step(state: TrainState, batch: dict, lr: jax.Array):
        micro = jax.tree.map(lambda x: _to_micro(x, num_micro, dp_size), batch)
        global_batch = next(iter(jax.tree.leaves(batch))).shape[0]
        micro_global = global_batch // num_micro

        grad_fn = jax.value_and_grad(base_loss, has_aux=True)

        # The microbatch scan carries ONLY (grads_acc, scalars): the diversity
        # grad_sum += sum_j m*g_j equals B*mean_grad exactly, so that param-
        # sized accumulator is updated once per step OUTSIDE the loop — one
        # fewer parameter-sized loop carry (matters at 405B/1T scale). The
        # moment estimator's Q = sum_j ||m*g_j||^2 is a scalar per microbatch
        # and stays inside.
        def micro_step(carry, mb):
            grads_acc, sq_sum, loss_acc = carry
            (loss, metrics), grads = grad_fn(state.params, mb)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(a.dtype), grads_acc, grads
            )
            if diversity_on:
                m = jnp.float32(micro_global)
                sq_sum = sq_sum + (m * m) * ptu.tree_sq_norm(grads)
            return (grads_acc, sq_sum, loss_acc + loss), None

        grads0 = ptu.tree_zeros_like(state.params, dtype=grad_accum_dtype)
        zero = jnp.zeros((), jnp.float32)
        (grads_acc, sq_sum, loss_sum), _ = jax.lax.scan(
            micro_step, (grads0, zero, zero), micro
        )
        grads = jax.tree.map(lambda g: (g / num_micro), grads_acc)

        div_state = state.div_state
        if diversity_on:
            b = jnp.float32(global_batch)
            div_state = diversity.DiversityState(
                grad_sum=jax.tree.map(
                    lambda acc, g: acc + b.astype(acc.dtype) * g.astype(acc.dtype),
                    div_state.grad_sum, grads,
                ),
                sq_norm_sum=div_state.sq_norm_sum + sq_sum,
                mb_count=div_state.mb_count + num_micro,
                sample_count=div_state.sample_count + b,
            )

        updates, opt_state = optimizer.update(grads, state.opt_state, state.params, lr)
        params = apply_updates(state.params, updates)
        new_state = TrainState(
            params=params, opt_state=opt_state, div_state=div_state,
            step=state.step + 1,
        )
        metrics = {
            "loss": loss_sum / num_micro,
            "grad_norm_sq": ptu.tree_sq_norm(grads),
        }
        return new_state, metrics

    return train_step


def epoch_end_host(state: TrainState, estimator: str = "moment") -> tuple[float, TrainState]:
    """Host-side epoch boundary: read the diversity estimate, reset the
    accumulators. Returns (Delta_hat, state-with-reset-accumulators)."""
    delta = float(jax.jit(functools.partial(diversity.estimate, estimator=estimator))(state.div_state))
    reset = jax.jit(diversity.reset_state)(state.div_state)
    return delta, TrainState(state.params, state.opt_state, reset, state.step)
