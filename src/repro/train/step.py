"""The train step: microbatch gradient accumulation + DiveBatch diversity
accumulation, as one jitted program. Every training path (the host ``Trainer``,
``launch/train.py``, the multi-pod dry-run, ``examples/train_lm.py``) obtains
its compiled steps from here via ``train/engine.py::StepEngine``.

Batch-size adaptivity at scale = adapting ``num_micro`` (the accumulation
length): the microbatch shape is fixed per mesh, the global batch is
``num_micro * micro_batch``, and the compile cache is keyed by the power-of-2
``num_micro`` bucket (core/batch_policy.bucket).

All three diversity-estimator tiers run INSIDE the jitted step (``estimator``):

  moment  Q += ||microbatch_sum_grad||^2 per microbatch — zero extra backward
          work, the tier used at 7B..1T scale.
  exact   Q += sum_i ||g_i||^2 via vmap(grad(example_loss)) over each
          microbatch — reference semantics, O(m) memory blowup.
  gram    Q += probe-trick per-sample norms (kernels/psgn) from one extra
          probe-gradient pass — exact for the dense kernels that dominate.

so an epoch performs no per-step host transfer beyond the scalar metrics.

The microbatch re-layout ``(B, ...) -> (G, M, ...)`` is sharding-preserving:
it splits the dp-sharded batch dim as (dp, G, M/dp), transposes, and merges
(dp, M/dp) back into the microbatch dim — every microbatch stays evenly
spread over all dp shards with zero communication.

Diversity accumulation uses the moment estimator (DESIGN.md §3): per
microbatch it costs one tree-axpy into the (ZeRO-sharded) grad_sum
accumulator plus one squared-norm reduction of the mean gradient the
optimizer already has — no per-sample work.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import diversity
from repro.kernels import ops as kernel_ops
from repro.models import transformer as tf
from repro.optim import Optimizer, apply_updates
from repro.train.state import TrainState
from repro.utils import pytree as ptu

PyTree = Any


def _to_micro(x: jax.Array, num_micro: int, dp_size: int) -> jax.Array:
    b = x.shape[0]
    if b % num_micro != 0:
        raise ValueError(
            f"global batch {b} is not divisible by the num_micro bucket "
            f"{num_micro}; batch sizes must land on the bucket lattice "
            f"(core/batch_policy.bucket)"
        )
    m = b // num_micro
    if dp_size > 1 and m % dp_size == 0:
        x = x.reshape(dp_size, num_micro, m // dp_size, *x.shape[1:])
        x = jnp.moveaxis(x, 0, 1)
        return x.reshape(num_micro, m, *x.shape[3:])
    return x.reshape(num_micro, m, *x.shape[1:])


def make_train_step(
    cfg: ModelConfig | None,
    optimizer: Optimizer,
    num_micro: int,
    *,
    dp_size: int = 1,
    moe_groups: int = 1,
    diversity_on: bool = True,
    grad_accum_dtype=jnp.float32,
    loss_fn: Callable | None = None,
    has_aux: bool | None = None,
    estimator: str = "moment",
    example_loss: Callable | None = None,
    probe_loss: Callable | None = None,
    probe_specs: Callable | None = None,
    psn_chunk: int | None = None,
    psn_impl: str = "auto",
    psn_interpret: bool | None = None,
) -> Callable[[TrainState, dict, jax.Array], tuple[TrainState, dict]]:
    """Returns train_step(state, batch, lr) -> (state, metrics).

    ``loss_fn(params, batch)`` defaults to the transformer LM loss (``cfg``
    required then). ``has_aux`` says whether it returns ``(loss, aux)``;
    defaults to True for the LM loss, False for a custom scalar loss.

    ``estimator`` selects the in-jit diversity tier (see module docstring):
    "moment" needs nothing extra, "exact" needs ``example_loss(params,
    example)``, "gram" needs ``probe_loss(params, probes, batch) -> (loss,
    acts)`` plus ``probe_specs(params, batch_size)``.

    ``psn_chunk`` bounds the exact tier's vmap width: per-sample gradients
    are materialised ``psn_chunk`` samples at a time (peak extra memory
    ``psn_chunk x param-size`` instead of ``microbatch x param-size``).

    ``psn_impl`` picks how the EXACT tier computes per-sample norms:
    "vmap" is vmap(grad(example_loss)) — reference semantics for any model;
    "kernel" replaces it with one probe-gradient pass through the fused
    kernels/psgn lane (``||X^T D||^2`` plus the bias terms ``||sum_s d||^2``
    per probed layer) — no per-sample gradient trees at all, exact for
    bias-complete dense models, requires ``probe_loss``/``probe_specs``.
    "auto" keeps vmap whenever ``example_loss`` is provided (bit-stable
    default) and falls back to the kernel path when only probes exist.
    ``psn_interpret`` forces the Pallas interpret flag (None = on-TPU
    detection via kernels/ops.default_interpret).
    """
    if loss_fn is None:
        if cfg is None:
            raise ValueError("make_train_step needs cfg or loss_fn")
        base_loss = lambda p, b: tf.loss_fn(cfg, p, b, moe_groups=moe_groups)
        aux = True
    else:
        aux = has_aux if has_aux is not None else False
        base_loss = loss_fn if aux else (lambda p, b: (loss_fn(p, b), {}))
    if psn_impl not in ("auto", "vmap", "kernel"):
        raise ValueError(f"unknown psn_impl {psn_impl!r}")
    if psn_impl == "auto":
        psn_impl = "vmap" if example_loss is not None else "kernel"
    if diversity_on:
        if estimator == "exact":
            if psn_impl == "vmap" and example_loss is None:
                raise ValueError("estimator='exact' needs example_loss")
            if psn_impl == "kernel" and (probe_loss is None or probe_specs is None):
                raise ValueError(
                    "estimator='exact' with psn_impl='kernel' needs "
                    "probe_loss and probe_specs"
                )
        if estimator == "gram" and (probe_loss is None or probe_specs is None):
            raise ValueError("estimator='gram' needs probe_loss and probe_specs")
        if estimator not in ("exact", "gram", "moment"):
            raise ValueError(f"unknown in-step estimator {estimator!r}")

    def _probe_sq_norms(params, mb, *, bias):
        """One probe-gradient pass -> summed per-sample sq-norms via the
        Pallas psgn lane (same-shape layers fused into one launch)."""
        bsz = jax.tree.leaves(mb)[0].shape[0]
        probes = probe_specs(params, bsz)
        (_, acts), pgrads = jax.value_and_grad(
            probe_loss, argnums=1, has_aux=True
        )(params, probes, mb)
        return jnp.sum(
            kernel_ops.persample_sq_norm_tree(
                acts, pgrads, scale=float(bsz), bias=bias,
                interpret=psn_interpret,
            )
        )

    def _micro_sq_contrib(params, mb, mean_grads, micro_global):
        """This microbatch's contribution to DiversityState.sq_norm_sum."""
        if estimator == "exact":
            if psn_impl == "kernel":
                # the fused lane: no vmap, no per-sample gradient trees —
                # bias terms included so dense+bias models stay exact
                return _probe_sq_norms(params, mb, bias=True)
            # Chunked so the vmap'd per-sample gradient trees never exceed
            # psn_chunk x param-size of live memory (the loop unrolls at
            # trace time; chunk sums accumulate in order).
            n = jax.tree.leaves(mb)[0].shape[0]
            chunk = min(psn_chunk or n, n)
            total = jnp.zeros((), jnp.float32)
            for i in range(0, n, chunk):
                sub = jax.tree.map(lambda x: x[i : i + chunk], mb)
                total = total + jnp.sum(
                    diversity.persample_sq_norms(example_loss, params, sub)
                )
            return total
        if estimator == "gram":
            return _probe_sq_norms(params, mb, bias=False)
        m = jnp.float32(micro_global)
        return (m * m) * ptu.tree_sq_norm(mean_grads)

    def train_step(state: TrainState, batch: dict, lr: jax.Array):
        micro = jax.tree.map(lambda x: _to_micro(x, num_micro, dp_size), batch)
        global_batch = next(iter(jax.tree.leaves(batch))).shape[0]
        micro_global = global_batch // num_micro

        grad_fn = jax.value_and_grad(base_loss, has_aux=True)

        # The microbatch scan carries ONLY (grads_acc, scalars): the diversity
        # grad_sum += sum_j m*g_j equals B*mean_grad exactly, so that param-
        # sized accumulator is updated once per step OUTSIDE the loop — one
        # fewer parameter-sized loop carry (matters at 405B/1T scale). The
        # estimator statistic Q (moment: sum_j ||m*g_j||^2; exact/gram:
        # sum_i ||g_i||^2) is a scalar per microbatch and stays inside.
        def micro_step(carry, mb):
            grads_acc, sq_sum, loss_acc = carry
            (loss, metrics), grads = grad_fn(state.params, mb)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(a.dtype), grads_acc, grads
            )
            if diversity_on:
                sq_sum = sq_sum + _micro_sq_contrib(
                    state.params, mb, grads, micro_global
                )
            return (grads_acc, sq_sum, loss_acc + loss), None

        grads0 = ptu.tree_zeros_like(state.params, dtype=grad_accum_dtype)
        zero = jnp.zeros((), jnp.float32)
        (grads_acc, sq_sum, loss_sum), _ = jax.lax.scan(
            micro_step, (grads0, zero, zero), micro
        )
        grads = jax.tree.map(lambda g: (g / num_micro), grads_acc)

        div_state = state.div_state
        if diversity_on:
            b = jnp.float32(global_batch)
            div_state = diversity.DiversityState(
                grad_sum=jax.tree.map(
                    lambda acc, g: acc + b.astype(acc.dtype) * g.astype(acc.dtype),
                    div_state.grad_sum, grads,
                ),
                sq_norm_sum=div_state.sq_norm_sum + sq_sum,
                mb_count=div_state.mb_count + num_micro,
                sample_count=div_state.sample_count + b,
            )

        updates, opt_state = optimizer.update(grads, state.opt_state, state.params, lr)
        params = apply_updates(state.params, updates)
        new_state = TrainState(
            params=params, opt_state=opt_state, div_state=div_state,
            step=state.step + 1,
        )
        metrics = {
            "loss": loss_sum / num_micro,
            "grad_norm_sq": ptu.tree_sq_norm(grads),
        }
        return new_state, metrics

    return train_step


@functools.lru_cache(maxsize=None)
def _estimate_jit(estimator: str):
    return jax.jit(functools.partial(diversity.estimate, estimator=estimator))


@functools.lru_cache(maxsize=None)
def _reset_jit():
    return jax.jit(diversity.reset_state)


def epoch_end_host(state: TrainState, estimator: str = "moment") -> tuple[float, TrainState]:
    """Host-side epoch boundary: read the diversity estimate, reset the
    accumulators. Returns (Delta_hat, state-with-reset-accumulators).

    The jits are cached at module level — an epoch boundary costs one scalar
    device->host transfer, never a retrace."""
    delta = float(_estimate_jit(estimator)(state.div_state))
    reset = _reset_jit()(state.div_state)
    return delta, state._replace(div_state=reset)
