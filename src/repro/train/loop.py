"""Host-side training shell — the paper's Algorithm 1 end to end.

The ``Trainer`` is a thin host loop over ``train/engine.py::StepEngine``: it
owns only the HOST decisions — the adaptation program, the data cursor,
checkpoint/resume, and eval cadence. All device work (the SGD step, the
diversity-tier accumulation, buffer donation, the per-bucket compile cache)
lives in the engine; each mini-batch is one SGD step (exactly Algorithm 1:
adapting the batch size changes the *step* granularity), and the only
per-step host transfer is the scalar loss.

Adaptation runs through ``repro.adapt`` — the single adaptation path.  The
4th constructor argument accepts either an ``adapt.AdaptationProgram`` (the
new API) or a legacy ``core.AdaptiveBatchController`` (the deprecated shim
over a program); both drive the identical program underneath.  Boundaries:

  * EPOCH ends (always): signals are read off the in-jit accumulators (one
    stacked scalar transfer), fed to ``program.observe``, and the
    accumulators reset — the classic DiveBatch cadence.
  * Every-k-steps TICKS (``program.tick_every > 0``) and injected EVENTS
    (``Trainer.inject_event``, e.g. a supervisor Watchdog flag): observed
    BETWEEN steps with the *running* accumulators (no reset).  A mid-epoch
    decision resizes the batch — phase-aligned so the new size continues
    the epoch permutation at an exact multiple of itself — reshards the
    elastic rung, and retargets lr/estimator, all before the next step.

API stability: the ``Trainer`` constructor and ``run``/``run_epoch``/
``save``/``resume`` signatures are unchanged; ``trainer.params`` etc. are
read-only views of the engine-owned ``TrainState``.

Elastic mode (``elastic=MeshLadder(...)``): the ladder co-adapts the device
footprint with the batch size — at any boundary that resizes the batch
(epoch end OR mid-epoch), the state is resharded onto the widest rung whose
dp width keeps the per-device microbatch >= the ladder granule
(``repro.elastic``); the engine's compile cache keys by (bucket, rung). A
``Decision`` carrying an explicit ``rung`` overrides the batch-derived one
(straggler evacuation).  The feed path double-buffers device transfers
(``data.pipeline.prefetch``; ``prefetch="thread"`` additionally overlaps
the host-side numpy gather, ``prefetch=False`` reverts to the synchronous
put-per-step loop — the trajectory is identical in all three modes).

Checkpointing captures the FULL adaptive state (program schema v2; v1
pre-redesign checkpoints restore unchanged); ``Trainer.resume()`` restores
mid-training with the identical remaining trajectory (tests assert this).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.adapt import (
    AdaptationProgram,
    Clock,
    Signals,
    ThroughputWindow,
    read_signals,
)
from repro.ckpt import CheckpointManager
from repro.core import AdaptiveBatchController, diversity
from repro.data import ArrayDataset, Cursor, EpochLoader
from repro.data.pipeline import (
    epoch_permutation,
    prefetch as prefetch_iter,
    put_global_batch,
)
from repro.dist.plan import current_plan
from repro.elastic import MeshLadder, place, reshard
from repro.obs import runlog as runlog_lib
from repro.obs import trace as trace_lib
from repro.train.engine import ModelFns, StepEngine, eval_fn_for
from repro.train.state import TrainState, init_state
from repro.utils.logging import get_logger

log = get_logger("train")

__all__ = ["ModelFns", "EpochRecord", "Trainer"]

#: estimator tiers that run inside the jitted step
_INJIT_TIERS = ("exact", "gram", "moment")


@dataclasses.dataclass
class EpochRecord:
    epoch: int
    batch_size: int
    lr: float
    train_loss: float
    val_loss: float
    val_metrics: dict
    diversity: float | None
    steps: int
    wall_s: float


class Trainer:
    def __init__(
        self,
        fns: ModelFns,
        params: Any,
        optimizer,
        controller: AdaptiveBatchController | AdaptationProgram,
        train_data: ArrayDataset,
        val_data: ArrayDataset,
        *,
        estimator: str = "exact",  # exact | gram | moment | oracle | none
        seed: int = 0,
        psn_microbatch: int = 256,
        ckpt: CheckpointManager | None = None,
        ckpt_every: int = 0,
        donate: bool = True,
        engine: StepEngine | None = None,
        elastic: MeshLadder | None = None,
        prefetch: bool | str = True,
        tracer=None,
        runlog=None,
    ):
        self.fns = fns
        # telemetry sinks (repro.obs); rebound for real at the end of init
        # via bind_obs once the engine/program exist
        self._tracer = trace_lib.NULL
        self._runlog = runlog_lib.NULL
        self.optimizer = optimizer
        self.controller = controller  # legacy view; may BE the program
        self.adapt = (
            controller.program
            if isinstance(controller, AdaptiveBatchController)
            else controller
        )
        self.train_data = train_data
        self.val_data = val_data
        self.estimator = estimator
        self.seed = seed
        self.psn_microbatch = psn_microbatch  # exact-tier vmap width / oracle chunk
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.cursor = Cursor()
        self.history: list[EpochRecord] = []
        self._events: list[str] = []  # injected, consumed between steps
        # Donation invalidates the buffers passed to each step, so the state
        # lives in exactly one place: self.state, replaced every step
        # (init_state makes the leaves donation-ready jax Arrays).
        self.state: TrainState = init_state(params, optimizer)
        self._plan = current_plan()
        if elastic is not None and self._plan is not None:
            raise ValueError(
                "Trainer(elastic=...) under an ambient dist plan is ambiguous: "
                "the ladder owns the sharding plan per rung — drop the "
                "use_plan context (or the elastic ladder)"
            )
        self._elastic = elastic
        self._rung = None
        if prefetch not in (True, False, "thread"):
            raise ValueError(
                f"prefetch must be True, False, or 'thread', got {prefetch!r}"
            )
        self._prefetch = prefetch
        # windowed steps/s for Signals.throughput: a policy reacting to a
        # straggler sees the recent rate, not the run-global average
        self._thru = ThroughputWindow()
        self._shardings: dict[tuple[int, int], Any] = {}
        self.engine = engine or self._build_engine(donate)
        # an injected engine may lack an eval fn; the Trainer owns the fns
        self.engine.ensure_eval_fn(eval_fn_for(fns))
        self.bind_obs(tracer=tracer, runlog=runlog)
        if self._elastic is not None:
            # initial placement: the rung for the starting batch size
            self._ensure_rung(self.adapt.batch_size)

    def _build_engine(self, donate: bool) -> StepEngine:
        # A ladder may supply its own rung-aware engine (duck-typed so the
        # base Trainer never imports repro.pod): PodLadder compiles the
        # shard_map'd compressed cross-pod step on pods>1 rungs.
        engine_for = getattr(self._elastic, "engine_for", None)
        if engine_for is not None:
            return engine_for(
                self.fns,
                self.optimizer,
                estimator=self.estimator,
                diversity_on=self.adapt.needs_diversity,
                donate=donate,
                psn_chunk=self.psn_microbatch,
            )
        return StepEngine.for_model_fns(
            self.fns,
            self.optimizer,
            estimator=self.estimator,
            diversity_on=self.adapt.needs_diversity,
            dp_size=self._plan.dp_size if self._plan else 1,
            donate=donate,
            psn_chunk=self.psn_microbatch,
        )

    # -- read-only views of the engine-owned state (API compatibility) -------
    @property
    def params(self):
        return self.state.params

    @property
    def opt_state(self):
        return self.state.opt_state

    @property
    def div_state(self):
        return self.state.div_state

    @property
    def rung(self):
        """The live elastic ladder rung (None outside elastic mode)."""
        return self._rung

    @property
    def elastic(self):
        """The elastic ladder driving this trainer (None outside elastic
        mode) — the supervisor reaches pod health through this."""
        return self._elastic

    # ------------------------------------------------------------------
    @property
    def _live_plan(self):
        """The plan batches/state live on: the elastic rung's when a ladder
        drives the run, else the ambient dist plan (None single-device)."""
        return self._rung.plan if self._rung is not None else self._plan

    def bind_obs(self, *, tracer=None, runlog=None) -> None:
        """Attach telemetry sinks (``repro.obs``) to the trainer, its engine,
        and its adaptation program.  ``None`` leaves a sink unchanged — the
        supervisor rebinds the same sinks onto every rebuilt Trainer so one
        trace/run log spans restarts."""
        if tracer is not None:
            self._tracer = tracer
            self.engine.tracer = tracer
        if runlog is not None:
            self._runlog = runlog
            self.engine.runlog = runlog
        bind = getattr(self.adapt, "bind_obs", None)
        if bind is not None:
            bind(tracer=tracer, runlog=runlog)

    def inject_event(self, name: str) -> None:
        """Queue an external event (e.g. a supervisor Watchdog straggler
        flag).  Consumed BETWEEN steps at the next opportunity: the adapt
        program observes it with ``boundary='event'`` and may resize /
        reshard / retune before the following step."""
        self._events.append(str(name))
        if self._runlog.enabled:
            self._runlog.emit("inject", name=str(name),
                              epoch=self.cursor.epoch,
                              step=self.engine.stats.steps)

    def _ensure_rung(self, batch_size: int) -> None:
        """Elastic transition: move the state onto the ladder rung for
        ``batch_size`` — called at any boundary that resizes the batch
        (epoch end or mid-epoch). Strict no-op when the rung is unchanged
        (reshard returns the identical state object)."""
        if self._elastic is None:
            return
        self._transition(self._elastic.rung_for_batch(batch_size),
                         note=f"for batch {batch_size}")

    def _transition(self, rung, note: str = "") -> None:
        if self._rung is not None and rung.index == self._rung.index:
            return
        src = self._rung
        # the initial placement must NOT donate: the state still aliases the
        # caller-passed params at that point (transitions own their buffers)
        with self._tracer.span("reshard", scope="train",
                               src=src.index if src else None,
                               dst=rung.index, dp=rung.dp):
            self.state = reshard(
                self.state, src.plan if src else None, rung.plan,
                donate=self.engine.donate and src is not None,
            )
        self._rung = rung
        self.engine.rung = rung.index
        # ladder-specific state (e.g. PodLadder's compression residuals) is
        # installed/dropped AFTER the reshard so it lands on the new mesh
        self.state = self._elastic.adapt_state(self.state, src, rung)
        if src is not None:  # initial placement is not a transition
            self.engine.stats.reshards += 1
            if self._runlog.enabled:
                self._runlog.emit("reshard", scope="train", src=src.index,
                                  dst=rung.index, dp=rung.dp,
                                  epoch=self.cursor.epoch,
                                  step=self.engine.stats.steps,
                                  note=note)
            log.info("elastic: rung %d -> %d (dp %d -> %d) %s",
                     src.index, rung.index, src.dp, rung.dp, note)

    def demote(self, note: str = "pod lost") -> tuple[int | None, int]:
        """Degrade-don't-restart: reshard the LIVE state onto the widest rung
        the (health-filtered) ladder still allows for the current batch size
        — no checkpoint restore, the surviving optimizer/diversity state
        carries straight on.  The supervisor calls this when a pod is lost
        (after marking it in the ladder's health registry).  Returns
        ``(src_rung_index, dst_rung_index)``; a no-op transition returns the
        same index twice."""
        if self._elastic is None:
            raise ValueError("demote() needs an elastic ladder")
        src = self._rung.index if self._rung is not None else None
        self._transition(self._elastic.rung_for_batch(self.adapt.batch_size),
                         note=note)
        return src, self._rung.index

    def _batch_sharding(self, leading: int):
        """NamedSharding over the live plan's dp axes, if one divides the
        batch (memoized by (leading dim, rung) — constant within an epoch)."""
        plan = self._live_plan
        if plan is None:
            return None
        key = (leading, self._rung.index if self._rung is not None else -1)
        if key not in self._shardings:
            self._shardings[key] = (
                NamedSharding(plan.mesh, P(tuple(plan.dp)))
                if leading % plan.dp_size == 0 else None
            )
        return self._shardings[key]

    def _put(self, batch_np: dict) -> dict:
        leading = len(next(iter(batch_np.values())))
        return put_global_batch(batch_np, self._batch_sharding(leading))

    def _oracle_diversity(self) -> float:
        batches = (
            {k: jnp.asarray(v) for k, v in self.train_data.get(idx).items()}
            for idx in np.array_split(
                np.arange(len(self.train_data)),
                max(1, len(self.train_data) // self.psn_microbatch),
            )
        )
        return float(
            diversity.dataset_diversity(
                self.fns.example_loss, self.state.params, batches
            )
        )

    def _throughput(self) -> float:
        """Windowed steps/s (ThroughputWindow); the run-global dispatch
        average only before the first step lands in the window."""
        rate = self._thru.rate()
        return rate if rate is not None else self.engine.stats.dispatch_steps_per_sec

    # -- decision plumbing ----------------------------------------------------
    def _read_estimator(self) -> str:
        """The tier signals are decoded with: the in-jit tier when one is
        active; 'exact' for estimator='none' (unfed accumulators estimate a
        legitimate 0.0, the pre-engine convention); 'moment' for oracle."""
        if self.estimator in _INJIT_TIERS:
            return self.estimator
        return "moment" if self.estimator == "oracle" else "exact"

    def _apply_estimator(self, tier: str | None) -> None:
        """Retarget the diversity tier from a Decision.  On a
        tier-parameterised engine this is just a new compile-cache key —
        (bucket, rung, tier) — so the new tier's buckets compile on first
        use and flipping back onto a seen tier is a cache hit.  Injected
        engines with a single-argument build fall back to the old
        rebuild-the-jit-family behaviour (stats carry over)."""
        if tier is None or tier == self.estimator:
            return
        if tier not in _INJIT_TIERS:
            raise ValueError(
                f"decision estimator must be one of {_INJIT_TIERS}, got {tier!r}"
            )
        log.info("adapt: estimator tier %s -> %s", self.estimator, tier)
        self.estimator = tier
        if self.engine.tiered:
            self.engine.tier = tier
            return
        stats, rung_token = self.engine.stats, self.engine.rung
        self.engine = self._build_engine(self.engine.donate)
        self.engine.ensure_eval_fn(eval_fn_for(self.fns))
        self.engine.stats = stats
        self.engine.rung = rung_token

    def _apply_decision(self, applied) -> None:
        """Non-batch effects of an applied decision (the batch size itself is
        handled by the step loop / epoch boundary)."""
        if applied is None:
            return
        self._apply_estimator(applied.estimator)
        if applied.rung is not None and self._elastic is not None:
            self._transition(self._elastic.rungs[applied.rung], note="(explicit)")

    def _observe_mid_epoch(self, steps_done: int, bsz: int,
                           last_loss: float) -> Any:
        """Tick/event boundaries between steps.  Reads the RUNNING
        accumulators (no reset — the epoch boundary owns the reset) at the
        cost of one stacked-scalar transfer, only when a boundary is due AND
        the policy can actually fire on it (an epoch-only policy under
        --tick-every must not pay a device sync per tick).

        Explicit-rung decisions are NOT applied here: the step loop owns
        that transition because it must also rebuild the feed (prefetched
        batches were put on the old rung's plan)."""
        clock = event = None
        if self._events:
            c = Clock(epoch=self.cursor.epoch, step=self.engine.stats.steps,
                      boundary="event")
            if self.adapt.policy.fires(c):
                event, clock = self._events.pop(0), c
            else:
                # never silently: the injector asked for a reaction the
                # active policy cannot give (and must not block a due tick)
                log.info("adapt: event %r dropped (policy does not fire on "
                         "events)", self._events.pop(0))
        if (clock is None and self.adapt.tick_every
                and steps_done % self.adapt.tick_every == 0):
            c = Clock(epoch=self.cursor.epoch, step=self.engine.stats.steps,
                      boundary="tick")
            if self.adapt.policy.fires(c):
                clock = c
        if clock is None:
            return None
        sig, self.state = read_signals(
            self.state, self._read_estimator(), reset=False,
            batch_size=bsz, loss=last_loss,
            throughput=self._throughput(), event=event,
        )
        applied = self.adapt.observe(sig, clock)
        if applied is not None:
            self._apply_estimator(applied.estimator)
        return applied

    def _epoch_signals(self, bsz: int, mean_loss: float) -> Signals:
        """Epoch-boundary signals: read + RESET the accumulators (one
        stacked scalar transfer); the oracle tier substitutes the exact
        full-dataset diversity it recomputes at fixed params."""
        if not self.adapt.needs_diversity:
            return Signals(loss=mean_loss, batch_size=bsz,
                           throughput=self._throughput())
        sig, self.state = read_signals(
            self.state, self._read_estimator(), reset=True,
            batch_size=bsz, loss=mean_loss,
            throughput=self._throughput(),
        )
        if self.estimator == "oracle":
            sig = dataclasses.replace(sig, diversity=self._oracle_diversity())
        return sig

    # ------------------------------------------------------------------
    def run_epoch(self) -> EpochRecord:
        tr = self._tracer
        if not tr.enabled:
            return self._run_epoch()
        with tr.span("epoch", epoch=self.cursor.epoch):
            return self._run_epoch()

    def _run_epoch(self) -> EpochRecord:
        t0 = time.time()
        prog = self.adapt
        bsz = prog.batch_size
        self._ensure_rung(bsz)
        lr = jnp.float32(prog.lr)
        n = len(self.train_data)
        consumed = self.cursor.sample_index or self.cursor.batch_index * bsz
        losses: list[float] = []
        # one O(n) shuffle per epoch, shared by every resize segment's loader
        perm = epoch_permutation(n, self.seed, self.cursor.epoch)

        # One (epoch, batch-size, rung) segment per inner loop: a mid-epoch
        # resize or explicit rung move breaks out, and the next loader
        # continues the SAME permutation at the exact sample offset already
        # consumed.  Tick cadence counts cursor.batch_index (persisted), so
        # a mid-epoch resume keeps the identical tick phase.
        while True:
            target = prog.batch_size
            if target != bsz and consumed % target == 0:
                bsz = target
                lr = jnp.float32(prog.lr)
                self._ensure_rung(bsz)
            loader = EpochLoader(
                self.train_data, bsz, epoch=self.cursor.epoch, seed=self.seed,
                start_sample=consumed, perm=perm,
            )
            if len(loader) == 0:
                break
            feed = (
                prefetch_iter(loader, put=self._put,
                              host_overlap=self._prefetch == "thread")
                if self._prefetch else (self._put(b) for b in loader)
            )
            rebuild = False
            try:
                for batch in feed:
                    self.state, metrics = self.engine.step(self.state, batch, lr)
                    losses.append(float(metrics["loss"]))  # per-step sync
                    self._thru.add(1.0)
                    consumed += bsz
                    self.cursor.batch_index += 1
                    self.cursor.sample_index = consumed
                    applied = self._observe_mid_epoch(
                        self.cursor.batch_index, bsz, losses[-1])
                    if (applied is not None and applied.rung is not None
                            and self._elastic is not None):
                        # explicit rung move: reshard, then rebuild the feed —
                        # buffered batches were put on the OLD rung's plan
                        self._transition(self._elastic.rungs[applied.rung],
                                         note="(explicit)")
                        rebuild = True
                        break
                    # Phase-aligned resize: apply a pending target size once
                    # the consumed offset is a multiple of it, so the new
                    # loader's batches tile the permutation exactly (shrinks
                    # on the pow2 lattice are always aligned; a grow waits at
                    # most target/bsz - 1 steps).  The coupled lr retarget is
                    # deferred WITH the resize — the rescaled lr must land on
                    # the batch it was scaled for, never on pending old-size
                    # steps.
                    target = prog.batch_size
                    if target != bsz:
                        if consumed % target == 0:
                            bsz = target
                            lr = jnp.float32(prog.lr)
                            self._ensure_rung(bsz)
                            rebuild = True
                            break
                    elif applied is not None:
                        lr = jnp.float32(prog.lr)
            finally:
                close = getattr(feed, "close", None)
                if close is not None:
                    close()
            if not rebuild:
                break

        # epoch boundary ------------------------------------------------
        mean_loss = float(np.mean(losses)) if losses else float("nan")
        sig = self._epoch_signals(bsz, mean_loss)
        applied = prog.observe(
            sig, Clock(epoch=self.cursor.epoch, step=self.engine.stats.steps,
                       boundary="epoch"),
        )
        self._apply_decision(applied)

        val = self._put(self.val_data.get(np.arange(len(self.val_data))))
        val_loss, val_metrics = self.engine.evaluate(self.state.params, val)
        rec = EpochRecord(
            epoch=self.cursor.epoch,
            batch_size=prog.batch_size,
            lr=prog.lr,
            train_loss=mean_loss,
            val_loss=float(val_loss),
            val_metrics={k: float(v) for k, v in val_metrics.items()},
            diversity=sig.diversity,
            steps=len(losses),
            wall_s=time.time() - t0,
        )
        self.history.append(rec)
        if self._runlog.enabled:
            self._runlog.emit(
                "epoch", epoch=rec.epoch, steps=rec.steps,
                batch_size=rec.batch_size, lr=rec.lr, loss=rec.train_loss,
                val_loss=rec.val_loss, diversity=rec.diversity,
                gns=sig.gns, throughput=sig.throughput,
                rung=self._rung.index if self._rung is not None else None,
                wall_s=rec.wall_s,
            )
        self.cursor.epoch += 1
        self.cursor.batch_index = 0
        self.cursor.sample_index = 0
        if self.ckpt and self.ckpt_every and self.cursor.epoch % self.ckpt_every == 0:
            self.save()
        return rec

    def run(self, epochs: int, verbose: bool = True) -> list[EpochRecord]:
        for _ in range(epochs):
            rec = self.run_epoch()
            if verbose:
                log.info(
                    "epoch %d: loss=%.4f val=%.4f metrics=%s m=%d lr=%.4g div=%s",
                    rec.epoch, rec.train_loss, rec.val_loss, rec.val_metrics,
                    rec.batch_size, rec.lr,
                    f"{rec.diversity:.4g}" if rec.diversity is not None else "-",
                )
        return self.history

    # ------------------------------------------------------------------
    def save(self):
        assert self.ckpt is not None
        self.ckpt.save(
            step=self.cursor.epoch,
            state={
                "params": self.state.params,
                "opt_state": self.state.opt_state,
                "div_state": self.state.div_state,
            },
            extra={
                "controller": self.adapt.state_dict(),
                "cursor": self.cursor.state_dict(),
                "history": [dataclasses.asdict(r) for r in self.history],
                "step": int(self.state.step),
            },
        )
        if self._runlog.enabled:
            self._runlog.emit("checkpoint", epoch=self.cursor.epoch,
                              step=int(self.state.step))

    def resume(self) -> bool:
        assert self.ckpt is not None
        if self.ckpt.latest_step() is None:
            return False
        # Checkpoints hold logical host tensors; restore places them onto
        # whatever plan is live (elastic.reshard.place) — a checkpoint saved
        # on one rung resumes on any other, or on no plan at all.
        out, extra = self.ckpt.restore(
            {"params": self.state.params, "opt_state": self.state.opt_state,
             "div_state": self.state.div_state}
        )
        # both schema versions load (v1: pre-redesign controller dicts)
        self.adapt.load_state_dict(extra["controller"])
        self.cursor.load_state_dict(extra["cursor"])
        self.history = [EpochRecord(**r) for r in extra.get("history", [])]
        if self._elastic is not None:
            # the restored batch size decides the rung, not the one this
            # (possibly fresh) Trainer started on — pick it BEFORE placing so
            # the state is transferred exactly once
            rung = self._elastic.rung_for_batch(self.adapt.batch_size)
            self._rung = rung
            self.engine.rung = rung.index
        self.state = place(
            TrainState(
                params=out["params"],
                opt_state=out["opt_state"],
                div_state=out["div_state"],
                step=np.asarray(extra.get("step", 0), np.int32),
            ),
            self._live_plan,
        )
        if self._elastic is not None and self._rung is not None:
            # checkpoints never carry ladder-specific state (err_state is
            # transient wire state): re-install it for the restored rung
            self.state = self._elastic.adapt_state(self.state, None, self._rung)
        log.info("resumed from epoch %d", self.cursor.epoch)
        return True
