"""Host-side epoch training shell — the paper's Algorithm 1 end to end.

The ``Trainer`` is a thin host loop over ``train/engine.py::StepEngine``: it
owns only the HOST decisions — the adaptive-batch controller, the data
cursor, checkpoint/resume, and eval cadence. All device work (the SGD step,
the diversity-tier accumulation, buffer donation, the per-bucket compile
cache) lives in the engine; each mini-batch is one SGD step (exactly
Algorithm 1: adapting the batch size changes the *step* granularity), and
the only per-step host transfer is the scalar loss.

API stability: the ``Trainer`` constructor and ``run``/``run_epoch``/
``save``/``resume`` signatures are unchanged from the pre-engine version —
examples and downstream code keep working; ``trainer.params`` etc. are now
read-only views of the engine-owned ``TrainState``.

Checkpointing captures the FULL adaptive state; ``Trainer.resume()`` restores
mid-training with the identical remaining trajectory (tests assert this).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import CheckpointManager
from repro.core import AdaptiveBatchController, diversity
from repro.data import ArrayDataset, Cursor, EpochLoader
from repro.data.pipeline import put_global_batch
from repro.dist.plan import current_plan
from repro.optim import Optimizer
from repro.train.engine import ModelFns, StepEngine, eval_fn_for
from repro.train.state import TrainState, init_state
from repro.train.step import epoch_end_host
from repro.utils.logging import get_logger

log = get_logger("train")

__all__ = ["ModelFns", "EpochRecord", "Trainer"]


@dataclasses.dataclass
class EpochRecord:
    epoch: int
    batch_size: int
    lr: float
    train_loss: float
    val_loss: float
    val_metrics: dict
    diversity: float | None
    steps: int
    wall_s: float


class Trainer:
    def __init__(
        self,
        fns: ModelFns,
        params: Any,
        optimizer: Optimizer,
        controller: AdaptiveBatchController,
        train_data: ArrayDataset,
        val_data: ArrayDataset,
        *,
        estimator: str = "exact",  # exact | gram | moment | oracle | none
        seed: int = 0,
        psn_microbatch: int = 256,
        ckpt: CheckpointManager | None = None,
        ckpt_every: int = 0,
        donate: bool = True,
        engine: StepEngine | None = None,
    ):
        self.fns = fns
        self.optimizer = optimizer
        self.controller = controller
        self.train_data = train_data
        self.val_data = val_data
        self.estimator = estimator
        self.seed = seed
        self.psn_microbatch = psn_microbatch  # exact-tier vmap width / oracle chunk
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.cursor = Cursor()
        self.history: list[EpochRecord] = []
        # Donation invalidates the buffers passed to each step, so the state
        # lives in exactly one place: self.state, replaced every step
        # (init_state makes the leaves donation-ready jax Arrays).
        self.state: TrainState = init_state(params, optimizer)
        self._plan = current_plan()
        self._shardings: dict[int, Any] = {}
        self.engine = engine or StepEngine.for_model_fns(
            fns,
            optimizer,
            estimator=estimator,
            diversity_on=controller.needs_diversity,
            dp_size=self._plan.dp_size if self._plan else 1,
            donate=donate,
            psn_chunk=psn_microbatch,
        )
        # an injected engine may lack an eval fn; the Trainer owns the fns
        self.engine.ensure_eval_fn(eval_fn_for(fns))

    # -- read-only views of the engine-owned state (API compatibility) -------
    @property
    def params(self):
        return self.state.params

    @property
    def opt_state(self):
        return self.state.opt_state

    @property
    def div_state(self):
        return self.state.div_state

    # ------------------------------------------------------------------
    def _batch_sharding(self, leading: int):
        """NamedSharding over the plan's dp axes, if one divides the batch
        (memoized by leading dim — constant within an epoch)."""
        if self._plan is None:
            return None
        if leading not in self._shardings:
            self._shardings[leading] = (
                NamedSharding(self._plan.mesh, P(tuple(self._plan.dp)))
                if leading % self._plan.dp_size == 0 else None
            )
        return self._shardings[leading]

    def _put(self, batch_np: dict) -> dict:
        leading = len(next(iter(batch_np.values())))
        return put_global_batch(batch_np, self._batch_sharding(leading))

    def _oracle_diversity(self) -> float:
        batches = (
            {k: jnp.asarray(v) for k, v in self.train_data.get(idx).items()}
            for idx in np.array_split(
                np.arange(len(self.train_data)),
                max(1, len(self.train_data) // self.psn_microbatch),
            )
        )
        return float(
            diversity.dataset_diversity(
                self.fns.example_loss, self.state.params, batches
            )
        )

    # ------------------------------------------------------------------
    def run_epoch(self) -> EpochRecord:
        t0 = time.time()
        bsz = self.controller.batch_size
        lr = jnp.float32(self.controller.lr)
        loader = EpochLoader(
            self.train_data, bsz, epoch=self.cursor.epoch, seed=self.seed,
            start_batch=self.cursor.batch_index,
        )
        losses = []
        for batch_np in loader:
            self.state, metrics = self.engine.step(
                self.state, self._put(batch_np), lr
            )
            losses.append(float(metrics["loss"]))
            self.cursor.batch_index += 1

        # epoch boundary ------------------------------------------------
        delta = None
        if self.controller.needs_diversity:
            if self.estimator == "oracle":
                delta = self._oracle_diversity()
                _, self.state = epoch_end_host(self.state, "moment")
            elif self.estimator in ("exact", "gram", "moment"):
                delta, self.state = epoch_end_host(self.state, self.estimator)
            else:
                # estimator='none' under a diversity-driven policy: degenerate
                # but supported — the accumulators were never fed, so the
                # estimate is 0.0 (matches the pre-engine loop).
                delta, self.state = epoch_end_host(self.state, "exact")
        decision = self.controller.on_epoch_end(delta)

        val = self._put(self.val_data.get(np.arange(len(self.val_data))))
        val_loss, val_metrics = self.engine.evaluate(self.state.params, val)
        rec = EpochRecord(
            epoch=self.cursor.epoch,
            batch_size=decision.batch_size,
            lr=decision.lr,
            train_loss=float(np.mean(losses)) if losses else float("nan"),
            val_loss=float(val_loss),
            val_metrics={k: float(v) for k, v in val_metrics.items()},
            diversity=delta,
            steps=len(losses),
            wall_s=time.time() - t0,
        )
        self.history.append(rec)
        self.cursor.epoch += 1
        self.cursor.batch_index = 0
        if self.ckpt and self.ckpt_every and self.cursor.epoch % self.ckpt_every == 0:
            self.save()
        return rec

    def run(self, epochs: int, verbose: bool = True) -> list[EpochRecord]:
        for _ in range(epochs):
            rec = self.run_epoch()
            if verbose:
                log.info(
                    "epoch %d: loss=%.4f val=%.4f metrics=%s m=%d lr=%.4g div=%s",
                    rec.epoch, rec.train_loss, rec.val_loss, rec.val_metrics,
                    rec.batch_size, rec.lr,
                    f"{rec.diversity:.4g}" if rec.diversity is not None else "-",
                )
        return self.history

    # ------------------------------------------------------------------
    def save(self):
        assert self.ckpt is not None
        self.ckpt.save(
            step=self.cursor.epoch,
            state={
                "params": self.state.params,
                "opt_state": self.state.opt_state,
                "div_state": self.state.div_state,
            },
            extra={
                "controller": self.controller.state_dict(),
                "cursor": self.cursor.state_dict(),
                "history": [dataclasses.asdict(r) for r in self.history],
                "step": int(self.state.step),
            },
        )

    def resume(self) -> bool:
        assert self.ckpt is not None
        if self.ckpt.latest_step() is None:
            return False
        out, extra = self.ckpt.restore(
            {"params": self.state.params, "opt_state": self.state.opt_state,
             "div_state": self.state.div_state}
        )
        self.state = TrainState(
            params=jax.tree.map(jnp.asarray, out["params"]),
            opt_state=jax.tree.map(jnp.asarray, out["opt_state"]),
            div_state=jax.tree.map(jnp.asarray, out["div_state"]),
            step=jnp.asarray(extra.get("step", 0), jnp.int32),
        )
        self.controller.load_state_dict(extra["controller"])
        self.cursor.load_state_dict(extra["cursor"])
        self.history = [EpochRecord(**r) for r in extra.get("history", [])]
        log.info("resumed from epoch %d", self.cursor.epoch)
        return True
