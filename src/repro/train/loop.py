"""Host-side epoch training loop — the paper's Algorithm 1 end to end.

Each mini-batch is one SGD step (exactly Algorithm 1: adapting the batch size
changes the *step* granularity, not an accumulation length — the multi-pod
variant in step.py is the scale adaptation of the same algorithm). Per step
the loop:
  1. computes the mean gradient and applies the optimizer update,
  2. feeds the DiversityState: grad_sum += B * mean_grad, plus the estimator
     tier's numerator statistic (exact vmap / gram probes+kernels / moment).
At the epoch boundary the controller turns Delta_hat into the next epoch's
batch size + learning rate (DiveBatch / AdaBatch / fixed / Oracle).

Checkpointing captures the FULL adaptive state; ``Trainer.resume()`` restores
mid-training with the identical remaining trajectory (tests assert this).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.core import AdaptiveBatchController, diversity
from repro.data import ArrayDataset, Cursor, EpochLoader
from repro.kernels import ops as kernel_ops
from repro.optim import Optimizer, apply_updates
from repro.utils import pytree as ptu
from repro.utils.logging import get_logger

log = get_logger("train")


@dataclasses.dataclass
class ModelFns:
    """Pure functions defining the trainee.

    batch_loss(params, batch) -> scalar mean loss
    example_loss(params, example) -> scalar (per-sample; for exact/oracle)
    metrics(params, batch) -> dict (e.g. accuracy)   [optional]
    probe_loss(params, probes, batch) -> (loss, acts)  [gram tier, optional]
    probe_specs(params, batch_size) -> probes pytree   [gram tier, optional]
    """

    batch_loss: Callable
    example_loss: Callable | None = None
    metrics: Callable | None = None
    probe_loss: Callable | None = None
    probe_specs: Callable | None = None


@dataclasses.dataclass
class EpochRecord:
    epoch: int
    batch_size: int
    lr: float
    train_loss: float
    val_loss: float
    val_metrics: dict
    diversity: float | None
    steps: int
    wall_s: float


class Trainer:
    def __init__(
        self,
        fns: ModelFns,
        params: Any,
        optimizer: Optimizer,
        controller: AdaptiveBatchController,
        train_data: ArrayDataset,
        val_data: ArrayDataset,
        *,
        estimator: str = "exact",  # exact | gram | moment | oracle | none
        seed: int = 0,
        psn_microbatch: int = 256,
        ckpt: CheckpointManager | None = None,
        ckpt_every: int = 0,
    ):
        self.fns = fns
        self.params = params
        self.optimizer = optimizer
        self.opt_state = optimizer.init(params)
        self.controller = controller
        self.train_data = train_data
        self.val_data = val_data
        self.estimator = estimator
        self.seed = seed
        self.psn_microbatch = psn_microbatch
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.cursor = Cursor()
        self.div_state = diversity.init_state(params)
        self.history: list[EpochRecord] = []
        self._build_jitted()

    # ------------------------------------------------------------------
    def _build_jitted(self):
        fns, opt = self.fns, self.optimizer

        @jax.jit
        def sgd_step(params, opt_state, batch, lr):
            loss, grads = jax.value_and_grad(fns.batch_loss)(params, batch)
            updates, opt_state = opt.update(grads, opt_state, params, lr)
            return apply_updates(params, updates), opt_state, loss, grads

        self._sgd_step = sgd_step

        if fns.example_loss is not None:
            self._psn_exact = jax.jit(
                lambda p, b: jnp.sum(diversity.persample_sq_norms(fns.example_loss, p, b))
            )
        if fns.probe_loss is not None:

            @jax.jit
            def psn_gram(params, batch):
                bsz = jax.tree.leaves(batch)[0].shape[0]
                probes = fns.probe_specs(params, bsz)
                (loss, acts), pgrads = jax.value_and_grad(
                    fns.probe_loss, argnums=1, has_aux=True
                )(params, probes, batch)
                return jnp.sum(
                    kernel_ops.persample_sq_norm_tree(acts, pgrads, scale=float(bsz))
                )

            self._psn_gram = psn_gram

        @jax.jit
        def evaluate(params, batch):
            loss = fns.batch_loss(params, batch)
            metrics = fns.metrics(params, batch) if fns.metrics else {}
            return loss, metrics

        self._evaluate = evaluate

        @jax.jit
        def accumulate_div(div, grads, bsz, psn):
            return diversity.accumulate(div, grads, bsz, psn)

        self._accumulate = accumulate_div

    # ------------------------------------------------------------------
    def _persample_sq_norm_sum(self, batch) -> jax.Array | None:
        if self.estimator == "exact":
            total = jnp.zeros((), jnp.float32)
            n = len(next(iter(batch.values())))
            mb = self.psn_microbatch
            for i in range(0, n, mb):
                sub = {k: v[i : i + mb] for k, v in batch.items()}
                total = total + self._psn_exact(self.params, sub)
            return total
        if self.estimator == "gram":
            return self._psn_gram(self.params, batch)
        return None  # moment / oracle / none

    def _oracle_diversity(self) -> float:
        batches = (
            {k: jnp.asarray(v) for k, v in self.train_data.get(idx).items()}
            for idx in np.array_split(
                np.arange(len(self.train_data)),
                max(1, len(self.train_data) // self.psn_microbatch),
            )
        )
        return float(
            diversity.dataset_diversity(self.fns.example_loss, self.params, batches)
        )

    # ------------------------------------------------------------------
    def run_epoch(self) -> EpochRecord:
        t0 = time.time()
        bsz = self.controller.batch_size
        lr = jnp.float32(self.controller.lr)
        loader = EpochLoader(
            self.train_data, bsz, epoch=self.cursor.epoch, seed=self.seed,
            start_batch=self.cursor.batch_index,
        )
        losses = []
        track_div = self.estimator in ("exact", "gram", "moment") and (
            self.controller.needs_diversity
        )
        for batch_np in loader:
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            self.params, self.opt_state, loss, grads = self._sgd_step(
                self.params, self.opt_state, batch, lr
            )
            if track_div:
                psn = self._persample_sq_norm_sum(batch)
                self.div_state = self._accumulate(self.div_state, grads, bsz, psn)
            losses.append(float(loss))
            self.cursor.batch_index += 1

        # epoch boundary ------------------------------------------------
        delta = None
        if self.controller.needs_diversity:
            if self.estimator == "oracle":
                delta = self._oracle_diversity()
            elif self.estimator == "moment":
                delta = float(diversity.diversity_moment(self.div_state))
            else:
                delta = float(diversity.diversity_exact(self.div_state))
        decision = self.controller.on_epoch_end(delta)
        self.div_state = diversity.reset_state(self.div_state)

        val = {k: jnp.asarray(v) for k, v in self.val_data.get(
            np.arange(len(self.val_data))).items()}
        val_loss, val_metrics = self._evaluate(self.params, val)
        rec = EpochRecord(
            epoch=self.cursor.epoch,
            batch_size=decision.batch_size,
            lr=decision.lr,
            train_loss=float(np.mean(losses)) if losses else float("nan"),
            val_loss=float(val_loss),
            val_metrics={k: float(v) for k, v in val_metrics.items()},
            diversity=delta,
            steps=len(losses),
            wall_s=time.time() - t0,
        )
        self.history.append(rec)
        self.cursor.epoch += 1
        self.cursor.batch_index = 0
        if self.ckpt and self.ckpt_every and self.cursor.epoch % self.ckpt_every == 0:
            self.save()
        return rec

    def run(self, epochs: int, verbose: bool = True) -> list[EpochRecord]:
        for _ in range(epochs):
            rec = self.run_epoch()
            if verbose:
                log.info(
                    "epoch %d: loss=%.4f val=%.4f metrics=%s m=%d lr=%.4g div=%s",
                    rec.epoch, rec.train_loss, rec.val_loss, rec.val_metrics,
                    rec.batch_size, rec.lr,
                    f"{rec.diversity:.4g}" if rec.diversity else "-",
                )
        return self.history

    # ------------------------------------------------------------------
    def save(self):
        assert self.ckpt is not None
        self.ckpt.save(
            step=self.cursor.epoch,
            state={
                "params": self.params,
                "opt_state": self.opt_state,
                "div_state": self.div_state,
            },
            extra={
                "controller": self.controller.state_dict(),
                "cursor": self.cursor.state_dict(),
                "history": [dataclasses.asdict(r) for r in self.history],
            },
        )

    def resume(self) -> bool:
        assert self.ckpt is not None
        if self.ckpt.latest_step() is None:
            return False
        out, extra = self.ckpt.restore(
            {"params": self.params, "opt_state": self.opt_state,
             "div_state": self.div_state}
        )
        self.params = out["params"]
        self.opt_state = out["opt_state"]
        self.div_state = out["div_state"]
        self.controller.load_state_dict(extra["controller"])
        self.cursor.load_state_dict(extra["cursor"])
        self.history = [EpochRecord(**r) for r in extra.get("history", [])]
        log.info("resumed from epoch %d", self.cursor.epoch)
        return True
